#!/usr/bin/env bash
# Profile-guided optimization build flow for the rosella binary.
#
# Instrument with -Cprofile-generate, train on the two hot workloads
# (the `hotpath` microbench sweep and an in-process `plane` run), merge
# the raw profiles with llvm-profdata, rebuild with -Cprofile-use, and
# emit BENCH_pgo.json comparing the mean decision-loop ns/op of the
# plain vs PGO builds measured back-to-back on the same machine.
#
# Requires the llvm-tools component for llvm-profdata:
#   rustup component add llvm-tools-preview
# and jq for the comparison report. Safe to run from any directory;
# artifacts land in rust/ (BENCH_pgo.json, target/pgo-*).
set -euo pipefail

cd "$(dirname "$0")/../rust"

HOST=$(rustc -vV | sed -n 's/^host: //p')
PROFDATA="$(rustc --print sysroot)/lib/rustlib/$HOST/bin/llvm-profdata"
if [ ! -x "$PROFDATA" ]; then
  echo "llvm-profdata not found at $PROFDATA" >&2
  echo "install it with: rustup component add llvm-tools-preview" >&2
  exit 1
fi

PROFDIR="$(pwd)/target/pgo-profiles"
rm -rf "$PROFDIR"
mkdir -p "$PROFDIR"

# Training workloads: the decision/simulator hot paths and a full
# in-process plane run (learners, consensus, worker pool) so the profile
# covers both the microbench loops and the real scheduling plane.
TRAIN_HOTPATH=(hotpath --quick --sizes 8,32 --frontends 1,2 --plane-decisions 5000)
TRAIN_PLANE=(plane --frontends 2 --duration 1 --rate 200
             --learners per-shard --sync-interval 0.2)

echo "== 1/4: plain release build + baseline measurement =="
cargo build --release
./target/release/rosella "${TRAIN_HOTPATH[@]}" --json BENCH_hotpath_plain.json

echo "== 2/4: instrumented build + training runs =="
RUSTFLAGS="-Cprofile-generate=$PROFDIR" \
  cargo build --release --target-dir target/pgo-gen
./target/pgo-gen/release/rosella "${TRAIN_HOTPATH[@]}" --json BENCH_hotpath_train.json
./target/pgo-gen/release/rosella "${TRAIN_PLANE[@]}"

echo "== 3/4: merge profiles + PGO rebuild =="
"$PROFDATA" merge -o "$PROFDIR/merged.profdata" "$PROFDIR"
RUSTFLAGS="-Cprofile-use=$PROFDIR/merged.profdata" \
  cargo build --release --target-dir target/pgo-use
./target/pgo-use/release/rosella "${TRAIN_HOTPATH[@]}" --json BENCH_hotpath_pgo.json

echo "== 4/4: compare plain vs PGO decision loop =="
PLAIN_NS=$(jq '[.decision[].ns_per_op] | add / length' BENCH_hotpath_plain.json)
PGO_NS=$(jq '[.decision[].ns_per_op] | add / length' BENCH_hotpath_pgo.json)
jq -n --argjson plain "$PLAIN_NS" --argjson pgo "$PGO_NS" '{
  bench: "pgo",
  plain_decision_ns: $plain,
  pgo_decision_ns: $pgo,
  plain_decisions_per_sec: (1e9 / $plain | round),
  pgo_decisions_per_sec: (1e9 / $pgo | round),
  speedup: ($plain / $pgo)
}' > BENCH_pgo.json
cat BENCH_pgo.json
