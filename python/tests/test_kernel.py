"""Kernel-vs-oracle correctness: the Pallas kernels must match the pure-jnp
references across a hypothesis sweep of shapes, values, and parameters."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import learner as learner_kernel
from compile.kernels import payload as payload_kernel
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def make_learner_inputs(rng, n, k, horizon=100.0, full_rows=None):
    """Random ring-buffer matrices with realistic structure (ages ascend
    newest-first; padding has huge age)."""
    durations = rng.uniform(0.01, 0.5, (n, k)).astype(np.float32)
    demands = rng.uniform(0.01, 0.3, (n, k)).astype(np.float32)
    counts = rng.randint(0, k + 1, n).astype(np.int32)
    if full_rows is not None:
        counts[full_rows] = k
    ages = np.cumsum(rng.uniform(0.0, 5.0, (n, k)), axis=1).astype(np.float32)
    idx = np.arange(k)[None, :]
    ages = np.where(idx < counts[:, None], ages, np.float32(1e30))
    return durations, demands, ages, counts


class TestLearnerKernel:
    def test_matches_ref_basic(self):
        rng = np.random.RandomState(0)
        n, k = 16, 64
        dur, dem, age, cnt = make_learner_inputs(rng, n, k)
        params = jnp.asarray([8.0, 0.06, 50.0, 1.0], jnp.float32)
        got = learner_kernel.learner_aggregate(dur, dem, age, cnt, params)
        want = ref.learner_aggregate_ref(dur, dem, age, cnt, params)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @given(
        n_blocks=st.integers(1, 4),
        k=st.sampled_from([8, 16, 64]),
        window=st.floats(1.0, 32.0),
        eps=st.floats(0.0, 0.3),
        horizon=st.floats(1.0, 200.0),
        cold=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_sweep(self, n_blocks, k, window, eps, horizon, cold, seed):
        rng = np.random.RandomState(seed)
        n = n_blocks * learner_kernel.BLOCK_N
        dur, dem, age, cnt = make_learner_inputs(rng, n, k)
        params = jnp.asarray(
            [window, eps, horizon, 1.0 if cold else 0.0], jnp.float32
        )
        got = learner_kernel.learner_aggregate(dur, dem, age, cnt, params)
        want = ref.learner_aggregate_ref(dur, dem, age, cnt, params)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_full_window_estimate_value(self):
        """A worker with constant duration/demand gets (1-eps)*speed."""
        n, k = 8, 16
        speed = 2.0
        demand = 0.1
        dur = np.full((n, k), demand / speed, np.float32)
        dem = np.full((n, k), demand, np.float32)
        age = np.tile(np.arange(k, dtype=np.float32), (n, 1))
        cnt = np.full(n, k, np.int32)
        params = jnp.asarray([8.0, 0.1, 100.0, 0.0], jnp.float32)
        got = np.asarray(learner_kernel.learner_aggregate(dur, dem, age, cnt, params))
        np.testing.assert_allclose(got, 0.9 * speed, rtol=1e-5)

    def test_silent_worker_zeroed_when_not_cold(self):
        n, k = 8, 16
        dur, dem, age, cnt = make_learner_inputs(np.random.RandomState(1), n, k)
        cnt[3] = 0
        params = jnp.asarray([4.0, 0.05, 50.0, 0.0], jnp.float32)
        got = np.asarray(learner_kernel.learner_aggregate(dur, dem, age, cnt, params))
        assert got[3] == 0.0

    def test_partial_window_kept_only_during_cold_start(self):
        n, k = 8, 16
        dur = np.full((n, k), 0.1, np.float32)
        dem = np.full((n, k), 0.1, np.float32)
        age = np.tile(np.arange(k, dtype=np.float32), (n, 1))
        cnt = np.full(n, 2, np.int32)  # fewer than the window of 8
        warm = jnp.asarray([8.0, 0.0, 100.0, 0.0], jnp.float32)
        cold = jnp.asarray([8.0, 0.0, 100.0, 1.0], jnp.float32)
        got_warm = np.asarray(learner_kernel.learner_aggregate(dur, dem, age, cnt, warm))
        got_cold = np.asarray(learner_kernel.learner_aggregate(dur, dem, age, cnt, cold))
        assert np.all(got_warm == 0.0)
        np.testing.assert_allclose(got_cold, 1.0, rtol=1e-5)

    def test_stale_samples_excluded(self):
        """Samples older than the horizon must not contribute."""
        n, k = 8, 8
        dur = np.full((n, k), 0.1, np.float32)
        dem = np.full((n, k), 0.1, np.float32)
        age = np.full((n, k), 1e6, np.float32)  # all stale
        cnt = np.full(n, k, np.int32)
        params = jnp.asarray([4.0, 0.0, 10.0, 0.0], jnp.float32)
        got = np.asarray(learner_kernel.learner_aggregate(dur, dem, age, cnt, params))
        assert np.all(got == 0.0)


class TestPayloadKernel:
    def test_matches_ref_basic(self):
        rng = np.random.RandomState(2)
        x = rng.uniform(-1, 1, (payload_kernel.BATCH, payload_kernel.D_IN)).astype(np.float32)
        w1 = rng.uniform(-0.1, 0.1, (payload_kernel.D_IN, payload_kernel.D_H)).astype(np.float32)
        b1 = rng.uniform(-0.1, 0.1, payload_kernel.D_H).astype(np.float32)
        w2 = rng.uniform(-0.1, 0.1, (payload_kernel.D_H, payload_kernel.D_OUT)).astype(np.float32)
        b2 = rng.uniform(-0.1, 0.1, payload_kernel.D_OUT).astype(np.float32)
        got = payload_kernel.payload_forward(x, w1, b1, w2, b2)
        want = ref.payload_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @given(
        blocks=st.integers(1, 3),
        d_in=st.sampled_from([16, 128]),
        d_h=st.sampled_from([32, 256]),
        d_out=st.sampled_from([16, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_sweep(self, blocks, d_in, d_h, d_out, seed):
        rng = np.random.RandomState(seed)
        b = blocks * payload_kernel.BATCH
        x = rng.uniform(-1, 1, (b, d_in)).astype(np.float32)
        w1 = rng.uniform(-0.2, 0.2, (d_in, d_h)).astype(np.float32)
        b1 = rng.uniform(-0.2, 0.2, d_h).astype(np.float32)
        w2 = rng.uniform(-0.2, 0.2, (d_h, d_out)).astype(np.float32)
        b2 = rng.uniform(-0.2, 0.2, d_out).astype(np.float32)
        got = payload_kernel.payload_forward(x, w1, b1, w2, b2)
        want = ref.payload_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_relu_actually_clips(self):
        """With a large negative bias the hidden layer saturates to zero and
        the output equals b2 exactly."""
        b, d_in, d_h, d_out = payload_kernel.BATCH, 16, 32, 16
        rng = np.random.RandomState(3)
        x = rng.uniform(-1, 1, (b, d_in)).astype(np.float32)
        w1 = rng.uniform(-0.1, 0.1, (d_in, d_h)).astype(np.float32)
        b1 = np.full(d_h, -100.0, np.float32)
        w2 = rng.uniform(-0.1, 0.1, (d_h, d_out)).astype(np.float32)
        b2 = rng.uniform(-0.5, 0.5, d_out).astype(np.float32)
        got = np.asarray(payload_kernel.payload_forward(x, w1, b1, w2, b2))
        np.testing.assert_allclose(got, np.tile(b2, (b, 1)), atol=1e-6)


class TestModelShapes:
    def test_learner_update_shape(self):
        from compile import model

        n, k = model.N_WORKERS, model.K_SAMPLES
        rng = np.random.RandomState(4)
        dur, dem, age, cnt = make_learner_inputs(rng, n, k)
        params = jnp.asarray([8.0, 0.06, 50.0, 1.0], jnp.float32)
        out = model.learner_update(dur, dem, age, cnt, params)
        assert out.shape == (n,)
        assert out.dtype == jnp.float32

    def test_payload_forward_shape(self):
        from compile import model

        w1, b1, w2, b2 = model.payload_init(0)
        x = jnp.ones((payload_kernel.BATCH, payload_kernel.D_IN), jnp.float32)
        out = model.payload_forward(x, w1, b1, w2, b2)
        assert out.shape == (payload_kernel.BATCH, payload_kernel.D_OUT)
        assert bool(jnp.all(jnp.isfinite(out)))
