"""AOT lowering: jit the L2 model functions and dump HLO *text* artifacts
the rust runtime loads through the PJRT CPU client.

HLO text (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import payload as payload_kernel


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_learner() -> str:
    """Lower learner_update for the fixed artifact shape."""
    n, k = model.N_WORKERS, model.K_SAMPLES
    spec = jax.ShapeDtypeStruct((n, k), jnp.float32)
    cnt = jax.ShapeDtypeStruct((n,), jnp.int32)
    par = jax.ShapeDtypeStruct((4,), jnp.float32)
    lowered = jax.jit(model.learner_update).lower(spec, spec, spec, cnt, par)
    return to_hlo_text(lowered)


def lower_payload() -> str:
    """Lower payload_forward for the fixed artifact shape."""
    x = jax.ShapeDtypeStruct((payload_kernel.BATCH, payload_kernel.D_IN), jnp.float32)
    w1 = jax.ShapeDtypeStruct((payload_kernel.D_IN, payload_kernel.D_H), jnp.float32)
    b1 = jax.ShapeDtypeStruct((payload_kernel.D_H,), jnp.float32)
    w2 = jax.ShapeDtypeStruct((payload_kernel.D_H, payload_kernel.D_OUT), jnp.float32)
    b2 = jax.ShapeDtypeStruct((payload_kernel.D_OUT,), jnp.float32)
    lowered = jax.jit(model.payload_forward).lower(x, w1, b1, w2, b2)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, text in [("learner", lower_learner()), ("payload", lower_payload())]:
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()
