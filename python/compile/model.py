"""Layer-2 JAX model: the computations Rosella's rust coordinator executes
through PJRT, expressed as jitted JAX functions that call the Layer-1
Pallas kernels.

Two entry points are AOT-lowered by ``aot.py``:

* ``learner_update`` — the performance learner's publish step for a fixed
  artifact shape (N_WORKERS x K_SAMPLES ring buffers -> mu_hat vector);
* ``payload_forward`` — the benchmark/request MLP payload.

Python never runs at serve time: these functions exist only to be lowered
to HLO text once (``make artifacts``).
"""

import jax.numpy as jnp

from compile.kernels import learner as learner_kernel
from compile.kernels import payload as payload_kernel

# Artifact shapes (kept in sync with rust/src/runtime/).
N_WORKERS = 16
K_SAMPLES = 64


def learner_update(durations, demands, ages, counts, params):
    """LEARNER-AGGREGATE over the full worker set (Pallas-backed).

    Shapes: durations/demands/ages f32[N_WORKERS, K_SAMPLES],
    counts i32[N_WORKERS], params f32[4] = [L, eps, horizon, cold].
    Returns f32[N_WORKERS].
    """
    return learner_kernel.learner_aggregate(durations, demands, ages, counts, params)


def payload_forward(x, w1, b1, w2, b2):
    """Benchmark-job MLP inference (Pallas-backed).

    Shapes: x f32[BATCH, D_IN], w1 f32[D_IN, D_H], b1 f32[D_H],
    w2 f32[D_H, D_OUT], b2 f32[D_OUT] -> f32[BATCH, D_OUT].
    """
    return payload_kernel.payload_forward(x, w1, b1, w2, b2)


def payload_init(seed: int = 0):
    """Deterministic payload weights used by both pytest and the rust
    runtime smoke tests (small values keep activations O(1))."""
    import numpy as np

    rng = np.random.RandomState(seed)
    w1 = rng.uniform(-0.05, 0.05, (payload_kernel.D_IN, payload_kernel.D_H))
    b1 = rng.uniform(-0.01, 0.01, payload_kernel.D_H)
    w2 = rng.uniform(-0.05, 0.05, (payload_kernel.D_H, payload_kernel.D_OUT))
    b2 = rng.uniform(-0.01, 0.01, payload_kernel.D_OUT)
    return (
        jnp.asarray(w1, jnp.float32),
        jnp.asarray(b1, jnp.float32),
        jnp.asarray(w2, jnp.float32),
        jnp.asarray(b2, jnp.float32),
    )
