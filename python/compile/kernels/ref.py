"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signals: every Pallas kernel in this package
must match its oracle to float32 tolerance across a hypothesis sweep of
shapes and parameters (see python/tests/).

Two kernels mirror the paper's two compute hot-spots:

* ``learner_aggregate_ref`` — the vectorised LEARNER-AGGREGATE rule
  (paper Fig. 6) over all workers' service-sample ring buffers. This is the
  per-publish O(n*L) sweep of Rosella's performance learner; the rust
  native implementation (rust/src/learner/perf.rs) follows the same rule
  and the runtime test verifies rust-vs-artifact equivalence.

* ``payload_forward_ref`` — the benchmark-job compute payload: a two-layer
  MLP inference batch (x @ W1 -> relu -> @ W2 + b), the "resembles recent
  workloads" stand-in executed by live workers through PJRT.
"""

import jax.numpy as jnp


def learner_aggregate_ref(durations, demands, ages, counts, params):
    """Vectorised LEARNER-AGGREGATE (paper Fig. 6).

    Args:
      durations: f32[n, k] -- service durations, newest first, zero-padded.
      demands:   f32[n, k] -- matching task demands (unit-speed seconds).
      ages:      f32[n, k] -- now - completion_time for each sample;
                 padding entries carry a huge value (> horizon).
      counts:    i32[n]    -- number of valid samples per worker.
      params:    f32[4]    -- [window L, epsilon, horizon, cold_start_flag].

    Returns:
      f32[n] -- speed estimates mu_hat, with the paper's semantics:
        * use the most recent min(count, L) samples with age <= horizon;
        * a full window of L fresh samples -> (1-eps) * sum(demand)/sum(dur);
        * fewer (but >0) fresh samples during cold start -> same formula;
        * otherwise -> 0 (worker discarded / "dead").
    """
    n, k = durations.shape
    window = params[0]
    eps = params[1]
    horizon = params[2]
    cold = params[3] > 0.5

    idx = jnp.arange(k, dtype=jnp.float32)[None, :]  # column index, newest=0
    valid = idx < jnp.minimum(counts.astype(jnp.float32)[:, None], window)
    fresh = jnp.logical_and(valid, ages <= horizon)
    # The paper walks newest-first and stops at the first stale sample;
    # with monotone ages (newest first) "fresh & within window" is the
    # same set.
    used = jnp.sum(fresh.astype(jnp.float32), axis=1)
    sum_dur = jnp.sum(jnp.where(fresh, durations, 0.0), axis=1)
    sum_dem = jnp.sum(jnp.where(fresh, demands, 0.0), axis=1)
    est = (1.0 - eps) * sum_dem / jnp.maximum(sum_dur, 1e-12)
    full = used >= window
    some = used > 0.0
    keep = jnp.logical_or(full, jnp.logical_and(some, cold))
    return jnp.where(keep, est, 0.0)


def payload_forward_ref(x, w1, b1, w2, b2):
    """Two-layer MLP inference: relu(x @ w1 + b1) @ w2 + b2.

    Shapes: x f32[B, D_in], w1 f32[D_in, D_h], b1 f32[D_h],
    w2 f32[D_h, D_out], b2 f32[D_out] -> f32[B, D_out].
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2
