"""Pallas kernel: the benchmark-job MLP payload.

The live workers execute this as their "real compute" when serving
requests (examples/live_serving.rs): a two-layer MLP inference batch.

TPU mapping (DESIGN.md §Hardware-Adaptation): the matmuls are tiled to the
128-lane MXU geometry -- D_in = D_out = 128, D_h = 256, so each grid step
computes a (BLOCK_B, 128) @ (128, 256) and a (BLOCK_B, 256) @ (256, 128)
contraction entirely from VMEM tiles. ``preferred_element_type=float32``
keeps the MXU accumulation in f32 (the bfloat16-input variant would halve
VMEM traffic; we stay f32 end-to-end because the CPU interpret path is the
correctness oracle).

VMEM per block: x (BLOCK_B x 128) + w1 (128 x 256) + w2 (256 x 128) +
intermediates ~ (8x128 + 2*128*256 + 8*256 + 8*128) x 4 B ~ 270 KiB --
comfortably VMEM-resident; weights are reused across the batch grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Artifact shapes (kept in sync with rust/src/runtime/payload.rs).
BATCH = 8
D_IN = 128
D_H = 256
D_OUT = 128


def _payload_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """relu(x @ w1 + b1) @ w2 + b2 for one batch block."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...][None, :], 0.0)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = o + b2_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_b",))
def payload_forward(x, w1, b1, w2, b2, block_b=BATCH):
    """Pallas-backed MLP forward; same contract as payload_forward_ref."""
    b, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    assert b % block_b == 0, f"batch {b} not a multiple of block {block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        _payload_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_h), lambda i: (0, 0)),
            pl.BlockSpec((d_h,), lambda i: (0,)),
            pl.BlockSpec((d_h, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2)
