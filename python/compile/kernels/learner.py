"""Pallas kernel: vectorised LEARNER-AGGREGATE over worker blocks.

The performance learner's publish step sweeps every worker's ring buffer of
recent service samples and applies the paper's Fig. 6 rule. On TPU this is
a classic VMEM-resident reduction:

* grid over blocks of ``BLOCK_N`` workers;
* each grid step holds a ``(BLOCK_N, K)`` tile of durations/demands/ages in
  VMEM (BlockSpec below), reduces along K in vector registers with a
  validity mask, and emits ``BLOCK_N`` estimates;
* the params vector (window, epsilon, horizon, cold-start flag) is
  broadcast to every block.

VMEM budget per block: 3 tiles x BLOCK_N x K x 4 B + small vectors.
With BLOCK_N=8, K=64 that is ~6 KiB -- far under the ~16 MiB VMEM of a
TPU core, leaving room to scale K for larger windows (K=1024 -> ~100 KiB).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode emits plain HLO with
identical numerics (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block size over workers (grid dimension).
BLOCK_N = 8


def _learner_kernel(dur_ref, dem_ref, age_ref, cnt_ref, par_ref, out_ref):
    """One grid step: estimates for a block of workers."""
    window = par_ref[0]
    eps = par_ref[1]
    horizon = par_ref[2]
    cold = par_ref[3] > 0.5

    dur = dur_ref[...]  # (BLOCK_N, K) in VMEM
    dem = dem_ref[...]
    age = age_ref[...]
    cnt = cnt_ref[...].astype(jnp.float32)  # (BLOCK_N,)

    k = dur.shape[1]
    idx = jax.lax.broadcasted_iota(jnp.float32, (dur.shape[0], k), 1)
    valid = idx < jnp.minimum(cnt[:, None], window)
    fresh = jnp.logical_and(valid, age <= horizon)
    maskf = fresh.astype(jnp.float32)

    used = jnp.sum(maskf, axis=1)
    sum_dur = jnp.sum(dur * maskf, axis=1)
    sum_dem = jnp.sum(dem * maskf, axis=1)
    est = (1.0 - eps) * sum_dem / jnp.maximum(sum_dur, 1e-12)
    keep = jnp.logical_or(used >= window, jnp.logical_and(used > 0.0, cold))
    out_ref[...] = jnp.where(keep, est, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n",))
def learner_aggregate(durations, demands, ages, counts, params, block_n=BLOCK_N):
    """Pallas-backed LEARNER-AGGREGATE.

    Same contract as ``ref.learner_aggregate_ref``; ``n`` must be a
    multiple of ``block_n`` (the AOT wrapper pads to the artifact shape).
    """
    n, k = durations.shape
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    tile = pl.BlockSpec((block_n, k), lambda i: (i, 0))
    vec = pl.BlockSpec((block_n,), lambda i: (i,))
    par = pl.BlockSpec((4,), lambda i: (0,))
    return pl.pallas_call(
        _learner_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, vec, par],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(durations, demands, ages, counts, params)
