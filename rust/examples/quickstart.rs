//! Quickstart: simulate Rosella vs the classical baselines on a small
//! heterogeneous cluster and print the response-time summary.
//!
//! Run: `cargo run --release --example quickstart`

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::metrics::report::{format_table, Row};
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;

fn main() {
    println!("Rosella quickstart — 15 heterogeneous workers (S1), load 0.8, 120 s\n");
    let policies: Vec<(&str, PolicyKind, LearnerConfig)> = vec![
        ("uniform", PolicyKind::Uniform, LearnerConfig::oracle()),
        ("pot", PolicyKind::PoT { d: 2 }, LearnerConfig::oracle()),
        (
            "rosella",
            PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            LearnerConfig::default(),
        ),
    ];
    let mut rows = Vec::new();
    for (name, policy, learner) in policies {
        let result = run(SimConfig {
            seed: 7,
            duration: 120.0,
            warmup: 20.0,
            speeds: SpeedProfile::S1,
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load: 0.8,
            policy,
            learner,
            queue_sample: None,
            timeline: None,
        });
        let s = result.responses.summary();
        rows.push(Row::new(
            name,
            vec![
                s.mean * 1e3,
                s.five.p50 * 1e3,
                s.five.p95 * 1e3,
                result.utilization,
                result.benchmark_fraction(),
            ],
        ));
    }
    println!(
        "{}",
        format_table(
            "response time (ms) and overheads",
            &["mean", "p50", "p95", "util", "bench_frac"],
            &rows,
            2
        )
    );
    println!("Rosella learns worker speeds online (no oracle) and still wins.");
}
