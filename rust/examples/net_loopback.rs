//! Loopback demonstration of the cross-process scheduling plane: one pool
//! server plus two remote scheduler frontends over real TCP on 127.0.0.1.
//!
//! The same run as two OS processes:
//!
//! ```text
//! rosella plane --listen 127.0.0.1:7411 --frontends 2 --duration 2 \
//!     --sync-interval 0.2 --json BENCH_net_smoke.json &
//! rosella frontend --connect 127.0.0.1:7411 --shard 0/2 &
//! rosella frontend --connect 127.0.0.1:7411 --shard 1/2
//! ```
//!
//! (learner ownership is inherently per-frontend on the net plane, so
//! there is no `--learners` flag on the `--listen` surface)
//!
//! ```bash
//! cargo run --example net_loopback
//! ```

use rosella::learner::SyncPolicyConfig;
use rosella::net::{run_remote_frontend, ConnectConfig, NetServer, NetServerConfig};
use std::thread;

fn main() {
    let cfg = NetServerConfig {
        listen: "127.0.0.1:0".into(),
        frontends: 2,
        speeds: vec![2.0, 1.0, 1.0, 0.5, 0.25],
        rate: 300.0,
        duration: 2.0,
        mean_demand: 0.004,
        sync_interval: 0.2,
        sync_policy: SyncPolicyConfig::adaptive(0.1),
        ..NetServerConfig::default()
    };
    let server = NetServer::bind(cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    println!("pool server listening on {addr}\n");
    let server_handle = thread::spawn(move || server.serve());

    let frontends: Vec<_> = (0..2)
        .map(|shard| {
            let addr = addr.clone();
            thread::spawn(move || run_remote_frontend(&ConnectConfig::new(addr, shard, 2)))
        })
        .collect();
    for h in frontends {
        match h.join().expect("frontend thread") {
            Ok(report) => println!("{}", report.render()),
            Err(e) => {
                eprintln!("frontend failed: {e}");
                std::process::exit(1);
            }
        }
    }
    match server_handle.join().expect("server thread") {
        Ok(report) => {
            println!("{}", report.render());
            assert_eq!(report.completed, report.dispatched, "tasks lost across the wire");
            assert!(report.sync_merges >= 1, "no consensus merge crossed the wire");
        }
        Err(e) => {
            eprintln!("server failed: {e}");
            std::process::exit(1);
        }
    }
}
