//! Heterogeneous-cluster study (the §6.2 "speeds known" setting): sweep
//! load ratios on a Zipf-flavoured cluster and show where each policy
//! breaks down — the Figure 10b experiment as a library consumer would
//! run it.
//!
//! Run: `cargo run --release --example heterogeneous_cluster [max_load]`

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::metrics::report::{format_table, Row};
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;

fn main() {
    let max_load: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.9);
    // A few powerful servers among many weak ones (§6.2 Zipf motivation).
    let speeds = SpeedProfile::Explicit(vec![
        0.25, 0.25, 0.25, 0.25, 0.25, 0.25, 0.25, 0.5, 0.5, 0.5, 0.5, 1.0, 1.0, 2.0, 4.0,
    ]);
    let loads: Vec<f64> =
        [0.3, 0.5, 0.7, 0.8, 0.9].iter().copied().filter(|l| *l <= max_load).collect();
    let policies: Vec<(&str, PolicyKind)> = vec![
        ("pot", PolicyKind::PoT { d: 2 }),
        ("pss", PolicyKind::Pss),
        ("halo", PolicyKind::Halo),
        ("ppot (rosella)", PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }),
    ];
    println!("mean response time (ms) vs load — worker speeds known (oracle)\n");
    let mut rows = Vec::new();
    for (name, policy) in policies {
        let mut cells = Vec::new();
        for &load in &loads {
            let r = run(SimConfig {
                seed: 11,
                duration: 300.0,
                warmup: 60.0,
                speeds: speeds.clone(),
                volatility: Volatility::Static,
                workload: WorkloadKind::Synthetic,
                load,
                policy: policy.clone(),
                learner: LearnerConfig::oracle(),
                queue_sample: None,
                timeline: None,
            });
            cells.push(r.responses.mean() * 1e3);
        }
        rows.push(Row::new(name, cells));
    }
    let headers: Vec<String> = loads.iter().map(|l| format!("load {l}")).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    println!("{}", format_table("Figure 10b reproduction", &headers_ref, &rows, 1));
    println!("Expect: PoT degrades sharply at high load (slow workers overloaded);");
    println!("PSS/Halo stay stationary; PPoT (Rosella's policy) is best throughout.");
}
