//! Volatile-cluster study: worker speeds are randomly permuted every
//! minute (the paper's shock model) and the self-driving learner must
//! re-learn them online. Shows the estimate-error trace around shocks and
//! the cost of disabling benchmark ("fake") jobs — the Figure 11/12 story.
//!
//! Run: `cargo run --release --example volatile_cluster`

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::metrics::report::{format_table, Row};
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;

fn simulate(learner: LearnerConfig, seed: u64) -> (f64, Vec<(f64, f64)>) {
    let r = run(SimConfig {
        seed,
        duration: 300.0,
        warmup: 60.0,
        speeds: SpeedProfile::S2,
        volatility: Volatility::Permute { period: 60.0 },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner,
        queue_sample: None,
        timeline: None,
    });
    (r.responses.mean() * 1e3, r.estimate_error)
}

fn main() {
    println!("Volatile cluster (S2, permute speeds every 60 s, load 0.8)\n");
    let (with_fakes, trace) = simulate(LearnerConfig::default(), 3);
    let (no_fakes_w10, _) = simulate(LearnerConfig::no_fake_jobs(10.0), 3);
    let (no_fakes_w40, _) = simulate(LearnerConfig::no_fake_jobs(40.0), 3);
    let rows = vec![
        Row::new("rosella (fake jobs)", vec![with_fakes]),
        Row::new("no fakes, w10", vec![no_fakes_w10]),
        Row::new("no fakes, w40", vec![no_fakes_w40]),
    ];
    println!("{}", format_table("mean response (ms)", &["mean_ms"], &rows, 1));

    println!("learner estimate error around shocks (shocks at t = 60, 120, ...):");
    // Print the error right before and right after each shock.
    for k in 1..=4 {
        let shock = 60.0 * k as f64;
        let before = trace
            .iter()
            .rev()
            .find(|(t, _)| *t < shock)
            .map(|(_, e)| *e)
            .unwrap_or(f64::NAN);
        let after = trace
            .iter()
            .find(|(t, _)| *t > shock + 2.0)
            .map(|(_, e)| *e)
            .unwrap_or(f64::NAN);
        let recovered = trace
            .iter()
            .find(|(t, _)| *t > shock + 30.0)
            .map(|(_, e)| *e)
            .unwrap_or(f64::NAN);
        println!(
            "  shock@{shock:>5.0}s: error before {before:.3} → after {after:.3} → +30 s {recovered:.3}"
        );
    }
    println!("\nFake jobs keep every worker freshly sampled, so the estimates");
    println!("recover within a fraction of the shock period (paper Result 2/3).");
}
