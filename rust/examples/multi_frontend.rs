//! Multi-frontend serving: the sharded scheduling plane end to end.
//!
//! Four frontend shards each run the complete Rosella loop — their own
//! Poisson arrival stream, PPoT policy instance, and arrival estimator —
//! against one shared pool of eight heterogeneous worker threads. The only
//! cross-frontend coordination is lock-free: atomic queue-length probes and
//! the seqlock-published speed-estimate table (paper §2 "minimum
//! coordination", §5 "distributed scheduler").
//!
//! The run is shown in both learner-ownership modes: the shared-aggregator
//! baseline, then the paper's §5 design — one private learner per
//! scheduler, each fed by only the completions it routed, consensus via
//! periodic estimate sync.
//!
//! Run: `cargo run --release --example multi_frontend`

use rosella::learner::{merge_estimates, SyncPolicyConfig};
use rosella::plane::{run_plane, sweep, DispatchMode, LearnerMode, PlaneConfig};

fn main() {
    let speeds = vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25];
    println!("sharded plane: 8 workers, speeds {speeds:?}\n");

    // 1. Full system: four frontends serving paced traffic, the shared
    //    learner discovering the speed mix online.
    let cfg = PlaneConfig {
        speeds: speeds.clone(),
        frontends: 4,
        rate: 800.0,
        duration: 4.0,
        mean_demand: 0.005,
        publish_interval: 0.1,
        ..PlaneConfig::default()
    };
    match run_plane(cfg.clone()) {
        Ok(report) => println!("{}", report.render()),
        Err(e) => {
            eprintln!("plane failed: {e}");
            std::process::exit(1);
        }
    }

    // 2. Same traffic, §5 learning topology: every scheduler owns a
    //    private learner; consensus only at estimate-sync epochs.
    let per_shard_cfg = PlaneConfig {
        learners: LearnerMode::PerShard,
        sync_interval: 0.25,
        ..cfg
    };
    match run_plane(per_shard_cfg) {
        Ok(report) => {
            println!("{}", report.render());
            println!("around the final sync epoch:");
            println!("  before (each scheduler's private view, worker μ̂ @ in-window samples):");
            for (s, views) in report.shard_views.iter().enumerate() {
                let cells: Vec<String> =
                    views.iter().map(|v| format!("{:.2}@{}", v.mu_hat, v.samples)).collect();
                println!("    shard {s}: [{}]", cells.join(", "));
            }
            let prior = speeds.iter().sum::<f64>() / speeds.len() as f64;
            let consensus = merge_estimates(&report.shard_views, prior);
            let cells: Vec<String> = consensus.iter().map(|m| format!("{m:.2}")).collect();
            println!("  after (merged consensus every scheduler adopts): [{}]", cells.join(", "));
            println!(
                "  {} sync epochs total; no shard saw more than its own slice of the\n  \
                 completion stream, yet the consensus recovers the speed mix.\n",
                report.sync_epochs
            );
        }
        Err(e) => {
            eprintln!("per-shard plane failed: {e}");
            std::process::exit(1);
        }
    }

    // 3. The pluggable consensus layer: same per-shard topology, three
    //    answers to "how regularly" schedulers synchronize. Merge counts
    //    are the coordination spent; adaptive should spend far fewer than
    //    the fixed timer on this stable cluster.
    println!("sync-policy comparison (per-shard learners, same traffic):");
    let policies: [(&str, SyncPolicyConfig); 3] = [
        ("periodic", SyncPolicyConfig::periodic()),
        ("adaptive", SyncPolicyConfig::adaptive(0.1)),
        ("gossip", SyncPolicyConfig::gossip()),
    ];
    for (name, sync_policy) in policies {
        let cfg = PlaneConfig {
            speeds: speeds.clone(),
            frontends: 4,
            rate: 800.0,
            duration: 2.0,
            mean_demand: 0.005,
            publish_interval: 0.1,
            learners: LearnerMode::PerShard,
            sync_interval: 0.2,
            sync_policy,
            ..PlaneConfig::default()
        };
        match run_plane(cfg) {
            Ok(r) => {
                let five = r.responses.five_num();
                println!(
                    "  {name:<8}: {:>3} check epochs → {:>3} merges, p95 {:>6.2} ms",
                    r.sync_epochs,
                    r.sync_merges,
                    five.p95 * 1e3
                );
            }
            Err(e) => {
                eprintln!("{name} plane failed: {e}");
                std::process::exit(1);
            }
        }
    }
    println!();

    // 4. Scaling sweep: raw scheduling throughput as frontends are added
    //    over the same worker pool (decide-only isolates the decision path).
    let base = PlaneConfig {
        speeds,
        rate: 10_000.0,
        duration: 1.0,
        mode: DispatchMode::DecideOnly,
        fake_jobs: false,
        batch: 256,
        ..PlaneConfig::default()
    };
    match sweep(&base, &[1, 2, 4]) {
        Ok(reports) => {
            println!("decision-throughput scaling (decide-only):");
            let base_rate = reports[0].decisions_per_sec.max(1.0);
            for r in &reports {
                println!(
                    "  {} frontend(s): {:>12.0} decisions/s ({:.2}x)",
                    r.frontends,
                    r.decisions_per_sec,
                    r.decisions_per_sec / base_rate
                );
            }
        }
        Err(e) => {
            eprintln!("sweep failed: {e}");
            std::process::exit(1);
        }
    }
    println!("\nThroughput should grow near-linearly 1→4 frontends: the only shared");
    println!("state on the decision path is atomic probes + the seqlock estimate table.");
}
