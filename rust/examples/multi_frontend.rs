//! Multi-frontend serving: the sharded scheduling plane end to end.
//!
//! Four frontend shards each run the complete Rosella loop — their own
//! Poisson arrival stream, PPoT policy instance, and arrival estimator —
//! against one shared pool of eight heterogeneous worker threads. The only
//! cross-frontend coordination is lock-free: atomic queue-length probes and
//! the seqlock-published speed-estimate table written by the shared
//! performance learner (paper §2 "minimum coordination", §5 "distributed
//! scheduler").
//!
//! Run: `cargo run --release --example multi_frontend`

use rosella::plane::{run_plane, sweep, DispatchMode, PlaneConfig};

fn main() {
    let speeds = vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25];
    println!("sharded plane: 8 workers, speeds {speeds:?}\n");

    // 1. Full system: four frontends serving paced traffic, the shared
    //    learner discovering the speed mix online.
    let cfg = PlaneConfig {
        speeds: speeds.clone(),
        frontends: 4,
        rate: 800.0,
        duration: 4.0,
        mean_demand: 0.005,
        publish_interval: 0.1,
        ..PlaneConfig::default()
    };
    match run_plane(cfg) {
        Ok(report) => println!("{}", report.render()),
        Err(e) => {
            eprintln!("plane failed: {e}");
            std::process::exit(1);
        }
    }

    // 2. Scaling sweep: raw scheduling throughput as frontends are added
    //    over the same worker pool (decide-only isolates the decision path).
    let base = PlaneConfig {
        speeds,
        rate: 10_000.0,
        duration: 1.0,
        mode: DispatchMode::DecideOnly,
        fake_jobs: false,
        batch: 256,
        ..PlaneConfig::default()
    };
    match sweep(&base, &[1, 2, 4]) {
        Ok(reports) => {
            println!("decision-throughput scaling (decide-only):");
            let base_rate = reports[0].decisions_per_sec.max(1.0);
            for r in &reports {
                println!(
                    "  {} frontend(s): {:>12.0} decisions/s ({:.2}x)",
                    r.frontends,
                    r.decisions_per_sec,
                    r.decisions_per_sec / base_rate
                );
            }
        }
        Err(e) => {
            eprintln!("sweep failed: {e}");
            std::process::exit(1);
        }
    }
    println!("\nThroughput should grow near-linearly 1→4 frontends: the only shared");
    println!("state on the decision path is atomic probes + the seqlock estimate table.");
}
