//! End-to-end driver: the full three-layer system serving real requests.
//!
//! * Layer 1/2 (build time): `make artifacts` lowered the Pallas MLP
//!   payload and the Pallas LEARNER-AGGREGATE kernel to HLO text.
//! * Runtime: rust loads both artifacts through the PJRT CPU client.
//! * Layer 3: the live coordinator spawns heterogeneous worker threads,
//!   serves Poisson request traffic with Rosella's PPoT policy, learns the
//!   worker speeds online (estimates published through the PJRT learner
//!   kernel), and reports latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example live_serving`
//! (falls back to sleep-task payloads if artifacts are missing).

use rosella::coordinator::{serve, LiveConfig, PayloadMode};
use rosella::scheduler::{PolicyKind, TieRule};

fn main() {
    let artifacts = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let have_artifacts = rosella::runtime::artifacts_present(&artifacts);
    if !have_artifacts {
        eprintln!("note: artifacts/ missing — run `make artifacts` for the PJRT payload.");
        eprintln!("      serving with sleep-task payloads instead.\n");
    }
    let payload = if have_artifacts {
        PayloadMode::Pjrt { artifacts_dir: artifacts }
    } else {
        PayloadMode::Sleep
    };

    // A deliberately lopsided 6-worker cluster: 4x spread in speeds.
    let speeds = vec![2.0, 1.0, 1.0, 0.5, 0.5, 0.5];
    println!("live serving: 6 workers, speeds {speeds:?}");
    println!("policy: Rosella PPoT(SQ2) + online learner + benchmark jobs\n");

    for (name, policy) in [
        ("uniform", PolicyKind::Uniform),
        ("rosella-ppot", PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }),
    ] {
        let cfg = LiveConfig {
            speeds: speeds.clone(),
            policy,
            rate: 120.0,
            duration: 8.0,
            mean_demand: 0.02,
            payload: payload.clone(),
            pjrt_learner: have_artifacts,
            seed: 42,
            publish_interval: 0.25,
        };
        match serve(cfg) {
            Ok(report) => {
                println!("--- {name} ---");
                println!("{}", report.render());
            }
            Err(e) => {
                eprintln!("{name}: serving failed: {e}");
                std::process::exit(1);
            }
        }
    }
    println!("Rosella should show lower p95 latency than uniform at equal throughput,");
    println!("with learned estimates ranking the workers correctly.");
}
