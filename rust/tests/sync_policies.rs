//! Acceptance suite for the pluggable consensus layer.
//!
//! Pins the three contract points of the sync-policy refactor:
//!
//! 1. `periodic` is the original engine — `tests/determinism.rs` already
//!    pins its bit-identity against the fused-consensus path; here the
//!    default-constructed config is pinned to the periodic policy so no
//!    caller silently changes strategy.
//! 2. On a **stable** cluster, `adaptive` performs strictly fewer merges
//!    than `periodic` at the same interval (coordination saved when
//!    estimates are not moving).
//! 3. On the **volatile S2 sweep** (the multisched cell), `adaptive` stays
//!    within 5% of periodic's mean response time — the saved merges do not
//!    cost scheduling quality, because divergence-triggered merges fire
//!    exactly when shocks invalidate the estimates.

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::{LearnerConfig, SyncKind, SyncPolicyConfig};
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;

fn stable_cfg(sync: SyncPolicyConfig) -> SimConfig {
    SimConfig {
        seed: 20200417,
        duration: 180.0,
        warmup: 30.0,
        speeds: SpeedProfile::S1,
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load: 0.7,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig {
            schedulers: 4,
            sync_interval: 0.5,
            sync,
            ..LearnerConfig::default()
        },
        queue_sample: None,
        timeline: None,
    }
}

fn volatile_cfg(sync: SyncPolicyConfig) -> SimConfig {
    SimConfig {
        seed: 20200417,
        duration: 240.0,
        warmup: 40.0,
        speeds: SpeedProfile::S2,
        volatility: Volatility::Permute { period: 50.0 },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig {
            schedulers: 4,
            sync_interval: 1.0,
            sync,
            ..LearnerConfig::default()
        },
        queue_sample: None,
        timeline: None,
    }
}

#[test]
fn default_config_is_the_periodic_policy() {
    // The bit-compatibility pins in tests/determinism.rs run against
    // LearnerConfig::default(); this keeps them meaning "periodic".
    let d = LearnerConfig::default();
    assert_eq!(d.sync.kind, SyncKind::Periodic);
    assert_eq!(d.sync, SyncPolicyConfig::periodic());
}

#[test]
fn adaptive_performs_strictly_fewer_merges_on_a_stable_cluster() {
    let periodic = run(stable_cfg(SyncPolicyConfig::periodic()));
    let adaptive = run(stable_cfg(SyncPolicyConfig::adaptive(0.1)));
    assert!(periodic.responses.count() > 1000, "periodic {}", periodic.responses.count());
    assert!(adaptive.responses.count() > 1000, "adaptive {}", adaptive.responses.count());
    // Periodic merges at every check epoch by construction.
    assert_eq!(periodic.sync_merges, periodic.sync_epochs);
    assert!(
        adaptive.sync_merges < periodic.sync_merges,
        "adaptive must save coordination on a stable cluster: {} vs {}",
        adaptive.sync_merges,
        periodic.sync_merges
    );
    // And not marginally: with static speeds, post-warmup divergence stays
    // under the threshold, so merges collapse toward the forced staleness
    // deadline (10 × interval ⇒ ≤ ~1/10th of periodic's, plus the initial
    // learning transient where divergence genuinely triggers).
    assert!(
        adaptive.sync_merges * 2 < periodic.sync_merges,
        "adaptive saved less than half the merges: {} vs {}",
        adaptive.sync_merges,
        periodic.sync_merges
    );
}

#[test]
fn adaptive_stays_within_5_percent_on_the_volatile_s2_sweep() {
    // Tight threshold + explicit bounds: merges fire promptly when a speed
    // permutation makes the estimates diverge, idle in between.
    let sync = SyncPolicyConfig { max_interval: 2.0, ..SyncPolicyConfig::adaptive(0.05) };
    let periodic = run(volatile_cfg(SyncPolicyConfig::periodic()));
    let adaptive = run(volatile_cfg(sync));
    assert!(periodic.responses.count() > 1000);
    assert!(adaptive.responses.count() > 1000);
    let ratio = adaptive.responses.mean() / periodic.responses.mean();
    assert!(
        (ratio - 1.0).abs() <= 0.05,
        "adaptive drifted {:.2}% off periodic's mean response on volatile S2",
        100.0 * (ratio - 1.0)
    );
    assert!(
        adaptive.sync_merges <= periodic.sync_merges,
        "adaptive spent more merges than the fixed timer: {} vs {}",
        adaptive.sync_merges,
        periodic.sync_merges
    );
}

#[test]
fn gossip_converges_on_the_volatile_sweep_and_reproduces_bitwise() {
    let cfg = volatile_cfg(SyncPolicyConfig::gossip());
    let a = run(cfg.clone());
    let b = run(cfg);
    assert!(a.responses.count() > 1000, "completed {}", a.responses.count());
    // k = 4: two disjoint pair merges per round, every round.
    assert_eq!(a.sync_merges, 2 * a.sync_epochs);
    // Pairwise-only exchange still keeps the installed consensus usable.
    let final_err = a.estimate_error.last().unwrap().1;
    assert!(final_err < 0.6, "gossip consensus error {final_err}");
    // Pairings come from a seed-forked stream: bit-reproducible.
    assert_eq!(a.completed_real, b.completed_real);
    assert_eq!(a.completed_bench, b.completed_bench);
    assert_eq!(a.responses.mean().to_bits(), b.responses.mean().to_bits());
}

#[test]
fn sync_policies_exchange_lambda_shares_not_even_splits() {
    // All policies must install a λ̂_global assembled from exchanged
    // shares: with k = 4 round-robin arrival routing, every estimator sees
    // ~1/4 of the stream, and the benchmark dispatcher still runs at the
    // aggregate-budget rate — completed benchmark counts should be in the
    // same ballpark as the centralized engine's, not 4× off.
    let mut one = stable_cfg(SyncPolicyConfig::periodic());
    one.learner.schedulers = 1;
    one.learner.sync_interval = 0.0;
    let central = run(one);
    for sync in [
        SyncPolicyConfig::periodic(),
        SyncPolicyConfig::adaptive(0.1),
        SyncPolicyConfig::gossip(),
    ] {
        let split = run(stable_cfg(sync));
        let ratio = split.completed_bench as f64 / central.completed_bench.max(1) as f64;
        assert!(
            (0.5..2.0).contains(&ratio),
            "{:?}: benchmark budget drifted {ratio}x off the centralized engine",
            sync.kind
        );
    }
}
