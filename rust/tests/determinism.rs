//! Golden determinism suite for the O(1) scheduling hot path.
//!
//! The seed engine recomputed every worker's queue length with an O(n)
//! sweep before each decision; the incremental engine maintains the same
//! vector with O(1) updates. Their equivalence is enforced *inside* the
//! engine by a debug-mode mirror assertion (`assert_qlen_mirror`, active in
//! every `cargo test` run): at each decision instant the incremental
//! `qlen` must equal the full recompute the seed engine performed. Given
//! that invariant, every decision sees bit-identical inputs, so the runs
//! below pin the refactored engine to the seed engine's exact
//! `(completed_real, responses.mean())` — and the run-twice checks pin the
//! whole system (workload buffer reuse, recycled event queue, in-place
//! alias rebuilds) to bit-identical reproducibility per policy.

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::plane::{CachePadded, FrontendCore};
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::types::JobSpec;
use rosella::workload::WorkloadKind;
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;

/// Every policy the engine can run.
fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Uniform,
        PolicyKind::PoT { d: 2 },
        PolicyKind::Pss,
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false },
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true },
        PolicyKind::Sparrow { probes_per_task: 2 },
        PolicyKind::Bandit { eta: 0.2 },
        PolicyKind::Halo,
    ]
}

fn golden_cfg(policy: PolicyKind, workload: WorkloadKind) -> SimConfig {
    SimConfig {
        seed: 2024,
        duration: 90.0,
        warmup: 10.0,
        speeds: SpeedProfile::S1,
        // Shocks exercise the per-worker completion cancellation; the
        // learning stack exercises in-place alias rebuilds.
        volatility: Volatility::Permute { period: 20.0 },
        workload,
        load: 0.6,
        policy,
        learner: LearnerConfig::default(),
        queue_sample: Some(1.0),
        timeline: None,
    }
}

#[test]
fn every_policy_reproduces_bit_identical_results_synthetic() {
    for policy in all_policies() {
        let cfg = golden_cfg(policy.clone(), WorkloadKind::Synthetic);
        let a = run(cfg.clone());
        let b = run(cfg);
        assert!(a.responses.count() > 200, "{policy:?}: only {} jobs", a.responses.count());
        assert_eq!(a.completed_real, b.completed_real, "{policy:?}: completed_real diverged");
        assert_eq!(a.completed_bench, b.completed_bench, "{policy:?}: completed_bench diverged");
        assert_eq!(a.responses.count(), b.responses.count(), "{policy:?}: count diverged");
        assert_eq!(
            a.responses.mean().to_bits(),
            b.responses.mean().to_bits(),
            "{policy:?}: mean response diverged bit-wise"
        );
        assert_eq!(a.incomplete_jobs, b.incomplete_jobs, "{policy:?}: backlog diverged");
    }
}

#[test]
fn multi_task_policies_reproduce_bit_identical_results_tpch() {
    // TPC-H stages exercise the multi-task paths: constrained tasks,
    // PerTask placement, and late-binding reservations.
    for policy in [
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true },
        PolicyKind::Sparrow { probes_per_task: 2 },
    ] {
        let mut cfg = golden_cfg(
            policy.clone(),
            WorkloadKind::Tpch { query: rosella::workload::tpch::Query::Q3 },
        );
        cfg.load = 0.5;
        let a = run(cfg.clone());
        let b = run(cfg);
        assert!(a.responses.count() > 100, "{policy:?}: only {} jobs", a.responses.count());
        assert_eq!(a.completed_real, b.completed_real, "{policy:?}: completed_real diverged");
        assert_eq!(
            a.responses.mean().to_bits(),
            b.responses.mean().to_bits(),
            "{policy:?}: mean response diverged bit-wise"
        );
    }
}

#[test]
fn oracle_mode_reproduces_bit_identical_results() {
    // Oracle shocks rebuild the sampler in place on the shock path.
    let mut cfg = golden_cfg(
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        WorkloadKind::Synthetic,
    );
    cfg.learner = LearnerConfig::oracle();
    cfg.volatility = Volatility::Permute { period: 5.0 };
    let a = run(cfg.clone());
    let b = run(cfg);
    assert!(a.responses.count() > 200);
    assert_eq!(a.completed_real, b.completed_real);
    assert_eq!(a.responses.mean().to_bits(), b.responses.mean().to_bits());
}

#[test]
fn aligned_sync_cadence_is_decision_equivalent_to_the_shared_learner_engine() {
    // §5 pin: the multi-scheduler machinery with the trivial partition
    // (one scheduler) and its sync epoch aligned to the publish cadence
    // must reproduce the shared-learner engine's decision stream
    // bit-for-bit. Publish fires before the same-timestamp sync epoch
    // (FIFO among equal times), so consensus installs identical values at
    // identical instants whether it runs fused into the publish
    // (sync_interval = 0) or as its own event.
    let shared = golden_cfg(
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        WorkloadKind::Synthetic,
    );
    let mut aligned = shared.clone();
    aligned.learner.sync_interval = aligned.learner.publish_interval;
    let a = run(shared);
    let b = run(aligned);
    assert!(a.responses.count() > 200, "only {} jobs", a.responses.count());
    assert_eq!(a.completed_real, b.completed_real, "completed_real diverged");
    assert_eq!(a.completed_bench, b.completed_bench, "completed_bench diverged");
    assert_eq!(a.responses.count(), b.responses.count(), "count diverged");
    assert_eq!(
        a.responses.mean().to_bits(),
        b.responses.mean().to_bits(),
        "mean response diverged bit-wise"
    );
    assert_eq!(a.incomplete_jobs, b.incomplete_jobs, "backlog diverged");
}

#[test]
fn multi_scheduler_split_reproduces_bit_identically() {
    // The k-way learner partition is deterministic: same seed, same split,
    // same consensus stream.
    let mut cfg = golden_cfg(
        PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        WorkloadKind::Synthetic,
    );
    cfg.learner.schedulers = 4;
    cfg.learner.sync_interval = 0.5;
    let a = run(cfg.clone());
    let b = run(cfg);
    assert!(a.responses.count() > 200);
    assert_eq!(a.completed_real, b.completed_real);
    assert_eq!(a.completed_bench, b.completed_bench);
    assert_eq!(a.responses.mean().to_bits(), b.responses.mean().to_bits());
}

#[test]
fn local_and_shared_views_yield_identical_decisions_for_every_policy() {
    // The same policy over the borrowed-slice view (DES engine, live
    // coordinator) and over the plane's atomic-probe view must produce the
    // same placement stream — this is what lets the coordinator switch its
    // arrival path from an O(n) queue snapshot to O(1) shared probes
    // without changing a single decision.
    for kind in all_policies() {
        let n = 8;
        let mut local = FrontendCore::new(&kind, n, 1.0, 0.01, 128, 2024);
        let mut shared = FrontendCore::new(&kind, n, 1.0, 0.01, 128, 2024);
        let qlocal: Vec<usize> = (0..n).map(|i| (i * 3) % 5).collect();
        let qshared: Vec<Arc<CachePadded<AtomicUsize>>> =
            qlocal.iter().map(|&q| Arc::new(CachePadded::new(AtomicUsize::new(q)))).collect();
        let job = JobSpec::single(0.02);
        for k in 0..3_000 {
            let t = k as f64 * 1e-3;
            local.on_arrival(t, 1);
            shared.on_arrival(t, 1);
            assert_eq!(
                local.decide_local(&job, &qlocal),
                shared.decide_shared(&job, &qshared),
                "{kind:?}: decision {k} diverged between views"
            );
        }
    }
}

#[test]
fn plane_pinning_modes_do_not_change_the_decision_stream() {
    // Pinning is a placement-of-threads decision, not a placement-of-tasks
    // decision: `--pin none` must stay bit-identical to today's plane, and
    // `--pin cores` touches no RNG and no decision input, so the recorded
    // placement streams of all shards must match exactly. (Sockets mode
    // may legitimately diverge on multi-package hosts — its socket-local
    // probing is a different, documented decision path — so it is pinned
    // by its own conservation tests, not here.)
    use rosella::plane::{run_plane, DispatchMode, PinMode, PlaneConfig};
    let cfg = |pin: PinMode| PlaneConfig {
        speeds: vec![1.0, 0.5, 0.25, 2.0],
        frontends: 2,
        rate: 400.0,
        duration: 30.0,
        mean_demand: 0.003,
        mode: DispatchMode::DecideOnly,
        max_decisions: Some(500),
        record_placements: true,
        fake_jobs: false,
        pin,
        ..PlaneConfig::default()
    };
    let unpinned = run_plane(cfg(PinMode::None)).expect("unpinned plane run");
    let pinned = run_plane(cfg(PinMode::Cores)).expect("pinned plane run");
    assert_eq!(unpinned.decisions, 1000);
    assert_eq!(pinned.decisions, 1000);
    for (shard, (a, b)) in unpinned.placements.iter().zip(pinned.placements.iter()).enumerate() {
        assert_eq!(a, b, "shard {shard}: placement stream diverged under --pin cores");
    }
}
