//! Property-based tests over the library's core invariants, driven by the
//! in-repo `testkit` harness (proptest is unavailable offline).

use rosella::stats::{AliasTable, SlidingMean};
use rosella::testkit::{assert_prop, Gen};

/// Alias tables preserve the exact normalized weights for arbitrary
/// non-negative weight vectors.
#[test]
fn prop_alias_table_matches_weights() {
    assert_prop("alias-exact-probabilities", 0xA11A5, 60, |g: &mut Gen| {
        let weights = g.vec_of(32, |g| if g.int_in(0, 4) == 0 { 0.0 } else { g.f64_in(0.01, 10.0) });
        let total: f64 = weights.iter().sum();
        let t = AliasTable::new(&weights);
        for (i, &w) in weights.iter().enumerate() {
            let expect = if total > 0.0 { w / total } else { 1.0 / weights.len() as f64 };
            let got = t.probability(i);
            if (got - expect).abs() > 1e-9 {
                return Err(format!("i={i} expect {expect} got {got} (weights {weights:?})"));
            }
        }
        Ok(())
    });
}

/// Sliding-window mean equals the naive mean of the last `cap` samples for
/// arbitrary streams and window sizes.
#[test]
fn prop_sliding_mean_matches_naive() {
    assert_prop("sliding-mean-naive", 0x51D, 60, |g: &mut Gen| {
        let cap = g.int_in(1, 32);
        let stream = g.vec_of(256, |g| g.f64_in(-100.0, 100.0));
        let mut w = SlidingMean::new(cap);
        for &x in &stream {
            w.push(x);
        }
        let tail: Vec<f64> = stream.iter().rev().take(cap).copied().collect();
        let naive = tail.iter().sum::<f64>() / tail.len() as f64;
        let got = w.mean().unwrap();
        if (got - naive).abs() > 1e-6 {
            return Err(format!("cap={cap} got {got} naive {naive}"));
        }
        Ok(())
    });
}

/// Percentiles are monotone in p and bracketed by min/max, for arbitrary
/// samples.
#[test]
fn prop_percentiles_monotone_and_bounded() {
    assert_prop("percentile-monotone", 0xC7, 60, |g: &mut Gen| {
        let xs = g.vec_of(128, |g| g.f64_in(-1e4, 1e4));
        let ps = [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0];
        let vals: Vec<f64> = ps.iter().map(|&p| rosella::stats::percentile(&xs, p)).collect();
        for w in vals.windows(2) {
            if w[0] > w[1] + 1e-9 {
                return Err(format!("non-monotone percentiles {vals:?}"));
            }
        }
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if vals[0] < lo - 1e-9 || *vals.last().unwrap() > hi + 1e-9 {
            return Err("percentiles escape [min, max]".into());
        }
        Ok(())
    });
}

/// Conservation: in any finished simulation, every arrived job is either
/// completed or still tracked as incomplete — none vanish. And the engine
/// is deterministic for a fixed seed.
#[test]
fn prop_simulation_conserves_jobs_and_is_deterministic() {
    use rosella::cluster::{SpeedProfile, Volatility};
    use rosella::learner::LearnerConfig;
    use rosella::scheduler::{PolicyKind, TieRule};
    use rosella::simulator::{run, SimConfig};
    use rosella::workload::WorkloadKind;

    assert_prop("sim-conservation", 0x51A1, 8, |g: &mut Gen| {
        let n = g.int_in(2, 12);
        let speeds: Vec<f64> = (0..n).map(|_| g.f64_in(0.2, 2.0)).collect();
        let policy = match g.int_in(0, 3) {
            0 => PolicyKind::Uniform,
            1 => PolicyKind::PoT { d: 2 },
            2 => PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            _ => PolicyKind::Sparrow { probes_per_task: 2 },
        };
        let cfg = SimConfig {
            seed: g.rng.next_u64(),
            duration: 30.0,
            warmup: 5.0,
            speeds: SpeedProfile::Explicit(speeds),
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load: g.f64_in(0.2, 0.8),
            policy,
            learner: LearnerConfig::oracle(),
            queue_sample: None,
            timeline: None,
        };
        let a = run(cfg.clone());
        let b = run(cfg);
        if a.completed_real != b.completed_real || a.responses.count() != b.responses.count() {
            return Err("nondeterministic run".into());
        }
        if a.responses.count() == 0 {
            return Err("no jobs completed at moderate load".into());
        }
        // Response times are non-negative and below the horizon.
        if a.responses.samples().iter().any(|&r| r < 0.0 || r > 30.0) {
            return Err("response time out of range".into());
        }
        Ok(())
    });
}

/// The learner's estimates never exceed the true speed by more than noise
/// (they are deliberate underestimates) for random stable clusters.
#[test]
fn prop_learner_underestimates() {
    use rosella::learner::PerfLearner;

    assert_prop("learner-underestimate", 0x1EA2, 40, |g: &mut Gen| {
        let speed = g.f64_in(0.1, 5.0);
        let demand = g.f64_in(0.01, 0.5);
        let mut l = PerfLearner::new(2, 10.0, demand, 20.0 / demand, 1.0, 0.0);
        let mut t = 0.0;
        let samples = g.int_in(30, 200);
        for _ in 0..samples {
            t += demand / speed;
            l.on_completion(0, t, demand / speed, demand);
        }
        l.publish(t, g.f64_in(0.0, 15.0) / demand);
        let est = l.mu_hat()[0];
        if est > speed * (1.0 + 1e-9) {
            return Err(format!("overestimate: est {est} > speed {speed}"));
        }
        if est > 0.0 && est < speed * 0.5 {
            return Err(format!("grossly low estimate {est} for speed {speed}"));
        }
        Ok(())
    });
}

/// JSON round-trip: parse(to_string(v)) == v for arbitrary generated
/// documents.
#[test]
fn prop_json_round_trip() {
    use rosella::config::{parse, to_string, Json};

    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.int_in(0, 3) } else { g.int_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.int_in(0, 1) == 1),
            2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"q\"\n", g.int_in(0, 999))),
            4 => Json::Arr((0..g.int_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for k in 0..g.int_in(0, 4) {
                    m.insert(format!("k{k}"), gen_json(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }

    assert_prop("json-round-trip", 0x150, 80, |g: &mut Gen| {
        let v = gen_json(g, 3);
        let s = to_string(&v);
        match parse(&s) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("round trip changed {s} -> {back:?}")),
            Err(e) => Err(format!("unparseable output {s}: {e}")),
        }
    });
}
