//! Integration tests pinning the paper's worked examples (§2.1, §3.1) and
//! learner lemmas (§4.3) against the full simulator.

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;

fn base(policy: PolicyKind, load: f64) -> SimConfig {
    SimConfig {
        seed: 1234,
        duration: 150.0,
        warmup: 30.0,
        speeds: SpeedProfile::Example1, // nine workers at 1.0, one at 6.0
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load,
        policy,
        learner: LearnerConfig::oracle(),
        queue_sample: Some(0.1),
        timeline: None,
    }
}

/// Example 1: uniform random at λ = 14 (load 14/15) overloads the nine
/// slow workers (each receives 1.4 > μ = 1) — queues diverge.
#[test]
fn example1_uniform_is_non_stationary() {
    let cfg = base(PolicyKind::Uniform, 14.0 / 15.0);
    let r = run(cfg);
    let q = r.queues.unwrap();
    // At least one slow worker must have built an enormous backlog.
    let worst_slow = (0..9).map(|w| q.max_len(w)).max().unwrap();
    assert!(worst_slow > 25, "slow-worker backlog only {worst_slow}");
    // And the backlog grows over the run (non-stationary): incomplete jobs
    // pile up.
    assert!(r.incomplete_jobs > 50, "incomplete {}", r.incomplete_jobs);
}

/// Example 2: classical PoT on the same cluster is still non-stationary —
/// 0.81 of probe pairs see only slow workers (aggregate 11.34 > 9).
#[test]
fn example2_pot_is_non_stationary() {
    let cfg = base(PolicyKind::PoT { d: 2 }, 14.0 / 15.0);
    let r = run(cfg);
    assert!(r.incomplete_jobs > 50, "incomplete {}", r.incomplete_jobs);
}

/// Rosella's PPoT on the same cluster is stationary: proportional probing
/// sends the fast worker its 6/15 share.
#[test]
fn ppot_is_stationary_where_pot_fails() {
    let cfg = base(PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }, 14.0 / 15.0);
    let r = run(cfg);
    // At λ = 14 tasks/s · 0.1 s demand the steady-state in-flight set is a
    // few dozen jobs (Little's law), so a bounded backlog means stationary.
    assert!(r.incomplete_jobs < 120, "incomplete {}", r.incomplete_jobs);
    let q = r.queues.unwrap();
    assert!(q.mean_max() < 20.0, "mean max queue {}", q.mean_max());
}

/// Example 3 (§3.1): under LL(2) the fast worker's queue grows to ~μ-ish
/// lengths — far beyond any slow worker's queue — because LL(2) keeps
/// preferring it until its expected wait matches the slow servers'.
#[test]
fn example3_ll2_congests_the_fast_worker() {
    // n = μ + 1 with μ = 8: worker 0 has speed 8, eight workers speed 1.
    let mut speeds = vec![8.0];
    speeds.extend(vec![1.0; 8]);
    let mk = |tie: TieRule| SimConfig {
        seed: 77,
        duration: 150.0,
        warmup: 30.0,
        speeds: SpeedProfile::Explicit(speeds.clone()),
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load: 0.75, // λ = 1.5μ/(2μ) as in the example
        policy: PolicyKind::PPoT { tie, late_binding: false },
        learner: LearnerConfig::oracle(),
        queue_sample: Some(0.1),
        timeline: None,
    };
    let ll2 = run(mk(TieRule::Ll2));
    let sq2 = run(mk(TieRule::Sq2));
    let qll = ll2.queues.unwrap();
    let qsq = sq2.queues.unwrap();
    // LL(2) piles jobs on the fast worker; SQ(2) does not.
    assert!(
        qll.mean_len(0) > 2.0 * qsq.mean_len(0),
        "LL2 fast queue {:.2} vs SQ2 {:.2}",
        qll.mean_len(0),
        qsq.mean_len(0)
    );
}

/// Lemma 5 flavored end-to-end: with learning enabled, a worker slower
/// than the floor μ* ends up discarded (μ̂ = 0) while healthy workers keep
/// accurate underestimates.
#[test]
fn lemma5_slow_worker_discarded_fast_workers_estimated() {
    // One near-dead worker (speed 0.01 ≪ μ* ≈ (1−α)/10 of mean) among
    // normal ones.
    let speeds = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.01];
    let cfg = SimConfig {
        seed: 5,
        duration: 400.0,
        warmup: 0.0,
        speeds: SpeedProfile::Explicit(speeds.clone()),
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load: 0.5,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::default(),
        queue_sample: None,
        timeline: None,
    };
    let sim = rosella::simulator::Simulation::new(cfg);
    let n = sim.n();
    assert_eq!(n, 8);
    let result = sim.run();
    // The learner error trace must have converged for healthy workers.
    let final_err = result.estimate_error.last().unwrap().1;
    assert!(final_err < 0.25, "final error {final_err}");
}

/// Figure 8 headline, pinned end-to-end: Rosella's mean TPC-H response is
/// well below Sparrow's (paper: 675 vs 1901 ms — 65% improvement; we pin
/// the direction and a ≥ 35% gap).
#[test]
fn rosella_beats_sparrow_tpch_static() {
    use rosella::experiments::{Baseline, Bench, Scale};
    let bench = Bench::tpch(Scale::Quick, rosella::workload::tpch::Query::Q3);
    let rosella = bench.run(Baseline::Rosella);
    let sparrow = bench.run(Baseline::Sparrow);
    let (mr, ms) = (rosella.responses.mean(), sparrow.responses.mean());
    assert!(mr < 0.65 * ms, "rosella {mr:.3}s vs sparrow {ms:.3}s");
}
