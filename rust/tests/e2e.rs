//! End-to-end integration: config file → simulation → report, the live
//! coordinator, and (when artifacts are built) the full PJRT path.

use rosella::config;
use rosella::coordinator::{serve, LiveConfig, PayloadMode};
use rosella::scheduler::PolicyKind;
use rosella::simulator::run;

#[test]
fn config_file_to_simulation() {
    let dir = std::env::temp_dir().join("rosella-e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{
            "seed": 99, "duration": 40.0, "warmup": 8.0,
            "speeds": "s1", "workload": "synthetic",
            "load": 0.6, "policy": "rosella"
        }"#,
    )
    .unwrap();
    let cfg = config::sim_config_from_file(path.to_str().unwrap()).unwrap();
    let result = run(cfg);
    assert!(result.responses.count() > 500, "completed {}", result.responses.count());
    assert!(result.responses.mean() > 0.0);
}

#[test]
fn live_coordinator_end_to_end_sleep() {
    let cfg = LiveConfig {
        speeds: vec![1.5, 0.75, 0.75],
        policy: PolicyKind::parse("ppot").unwrap(),
        rate: 120.0,
        duration: 2.0,
        mean_demand: 0.004,
        payload: PayloadMode::Sleep,
        pjrt_learner: false,
        seed: 7,
        publish_interval: 0.2,
    };
    let r = serve(cfg).unwrap();
    assert!(r.completed > 100, "completed {}", r.completed);
    assert!(r.throughput > 50.0, "throughput {}", r.throughput);
    assert!(r.five.p95 < 1.0, "p95 {}", r.five.p95);
}

#[test]
fn live_coordinator_with_pjrt_payload() {
    let dir = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !rosella::runtime::artifacts_present(&dir) {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let cfg = LiveConfig {
        speeds: vec![1.0, 0.5],
        policy: PolicyKind::parse("ppot").unwrap(),
        rate: 60.0,
        duration: 2.0,
        mean_demand: 0.01,
        payload: PayloadMode::Pjrt { artifacts_dir: dir },
        pjrt_learner: true,
        seed: 8,
        publish_interval: 0.25,
    };
    let r = serve(cfg).unwrap();
    assert!(r.completed > 40, "completed {}", r.completed);
    assert_eq!(r.learner_backend, "pjrt");
    // Learned ordering must match configured speeds.
    assert!(
        r.estimates[0].1 > r.estimates[1].1,
        "estimates out of order: {:?}",
        r.estimates
    );
}

#[test]
fn experiment_driver_smoke() {
    use rosella::experiments::{run_by_name, Scale};
    // fig13 is the cheapest full experiment; it exercises queue sampling,
    // the SQ2/LL2 tie rules, and the report formatter.
    let report = run_by_name("fig13", Scale::Quick).unwrap();
    assert!(report.contains("Fig 13a"));
    assert!(report.contains("Fig 13b"));
    assert!(report.contains("speed 1.6"));
}

#[test]
fn cli_binary_smoke() {
    let bin = env!("CARGO_BIN_EXE_rosella");
    let out = std::process::Command::new(bin).arg("list").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fig13") && text.contains("rosella"));

    let out = std::process::Command::new(bin)
        .args(["simulate", "--duration", "20", "--warmup", "4", "--load", "0.5", "--policy", "ppot"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean response"), "{text}");

    // The §5 distributed-learning surface: per-shard learners end to end.
    let out = std::process::Command::new(bin)
        .args([
            "plane",
            "--frontends",
            "2",
            "--duration",
            "1",
            "--rate",
            "150",
            "--learners",
            "per-shard",
            "--sync-interval",
            "0.2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("per-shard learners"), "{text}");
    assert!(text.contains("in-window samples"), "{text}");

    // Unknown options/subcommands fail loudly.
    let out = std::process::Command::new(bin).arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let out = std::process::Command::new(bin)
        .args(["simulate", "--policy", "nonsense"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = std::process::Command::new(bin)
        .args(["plane", "--learners", "nonsense"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
