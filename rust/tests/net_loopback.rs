//! End-to-end acceptance for the cross-process scheduling plane: one
//! in-process pool server + k remote frontends over real loopback TCP.
//!
//! This is the paper's distributed topology made literal — separate
//! scheduler "processes" (threads here, OS processes in the CI smoke)
//! exchanging compact wire messages — pinned on the same conservation
//! contracts the in-process plane satisfies:
//!
//! * every submitted task completes exactly once, at exactly one
//!   scheduler's latency recorder;
//! * at least one cross-process sync merge happens under every consensus
//!   policy (periodic / adaptive / gossip);
//! * the merged report's totals equal the sum of the per-frontend reports.

use rosella::learner::SyncPolicyConfig;
use rosella::net::{
    run_remote_frontend, ConnectConfig, FrontendReport, NetReport, NetServer, NetServerConfig,
};
use std::thread;
use std::time::Duration;

fn quick_cfg(frontends: usize, sync_policy: SyncPolicyConfig) -> NetServerConfig {
    NetServerConfig {
        listen: "127.0.0.1:0".into(),
        frontends,
        speeds: vec![2.0, 1.0, 0.5, 0.25],
        policy: "ppot".into(),
        rate: 300.0,
        duration: 1.2,
        mean_demand: 0.003,
        batch: 32,
        net_batch: 64,
        net_flush_us: 200.0,
        seed: 42,
        publish_interval: 0.1,
        warmup: 0.0,
        fake_jobs: true,
        sync_interval: 0.1,
        sync_policy,
        read_timeout: Duration::from_secs(10),
        metrics_listen: None,
        flight_record: None,
        ..NetServerConfig::default()
    }
}

fn run_loopback(cfg: NetServerConfig) -> (NetReport, Vec<FrontendReport>) {
    run_loopback_with(cfg, None)
}

/// Run one loopback plane, optionally overriding the server-advertised
/// submit-coalescing batch size on every frontend (`Some(1)` forces the
/// eager one-frame-per-task protocol).
fn run_loopback_with(
    cfg: NetServerConfig,
    net_batch: Option<usize>,
) -> (NetReport, Vec<FrontendReport>) {
    let k = cfg.frontends;
    let server = NetServer::bind(cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let server_handle = thread::spawn(move || server.serve());
    let frontend_handles: Vec<_> = (0..k)
        .map(|shard| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut ccfg = ConnectConfig::new(addr, shard, k);
                ccfg.net_batch = net_batch;
                run_remote_frontend(&ccfg)
            })
        })
        .collect();
    let reports: Vec<FrontendReport> = frontend_handles
        .into_iter()
        .map(|h| h.join().expect("frontend thread").expect("frontend run"))
        .collect();
    let net = server_handle.join().expect("server thread").expect("server run");
    (net, reports)
}

#[test]
fn loopback_frontends_complete_every_task_under_every_policy() {
    for sync in [
        SyncPolicyConfig::periodic(),
        SyncPolicyConfig::adaptive(0.05),
        SyncPolicyConfig::gossip(),
    ] {
        let (net, reports) = run_loopback(quick_cfg(2, sync));
        assert_eq!(net.frontends, 2);
        assert_eq!(net.workers, 4);
        assert!(net.dispatched > 50, "{:?}: dispatched {}", sync.kind, net.dispatched);
        // The acceptance bar: all submitted tasks completed after the
        // drain — none lost in a socket, none duplicated by routing.
        assert_eq!(
            net.completed, net.dispatched,
            "{:?}: tasks lost or duplicated across the wire",
            sync.kind
        );
        assert_eq!(net.submit_dropped, 0, "{:?}: late submits dropped", sync.kind);
        // ≥1 cross-process sync merge per policy (the drain-time epoch
        // guarantees one even for an adaptive policy that never triggered)
        // — and, the non-vacuous half, actual consensus payloads crossed
        // the wire: every frontend ships at least its final drain-time
        // export, plus one per publish interval during the run.
        assert!(net.sync_merges >= 1, "{:?}: no merge ran", sync.kind);
        assert!(net.sync_epochs >= 1, "{:?}: no consensus epoch ran", sync.kind);
        assert!(
            net.sync_exports >= 2,
            "{:?}: only {} sync payloads crossed the wire",
            sync.kind,
            net.sync_exports
        );
        if sync.kind == rosella::learner::SyncKind::Periodic {
            // Periodic merges every dirty epoch: beyond the drain merge,
            // wire-exported views must have driven real merges.
            assert!(net.sync_merges >= 2, "no wire-driven merge: {}", net.sync_merges);
        }
        assert!(net.tasks_per_sec > 0.0, "{:?}: zero throughput", sync.kind);
        // The merged report is exactly the sum of the per-frontend runs.
        assert_eq!(net.decisions, reports.iter().map(|r| r.decisions).sum::<u64>());
        assert_eq!(net.benchmarks, reports.iter().map(|r| r.benchmarks).sum::<u64>());
        assert!(
            reports.iter().all(|r| r.decisions > 0),
            "{:?}: idle frontend",
            sync.kind
        );
        // Completion routing: every real completion landed at exactly the
        // scheduler that routed it, and nowhere else.
        let recorded: u64 = reports.iter().map(|r| r.responses.count() as u64).sum();
        assert_eq!(recorded, net.completed, "{:?}: latency records diverge", sync.kind);
        assert_eq!(net.estimates.len(), 4);
        // Benchmark probing ran, throttled but alive, on every frontend.
        assert!(net.benchmarks > 0, "{:?}: benchmark dispatchers idle", sync.kind);
    }
}

#[test]
fn loopback_run_learns_speed_ordering_across_processes() {
    // Two workers 8x apart: the consensus assembled purely from payloads
    // that crossed the wire must order them correctly.
    let cfg = NetServerConfig {
        speeds: vec![2.0, 0.25],
        rate: 200.0,
        duration: 2.0,
        mean_demand: 0.004,
        ..quick_cfg(2, SyncPolicyConfig::periodic())
    };
    let (net, reports) = run_loopback(cfg);
    assert!(net.completed > 100, "completed {}", net.completed);
    let (t0, e0) = net.estimates[0];
    let (t1, e1) = net.estimates[1];
    assert!(
        e0 > e1,
        "cross-process consensus failed to order speeds: {e0} vs {e1} (true {t0} vs {t1})"
    );
    // Every frontend ends the run holding the published consensus.
    for r in &reports {
        assert_eq!(r.final_estimates.len(), 2);
    }
}

#[test]
fn batched_and_unbatched_runs_agree_on_the_physics() {
    // The coalescing layer is a transport optimization, not a semantics
    // change: whether dispatches ride 64-task `SubmitBatch` frames or the
    // frontends are forced back to the eager one-frame-per-task protocol,
    // every task completes exactly once, consensus payloads still cross
    // the wire, and both runs learn the same speed ordering.
    let cfg = || NetServerConfig {
        speeds: vec![2.0, 0.25],
        rate: 200.0,
        duration: 1.5,
        mean_demand: 0.004,
        ..quick_cfg(2, SyncPolicyConfig::periodic())
    };
    let (batched, _) = run_loopback_with(cfg(), None);
    let (eager, _) = run_loopback_with(cfg(), Some(1));
    for (label, net) in [("batched", &batched), ("eager", &eager)] {
        assert!(net.dispatched > 50, "{label}: dispatched {}", net.dispatched);
        assert_eq!(
            net.completed, net.dispatched,
            "{label}: tasks lost or duplicated across the wire"
        );
        assert_eq!(net.submit_dropped, 0, "{label}: late submits dropped");
        assert!(net.sync_merges >= 1, "{label}: no sync merge ran");
        assert!(
            net.sync_exports >= 2,
            "{label}: only {} sync payloads crossed the wire",
            net.sync_exports
        );
        let (_, e0) = net.estimates[0];
        let (_, e1) = net.estimates[1];
        assert!(
            e0 > e1,
            "{label}: consensus failed to order the 8x-apart speeds: {e0} vs {e1}"
        );
    }
}

#[test]
fn fallback_poller_matches_epoll_on_the_physics() {
    // The poll backend is a wakeup mechanism, not a semantics change: with
    // the kernel-event poller swapped out for the portable timed sweep and
    // the connections split across two poll shards, every conservation
    // contract still holds — no task lost or duplicated, consensus payloads
    // still cross the wire, and the run still learns the 8x speed ordering
    // — whether dispatches ride batched frames or the eager protocol.
    let cfg = || NetServerConfig {
        speeds: vec![2.0, 0.25],
        rate: 200.0,
        duration: 1.5,
        mean_demand: 0.004,
        force_poll_fallback: true,
        poll_shards: Some(2),
        ..quick_cfg(2, SyncPolicyConfig::periodic())
    };
    let (batched, reports) = run_loopback_with(cfg(), None);
    let (eager, _) = run_loopback_with(cfg(), Some(1));
    for (label, net) in [("batched", &batched), ("eager", &eager)] {
        assert_eq!(net.poll_shards, 2, "{label}: shard override ignored");
        assert!(net.dispatched > 50, "{label}: dispatched {}", net.dispatched);
        assert_eq!(
            net.completed, net.dispatched,
            "{label}: tasks lost or duplicated on the fallback poller"
        );
        assert_eq!(net.submit_dropped, 0, "{label}: late submits dropped");
        assert!(net.sync_merges >= 1, "{label}: no sync merge ran");
        assert!(
            net.sync_exports >= 2,
            "{label}: only {} sync payloads crossed the wire",
            net.sync_exports
        );
        assert!(net.poll_wakeups > 0, "{label}: poller never woke");
        let (_, e0) = net.estimates[0];
        let (_, e1) = net.estimates[1];
        assert!(
            e0 > e1,
            "{label}: consensus failed to order the 8x-apart speeds: {e0} vs {e1}"
        );
    }
    // Completion routing survives sharding: each frontend's recorder saw
    // exactly the completions it routed.
    let recorded: u64 = reports.iter().map(|r| r.responses.count() as u64).sum();
    assert_eq!(recorded, batched.completed, "latency records diverge across shards");
}

#[test]
fn server_times_out_when_frontends_never_connect() {
    // A missing frontend must fail the run with a clear error, not wedge
    // the server in accept() forever.
    // Both pollers must bound the handshake wait identically.
    for fallback in [false, true] {
        let mut cfg = quick_cfg(2, SyncPolicyConfig::periodic());
        cfg.read_timeout = Duration::from_millis(300);
        cfg.force_poll_fallback = fallback;
        let server = NetServer::bind(cfg).unwrap();
        let start = std::time::Instant::now();
        let err = server.serve().unwrap_err();
        assert!(err.contains("timed out waiting for frontends"), "{err}");
        assert!(start.elapsed() < Duration::from_secs(10), "timeout not bounded");
    }
}

#[test]
fn traced_loopback_stage_sums_reconcile_with_response_times() {
    // The tentpole acceptance: a traced loopback run assembles complete
    // six-stage lifecycle spans whose stage sums reconcile with the
    // frontend-measured response time, and the server dumps them as
    // Perfetto-loadable Chrome trace-event JSON.
    let trace_path = std::env::temp_dir()
        .join(format!("rosella_trace_loopback_{}.json", std::process::id()));
    let cfg = NetServerConfig {
        speeds: vec![2.0, 0.25],
        rate: 200.0,
        duration: 1.5,
        mean_demand: 0.004,
        trace_sample: 4,
        trace_json: Some(trace_path.to_str().unwrap().to_string()),
        ..quick_cfg(2, SyncPolicyConfig::periodic())
    };
    let (net, reports) = run_loopback(cfg);
    assert_eq!(net.completed, net.dispatched, "tracing must not lose tasks");
    assert!(net.traced_spans > 0, "server aggregated no lifecycle spans");
    for (i, r) in reports.iter().enumerate() {
        assert!(r.traced > 0, "frontend {i} assembled no spans");
        // Stage decomposition reconciles: decide + coalesce + wire +
        // queue + service + reply covers the measured lifetime to within
        // 5% (the only unaccounted gap is the server's receive-to-enqueue
        // dispatch, microseconds against millisecond tasks).
        assert!(
            r.trace_max_dev_pct <= 5.0,
            "frontend {i}: stage sums deviate {:.2}% from response time",
            r.trace_max_dev_pct
        );
    }
    // The dump is valid JSON holding complete ("ph":"X") events named
    // after the lifecycle stages — what Perfetto's Chrome-trace importer
    // requires.
    let dump = std::fs::read_to_string(&trace_path).expect("trace json written");
    let _ = std::fs::remove_file(&trace_path);
    let doc = rosella::config::parse(&dump).expect("trace json parses");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(events.len() >= 6, "expected at least one full span, got {}", events.len());
    let stages: Vec<&str> = rosella::obs::STAGES.to_vec();
    for ev in events {
        assert_eq!(ev.get("ph").and_then(|p| p.as_str()), Some("X"));
        let name = ev.get("name").and_then(|n| n.as_str()).expect("event name");
        assert!(stages.contains(&name), "unknown stage {name}");
        assert!(ev.get("ts").and_then(|t| t.as_u64()).is_some());
        assert!(ev.get("dur").and_then(|d| d.as_u64()).is_some());
    }
}

#[test]
fn v2_hello_gets_a_v2_ack_from_a_tracing_server() {
    // Version negotiation, mirror rule: a v2 client (no Hello timestamp)
    // talking to a v3 server with tracing ON must receive a byte-level v2
    // HelloAck — no clock appendix the old decoder would choke on.
    use rosella::net::wire::{header_payload_len, Msg, HEADER_LEN, MIN_VERSION};
    use std::io::{Read, Write};

    let mut cfg = quick_cfg(1, SyncPolicyConfig::periodic());
    cfg.trace_sample = 64;
    cfg.read_timeout = Duration::from_millis(500);
    let server = NetServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let server_handle = thread::spawn(move || server.serve());

    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut frame = Vec::new();
    // t0_ns: None is exactly what a v2 build emits (version-iff-appendix).
    Msg::Hello { shard: 0, shards: 1, t0_ns: None }.encode_into(&mut frame);
    assert_eq!(u16::from_le_bytes([frame[4], frame[5]]), MIN_VERSION);
    s.write_all(&frame).unwrap();

    let mut header = [0u8; HEADER_LEN];
    s.read_exact(&mut header).unwrap();
    let len = header_payload_len(&header).expect("valid ack header");
    // The ack mirrors the client's version: v2 on the wire, not v3.
    assert_eq!(u16::from_le_bytes([header[4], header[5]]), MIN_VERSION);
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    let mut whole = header.to_vec();
    whole.extend_from_slice(&body);
    match Msg::decode(&whole).expect("ack decodes") {
        Msg::HelloAck(ack) => {
            assert!(ack.clock.is_none(), "v2 client must not receive a clock appendix");
        }
        other => panic!("expected HelloAck, got {other:?}"),
    }
    // Dropping the socket mid-run fails the server cleanly (not a hang).
    drop(s);
    assert!(server_handle.join().unwrap().is_err());
}

#[test]
fn truncated_trace_appendix_is_rejected_at_the_handshake() {
    // A hostile v3 Hello that claims a clock timestamp but truncates it
    // must fail the run with a decode error — never a hang, never a
    // garbage handshake.
    use rosella::net::wire::{Msg, HEADER_LEN};
    use std::io::Write;

    let mut cfg = quick_cfg(1, SyncPolicyConfig::periodic());
    cfg.read_timeout = Duration::from_millis(500);
    let server = NetServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let server_handle = thread::spawn(move || server.serve());

    let mut frame = Vec::new();
    Msg::Hello { shard: 0, shards: 1, t0_ns: Some(42) }.encode_into(&mut frame);
    // Drop half the 8-byte timestamp appendix and shrink the declared
    // payload length to match: a self-consistent frame whose appendix is
    // too short to hold the timestamp it promises.
    frame.truncate(frame.len() - 4);
    let body_len = (frame.len() - HEADER_LEN) as u32;
    frame[8..12].copy_from_slice(&body_len.to_le_bytes());

    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.write_all(&frame).unwrap();
    let err = server_handle.join().unwrap().unwrap_err();
    assert!(
        err.contains("decode") || err.contains("truncated") || err.contains("malformed"),
        "expected a decode failure, got: {err}"
    );
}

#[test]
fn handshake_rejects_mismatched_topologies() {
    let server = NetServer::bind(quick_cfg(2, SyncPolicyConfig::periodic())).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_handle = thread::spawn(move || server.serve());
    // A frontend built for a 3-scheduler run against a 2-scheduler server:
    // the server fails the run with a clear error; the frontend sees its
    // socket close instead of a HelloAck.
    let mut cfg = ConnectConfig::new(addr, 2, 3);
    cfg.connect_timeout = Duration::from_secs(5);
    assert!(run_remote_frontend(&cfg).is_err());
    let err = server_handle.join().unwrap().unwrap_err();
    assert!(err.contains("expects 3 shards"), "{err}");
}
