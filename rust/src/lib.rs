//! # Rosella — a self-driving distributed scheduler for heterogeneous clusters
//!
//! Production-quality reproduction of *Rosella: A Self-Driving Distributed
//! Scheduler for Heterogeneous Clusters* (Wu, Manandhar, Liu — CS.DC 2020).
//!
//! The library provides:
//!
//! * the paper's **scheduling policy** (proportional sampling +
//!   power-of-two-choices with SQ(2), [`scheduler::PPoT`]) and every
//!   baseline evaluated in §6 (uniform, PoT, Sparrow, PSS, ε-greedy bandit,
//!   Halo, LL(2)) — all written against the [`types::ClusterView`] trait,
//!   so the same policy code runs single-threaded or over lock-free
//!   shared state;
//! * the **self-driving learning stack** (§3): arrival estimator,
//!   performance learner with the dynamic window `L = c/(1−α̂)` and the
//!   timeout/discard rule, and the benchmark-job dispatcher with rate
//!   `c0(μ̄ − λ̂)`;
//! * a **discrete-event cluster simulator** reproducing the paper's
//!   evaluation environment (heterogeneous speeds, permutation shocks,
//!   dual-priority worker queues, late binding);
//! * a **live threaded coordinator** ([`coordinator`]) with real worker
//!   threads that execute AOT-compiled JAX/Pallas payloads through PJRT
//!   ([`runtime`], behind the `pjrt` feature);
//! * the **sharded scheduling plane** ([`plane`]): N frontend threads each
//!   running the full Rosella loop over a shared worker pool, coordinating
//!   only through per-worker atomic queue probes and a seqlock-published
//!   estimate table (§2's "minimum coordination") — the multi-frontend
//!   regime centralized schedulers cannot reach. Learning itself
//!   decentralizes (§5, `--learners per-shard`): one [`learner::PerfLearner`]
//!   per scheduler, fed by only the completions that scheduler routed, its
//!   benchmark dispatcher throttled to `c0(μ̄ − λ̂_global)/k`, with
//!   cross-scheduler agreement reduced to [`learner::merge_estimates`]
//!   consensus over exchanged [`learner::SyncPayload`]s — "schedulers need
//!   only synchronize the estimates of worker speeds regularly". *When* and
//!   *with whom* they synchronize is a pluggable [`learner::SyncPolicy`]
//!   (see **Sync policies** below). The same topology runs
//!   deterministically in the DES engine (`LearnerConfig::schedulers` /
//!   `sync_interval` / `sync`; `multisched` maps the coordination/quality
//!   frontier);
//! * the **cross-process scheduling plane** ([`net`]): a dependency-free
//!   RPC/wire layer (`std::net::TcpStream` only) that runs the same
//!   topology across OS processes — see **Cross-process plane** below;
//! * **experiment drivers** ([`experiments`]) regenerating every figure of
//!   the paper's evaluation section.
//!
//! ## Per-decision complexity
//!
//! Rosella's headline property is that each scheduling decision "only
//! performs simple operations" (§3) — constant work regardless of cluster
//! size `n`. The engines preserve that profile end to end; `d` is the probe
//! count (2 for power-of-two-choices):
//!
//! | operation | cost | where |
//! |---|---|---|
//! | queue probe | O(d) | [`types::ClusterView::queue_len`] — incremental mirror in the DES engine, atomic counters in the plane/coordinator |
//! | proportional sample | O(1) | [`stats::AliasTable::sample`] (Vose alias draw) |
//! | scheduling decision | O(d) | probes + samples + a comparison; no allocation |
//! | job arrival | O(1) + O(tasks) | reusable job buffer ([`workload::Workload::next_job_into`]), incremental queue lengths — no O(n) sweep |
//! | event push/pop | O(log m) | compact `Copy` heap entries; stale completions cancelled at source ([`simulator::EventQueue`]) |
//! | estimate publish | O(n) | rate-limited background event; in-place [`stats::AliasTable::rebuild`], allocation-free |
//! | estimate sync | O(k·n) periodic/adaptive, O(n) per gossip pair | rate-limited consensus over exchanged payloads ([`learner::merge_estimates_into`], reused buffers); never on the decision path |
//!
//! `rosella hotpath --json BENCH_hotpath.json` ([`hotpath`]) measures all
//! of this per cluster size, so an accidental O(n) term in the decision
//! path shows up as a slope in the tracked numbers.
//!
//! ## Sync policies
//!
//! §5's "synchronize ... regularly" is a whole design axis, and the
//! paper's own §2 argument — minimum coordination — cuts against the one
//! pattern that is easiest to build (a fixed-timer all-to-all epoch). The
//! consensus layer is therefore pluggable ([`learner::SyncPolicyConfig`],
//! `--sync-policy` on `plane` and `simulate`, `learner.sync` in JSON
//! configs), with one [`learner::SyncPolicy`] state machine driving both
//! the threaded plane and the deterministic simulator:
//!
//! | policy | when it merges | coordination cost |
//! |---|---|---|
//! | `periodic` | every `sync_interval` (the original behavior, bit-compatible) | k views per epoch |
//! | `adaptive` | when a scheduler's local estimates diverge > `--sync-threshold` relative error from its last adopted consensus ([`learner::divergence_of`]); a staleness deadline forces a merge | zero on quiet epochs |
//! | `gossip` | every round, a deterministic-RNG pairing merges view *pairs*; information spreads epidemically in O(log k) rounds | 2 views per pair |
//!
//! The exchanged payload carries each scheduler's λ̂ share alongside its μ̂
//! views, so the benchmark throttle `c0(μ̄ − λ̂_global)/k` runs on the *sum
//! of exchanged shares* — correct under skewed arrival routing, where
//! extrapolating any single scheduler's estimate to an assumed even split
//! misses the budget. `rosella experiment multisched --json` sweeps
//! policy × threshold × k and reports merges-performed against response
//! degradation — the coordination/quality frontier.
//!
//! ## Cross-process plane
//!
//! The paper's §2 claim is parallel scheduling "on multiple machines";
//! [`net`] makes the landed in-process topology cross-process without a
//! single new dependency. The pieces:
//!
//! * a **versioned, length-prefixed binary wire protocol**
//!   ([`net::wire`]): explicit little-endian encoding, bit-exact float
//!   round-trips, hard frame-size bounds, and a message set that is
//!   exactly the §5 coordination surface — task submit/result (single or
//!   batched: a `SubmitBatch` frame carries up to ~49k dispatches behind
//!   one header, optionally piggybacking the beat), queue-probe ticks,
//!   [`learner::SyncPayload`] exports, worker-pool handshake;
//! * a **`Transport` seam** ([`net::Transport`]): the transport-generic §5
//!   frontend loop ([`net::run_frontend_loop`], built on
//!   [`plane::FrontendCore`]) runs over in-process channels
//!   ([`net::LocalTransport`]) or TCP ([`net::TcpTransport`]) unchanged.
//!   Consensus needs no seam: remote exports land in the same
//!   [`plane::SharedViews`] slots, so the plane's sync thread (all three
//!   [`learner::SyncPolicy`] strategies) serves both planes byte-for-byte;
//! * **two processes**: `rosella plane --listen ADDR` hosts the shared
//!   worker pool + seqlock estimate table and serves remote schedulers;
//!   `rosella frontend --connect ADDR --shard i/k` runs a complete §5
//!   scheduler — private learner, throttled benchmark dispatcher, local
//!   decisions over served probes — shipping its sync payloads over the
//!   wire instead of through shared memory.
//!
//! Throughput-wise the wire is batched at both ends and event-driven in
//! the middle: frontends coalesce dispatches under an adaptive flush
//! policy (send at `--net-batch` B tasks or after `--net-flush-us` D
//! microseconds, whichever first — B amortizes headers and write
//! syscalls at saturation, D preserves eager latency under light load;
//! the server advertises defaults in its `HelloAck`, each frontend may
//! override), and the pool server runs **N kernel-event-driven poll
//! shards** ([`net::poll`]: raw-syscall epoll with a portable sweep
//! fallback) — connections partitioned round-robin at handshake, each
//! shard thread pinned by the topology plane and owning its
//! connections' read/write buffers and decode scratch outright, so the
//! steady-state frame path allocates nothing and idle shards park in
//! the kernel instead of burning a sweep loop. Default shard count is
//! one per CPU package capped at 4 (`--net-poll-shards` overrides).
//! `obs`'s `rosella_wire_tasks_per_frame` histogram reports the
//! realized coalescing; `rosella_poll_wakeups_total` and
//! `rosella_poll_events_per_wake` report how busy each shard's poller
//! runs.
//!
//! A loopback run (one pool + k frontend processes) emits
//! `BENCH_net_smoke.json` with aggregate throughput and cross-process
//! merge counts; CI smokes it, and `benches/bench_net.rs` writes
//! `BENCH_net.json` gating net-vs-in-process parity on a paced workload
//! (≥ 0.6×), the coalescing speedup at saturation (B ≥ 64 moving ≥ 2×
//! the B=1 tasks/sec), and the sharded headline (best of 2/4 poll
//! shards ≥ 1.2× single-shard tasks/sec at saturation).
//!
//! ## Observability
//!
//! A live plane is observable without being perturbed ([`obs`]):
//!
//! * **metrics registry** ([`obs::Registry`]) — atomic counters, f64-bits
//!   gauges, and fixed-bucket log2 histograms ([`obs::Log2Histogram`]);
//!   one [`obs::ShardSlot`] per scheduler thread, written only by its
//!   owner and aggregated on read, so the decision hot path stays O(1),
//!   allocation-free, and uncontended. Both planes keep it always on —
//!   the `hotpath` metrics-overhead bench pins the cost at ≤ 1.10× the
//!   uninstrumented decision ns/op (CI-gated, within-run ratio).
//! * **decision flight recorder** ([`obs::FlightRecorder`]) — a bounded
//!   per-scheduler ring of recent placements (task id, probed workers and
//!   queue lengths seen, chosen worker, μ̂/λ̂, decision ns) and consensus
//!   events (policy, divergence at trigger, views merged, epoch lag).
//!   Opt-in (`--flight-record PATH`), dumped as JSONL on drain or live
//!   from the scrape endpoint's `/flight` route.
//! * **scrape endpoint** ([`obs::MetricsServer`]) — `--metrics-listen
//!   ADDR` on `rosella plane` (in-process and `--listen` server modes)
//!   serves Prometheus text exposition at `/metrics`: per-shard task
//!   counters, queue-length / response-time histograms, per-worker μ̂ and
//!   live queue gauges, λ̂, sync merge/export counters, and the wire-frame
//!   counters from [`net::wire`].
//! * **leveled logging** ([`obs::log`]) — `ROSELLA_LOG=error|warn|info|
//!   debug` on stderr, off by default so benches are unaffected.
//! * **DES time series** — `--timeline-interval` on `rosella simulate`
//!   samples the same signal surface (λ̂, per-worker μ̂ vs true speed,
//!   queue p99, backlog) per window into timeline JSON
//!   ([`simulator::TimelinePoint`]) for the volatile scenarios.
//!
//! Instrumentation never draws from an RNG stream or reorders a decision,
//! which is what keeps `tests/determinism.rs` bit-exact with all of it
//! compiled in.
//!
//! ## Tracing
//!
//! Aggregates say *that* response time moved; spans say *where*. The
//! lifecycle tracer ([`obs::Tracer`]) decomposes each sampled task's life
//! into six stages — `decide` (admission → placement chosen), `coalesce`
//! (placed → flushed to the wire), `wire` (frontend send → server receive,
//! clock-aligned), `queue` (worker backlog wait), `service` (execution),
//! `reply` (completion → frontend observes it) — and publishes them three
//! ways:
//!
//! * **`/metrics`** — per-stage [`obs::Log2Histogram`]s as
//!   `rosella_stage_us{stage=...}`, plus `rosella_trace_spans_total` and
//!   the live clock-alignment gauges;
//! * **`/trace` and `--trace-json PATH`** — raw sampled spans as Chrome
//!   trace-event JSON (`{"traceEvents": [...]}`, complete `"X"` events),
//!   loadable directly in [Perfetto](https://ui.perfetto.dev);
//! * **DES timelines** — `queue_wait_us`/`service_us` p50/p99 per
//!   [`simulator::TimelinePoint`] window, same decomposition,
//!   deterministic.
//!
//! Sampling is deterministic by task-id hash (`--trace-sample 1/N`, off by
//! default): both sides of the wire agree on which tasks are traced
//! without negotiating per task, and a run is reproducible under tracing.
//! Sampled frames carry a protocol-v3 timestamp appendix; unsampled
//! frames stay bit-identical to v2 (see [`net`] for the compat matrix).
//! Cross-process stages subtract the NTP-style offset estimated from the
//! Hello/HelloAck four-timestamp exchange ([`obs::ClockAlign`], refreshed
//! on ticks), and each frontend reconciles span stage-sums against its own
//! measured response times, reporting the worst deviation
//! (`trace_max_dev_pct`, integration-tested ≤ 5%). With tracing off the
//! hot path gains no allocations and no timestamp reads; at 1/1024
//! sampling the `hotpath` bench gates the decision loop at ≤ 1.10× plain
//! (`traced_ratio`, CI-gated).
//!
//! ## Topology & pinning
//!
//! The plane's shared state is deliberately tiny — per-worker queue
//! probes, a seqlock estimate table, per-scheduler consensus slots — which
//! makes its layout, not its volume, the scaling hazard: adjacent atomics
//! on one cache line turn independent shards into a coherence convoy.
//! [`plane::topo`] closes that gap dependency-free (std only):
//!
//! * **false-sharing-free layout** — every cross-thread hot word sits in a
//!   [`plane::CachePadded`] (64-byte aligned) slot: worker queue probes,
//!   the estimate table's seqlock word and λ̂ cell, and each scheduler's
//!   [`plane::SharedViews`] dirty flag and payload slot. A debug
//!   assertion pins the alignment; `hotpath`'s false-sharing bench
//!   measures packed-vs-padded ns/op and CI gates `padded_ratio >= 1.0`.
//! * **CPU topology discovery** ([`plane::CpuTopology`]) — parsed from
//!   `/sys/devices/system/cpu/*/topology/` on Linux (fixture-tested
//!   against checked-in sysfs trees, hostile inputs included), with a
//!   flat single-package fallback everywhere else.
//! * **thread pinning** (`--pin {none,cores,sockets}` on `plane` and
//!   `frontend`) — a [`plane::PlacementPlan`] spreads shards across
//!   packages and co-locates each shard with workers on its package;
//!   threads pin via a raw `sched_setaffinity` syscall (no libc crate),
//!   best-effort: a denied syscall degrades to the unpinned layout, never
//!   an error. `none` and `cores` are bit-identical to the unpinned
//!   decision stream (pinned by `tests/determinism.rs`); `sockets`
//!   additionally partitions workers per package so power-of-two probing
//!   prefers same-socket workers, spilling cross-socket only when the
//!   local minimum queue exceeds [`plane::DEFAULT_SPILL_THRESHOLD`]
//!   (spills counted in `rosella_cross_socket_decisions_total`; realized
//!   shard placement in the `rosella_shard_cpu` gauge, −1 when unpinned).
//!
//! ## Quick start
//!
//! ```
//! use rosella::simulator::{run, SimConfig};
//! let mut cfg = SimConfig::synthetic_default();
//! cfg.duration = 30.0;
//! cfg.warmup = 5.0;
//! let result = run(cfg);
//! assert!(result.responses.count() > 0);
//! ```
//!
//! ## Parallel serving
//!
//! ```
//! use rosella::plane::{run_plane, DispatchMode, PlaneConfig};
//! let cfg = PlaneConfig {
//!     frontends: 2,
//!     duration: 0.5,
//!     mode: DispatchMode::DecideOnly,
//!     max_decisions: Some(1_000),
//!     ..PlaneConfig::default()
//! };
//! let report = run_plane(cfg).unwrap();
//! assert_eq!(report.decisions, 2_000);
//! ```

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hotpath;
pub mod learner;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod plane;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod stats;
pub mod testkit;
pub mod types;
pub mod workload;
