//! # Rosella — a self-driving distributed scheduler for heterogeneous clusters
//!
//! Production-quality reproduction of *Rosella: A Self-Driving Distributed
//! Scheduler for Heterogeneous Clusters* (Wu, Manandhar, Liu — CS.DC 2020).
//!
//! The library provides:
//!
//! * the paper's **scheduling policy** (proportional sampling +
//!   power-of-two-choices with SQ(2), [`scheduler::PPoT`]) and every
//!   baseline evaluated in §6 (uniform, PoT, Sparrow, PSS, ε-greedy bandit,
//!   Halo, LL(2));
//! * the **self-driving learning stack** (§3): arrival estimator,
//!   performance learner with the dynamic window `L = c/(1−α̂)` and the
//!   timeout/discard rule, and the benchmark-job dispatcher with rate
//!   `c0(μ̄ − λ̂)`;
//! * a **discrete-event cluster simulator** reproducing the paper's
//!   evaluation environment (heterogeneous speeds, permutation shocks,
//!   dual-priority worker queues, late binding);
//! * a **live threaded coordinator** ([`coordinator`]) with real worker
//!   threads that execute AOT-compiled JAX/Pallas payloads through PJRT
//!   ([`runtime`]);
//! * **experiment drivers** ([`experiments`]) regenerating every figure of
//!   the paper's evaluation section.
//!
//! ## Quick start
//!
//! ```
//! use rosella::simulator::{run, SimConfig};
//! let mut cfg = SimConfig::synthetic_default();
//! cfg.duration = 30.0;
//! cfg.warmup = 5.0;
//! let result = run(cfg);
//! assert!(result.responses.count() > 0);
//! ```

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod learner;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod stats;
pub mod testkit;
pub mod types;
pub mod workload;
