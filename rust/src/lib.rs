//! # Rosella — a self-driving distributed scheduler for heterogeneous clusters
//!
//! Production-quality reproduction of *Rosella: A Self-Driving Distributed
//! Scheduler for Heterogeneous Clusters* (Wu, Manandhar, Liu — CS.DC 2020).
//!
//! The library provides:
//!
//! * the paper's **scheduling policy** (proportional sampling +
//!   power-of-two-choices with SQ(2), [`scheduler::PPoT`]) and every
//!   baseline evaluated in §6 (uniform, PoT, Sparrow, PSS, ε-greedy bandit,
//!   Halo, LL(2)) — all written against the [`types::ClusterView`] trait,
//!   so the same policy code runs single-threaded or over lock-free
//!   shared state;
//! * the **self-driving learning stack** (§3): arrival estimator,
//!   performance learner with the dynamic window `L = c/(1−α̂)` and the
//!   timeout/discard rule, and the benchmark-job dispatcher with rate
//!   `c0(μ̄ − λ̂)`;
//! * a **discrete-event cluster simulator** reproducing the paper's
//!   evaluation environment (heterogeneous speeds, permutation shocks,
//!   dual-priority worker queues, late binding);
//! * a **live threaded coordinator** ([`coordinator`]) with real worker
//!   threads that execute AOT-compiled JAX/Pallas payloads through PJRT
//!   ([`runtime`], behind the `pjrt` feature);
//! * the **sharded scheduling plane** ([`plane`]): N frontend threads each
//!   running the full Rosella loop over a shared worker pool, coordinating
//!   only through per-worker atomic queue probes and a seqlock-published
//!   estimate table (§2's "minimum coordination") — the multi-frontend
//!   regime centralized schedulers cannot reach. Learning itself
//!   decentralizes (§5, `--learners per-shard`): one [`learner::PerfLearner`]
//!   per scheduler, fed by only the completions that scheduler routed, its
//!   benchmark dispatcher throttled to `c0(μ̄ − λ̂)/k`, with cross-scheduler
//!   agreement reduced to periodic [`learner::merge_estimates`] consensus —
//!   "schedulers need only synchronize the estimates of worker speeds
//!   regularly". The same topology runs deterministically in the DES engine
//!   (`LearnerConfig::schedulers` / `sync_interval`; `multisched` sweeps
//!   the staleness cost);
//! * **experiment drivers** ([`experiments`]) regenerating every figure of
//!   the paper's evaluation section.
//!
//! ## Per-decision complexity
//!
//! Rosella's headline property is that each scheduling decision "only
//! performs simple operations" (§3) — constant work regardless of cluster
//! size `n`. The engines preserve that profile end to end; `d` is the probe
//! count (2 for power-of-two-choices):
//!
//! | operation | cost | where |
//! |---|---|---|
//! | queue probe | O(d) | [`types::ClusterView::queue_len`] — incremental mirror in the DES engine, atomic counters in the plane/coordinator |
//! | proportional sample | O(1) | [`stats::AliasTable::sample`] (Vose alias draw) |
//! | scheduling decision | O(d) | probes + samples + a comparison; no allocation |
//! | job arrival | O(1) + O(tasks) | reusable job buffer ([`workload::Workload::next_job_into`]), incremental queue lengths — no O(n) sweep |
//! | event push/pop | O(log m) | compact `Copy` heap entries; stale completions cancelled at source ([`simulator::EventQueue`]) |
//! | estimate publish | O(n) | rate-limited background event; in-place [`stats::AliasTable::rebuild`], allocation-free |
//! | estimate sync | O(k·n) | rate-limited consensus of k per-scheduler views ([`learner::merge_estimates_into`], reused buffers); never on the decision path |
//!
//! `rosella hotpath --json BENCH_hotpath.json` ([`hotpath`]) measures all
//! of this per cluster size, so an accidental O(n) term in the decision
//! path shows up as a slope in the tracked numbers.
//!
//! ## Quick start
//!
//! ```
//! use rosella::simulator::{run, SimConfig};
//! let mut cfg = SimConfig::synthetic_default();
//! cfg.duration = 30.0;
//! cfg.warmup = 5.0;
//! let result = run(cfg);
//! assert!(result.responses.count() > 0);
//! ```
//!
//! ## Parallel serving
//!
//! ```
//! use rosella::plane::{run_plane, DispatchMode, PlaneConfig};
//! let cfg = PlaneConfig {
//!     frontends: 2,
//!     duration: 0.5,
//!     mode: DispatchMode::DecideOnly,
//!     max_decisions: Some(1_000),
//!     ..PlaneConfig::default()
//! };
//! let report = run_plane(cfg).unwrap();
//! assert_eq!(report.decisions, 2_000);
//! ```

pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hotpath;
pub mod learner;
pub mod metrics;
pub mod plane;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod stats;
pub mod testkit;
pub mod types;
pub mod workload;
