//! Hot-path measurement harness: the per-decision and per-event costs that
//! the paper's "millions of tasks per second" claim rests on (§3).
//!
//! Rosella's design argument is that every scheduling decision "only
//! performs simple operations" — constant work, independent of cluster
//! size. This module measures exactly that, at several cluster sizes, so a
//! hidden O(n) term shows up as a slope instead of hiding inside a single
//! data point:
//!
//! * **decision latency** — ns per `Policy::schedule_job` against a
//!   [`LocalView`], per policy and per cluster size (flat ⇒ O(1));
//! * **alias rebuild** — the estimate-publish cost, comparing the in-place
//!   [`AliasTable::rebuild`] against a fresh allocation (publish is O(n) by
//!   design; the rebuild removes the allocator from it);
//! * **simulator throughput** — events/sec of the full discrete-event loop
//!   (arrival → decision → completion), the experiment-turnaround bound;
//! * **plane throughput** — decisions/sec of the sharded plane in
//!   decide-only mode (tasks/sec of the scheduling layer proper).
//!
//! Shared by the `rosella hotpath` subcommand (which emits
//! `BENCH_hotpath.json`, tracked across PRs alongside `BENCH_plane.json`)
//! and `benches/bench_hotpath.rs`, so the tracked trajectory and the
//! interactive bench measure the same code.

use crate::cluster::{SpeedProfile, Volatility};
use crate::config::Json;
use crate::learner::LearnerConfig;
use crate::plane::{run_plane, CachePadded, DispatchMode, LearnerMode, PinMode, PlaneConfig};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig};
use crate::stats::{AliasTable, Rng};
use crate::types::{JobPlacement, JobSpec, LocalView};
use crate::workload::WorkloadKind;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;
use std::time::Instant;

/// Run `f(reps)` once for warmup and `runs` measured times; return the best
/// run's nanoseconds per repetition (best-of filters scheduler noise).
pub fn best_ns_per_op(reps: u64, runs: usize, mut f: impl FnMut(u64)) -> f64 {
    f(reps / 10 + 1); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..runs.max(1) {
        let start = Instant::now();
        f(reps);
        let elapsed = start.elapsed().as_nanos() as f64;
        best = best.min(elapsed / reps as f64);
    }
    best
}

/// The policies whose decision latency is tracked.
pub fn tracked_policies() -> Vec<(&'static str, PolicyKind)> {
    vec![
        ("uniform", PolicyKind::Uniform),
        ("pot2", PolicyKind::PoT { d: 2 }),
        ("pss", PolicyKind::Pss),
        ("ppot-sq2", PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }),
        ("ppot-ll2", PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false }),
        ("halo", PolicyKind::Halo),
    ]
}

/// One decision-latency sample.
#[derive(Debug, Clone)]
pub struct DecisionPoint {
    /// Policy label.
    pub policy: String,
    /// Cluster size the view exposed.
    pub n: usize,
    /// Best-run nanoseconds per scheduling decision.
    pub ns_per_op: f64,
}

/// Synthetic fixture for a decision bench at cluster size `n`.
fn fixture(n: usize) -> (Vec<f64>, Vec<usize>) {
    let speeds: Vec<f64> = (0..n).map(|i| 0.1 + (i % 9) as f64 * 0.1).collect();
    let qlen: Vec<usize> = (0..n).map(|i| i % 7).collect();
    (speeds, qlen)
}

/// Measure per-decision latency for every tracked policy at every cluster
/// size in `sizes`. O(1) decisions show up as a flat row across sizes.
pub fn decision_bench(sizes: &[usize], reps: u64, runs: usize) -> Vec<DecisionPoint> {
    let mut out = Vec::new();
    let mut rng = Rng::new(1);
    let job = JobSpec::single(0.1);
    for &n in sizes {
        let (speeds, qlen) = fixture(n);
        let table = AliasTable::new(&speeds);
        for (label, kind) in tracked_policies() {
            let mut policy = kind.build(n);
            policy.on_estimates(&speeds, 100.0);
            let view = LocalView {
                queue_len: &qlen,
                mu_hat: &speeds,
                sampler: &table,
                lambda_hat: 100.0,
            };
            let mut sink = 0usize;
            let ns = best_ns_per_op(reps, runs, |reps| {
                for _ in 0..reps {
                    if let JobPlacement::Single(w) = policy.schedule_job(&job, &view, &mut rng)
                    {
                        sink ^= w;
                    }
                }
            });
            std::hint::black_box(sink);
            out.push(DecisionPoint { policy: label.to_string(), n, ns_per_op: ns });
        }
    }
    out
}

/// One estimate-publish (alias rebuild) sample.
#[derive(Debug, Clone)]
pub struct RebuildPoint {
    /// Cluster size.
    pub n: usize,
    /// ns per in-place [`AliasTable::rebuild`] (the publish path).
    pub rebuild_ns: f64,
    /// ns per fresh [`AliasTable::new`] (the pre-refactor publish path).
    pub fresh_ns: f64,
}

/// Measure the estimate-publish cost: in-place rebuild vs fresh build.
pub fn alias_rebuild_bench(sizes: &[usize], reps: u64, runs: usize) -> Vec<RebuildPoint> {
    sizes
        .iter()
        .map(|&n| {
            let (speeds, _) = fixture(n);
            let mut table = AliasTable::new(&speeds);
            let rebuild_ns = best_ns_per_op(reps, runs, |reps| {
                for _ in 0..reps {
                    table.rebuild(&speeds);
                }
            });
            std::hint::black_box(&table);
            let fresh_ns = best_ns_per_op(reps, runs, |reps| {
                for _ in 0..reps {
                    std::hint::black_box(AliasTable::new(&speeds));
                }
            });
            RebuildPoint { n, rebuild_ns, fresh_ns }
        })
        .collect()
}

/// One simulator-throughput sample.
#[derive(Debug, Clone)]
pub struct SimPoint {
    /// Cluster size.
    pub n: usize,
    /// Real tasks completed in the run.
    pub tasks: u64,
    /// Processed events per wall-clock second (arrival + completion per
    /// task).
    pub events_per_sec: f64,
    /// Wall-clock seconds the run took.
    pub wall_secs: f64,
}

/// Measure full DES-loop throughput at each cluster size: homogeneous
/// speeds, oracle learner (isolates the event loop from learning noise),
/// load 0.8 synthetic single-task jobs.
pub fn sim_bench(sizes: &[usize], duration: f64) -> Vec<SimPoint> {
    sizes
        .iter()
        .map(|&n| {
            let cfg = SimConfig {
                seed: 3,
                duration,
                warmup: 0.0,
                speeds: SpeedProfile::Homogeneous { n, speed: 1.0 },
                volatility: Volatility::Static,
                workload: WorkloadKind::Synthetic,
                load: 0.8,
                policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
                learner: LearnerConfig::oracle(),
                queue_sample: None,
                timeline: None,
            };
            let start = Instant::now();
            let r = sim_run(cfg);
            let wall = start.elapsed().as_secs_f64();
            let events = (r.completed_real * 2) as f64;
            SimPoint {
                n,
                tasks: r.completed_real,
                events_per_sec: events / wall,
                wall_secs: wall,
            }
        })
        .collect()
}

/// Decision cost with and without the live registry's per-decision writes
/// — the observability overhead the `/metrics` endpoint costs the hot path.
#[derive(Debug, Clone)]
pub struct OverheadPoint {
    /// Cluster size the view exposed.
    pub n: usize,
    /// ns per decision, bare loop (registry compiled in but untouched).
    pub plain_ns: f64,
    /// ns per decision plus the plane's per-decision registry writes
    /// (decision counter + chosen-queue-length histogram sample).
    pub instrumented_ns: f64,
    /// ns per decision with registry writes *and* the lifecycle-trace
    /// sampling check at 1/1024 (the tracing-on, task-unsampled fast
    /// path: one hash + compare, no clock read, no allocation).
    pub traced_ns: f64,
}

impl OverheadPoint {
    /// Within-run instrumented/plain ratio (the CI gate holds it ≤ 1.10).
    pub fn ratio(&self) -> f64 {
        if self.plain_ns > 0.0 {
            self.instrumented_ns / self.plain_ns
        } else {
            f64::INFINITY
        }
    }

    /// Within-run traced/plain ratio (the CI gate holds it ≤ 1.10 too:
    /// sampling at 1/1024 must be invisible on the decision path).
    pub fn traced_ratio(&self) -> f64 {
        if self.plain_ns > 0.0 {
            self.traced_ns / self.plain_ns
        } else {
            f64::INFINITY
        }
    }
}

/// Measure the registry's hot-path overhead: the same ppot decision loop,
/// bare vs with the two relaxed-atomic writes the plane performs per
/// decision. Both loops run in one process back to back, so the ratio is
/// machine-independent.
pub fn metrics_overhead_bench(n: usize, reps: u64, runs: usize) -> OverheadPoint {
    let (speeds, qlen) = fixture(n);
    let table = AliasTable::new(&speeds);
    let mut rng = Rng::new(1);
    let job = JobSpec::single(0.1);
    let mut policy = PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }.build(n);
    policy.on_estimates(&speeds, 100.0);
    let view = LocalView { queue_len: &qlen, mu_hat: &speeds, sampler: &table, lambda_hat: 100.0 };
    let obs = crate::obs::Registry::new(1, n);
    let mut sink = 0usize;
    let plain_ns = best_ns_per_op(reps, runs, |reps| {
        for _ in 0..reps {
            if let JobPlacement::Single(w) = policy.schedule_job(&job, &view, &mut rng) {
                sink ^= w;
            }
        }
    });
    let slot = obs.shard(0);
    let instrumented_ns = best_ns_per_op(reps, runs, |reps| {
        for _ in 0..reps {
            if let JobPlacement::Single(w) = policy.schedule_job(&job, &view, &mut rng) {
                sink ^= w;
                slot.decisions.inc();
                slot.queue_len.record(qlen[w] as u64);
            }
        }
    });
    // Registry writes plus the tracing sampling gate at 1/1024, the same
    // check the frontend dispatch loop runs per decision when `--trace-
    // sample` is on. Unsampled tasks (the 1023/1024 common case) must pay
    // one hash + compare, nothing more.
    let mut task_id = 0u64;
    let mut origin_sink = 0u64;
    let traced_ns = best_ns_per_op(reps, runs, |reps| {
        for _ in 0..reps {
            if let JobPlacement::Single(w) = policy.schedule_job(&job, &view, &mut rng) {
                sink ^= w;
                slot.decisions.inc();
                slot.queue_len.record(qlen[w] as u64);
                task_id = task_id.wrapping_add(1);
                if crate::obs::trace::sampled(task_id, 1024) {
                    origin_sink ^= crate::obs::trace::now_ns();
                }
            }
        }
    });
    std::hint::black_box(sink);
    std::hint::black_box(origin_sink);
    std::hint::black_box(&obs);
    OverheadPoint { n, plain_ns, instrumented_ns, traced_ns }
}

/// One plane-throughput sample.
#[derive(Debug, Clone)]
pub struct PlanePoint {
    /// Frontend shard count.
    pub frontends: usize,
    /// Scheduling decisions made (each places one task).
    pub decisions: u64,
    /// Aggregate tasks scheduled per second.
    pub tasks_per_sec: f64,
}

/// Measure raw plane scheduling throughput (decide-only, budgeted).
/// `learners` selects the ownership mode so the per-shard consensus
/// plumbing's (intended: zero) impact on raw decision throughput is
/// measurable.
pub fn plane_bench(
    frontend_counts: &[usize],
    workers: usize,
    decisions_per_shard: u64,
    learners: LearnerMode,
) -> Result<Vec<PlanePoint>, String> {
    let speeds = bench_speeds(workers);
    let mut out = Vec::new();
    for &k in frontend_counts {
        let cfg = PlaneConfig {
            speeds: speeds.clone(),
            frontends: k,
            mode: DispatchMode::DecideOnly,
            max_decisions: Some(decisions_per_shard),
            fake_jobs: false,
            duration: 60.0, // budget, not deadline: shards stop at max_decisions
            learners,
            ..PlaneConfig::default()
        };
        let r = run_plane(cfg)?;
        out.push(PlanePoint {
            frontends: k,
            decisions: r.decisions,
            tasks_per_sec: r.decisions_per_sec,
        });
    }
    Ok(out)
}

/// The heterogeneous speed mix every plane-throughput bench runs on.
fn bench_speeds(workers: usize) -> Vec<f64> {
    let base = [2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25];
    (0..workers.max(1)).map(|i| base[i % base.len()]).collect()
}

/// The topology section of `BENCH_hotpath.json`: false-sharing cost of the
/// per-worker probe slots (padded vs packed) and decide-only plane
/// throughput pinned vs unpinned. Both pairs run back to back in one
/// process, so the tracked quantities are within-run ratios, not absolute
/// machine-dependent numbers.
#[derive(Debug, Clone)]
pub struct TopologyPoint {
    /// Contending threads in the probe-hammer loops.
    pub threads: usize,
    /// ns per `fetch_add` with every slot packed into one contiguous
    /// array (neighbouring slots share cache lines).
    pub unpadded_ns: f64,
    /// ns per `fetch_add` with each slot in its own [`CachePadded`] line.
    pub padded_ns: f64,
    /// Decide-only plane throughput with `--pin none` (today's default).
    pub unpinned_tasks_per_sec: f64,
    /// Decide-only plane throughput with `--pin cores`.
    pub pinned_tasks_per_sec: f64,
}

impl TopologyPoint {
    /// Within-run unpadded/padded ratio: ≥ 1.0 means padding pays (the CI
    /// gate holds it there — padding must never make probes slower).
    pub fn padded_ratio(&self) -> f64 {
        if self.padded_ns > 0.0 {
            self.unpadded_ns / self.padded_ns
        } else {
            f64::INFINITY
        }
    }

    /// Within-run pinned/unpinned throughput ratio (the CI gate holds it
    /// ≥ 0.9 — pinning must not cost the plane real throughput even on
    /// runners where it cannot help).
    pub fn pinned_ratio(&self) -> f64 {
        if self.unpinned_tasks_per_sec > 0.0 {
            self.pinned_tasks_per_sec / self.unpinned_tasks_per_sec
        } else {
            f64::INFINITY
        }
    }
}

/// One contended-probe round: each thread hammers `fetch_add` on its own
/// slot behind a barrier, so the only cross-thread traffic is whatever the
/// slot *layout* forces. Returns the slowest thread's ns/op — false
/// sharing shows up as every thread dragging, so the max is the honest
/// number.
fn hammer_ns(slots: &[&AtomicUsize], reps: u64) -> f64 {
    let barrier = Barrier::new(slots.len());
    let mut worst = 0.0f64;
    std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .iter()
            .map(|&slot| {
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    let start = Instant::now();
                    for _ in 0..reps {
                        slot.fetch_add(1, Ordering::Relaxed);
                    }
                    start.elapsed().as_nanos() as f64 / reps as f64
                })
            })
            .collect();
        for h in handles {
            worst = worst.max(h.join().expect("hammer thread panicked"));
        }
    });
    worst
}

/// Measure the probe-slot layouts against each other: `threads` writers,
/// each owning one slot, packed vs cache-line padded. Best-of `runs` per
/// layout (same filter [`best_ns_per_op`] applies to the serial benches).
pub fn false_sharing_bench(threads: usize, reps: u64, runs: usize) -> (f64, f64) {
    let threads = threads.max(2);
    let unpadded: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
    let padded: Vec<CachePadded<AtomicUsize>> =
        (0..threads).map(|_| CachePadded::new(AtomicUsize::new(0))).collect();
    let unpadded_refs: Vec<&AtomicUsize> = unpadded.iter().collect();
    let padded_refs: Vec<&AtomicUsize> = padded.iter().map(|p| &**p).collect();
    hammer_ns(&unpadded_refs, reps / 10 + 1); // warmup
    let mut unpadded_ns = f64::INFINITY;
    let mut padded_ns = f64::INFINITY;
    for _ in 0..runs.max(1) {
        unpadded_ns = unpadded_ns.min(hammer_ns(&unpadded_refs, reps));
        padded_ns = padded_ns.min(hammer_ns(&padded_refs, reps));
    }
    (unpadded_ns, padded_ns)
}

/// The full topology section: the false-sharing pair plus two decide-only
/// plane runs (pin none, then pin cores) on the same budget.
pub fn topology_bench(
    workers: usize,
    decisions_per_shard: u64,
    learners: LearnerMode,
    reps: u64,
    runs: usize,
) -> Result<TopologyPoint, String> {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
    let (unpadded_ns, padded_ns) = false_sharing_bench(threads, reps, runs);
    let speeds = bench_speeds(workers);
    let mut throughput = |pin: PinMode| -> Result<f64, String> {
        let cfg = PlaneConfig {
            speeds: speeds.clone(),
            frontends: 2,
            mode: DispatchMode::DecideOnly,
            max_decisions: Some(decisions_per_shard),
            fake_jobs: false,
            duration: 60.0, // budget, not deadline: shards stop at max_decisions
            learners,
            pin,
            ..PlaneConfig::default()
        };
        Ok(run_plane(cfg)?.decisions_per_sec)
    };
    let unpinned_tasks_per_sec = throughput(PinMode::None)?;
    let pinned_tasks_per_sec = throughput(PinMode::Cores)?;
    Ok(TopologyPoint {
        threads,
        unpadded_ns,
        padded_ns,
        unpinned_tasks_per_sec,
        pinned_tasks_per_sec,
    })
}

/// Everything one `rosella hotpath` invocation measured.
#[derive(Debug, Clone)]
pub struct HotpathReport {
    pub sizes: Vec<usize>,
    pub decisions: Vec<DecisionPoint>,
    pub rebuilds: Vec<RebuildPoint>,
    pub sims: Vec<SimPoint>,
    pub planes: Vec<PlanePoint>,
    pub metrics_overhead: Option<OverheadPoint>,
    pub topology: Option<TopologyPoint>,
}

impl HotpathReport {
    /// Worst max/min decision-latency ratio across sizes, per policy —
    /// ~1.0 means the decision cost is flat in cluster size (no O(n)
    /// term). Returns `(policy, ratio)` of the worst offender.
    pub fn worst_flatness(&self) -> Option<(String, f64)> {
        let mut worst: Option<(String, f64)> = None;
        for (label, _) in tracked_policies() {
            let ns: Vec<f64> = self
                .decisions
                .iter()
                .filter(|d| d.policy == label)
                .map(|d| d.ns_per_op)
                .collect();
            if ns.len() < 2 {
                continue;
            }
            let lo = ns.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = ns.iter().cloned().fold(0.0f64, f64::max);
            if lo > 0.0 {
                let ratio = hi / lo;
                match &worst {
                    Some((_, w)) if ratio <= *w => {}
                    _ => worst = Some((label.to_string(), ratio)),
                }
            }
        }
        worst
    }

    /// Render a human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("-- scheduling decision latency (ns/op) --\n");
        out.push_str(&format!("{:<12}", "policy"));
        for &n in &self.sizes {
            out.push_str(&format!(" {:>10}", format!("n={n}")));
        }
        out.push('\n');
        for (label, _) in tracked_policies() {
            out.push_str(&format!("{label:<12}"));
            for &n in &self.sizes {
                match self.decisions.iter().find(|d| d.policy == label && d.n == n) {
                    Some(d) => out.push_str(&format!(" {:>10.1}", d.ns_per_op)),
                    None => out.push_str(&format!(" {:>10}", "-")),
                }
            }
            out.push('\n');
        }
        if let Some((policy, ratio)) = self.worst_flatness() {
            out.push_str(&format!(
                "worst decision flatness (max/min across sizes): {ratio:.2}x ({policy})\n"
            ));
        }
        out.push_str("-- estimate publish: alias table (ns/op) --\n");
        for r in &self.rebuilds {
            out.push_str(&format!(
                "n={:<5} rebuild {:>9.1}  fresh-alloc {:>9.1}\n",
                r.n, r.rebuild_ns, r.fresh_ns
            ));
        }
        out.push_str("-- simulator event throughput --\n");
        for s in &self.sims {
            out.push_str(&format!(
                "n={:<5} {:>9} tasks  {:>13.0} events/s  ({:.2}s wall)\n",
                s.n, s.tasks, s.events_per_sec, s.wall_secs
            ));
        }
        if !self.planes.is_empty() {
            out.push_str("-- plane scheduling throughput (decide-only) --\n");
            for p in &self.planes {
                out.push_str(&format!(
                    "frontends={:<3} {:>9} decisions  {:>13.0} tasks/s\n",
                    p.frontends, p.decisions, p.tasks_per_sec
                ));
            }
        }
        if let Some(o) = &self.metrics_overhead {
            out.push_str("-- metrics registry overhead (ppot decision) --\n");
            out.push_str(&format!(
                "n={:<5} plain {:>8.1} ns  instrumented {:>8.1} ns  ratio {:.3}x\n",
                o.n,
                o.plain_ns,
                o.instrumented_ns,
                o.ratio()
            ));
            out.push_str(&format!(
                "n={:<5} traced(1/1024) {:>8.1} ns  ratio {:.3}x\n",
                o.n,
                o.traced_ns,
                o.traced_ratio()
            ));
        }
        if let Some(t) = &self.topology {
            out.push_str("-- topology: false sharing & pinning --\n");
            out.push_str(&format!(
                "probe hammer ({} threads): packed {:>8.1} ns  padded {:>8.1} ns  \
                 ratio {:.3}x\n",
                t.threads,
                t.unpadded_ns,
                t.padded_ns,
                t.padded_ratio()
            ));
            out.push_str(&format!(
                "plane decide-only: unpinned {:>10.0} tasks/s  pinned {:>10.0} tasks/s  \
                 ratio {:.3}x\n",
                t.unpinned_tasks_per_sec,
                t.pinned_tasks_per_sec,
                t.pinned_ratio()
            ));
        }
        out
    }

    /// Machine-readable results (`BENCH_hotpath.json`) so the perf
    /// trajectory is tracked across PRs.
    pub fn to_json(&self, seed_note: &str) -> Json {
        let decisions: Vec<Json> = self
            .decisions
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("policy".into(), Json::Str(d.policy.clone()));
                m.insert("n".into(), Json::Num(d.n as f64));
                m.insert("ns_per_op".into(), Json::Num(d.ns_per_op));
                Json::Obj(m)
            })
            .collect();
        let rebuilds: Vec<Json> = self
            .rebuilds
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("n".into(), Json::Num(r.n as f64));
                m.insert("rebuild_ns".into(), Json::Num(r.rebuild_ns));
                m.insert("fresh_ns".into(), Json::Num(r.fresh_ns));
                Json::Obj(m)
            })
            .collect();
        let sims: Vec<Json> = self
            .sims
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("n".into(), Json::Num(s.n as f64));
                m.insert("tasks".into(), Json::Num(s.tasks as f64));
                m.insert("events_per_sec".into(), Json::Num(s.events_per_sec.round()));
                Json::Obj(m)
            })
            .collect();
        let planes: Vec<Json> = self
            .planes
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("frontends".into(), Json::Num(p.frontends as f64));
                m.insert("decisions".into(), Json::Num(p.decisions as f64));
                m.insert("tasks_per_sec".into(), Json::Num(p.tasks_per_sec.round()));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("bench".into(), Json::Str("hotpath".into()));
        top.insert("note".into(), Json::Str(seed_note.into()));
        top.insert(
            "sizes".into(),
            Json::Arr(self.sizes.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        if let Some((policy, ratio)) = self.worst_flatness() {
            let mut m = BTreeMap::new();
            m.insert("policy".into(), Json::Str(policy));
            m.insert("max_over_min".into(), Json::Num((ratio * 1000.0).round() / 1000.0));
            top.insert("worst_decision_flatness".into(), Json::Obj(m));
        }
        top.insert("decision".into(), Json::Arr(decisions));
        top.insert("alias_rebuild".into(), Json::Arr(rebuilds));
        top.insert("sim".into(), Json::Arr(sims));
        top.insert("plane".into(), Json::Arr(planes));
        if let Some(o) = &self.metrics_overhead {
            let mut m = BTreeMap::new();
            m.insert("n".into(), Json::Num(o.n as f64));
            m.insert("plain_ns".into(), Json::Num((o.plain_ns * 10.0).round() / 10.0));
            m.insert(
                "instrumented_ns".into(),
                Json::Num((o.instrumented_ns * 10.0).round() / 10.0),
            );
            m.insert("ratio".into(), Json::Num((o.ratio() * 1000.0).round() / 1000.0));
            m.insert("traced_ns".into(), Json::Num((o.traced_ns * 10.0).round() / 10.0));
            m.insert(
                "traced_ratio".into(),
                Json::Num((o.traced_ratio() * 1000.0).round() / 1000.0),
            );
            top.insert("metrics_overhead".into(), Json::Obj(m));
        }
        if let Some(t) = &self.topology {
            let mut m = BTreeMap::new();
            m.insert("threads".into(), Json::Num(t.threads as f64));
            m.insert("unpadded_ns".into(), Json::Num((t.unpadded_ns * 10.0).round() / 10.0));
            m.insert("padded_ns".into(), Json::Num((t.padded_ns * 10.0).round() / 10.0));
            let round3 = |x: f64| (x * 1000.0).round() / 1000.0;
            m.insert("padded_ratio".into(), Json::Num(round3(t.padded_ratio())));
            m.insert("unpinned_tasks_per_sec".into(), Json::Num(t.unpinned_tasks_per_sec.round()));
            m.insert("pinned_tasks_per_sec".into(), Json::Num(t.pinned_tasks_per_sec.round()));
            m.insert("pinned_ratio".into(), Json::Num(round3(t.pinned_ratio())));
            top.insert("topology".into(), Json::Obj(m));
        }
        Json::Obj(top)
    }
}

/// Parse a comma-separated list of positive integers.
fn parse_csv_usize(s: &str, what: &str) -> Result<Vec<usize>, String> {
    let v: Vec<usize> = s
        .split(',')
        .map(|t| t.trim().parse::<usize>().map_err(|e| format!("bad {what} '{t}': {e}")))
        .collect::<Result<_, _>>()?;
    if v.is_empty() || v.contains(&0) {
        return Err(format!("{what} must be a non-empty list of positive integers"));
    }
    Ok(v)
}

/// CLI adapter for `rosella hotpath`.
pub fn hotpath_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let quick = p.flag("quick");
    let sizes = parse_csv_usize(p.get("sizes").unwrap_or("30,256"), "cluster size")?;
    let frontend_counts = parse_csv_usize(p.get("frontends").unwrap_or("1,2,4"), "frontend count")?;
    let reps: u64 = p.parse_as("reps")?.unwrap_or(if quick { 50_000 } else { 1_000_000 });
    let runs: usize = p.parse_as("runs")?.unwrap_or(3);
    let sim_duration: f64 = p.parse_as("sim-duration")?.unwrap_or(if quick { 5.0 } else { 60.0 });
    let plane_decisions: u64 =
        p.parse_as("plane-decisions")?.unwrap_or(if quick { 20_000 } else { 500_000 });
    let workers: usize = p.parse_as("workers")?.unwrap_or(8);
    let learners = LearnerMode::parse(p.get("learners").unwrap_or("shared"))?;

    let report = HotpathReport {
        decisions: decision_bench(&sizes, reps, runs),
        rebuilds: alias_rebuild_bench(&sizes, (reps / 10).max(1), runs),
        sims: sim_bench(&sizes, sim_duration),
        planes: if p.flag("no-plane") {
            Vec::new()
        } else {
            plane_bench(&frontend_counts, workers, plane_decisions, learners)?
        },
        metrics_overhead: Some(metrics_overhead_bench(
            sizes.iter().copied().max().unwrap_or(256),
            reps,
            runs,
        )),
        topology: if p.flag("no-plane") {
            None
        } else {
            Some(topology_bench(workers, plane_decisions, learners, reps, runs)?)
        },
        sizes,
    };

    let mut out = report.render();
    if let Some(path) = p.get("json") {
        let doc = crate::config::to_string(&report.to_json(if quick { "quick" } else { "full" }));
        std::fs::write(path, doc).map_err(|e| format!("write {path}: {e}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> HotpathReport {
        let sizes = vec![4, 8];
        HotpathReport {
            decisions: decision_bench(&sizes, 2_000, 1),
            rebuilds: alias_rebuild_bench(&sizes, 500, 1),
            sims: sim_bench(&[4], 2.0),
            planes: Vec::new(),
            metrics_overhead: Some(metrics_overhead_bench(8, 2_000, 1)),
            topology: None,
            sizes,
        }
    }

    #[test]
    fn report_covers_every_policy_and_size() {
        let r = tiny_report();
        assert_eq!(r.decisions.len(), tracked_policies().len() * 2);
        assert!(r.decisions.iter().all(|d| d.ns_per_op > 0.0 && d.ns_per_op.is_finite()));
        assert!(r.sims[0].tasks > 0);
        assert!(r.sims[0].events_per_sec > 0.0);
        let (_, ratio) = r.worst_flatness().expect("two sizes -> flatness defined");
        assert!(ratio >= 1.0);
    }

    #[test]
    fn json_round_trips() {
        let r = tiny_report();
        let doc = crate::config::to_string(&r.to_json("test"));
        let back = crate::config::parse(&doc).expect("hotpath json must parse");
        for key in ["bench", "decision", "alias_rebuild", "sim", "plane", "sizes"] {
            assert!(back.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(back.get("bench").and_then(|j| j.as_str()), Some("hotpath"));
    }

    #[test]
    fn render_mentions_all_sections() {
        let r = tiny_report();
        let s = r.render();
        assert!(s.contains("decision latency"));
        assert!(s.contains("alias table"));
        assert!(s.contains("event throughput"));
    }

    #[test]
    fn metrics_overhead_measures_both_loops() {
        let o = metrics_overhead_bench(16, 2_000, 1);
        assert!(o.plain_ns > 0.0 && o.plain_ns.is_finite());
        assert!(o.instrumented_ns > 0.0 && o.instrumented_ns.is_finite());
        assert!(o.ratio() > 0.0 && o.ratio().is_finite());
        assert!(o.traced_ns > 0.0 && o.traced_ns.is_finite());
        assert!(o.traced_ratio() > 0.0 && o.traced_ratio().is_finite());
    }

    #[test]
    fn overhead_lands_in_the_tracked_json() {
        let r = tiny_report();
        let doc = crate::config::to_string(&r.to_json("test"));
        let back = crate::config::parse(&doc).expect("hotpath json must parse");
        let o = back.get("metrics_overhead").expect("metrics_overhead key");
        for key in ["plain_ns", "instrumented_ns", "ratio", "traced_ns", "traced_ratio"] {
            assert!(
                o.get(key).and_then(|j| j.as_f64()).is_some_and(|v| v > 0.0),
                "missing/invalid {key}"
            );
        }
    }

    #[test]
    fn false_sharing_bench_measures_both_layouts() {
        let (unpadded_ns, padded_ns) = false_sharing_bench(2, 2_000, 1);
        assert!(unpadded_ns > 0.0 && unpadded_ns.is_finite());
        assert!(padded_ns > 0.0 && padded_ns.is_finite());
    }

    #[test]
    fn topology_bench_produces_finite_ratios() {
        let t = topology_bench(4, 500, LearnerMode::Shared, 2_000, 1).expect("topology bench");
        assert!(t.threads >= 2);
        assert!(t.padded_ratio() > 0.0 && t.padded_ratio().is_finite());
        assert!(t.pinned_ratio() > 0.0 && t.pinned_ratio().is_finite());
        assert!(t.unpinned_tasks_per_sec > 0.0);
        assert!(t.pinned_tasks_per_sec > 0.0);
    }

    #[test]
    fn topology_lands_in_the_tracked_json() {
        let mut r = tiny_report();
        r.topology = Some(TopologyPoint {
            threads: 4,
            unpadded_ns: 41.7,
            padded_ns: 12.3,
            unpinned_tasks_per_sec: 900_000.0,
            pinned_tasks_per_sec: 910_000.0,
        });
        let doc = crate::config::to_string(&r.to_json("test"));
        let back = crate::config::parse(&doc).expect("hotpath json must parse");
        let t = back.get("topology").expect("topology key");
        for key in ["threads", "unpadded_ns", "padded_ns", "padded_ratio", "pinned_ratio"] {
            assert!(
                t.get(key).and_then(|j| j.as_f64()).is_some_and(|v| v > 0.0),
                "missing/invalid {key}"
            );
        }
        // The ratios are the CI-gated quantities; spot-check the rounding.
        let padded = t.get("padded_ratio").and_then(|j| j.as_f64()).unwrap();
        assert!((padded - 3.39).abs() < 0.01, "padded_ratio {padded}");
        let pinned = t.get("pinned_ratio").and_then(|j| j.as_f64()).unwrap();
        assert!((pinned - 1.011).abs() < 1e-9, "pinned_ratio {pinned}");
    }

    #[test]
    fn csv_parser_rejects_garbage() {
        assert!(parse_csv_usize("30,256", "x").is_ok());
        assert!(parse_csv_usize("30,abc", "x").is_err());
        assert!(parse_csv_usize("0", "x").is_err());
        assert!(parse_csv_usize("", "x").is_err());
    }
}
