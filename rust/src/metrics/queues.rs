//! Queue-length statistics.
//!
//! Figure 13 plots the *distribution* of queue lengths per worker under
//! SQ(2) vs LL(2); the theory section tracks the *maximum* queue length
//! (Results 1 and the O(log log n) bound). `QueueStats` samples both from
//! periodic snapshots supplied by the engine.

use crate::stats::IntHistogram;

/// Accumulates queue-length snapshots per worker.
#[derive(Debug, Clone)]
pub struct QueueStats {
    per_worker: Vec<IntHistogram>,
    max_hist: IntHistogram,
    snapshots: u64,
    max_ever: usize,
}

impl QueueStats {
    /// Stats for `n` workers.
    pub fn new(n: usize) -> Self {
        Self {
            per_worker: (0..n).map(|_| IntHistogram::new()).collect(),
            max_hist: IntHistogram::new(),
            snapshots: 0,
            max_ever: 0,
        }
    }

    /// Record one snapshot of all queue lengths.
    pub fn record(&mut self, queue_lens: &[usize]) {
        debug_assert_eq!(queue_lens.len(), self.per_worker.len());
        let mut max = 0usize;
        for (h, &q) in self.per_worker.iter_mut().zip(queue_lens) {
            h.record(q);
            max = max.max(q);
        }
        self.max_hist.record(max);
        self.max_ever = self.max_ever.max(max);
        self.snapshots += 1;
    }

    /// Number of snapshots taken.
    pub fn snapshots(&self) -> u64 {
        self.snapshots
    }

    /// Queue-length PMF of worker `w` (Figure 13's per-worker histogram).
    pub fn pmf(&self, w: usize) -> Vec<f64> {
        self.per_worker[w].pmf()
    }

    /// Mean queue length of worker `w`.
    pub fn mean_len(&self, w: usize) -> f64 {
        self.per_worker[w].mean()
    }

    /// Largest queue length ever observed on worker `w`.
    pub fn max_len(&self, w: usize) -> usize {
        self.per_worker[w].max()
    }

    /// Mean of the per-snapshot maximum queue length (the quantity bounded
    /// by O(log log n) in Lemma 4).
    pub fn mean_max(&self) -> f64 {
        self.max_hist.mean()
    }

    /// Largest queue length across all snapshots and workers.
    pub fn max_ever(&self) -> usize {
        self.max_ever
    }

    /// Fraction of snapshots in which worker `w` had ≥ `k` entries.
    pub fn tail(&self, w: usize, k: usize) -> f64 {
        self.per_worker[w].tail(k)
    }

    /// Absorb another collector's snapshots over the *same* worker set
    /// (shards sampling the shared pool at different instants). Snapshot
    /// populations concatenate: per-worker histograms and the per-snapshot
    /// maximum distribution add count-for-count, so no snapshot is ever
    /// double counted.
    pub fn merge(&mut self, other: &QueueStats) {
        assert_eq!(
            self.per_worker.len(),
            other.per_worker.len(),
            "cannot merge queue stats over different worker counts"
        );
        for (a, b) in self.per_worker.iter_mut().zip(other.per_worker.iter()) {
            a.merge(b);
        }
        self.max_hist.merge(&other.max_hist);
        self.snapshots += other.snapshots;
        self.max_ever = self.max_ever.max(other.max_ever);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_snapshots() {
        let mut s = QueueStats::new(3);
        s.record(&[1, 2, 3]);
        s.record(&[3, 2, 1]);
        assert_eq!(s.snapshots(), 2);
        assert!((s.mean_len(0) - 2.0).abs() < 1e-12);
        assert!((s.mean_len(2) - 2.0).abs() < 1e-12);
        assert_eq!(s.max_ever(), 3);
        assert!((s.mean_max() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pmf_per_worker() {
        let mut s = QueueStats::new(1);
        for q in [0, 0, 1, 1, 1, 2] {
            s.record(&[q]);
        }
        let p = s.pmf(0);
        assert!((p[0] - 2.0 / 6.0).abs() < 1e-12);
        assert!((p[1] - 3.0 / 6.0).abs() < 1e-12);
        assert!((p[2] - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn tail_and_max() {
        let mut s = QueueStats::new(2);
        for q in 0..10 {
            s.record(&[q, 0]);
        }
        assert!((s.tail(0, 5) - 0.5).abs() < 1e-12);
        assert_eq!(s.max_len(0), 9);
        assert_eq!(s.max_len(1), 0);
    }

    #[test]
    fn merge_concatenates_snapshot_populations() {
        let mut a = QueueStats::new(2);
        let mut b = QueueStats::new(2);
        a.record(&[1, 4]);
        a.record(&[2, 0]);
        b.record(&[7, 1]);
        a.merge(&b);
        assert_eq!(a.snapshots(), 3);
        assert_eq!(a.max_ever(), 7);
        assert!((a.mean_len(0) - 10.0 / 3.0).abs() < 1e-12);
        assert!((a.mean_max() - (4.0 + 2.0 + 7.0) / 3.0).abs() < 1e-12);
        // Per-worker PMFs renormalize over the combined population.
        let p = a.pmf(1);
        assert!((p[0] - 1.0 / 3.0).abs() < 1e-12);
        assert!((p[1] - 1.0 / 3.0).abs() < 1e-12);
        assert!((p[4] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_worker_counts() {
        let mut a = QueueStats::new(2);
        let b = QueueStats::new(3);
        a.merge(&b);
    }
}
