//! Job response-time recording.
//!
//! The response time of a job is "the time between the job arrives at the
//! scheduler and the time when the last task in the job is executed" (§6.1).
//! The recorder keeps both the raw series (for Figure 10a's response-vs-
//! job-index plot and for exact percentiles) and a bounded log histogram
//! (for the Figure 8 distribution curves).

use crate::stats::{FiveNum, LogHistogram, Summary};

/// Records completed-job response times after an optional warmup.
#[derive(Debug, Clone)]
pub struct ResponseRecorder {
    warmup: f64,
    samples: Vec<f64>,
    /// (arrival time, response) pairs in completion order, for trend plots.
    series: Vec<(f64, f64)>,
    hist: LogHistogram,
    dropped_warmup: u64,
}

impl ResponseRecorder {
    /// Recorder that ignores jobs *arriving* before `warmup` seconds.
    pub fn new(warmup: f64) -> Self {
        Self {
            warmup,
            samples: Vec::new(),
            series: Vec::new(),
            hist: LogHistogram::latency(),
            dropped_warmup: 0,
        }
    }

    /// Record a job that arrived at `arrival` and completed at `completion`.
    pub fn record(&mut self, arrival: f64, completion: f64) {
        debug_assert!(completion >= arrival, "negative response time");
        if arrival < self.warmup {
            self.dropped_warmup += 1;
            return;
        }
        let resp = completion - arrival;
        self.samples.push(resp);
        self.series.push((arrival, resp));
        self.hist.record(resp);
    }

    /// Number of recorded jobs.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Jobs excluded by warmup.
    pub fn dropped_warmup(&self) -> u64 {
        self.dropped_warmup
    }

    /// Raw response times in completion order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// `(arrival, response)` series in completion order.
    pub fn series(&self) -> &[(f64, f64)] {
        &self.series
    }

    /// Mean response time (seconds).
    pub fn mean(&self) -> f64 {
        crate::stats::mean(&self.samples)
    }

    /// Exact five-number summary (Figure 9's percentiles).
    pub fn five_num(&self) -> FiveNum {
        FiveNum::of(&self.samples)
    }

    /// Full summary.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// Distribution histogram (Figure 8 curves).
    pub fn histogram(&self) -> &LogHistogram {
        &self.hist
    }

    /// Fraction of jobs with response time above `threshold` seconds
    /// (Figure 8 highlights the mass beyond 2,000 ms).
    pub fn tail_fraction(&self, threshold: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|&&r| r > threshold).count() as f64 / self.samples.len() as f64
    }

    /// Absorb another recorder's jobs (the sharded plane records responses
    /// per frontend shard and merges at drain).
    ///
    /// Both recorders must share the same warmup so the exclusion rule was
    /// applied identically; each shard records a disjoint set of jobs and
    /// already dropped its own warmup arrivals, so counts — including
    /// `dropped_warmup` — add without double counting. The merged series
    /// is re-sorted by arrival time (completion order is meaningless
    /// across shards), which `samples()` mirrors.
    pub fn merge(&mut self, other: &ResponseRecorder) {
        assert!(
            (self.warmup - other.warmup).abs() < 1e-12,
            "cannot merge recorders with different warmups ({} vs {})",
            self.warmup,
            other.warmup
        );
        self.dropped_warmup += other.dropped_warmup;
        self.hist.merge(&other.hist);
        self.series.extend_from_slice(&other.series);
        self.series.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite arrival times"));
        self.samples.clear();
        self.samples.extend(self.series.iter().map(|&(_, resp)| resp));
    }

    /// Mean response over a window of job indices (for Figure 10a's
    /// per-index growth curve): chunk the completion-ordered series into
    /// `bins` equal groups and return each group's mean.
    pub fn binned_means(&self, bins: usize) -> Vec<f64> {
        if self.samples.is_empty() || bins == 0 {
            return Vec::new();
        }
        let chunk = (self.samples.len() as f64 / bins as f64).ceil().max(1.0) as usize;
        self.samples.chunks(chunk).map(crate::stats::mean).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_jobs_excluded() {
        let mut r = ResponseRecorder::new(10.0);
        r.record(5.0, 6.0); // arrives during warmup
        r.record(11.0, 12.5);
        assert_eq!(r.count(), 1);
        assert_eq!(r.dropped_warmup(), 1);
        assert!((r.mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn five_num_on_known_data() {
        let mut r = ResponseRecorder::new(0.0);
        for i in 1..=100 {
            r.record(0.0, i as f64);
        }
        let f = r.five_num();
        assert!((f.p50 - 50.5).abs() < 1e-9);
        assert!((f.p95 - 95.05).abs() < 0.1);
    }

    #[test]
    fn tail_fraction() {
        let mut r = ResponseRecorder::new(0.0);
        for i in 1..=10 {
            r.record(0.0, i as f64);
        }
        assert!((r.tail_fraction(8.0) - 0.2).abs() < 1e-12);
        assert_eq!(r.tail_fraction(100.0), 0.0);
    }

    #[test]
    fn binned_means_track_growth() {
        let mut r = ResponseRecorder::new(0.0);
        for i in 0..1000 {
            r.record(i as f64, i as f64 + 1.0 + i as f64 * 0.01);
        }
        let bins = r.binned_means(10);
        assert_eq!(bins.len(), 10);
        assert!(bins.last().unwrap() > bins.first().unwrap());
    }

    #[test]
    fn histogram_matches_samples() {
        let mut r = ResponseRecorder::new(0.0);
        r.record(0.0, 0.5);
        r.record(0.0, 1.5);
        assert_eq!(r.histogram().count(), 2);
        assert!((r.histogram().mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_recorder_is_sane() {
        let r = ResponseRecorder::new(0.0);
        assert_eq!(r.count(), 0);
        assert_eq!(r.mean(), 0.0);
        assert!(r.binned_means(5).is_empty());
        assert_eq!(r.tail_fraction(1.0), 0.0);
    }

    #[test]
    fn merge_combines_shards_without_double_counting() {
        let mut a = ResponseRecorder::new(10.0);
        let mut b = ResponseRecorder::new(10.0);
        a.record(5.0, 6.0); // warmup-dropped by shard a
        a.record(12.0, 13.0);
        a.record(20.0, 22.0);
        b.record(9.0, 9.5); // warmup-dropped by shard b
        b.record(11.0, 14.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.dropped_warmup(), 2);
        assert!((a.mean() - (1.0 + 2.0 + 3.0) / 3.0).abs() < 1e-12);
        assert_eq!(a.histogram().count(), 3);
        // Series re-sorted by arrival, samples kept aligned.
        let arrivals: Vec<f64> = a.series().iter().map(|&(t, _)| t).collect();
        assert_eq!(arrivals, vec![11.0, 12.0, 20.0]);
        assert_eq!(a.samples(), &[3.0, 1.0, 2.0]);
    }

    #[test]
    fn merge_into_empty_recorder() {
        let mut total = ResponseRecorder::new(0.0);
        let mut shard = ResponseRecorder::new(0.0);
        shard.record(1.0, 2.5);
        total.merge(&shard);
        total.merge(&ResponseRecorder::new(0.0));
        assert_eq!(total.count(), 1);
        assert!((total.mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_warmup() {
        let mut a = ResponseRecorder::new(1.0);
        let b = ResponseRecorder::new(2.0);
        a.merge(&b);
    }
}
