//! Plain-text table/series formatting for experiment reports.
//!
//! Every experiment prints the same rows/series the paper's figure shows;
//! these helpers keep the output aligned and machine-greppable.

/// One labelled row of numeric cells.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub cells: Vec<f64>,
}

impl Row {
    /// Build a row.
    pub fn new(label: impl Into<String>, cells: Vec<f64>) -> Self {
        Self { label: label.into(), cells }
    }
}

/// Format a table with a header and aligned columns. Values are printed
/// with `prec` decimal places.
pub fn format_table(title: &str, header: &[&str], rows: &[Row], prec: usize) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    let label_w = rows
        .iter()
        .map(|r| r.label.len())
        .chain(std::iter::once(4))
        .max()
        .unwrap_or(4);
    for r in rows {
        for (i, c) in r.cells.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(format!("{c:.prec$}").len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!("{:label_w$}", ""));
    for (h, w) in header.iter().zip(&widths) {
        out.push_str(&format!("  {h:>w$}"));
    }
    out.push('\n');
    for r in rows {
        out.push_str(&format!("{:label_w$}", r.label));
        for (i, c) in r.cells.iter().enumerate() {
            let w = widths.get(i).copied().unwrap_or(10);
            out.push_str(&format!("  {c:>w$.prec$}"));
        }
        out.push('\n');
    }
    out
}

/// Format an `(x, y)` series as two aligned columns.
pub fn format_series(title: &str, xlabel: &str, ylabel: &str, points: &[(f64, f64)]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n{xlabel:>14}  {ylabel:>14}\n"));
    for (x, y) in points {
        out.push_str(&format!("{x:>14.4}  {y:>14.4}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_all_cells() {
        let rows = vec![
            Row::new("rosella", vec![1.0, 2.5]),
            Row::new("sparrow", vec![3.25, 4.0]),
        ];
        let t = format_table("demo", &["p50", "p95"], &rows, 2);
        assert!(t.contains("rosella"));
        assert!(t.contains("sparrow"));
        assert!(t.contains("3.25"));
        assert!(t.contains("p95"));
    }

    #[test]
    fn table_aligns_columns() {
        let rows = vec![Row::new("a", vec![1.0]), Row::new("longer-name", vec![100000.0])];
        let t = format_table("demo", &["v"], &rows, 1);
        let lines: Vec<&str> = t.lines().skip(1).collect();
        // All data lines equal length.
        assert_eq!(lines[1].len(), lines[2].len());
    }

    #[test]
    fn series_formats_points() {
        let s = format_series("curve", "load", "ms", &[(0.1, 5.0), (0.9, 50.0)]);
        assert!(s.contains("0.1000"));
        assert!(s.contains("50.0000"));
        assert!(s.contains("load"));
    }
}
