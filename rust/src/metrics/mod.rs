//! Measurement: response-time recording, queue-length distributions, and
//! report formatting for the paper's figures.

pub mod queues;
pub mod report;
pub mod response;

pub use queues::QueueStats;
pub use report::{format_table, Row};
pub use response::ResponseRecorder;
