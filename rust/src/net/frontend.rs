//! The remote scheduler frontend: the full §5 Rosella stack over a
//! [`Transport`].
//!
//! [`run_frontend_loop`] is the sharded plane's per-scheduler loop —
//! private [`PerfLearner`] fed by only the completions this scheduler
//! routed, benchmark dispatcher throttled to `c0(μ̄ − λ̂_global)/k`, local
//! decision loop over served queue probes, and sync-payload export — with
//! every interaction with the shared pool routed through the transport
//! seam. Run it over a [`LocalTransport`](super::transport::LocalTransport)
//! and it is an in-process shard; over a
//! [`TcpTransport`](super::transport::TcpTransport)
//! ([`run_remote_frontend`]) it is `rosella frontend --connect`, a separate
//! OS process exchanging compact wire messages with the pool server's
//! epoll poll shard that owns its connection — the paper's distributed
//! topology made literal.
//!
//! Decisions run against the *cached* probe snapshot from the last
//! coordination beat (refreshed every [`TICK_INTERVAL`]); each submit bumps
//! its cached probe so back-to-back decisions between refreshes do not
//! dogpile one worker. That staleness is exactly the coordination price §2
//! argues is affordable — and the loopback benchmark measures it.

use super::transport::{BeatTrace, TcpTransport, Transport};
use super::wire::{DoneStats, HelloAck, Msg, WireCompletion, WireSpan};
use crate::obs::trace::{self, ClockAlign};
use crate::learner::{
    EstimateView, FakeJobDispatcher, PerfLearner, SyncKind, SyncPolicyConfig,
};
use crate::metrics::ResponseRecorder;
use crate::plane::{
    encode_job, pin_current_thread, shard_seeds, ArrivalBatcher, CpuTopology, FrontendCore,
    PinMode, PlacementPlan, BENCH_LOCAL_JOB,
};
use crate::scheduler::PolicyKind;
use crate::stats::{Exponential, Rng};
use crate::types::{JobSpec, TaskKind};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Cadence of the coordination beat: probe refresh, completion intake,
/// consensus adoption, benchmark catch-up.
pub const TICK_INTERVAL: Duration = Duration::from_millis(2);

/// Run parameters a frontend derives from the server's [`HelloAck`], so
/// `rosella frontend` needs nothing beyond `--connect` and `--shard`.
#[derive(Debug, Clone)]
pub struct RunParams {
    /// Scheduling policy (parsed from the server's spelling).
    pub policy: PolicyKind,
    /// Worker count.
    pub n: usize,
    /// Prior speed estimate.
    pub prior: f64,
    /// Mean task demand τ̄ (unit-speed seconds).
    pub mean_demand: f64,
    /// Guaranteed total throughput μ̄ (tasks/second).
    pub mu_bar: f64,
    /// This shard's arrival rate (the aggregate split across shards).
    pub rate_per_shard: f64,
    /// Arrival ingestion batch size.
    pub batch: usize,
    /// Run seed (per-shard streams via [`shard_seeds`]).
    pub seed: u64,
    /// Warmup cutoff for response metrics (seconds).
    pub warmup: f64,
    /// Local learner publish/export cadence (seconds).
    pub publish_interval: f64,
    /// Whether this frontend runs its benchmark dispatcher.
    pub fake_jobs: bool,
    /// Adaptive sync: request a merge when local estimates diverge beyond
    /// this √k-scaled threshold (`None` under periodic/gossip).
    pub divergence_threshold: Option<f64>,
    /// Submit-coalescing batch size B (tasks per wire frame).
    pub net_batch: usize,
    /// Submit-coalescing flush deadline D.
    pub net_flush: Duration,
    /// Lifecycle-trace sampling: every task whose id hashes to 0 mod N is
    /// traced (0 = tracing off, the server's negotiated rate).
    pub trace_sample: u32,
}

impl RunParams {
    /// Derive the run parameters for one of `shards` schedulers from the
    /// server's handshake reply.
    pub fn from_hello_ack(ack: &HelloAck, shards: usize) -> Result<Self, String> {
        if shards == 0 {
            return Err("need at least one shard".into());
        }
        let n = ack.workers as usize;
        if n == 0 {
            return Err("server advertised zero workers".into());
        }
        if !(ack.rate > 0.0 && ack.mean_demand > 0.0 && ack.mu_bar > 0.0) {
            return Err(format!(
                "server advertised a degenerate run (rate {}, demand {}, mu_bar {})",
                ack.rate, ack.mean_demand, ack.mu_bar
            ));
        }
        if !(ack.publish_interval > 0.0 && ack.publish_interval.is_finite()) {
            return Err("server advertised a non-positive publish interval".into());
        }
        if !(ack.net_flush_us.is_finite() && ack.net_flush_us >= 0.0) {
            return Err("server advertised a non-finite or negative flush deadline".into());
        }
        let policy = PolicyKind::parse(&ack.policy)?;
        let sync_kind = SyncKind::parse(&ack.sync_policy)?;
        let divergence_threshold = (sync_kind == SyncKind::Adaptive).then(|| {
            SyncPolicyConfig::adaptive(ack.sync_threshold).scaled_threshold(shards)
        });
        Ok(Self {
            policy,
            n,
            prior: ack.prior,
            mean_demand: ack.mean_demand,
            mu_bar: ack.mu_bar,
            rate_per_shard: ack.rate / shards as f64,
            batch: (ack.batch as usize).max(1),
            seed: ack.seed,
            warmup: ack.warmup,
            publish_interval: ack.publish_interval,
            fake_jobs: ack.fake_jobs,
            divergence_threshold,
            net_batch: (ack.net_batch as usize).max(1),
            net_flush: Duration::from_secs_f64(ack.net_flush_us * 1e-6),
            trace_sample: ack.clock.map_or(0, |c| c.sample_n),
        })
    }
}

/// What one frontend reports when its run completes.
#[derive(Debug)]
pub struct FrontendReport {
    /// This frontend's shard index.
    pub shard: usize,
    /// Total scheduler count k.
    pub shards: usize,
    /// Scheduling decisions made.
    pub decisions: u64,
    /// Real tasks submitted.
    pub dispatched: u64,
    /// Benchmark tasks submitted.
    pub benchmarks: u64,
    /// Completion reports absorbed (real + benchmark).
    pub completions_seen: u64,
    /// This scheduler's latency record (only the jobs it routed).
    pub responses: ResponseRecorder,
    /// Final consensus estimates this frontend holds.
    pub final_estimates: Vec<f64>,
    /// Full lifecycle spans assembled from the server's completion-trace
    /// echoes (0 unless tracing was negotiated).
    pub traced: u64,
    /// Worst stage-sum reconciliation error across assembled spans: how
    /// far |Σ stages − measured response| drifted, as a percentage of the
    /// measured response.
    pub trace_max_dev_pct: f64,
}

impl FrontendReport {
    /// Final per-frontend statistics for the server's merged report.
    pub fn done_stats(&self) -> DoneStats {
        let (mean, p50, p95) = if self.responses.count() > 0 {
            let five = self.responses.five_num();
            (self.responses.mean(), five.p50, five.p95)
        } else {
            (0.0, 0.0, 0.0)
        };
        DoneStats {
            decisions: self.decisions,
            dispatched: self.dispatched,
            benchmarks: self.benchmarks,
            resp_count: self.responses.count() as u64,
            resp_mean: mean,
            resp_p50: p50,
            resp_p95: p95,
        }
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "frontend {}/{}: {} decisions, {} dispatched, {} benchmarks\n",
            self.shard, self.shards, self.decisions, self.dispatched, self.benchmarks
        ));
        out.push_str(&format!("completions absorbed: {}\n", self.completions_seen));
        if self.responses.count() > 0 {
            let five = self.responses.five_num();
            out.push_str(&format!(
                "latency ms : mean {:.1} | p50 {:.1} | p95 {:.1} ({} jobs)\n",
                self.responses.mean() * 1e3,
                five.p50 * 1e3,
                five.p95 * 1e3,
                self.responses.count()
            ));
        }
        if self.traced > 0 {
            out.push_str(&format!(
                "traced spans: {} (max stage-sum deviation {:.2}%)\n",
                self.traced, self.trace_max_dev_pct
            ));
        }
        let est: Vec<String> =
            self.final_estimates.iter().map(|e| format!("{e:.2}")).collect();
        out.push_str(&format!("final consensus μ̂: [{}]\n", est.join(", ")));
        out
    }
}

/// The learner half of a frontend's state — everything the coordination
/// beat touches, kept apart from the decision state so the beat can borrow
/// it wholesale.
struct BeatState {
    perf: PerfLearner,
    dispatcher: FakeJobDispatcher,
    demand_dist: Exponential,
    rng: Rng,
    responses: ResponseRecorder,
    view_buf: Vec<EstimateView>,
    comp_buf: Vec<WireCompletion>,
    qlen: Vec<usize>,
    epoch: u64,
    lambda_consensus: f64,
    lambda_live: f64,
    stop: bool,
    drained: bool,
    benchmarks: u64,
    completions_seen: u64,
    next_publish: Instant,
    next_bench: Instant,
    next_tick: Instant,
    start: Instant,
    publish_interval: f64,
    divergence_threshold: Option<f64>,
    shard: usize,
    /// Cross-process clock-offset estimator (seeded by the handshake
    /// exchange, refreshed by every plain-Tick beat).
    clock: ClockAlign,
    /// Lifecycle-trace sampling rate (0 = off).
    trace_sample: u32,
    /// Spans assembled from completion-trace echoes.
    traced: u64,
    /// Worst |Σ stages − total| / total seen, in percent.
    trace_max_dev_pct: f64,
}

impl BeatState {
    /// λ̂_global this scheduler's learning stack runs on: the exchanged
    /// consensus value when one has been published, otherwise the live sum
    /// of every scheduler's reported λ̂ₛ (the same bootstrap the in-process
    /// plane uses, so the §5 throttle never assumes zero load).
    fn lambda_global(&self) -> f64 {
        if self.lambda_consensus > 0.0 {
            self.lambda_consensus
        } else {
            self.lambda_live
        }
    }

    /// One coordination beat: transport tick, completion intake, consensus
    /// adoption, benchmark catch-up, and the local publish/export cadence.
    fn beat<T: Transport>(
        &mut self,
        t: &mut T,
        core: &mut FrontendCore,
    ) -> Result<(), String> {
        self.comp_buf.clear();
        let out = t.tick(self.epoch, core.lambda_or(0.0), &mut self.qlen, &mut self.comp_buf)?;
        self.lambda_live = out.lambda_live;
        self.stop |= out.stop;
        self.drained |= out.drained;
        if let Some(est) = out.estimates {
            // Wire-supplied consensus is validated before installation: a
            // wrong-length vector would desync the policy and sampler.
            if est.mu_hat.len() != self.qlen.len() {
                return Err(format!(
                    "consensus length {} does not match the {}-worker cluster",
                    est.mu_hat.len(),
                    self.qlen.len()
                ));
            }
            // Fresh consensus: install it as the decision estimates and
            // adopt it into the private learner (cold-start fallback).
            core.set_estimates(&est.mu_hat, est.lambda);
            self.epoch = est.epoch;
            self.lambda_consensus = est.lambda;
            self.perf.adopt(core.mu_hat());
        }
        for c in &self.comp_buf {
            // Completion worker indices come off the wire: bound-check
            // before indexing the learner's per-worker histories.
            if c.worker as usize >= self.qlen.len() {
                return Err(format!(
                    "completion for unknown worker {} (cluster has {})",
                    c.worker,
                    self.qlen.len()
                ));
            }
            self.perf.on_completion(
                c.worker as usize,
                c.at.max(0.0),
                c.duration.max(1e-6),
                c.demand.max(1e-6),
            );
            self.completions_seen += 1;
            if c.kind == TaskKind::Real {
                self.responses.record((c.at - c.sojourn).max(0.0), c.at);
            }
        }
        if let Some(bt) = out.trace {
            self.absorb_beat_trace(t, bt);
        }
        if !self.stop {
            // The same LEARNER-DISPATCHER catch-up pass the in-process
            // plane runs, submitted through the transport instead of a
            // pool enqueue — one throttle loop, two planes.
            let lambda = self.lambda_global();
            let workers = self.qlen.len();
            let shard = self.shard;
            self.benchmarks += crate::plane::dispatch_benchmarks_with(
                &self.dispatcher,
                workers,
                lambda,
                &self.demand_dist,
                &mut self.rng,
                &mut self.next_bench,
                |w, demand| {
                    t.submit(encode_job(shard, BENCH_LOCAL_JOB), w, TaskKind::Benchmark, demand)
                },
            )?;
        }
        if Instant::now() >= self.next_publish {
            self.publish_and_export(t, core)?;
            self.next_publish += Duration::from_secs_f64(self.publish_interval);
        }
        self.next_tick = Instant::now() + TICK_INTERVAL;
        Ok(())
    }

    /// Absorb one beat's trace payload: fold the four-timestamp clock
    /// exchange into the offset estimator, then assemble a full lifecycle
    /// span for each completion-trace echo and ship it back for the
    /// server's aggregator.
    ///
    /// Stage sums reconcile with the frontend-measured response because
    /// the chain is continuous: decide/coalesce come from local submit
    /// stamps, wire maps the server's receive stamp through θ, queue and
    /// service are the worker's own sojourn decomposition, and reply maps
    /// the server's completion-drain stamp back. The only unaccounted gap
    /// is the server's receive→worker-enqueue dispatch (microseconds), and
    /// the two θ applications cancel in the sum — so reconciliation error
    /// is insensitive to the offset estimate itself.
    fn absorb_beat_trace<T: Transport>(&mut self, t: &mut T, bt: BeatTrace) {
        if bt.t0_ns != 0 && bt.reply.t1_ns != 0 {
            self.clock.observe(bt.t0_ns, bt.reply.t1_ns, bt.reply.t2_ns, bt.t3_ns);
            t.set_clock_estimate(self.clock.offset_ns(), self.clock.error_ns());
        }
        if !self.clock.aligned() || bt.reply.traced.is_empty() {
            return;
        }
        let theta = self.clock.offset_ns();
        let now = trace::now_ns();
        for ct in &bt.reply.traced {
            // Echo indices address this beat's completion list; an
            // out-of-range echo is dropped, not trusted.
            let Some(c) = self.comp_buf.get(ct.idx as usize) else { continue };
            let us = |ns: u64| (ns as f64 / 1e3) as u32;
            let decide = us(ct.enq_ns.saturating_sub(ct.origin_ns));
            let coalesce = us(ct.send_ns.saturating_sub(ct.enq_ns));
            // recv/done are server-clock stamps; θ (server − frontend)
            // maps them onto the local timeline.
            let wire_us =
                (((ct.recv_ns as f64 - theta) - ct.send_ns as f64) / 1e3).max(0.0) as u32;
            let queue = ((c.sojourn - c.duration).max(0.0) * 1e6) as u32;
            let service = (c.duration.max(0.0) * 1e6) as u32;
            let reply =
                ((now as f64 - (ct.done_ns as f64 - theta)) / 1e3).max(0.0) as u32;
            let stages_us = [decide, coalesce, wire_us, queue, service, reply];
            let total = (now.saturating_sub(ct.origin_ns) as f64 / 1e3).max(1.0);
            let sum: f64 = stages_us.iter().map(|&s| s as f64).sum();
            let dev_pct = (sum - total).abs() / total * 100.0;
            self.traced += 1;
            if dev_pct > self.trace_max_dev_pct {
                self.trace_max_dev_pct = dev_pct;
            }
            t.ship_span(WireSpan {
                job: c.job,
                // Export on the server's timeline so spans from every
                // frontend land on one comparable axis.
                origin_us: ((ct.origin_ns as f64 + theta) / 1e3).max(0.0) as u64,
                stages_us,
            });
        }
    }

    /// Publish the local learner and export its sync payload — estimate
    /// views plus this scheduler's local arrival share λ̂ₛ. Under adaptive
    /// sync, also run the divergence test against the last adopted
    /// consensus and flag a merge request.
    fn publish_and_export<T: Transport>(
        &mut self,
        t: &mut T,
        core: &FrontendCore,
    ) -> Result<(), String> {
        let now_s = self.start.elapsed().as_secs_f64();
        self.perf.publish(now_s, self.lambda_global());
        self.perf.export_views_into(&mut self.view_buf);
        let diverged = self
            .divergence_threshold
            .is_some_and(|th| self.perf.divergence_from(core.mu_hat()) > th);
        t.export(&self.view_buf, core.lambda_or(0.0), diverged)
    }
}

/// Run the full §5 frontend loop over `t` until the plane signals stop,
/// then drain: absorb every completion this scheduler routed and export the
/// final learner view for the drain-time consensus epoch.
///
/// With `flight` set, every placement is captured into lane 0 of the
/// recorder (one remote frontend is one scheduler; the `shard` field of
/// each event still carries the global shard index). Recording adds two
/// clock reads per decision and nothing when `flight` is `None`.
pub fn run_frontend_loop<T: Transport>(
    t: &mut T,
    p: &RunParams,
    shard: usize,
    shards: usize,
    flight: Option<&crate::obs::FlightRecorder>,
    clock: ClockAlign,
) -> Result<FrontendReport, String> {
    if shard >= shards {
        return Err(format!("shard {shard} out of range for {shards} shards"));
    }
    let (core_seed, stream_seed) = shard_seeds(p.seed, shard);
    let mut core =
        FrontendCore::new(&p.policy, p.n, p.prior, p.mean_demand, 128, core_seed);
    let mut stream_rng = Rng::new(stream_seed);
    let mut batcher = ArrivalBatcher::new(p.rate_per_shard, p.mean_demand, p.batch);
    let mut batch = Vec::with_capacity(p.batch);
    let mut job = JobSpec::single(p.mean_demand);
    let start = Instant::now();
    let mut state = BeatState {
        perf: PerfLearner::new(p.n, 10.0, p.mean_demand, p.mu_bar, p.prior, 0.0)
            .shared_among(shards),
        dispatcher: FakeJobDispatcher::new_sharded(0.1, p.mu_bar, p.fake_jobs, shards),
        demand_dist: Exponential::with_mean(p.mean_demand),
        rng: Rng::new(core_seed ^ stream_seed ^ 0xFA_CE),
        responses: ResponseRecorder::new(p.warmup),
        view_buf: Vec::with_capacity(p.n),
        comp_buf: Vec::new(),
        qlen: vec![0; p.n],
        epoch: 0,
        lambda_consensus: 0.0,
        lambda_live: 0.0,
        stop: false,
        drained: false,
        benchmarks: 0,
        completions_seen: 0,
        next_publish: start + Duration::from_secs_f64(p.publish_interval),
        next_bench: start + Duration::from_secs_f64(0.05),
        next_tick: start,
        start,
        publish_interval: p.publish_interval,
        divergence_threshold: p.divergence_threshold,
        shard,
        clock,
        trace_sample: p.trace_sample,
        traced: 0,
        trace_max_dev_pct: 0.0,
    };
    let mut decisions = 0u64;
    let mut dispatched = 0u64;
    let mut local_jobs = 0u64;
    let trace = crate::obs::ProbeTrace::new();

    'outer: while !state.stop {
        batcher.fill(&mut stream_rng, &mut batch);
        for a in &batch {
            // Pace the batch: dispatch each arrival when it is due,
            // servicing the coordination beat while waiting.
            loop {
                if Instant::now() >= state.next_tick {
                    state.beat(t, &mut core)?;
                }
                if state.stop {
                    break 'outer;
                }
                let elapsed = start.elapsed().as_secs_f64();
                if elapsed >= a.at {
                    break;
                }
                // Idle until the arrival is due: any coalesced submission
                // past its flush deadline goes out now, so low load never
                // trades latency for batching.
                t.flush_due()?;
                std::thread::sleep(Duration::from_secs_f64((a.at - elapsed).min(1e-3)));
            }
            core.on_arrival(a.at, 1);
            job.tasks[0].demand = a.demand;
            let job_id = encode_job(shard, local_jobs);
            // Sampled tasks stamp their origin before the decision so the
            // decide stage covers it; everything else stays on the
            // stamp-free path (one branch, no clock read).
            let origin_ns = (state.trace_sample != 0
                && trace::sampled(job_id, state.trace_sample))
            .then(trace::now_ns);
            let w = match flight {
                Some(rec) => {
                    trace.clear();
                    let t0 = Instant::now();
                    let w = core.decide_local_traced(&job, &state.qlen, Some(&trace));
                    let decision_ns = t0.elapsed().as_nanos() as u64;
                    rec.record(
                        0,
                        crate::obs::FlightEvent::Placement {
                            t_ns: start.elapsed().as_nanos() as u64,
                            shard: shard as u32,
                            task: job_id,
                            probed: trace.probes(),
                            chosen: w as u32,
                            mu_chosen: core.mu_hat().get(w).copied().unwrap_or(0.0),
                            lambda_hat: core.lambda_or(0.0),
                            decision_ns,
                        },
                    );
                    w
                }
                None => core.decide_local(&job, &state.qlen),
            };
            decisions += 1;
            match origin_ns {
                Some(o) => t.submit_traced(job_id, w, TaskKind::Real, a.demand, o)?,
                None => t.submit(job_id, w, TaskKind::Real, a.demand)?,
            }
            // Optimistic probe bump until the next refresh, so decisions
            // within one beat do not dogpile the same worker.
            state.qlen[w] += 1;
            local_jobs += 1;
            dispatched += 1;
        }
    }

    // Drain: keep beating until the pool has drained and every completion
    // this scheduler routed has arrived, then export the final view for
    // the drain-time consensus epoch.
    let drain_deadline = Instant::now() + Duration::from_secs(60);
    while !state.drained {
        if Instant::now() >= drain_deadline {
            return Err("drain timed out waiting for the pool".into());
        }
        state.beat(t, &mut core)?;
        std::thread::sleep(Duration::from_millis(1));
    }
    state.publish_and_export(t, &core)?;

    Ok(FrontendReport {
        shard,
        shards,
        decisions,
        dispatched,
        benchmarks: state.benchmarks,
        completions_seen: state.completions_seen,
        responses: state.responses,
        final_estimates: core.mu_hat().to_vec(),
        traced: state.traced,
        trace_max_dev_pct: state.trace_max_dev_pct,
    })
}

/// Connection settings for a remote frontend.
#[derive(Debug, Clone)]
pub struct ConnectConfig {
    /// Pool server address (`host:port`).
    pub addr: String,
    /// This frontend's shard index.
    pub shard: usize,
    /// Total scheduler count k (must match every other frontend).
    pub shards: usize,
    /// How long to keep retrying the initial connect.
    pub connect_timeout: Duration,
    /// Per-read socket timeout during the run.
    pub read_timeout: Duration,
    /// Dump this frontend's placement flight record as JSONL to this path
    /// at drain (`None` disables recording entirely).
    pub flight_record: Option<String>,
    /// Override the server-advertised submit-coalescing batch size B.
    pub net_batch: Option<usize>,
    /// Override the server-advertised flush deadline D (microseconds).
    pub net_flush_us: Option<f64>,
    /// Pin this frontend's decision thread to a CPU chosen from the local
    /// topology by shard index (best-effort; `None` mode leaves placement
    /// to the OS).
    pub pin: PinMode,
}

impl ConnectConfig {
    /// Defaults: 15 s connect retry window, 30 s read timeout, no flight
    /// recording, and the server's coalescing policy.
    pub fn new(addr: impl Into<String>, shard: usize, shards: usize) -> Self {
        Self {
            addr: addr.into(),
            shard,
            shards,
            connect_timeout: Duration::from_secs(15),
            read_timeout: Duration::from_secs(30),
            flight_record: None,
            net_batch: None,
            net_flush_us: None,
            pin: PinMode::None,
        }
    }
}

fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream, String> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(format!("connect {addr}: {e}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Run one remote frontend process end to end: connect, handshake, run the
/// §5 loop over TCP, and ship the final statistics.
pub fn run_remote_frontend(cfg: &ConnectConfig) -> Result<FrontendReport, String> {
    if cfg.shards == 0 || cfg.shard >= cfg.shards {
        return Err(format!(
            "shard {}/{} is not a valid shard spec",
            cfg.shard, cfg.shards
        ));
    }
    // Best-effort pin before any scheduling work: each remote frontend is
    // one shard, so it claims the shard slot its global index maps to on
    // this machine's topology (the pool's workers live in the server
    // process and are placed there).
    if cfg.pin != PinMode::None {
        let plan = PlacementPlan::new(cfg.pin, &CpuTopology::detect(), cfg.shards, 0);
        if let Some(cpu) = plan.shard_cpus[cfg.shard] {
            pin_current_thread(cpu);
        }
    }
    let stream = connect_with_retry(&cfg.addr, cfg.connect_timeout)?;
    stream.set_nodelay(true).map_err(|e| format!("set nodelay: {e}"))?;
    stream
        .set_read_timeout(Some(cfg.read_timeout))
        .map_err(|e| format!("set read timeout: {e}"))?;
    let mut t = TcpTransport::new(stream, cfg.shard);
    // The handshake doubles as the first four-timestamp clock exchange:
    // t0 stamped here, t1/t2 by the server inside the ack, t3 on receipt.
    let t0 = trace::now_ns();
    t.send(&Msg::Hello {
        shard: cfg.shard as u32,
        shards: cfg.shards as u32,
        t0_ns: Some(t0),
    })?;
    let ack = match t.recv()? {
        Msg::HelloAck(a) => a,
        other => return Err(format!("expected HelloAck, got tag {}", other.tag())),
    };
    let t3 = trace::now_ns();
    let mut clock = ClockAlign::new();
    if let Some(c) = ack.clock {
        if c.t1_ns != 0 {
            clock.observe(t0, c.t1_ns, c.t2_ns, t3);
        }
    }
    let params = RunParams::from_hello_ack(&ack, cfg.shards)?;
    // The server's HelloAck carries the run's coalescing policy; local
    // --net-batch/--net-flush-us flags override it for this frontend only.
    let batch = cfg.net_batch.unwrap_or(params.net_batch);
    let flush = cfg
        .net_flush_us
        .map_or(params.net_flush, |us| Duration::from_secs_f64(us * 1e-6));
    t.configure_batching(batch, flush);
    if params.trace_sample > 0 {
        t.configure_tracing(true);
        t.set_clock_estimate(clock.offset_ns(), clock.error_ns());
    }
    match t.recv()? {
        Msg::Start => {}
        other => return Err(format!("expected Start, got tag {}", other.tag())),
    }
    let flight = cfg.flight_record.as_deref().map(|_| {
        crate::obs::FlightRecorder::new(1, crate::obs::flight::DEFAULT_CAPACITY)
    });
    let report =
        run_frontend_loop(&mut t, &params, cfg.shard, cfg.shards, flight.as_ref(), clock)?;
    if let (Some(path), Some(rec)) = (cfg.flight_record.as_deref(), flight.as_ref()) {
        std::fs::write(path, rec.dump_jsonl())
            .map_err(|e| format!("write flight record {path}: {e}"))?;
    }
    t.send(&Msg::Done(report.done_stats()))?;
    match t.recv()? {
        Msg::DoneAck => {}
        other => return Err(format!("expected DoneAck, got tag {}", other.tag())),
    }
    Ok(report)
}

/// Parse an `i/k` shard spec.
pub fn parse_shard_spec(s: &str) -> Result<(usize, usize), String> {
    let (i, k) = s
        .split_once('/')
        .ok_or_else(|| format!("bad shard spec '{s}' (expected i/k, e.g. 0/2)"))?;
    let shard: usize =
        i.trim().parse().map_err(|e| format!("bad shard index in '{s}': {e}"))?;
    let shards: usize =
        k.trim().parse().map_err(|e| format!("bad shard count in '{s}': {e}"))?;
    if shards == 0 || shard >= shards {
        return Err(format!("shard {shard} out of range for {shards} shards"));
    }
    Ok((shard, shards))
}

/// CLI adapter for `rosella frontend`. Flags and the `net` JSON block
/// (`--config file.json`) are merged; the file wins where both name a
/// field.
pub fn frontend_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let mut cfg = ConnectConfig::new(p.get("connect").unwrap_or("").to_string(), 0, 1);
    let mut have_shard = false;
    if let Some(s) = p.get("shard") {
        let (shard, shards) = parse_shard_spec(s)?;
        cfg.shard = shard;
        cfg.shards = shards;
        have_shard = true;
    }
    if let Some(path) = p.get("config") {
        let opts = crate::config::net_options_from_file(path).map_err(|e| e.to_string())?;
        have_shard |= opts.shard.is_some();
        opts.apply_frontend(&mut cfg);
    }
    if cfg.addr.is_empty() {
        return Err("missing --connect ADDR (or a net.connect entry in --config)".into());
    }
    if !have_shard {
        return Err("missing --shard i/k (or a net.shard entry in --config)".into());
    }
    if let Some(t) = p.parse_as::<f64>("connect-timeout")? {
        if !(t > 0.0 && t.is_finite()) {
            return Err("--connect-timeout must be positive and finite".into());
        }
        cfg.connect_timeout = Duration::from_secs_f64(t);
    }
    if let Some(b) = p.parse_as::<usize>("net-batch")? {
        if b == 0 {
            return Err("--net-batch must be at least 1".into());
        }
        cfg.net_batch = Some(b);
    }
    if let Some(us) = p.parse_as::<f64>("net-flush-us")? {
        if !(us.is_finite() && us >= 0.0) {
            return Err("--net-flush-us must be finite and non-negative".into());
        }
        cfg.net_flush_us = Some(us);
    }
    cfg.flight_record = p.get("flight-record").map(str::to_string);
    if let Some(mode) = p.get("pin") {
        cfg.pin = PinMode::parse(mode)?;
    }
    let report = run_remote_frontend(&cfg)?;
    Ok(report.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ack() -> HelloAck {
        HelloAck {
            workers: 4,
            batch: 32,
            net_batch: 64,
            net_flush_us: 200.0,
            seed: 42,
            prior: 0.9375,
            mean_demand: 0.01,
            mu_bar: 375.0,
            rate: 400.0,
            duration: 2.0,
            warmup: 0.0,
            publish_interval: 0.2,
            sync_interval: 0.2,
            sync_threshold: 0.1,
            fake_jobs: true,
            policy: "ppot".into(),
            sync_policy: "periodic".into(),
            speeds: vec![2.0, 1.0, 0.5, 0.25],
            clock: None,
        }
    }

    #[test]
    fn run_params_derive_from_hello_ack() {
        let p = RunParams::from_hello_ack(&ack(), 2).unwrap();
        assert_eq!(p.n, 4);
        assert_eq!(p.rate_per_shard, 200.0);
        assert_eq!(p.divergence_threshold, None, "periodic sync has no trigger");
        assert_eq!(p.net_batch, 64);
        assert_eq!(p.net_flush, Duration::from_micros(200));
        let mut a = ack();
        a.sync_policy = "adaptive".into();
        let p = RunParams::from_hello_ack(&a, 4).unwrap();
        // The adaptive trigger arrives √k-scaled (k = 4 ⇒ 2×).
        let th = p.divergence_threshold.expect("adaptive sync sets a trigger");
        assert!((th - 0.2).abs() < 1e-12, "threshold {th}");
        assert_eq!(p.trace_sample, 0, "no clock appendix: tracing off");
        let mut a = ack();
        a.clock = Some(crate::net::wire::AckClock { t1_ns: 1, t2_ns: 2, sample_n: 64 });
        let p = RunParams::from_hello_ack(&a, 2).unwrap();
        assert_eq!(p.trace_sample, 64, "negotiated sampling rides the ack clock");
    }

    #[test]
    fn degenerate_hello_acks_are_rejected() {
        let mut a = ack();
        a.workers = 0;
        assert!(RunParams::from_hello_ack(&a, 2).is_err());
        let mut a = ack();
        a.rate = 0.0;
        assert!(RunParams::from_hello_ack(&a, 2).is_err());
        let mut a = ack();
        a.policy = "nonsense".into();
        assert!(RunParams::from_hello_ack(&a, 2).is_err());
        let mut a = ack();
        a.sync_policy = "nonsense".into();
        assert!(RunParams::from_hello_ack(&a, 2).is_err());
        let mut a = ack();
        a.net_flush_us = f64::NAN;
        assert!(RunParams::from_hello_ack(&a, 2).is_err());
        let mut a = ack();
        a.net_batch = 0;
        assert_eq!(
            RunParams::from_hello_ack(&a, 2).unwrap().net_batch,
            1,
            "B=0 degrades to unbatched"
        );
        assert!(RunParams::from_hello_ack(&ack(), 0).is_err());
    }

    #[test]
    fn frontend_loop_runs_over_the_local_transport() {
        // The Transport seam's contract: the same §5 loop that speaks TCP
        // runs over in-process channels, against the plane's own shared
        // state, with the same conservation guarantees.
        use crate::coordinator::worker::{self, CompletionSink, PayloadMode};
        use crate::learner::SyncPolicy;
        use crate::net::transport::LocalTransport;
        use crate::plane::consensus::{run_sync, SyncRun};
        use crate::plane::{EstimateTable, SharedViews};
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::sync::Arc;

        let speeds = [2.0, 1.0, 0.5, 0.25];
        let n = speeds.len();
        let prior = speeds.iter().sum::<f64>() / n as f64;
        let mean_demand = 0.003;
        let (tx, rx) = std::sync::mpsc::channel();
        let sink = CompletionSink::sharded(vec![tx]);
        let pool: Vec<_> = speeds
            .iter()
            .enumerate()
            .map(|(i, &s)| worker::spawn(i, s, PayloadMode::Sleep, sink.clone()))
            .collect();
        drop(sink);
        let completed: Vec<Arc<AtomicU64>> =
            pool.iter().map(|w| w.client.completed_real.clone()).collect();
        let table = Arc::new(EstimateTable::new(n, prior));
        let views = Arc::new(SharedViews::new(1, n, prior));
        let stop = Arc::new(AtomicBool::new(false));
        let sync_stop = Arc::new(AtomicBool::new(false));
        let slots = vec![Arc::new(AtomicU64::new(0f64.to_bits()))];
        let start = Instant::now();
        let sync_ctx = SyncRun {
            views: views.clone(),
            table: table.clone(),
            stop: sync_stop.clone(),
            policy: SyncPolicy::new(&SyncPolicyConfig::periodic(), 0.1, 1, 7),
            prior,
            start,
            obs: Arc::new(crate::obs::Registry::new(1, n)),
            flight: None,
        };
        let sync = std::thread::spawn(move || run_sync(sync_ctx));
        let params = RunParams {
            policy: PolicyKind::parse("ppot").unwrap(),
            n,
            prior,
            mean_demand,
            mu_bar: speeds.iter().sum::<f64>() / mean_demand,
            rate_per_shard: 200.0,
            batch: 32,
            seed: 42,
            warmup: 0.0,
            publish_interval: 0.1,
            fake_jobs: true,
            divergence_threshold: None,
            net_batch: 64,
            net_flush: Duration::from_micros(200),
            trace_sample: 0,
        };
        let t = LocalTransport::new(
            pool.iter().map(|w| w.client.clone()).collect(),
            rx,
            table.clone(),
            views,
            slots,
            0,
            stop.clone(),
            start,
        );
        // Record the run's flight while we're here: the recorder must not
        // change decisions, and its dump must hold our placements.
        let rec = std::sync::Arc::new(crate::obs::FlightRecorder::new(1, 512));
        let rec_loop = rec.clone();
        let loop_handle = std::thread::spawn(move || {
            let mut t = t;
            run_frontend_loop(&mut t, &params, 0, 1, Some(&*rec_loop), ClockAlign::new())
        });
        std::thread::sleep(Duration::from_millis(700));
        stop.store(true, Ordering::Relaxed);
        // The loop releases its ingress on its next beat; the pool then
        // drains, disconnects the completion channel, and the loop's drain
        // phase completes.
        for w in pool {
            w.shutdown();
        }
        let report = loop_handle.join().expect("loop thread").expect("loop run");
        sync_stop.store(true, Ordering::Release);
        let outcome = sync.join().expect("sync thread");

        assert!(report.decisions > 0, "no decisions made");
        assert!(report.dispatched > 0, "nothing dispatched");
        assert!(report.benchmarks > 0, "benchmark dispatcher idle");
        let done: u64 = completed.iter().map(|c| c.load(Ordering::Acquire)).sum();
        assert_eq!(done, report.dispatched, "tasks lost or duplicated");
        assert_eq!(report.responses.count() as u64, done, "latency records diverge");
        assert!(outcome.merges >= 1, "no consensus merge ran");
        assert_eq!(report.final_estimates.len(), n);
        // Flight recording rode along without changing the run: every
        // placement decision left one JSONL-parseable event behind.
        assert!(rec.total() > 0, "flight recorder captured no placements");
        let dump = rec.dump_jsonl();
        assert!(dump.contains("\"chosen\""), "placement events missing fields");
        for line in dump.lines() {
            crate::config::parse(line).expect("flight line must parse as JSON");
        }
    }

    #[test]
    fn shard_specs_parse_and_validate() {
        assert_eq!(parse_shard_spec("0/2").unwrap(), (0, 2));
        assert_eq!(parse_shard_spec("3/4").unwrap(), (3, 4));
        assert!(parse_shard_spec("2/2").is_err());
        assert!(parse_shard_spec("0/0").is_err());
        assert!(parse_shard_spec("a/2").is_err());
        assert!(parse_shard_spec("02").is_err());
    }
}
