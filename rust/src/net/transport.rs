//! The transport seam between a Rosella frontend and the shared worker
//! pool.
//!
//! The §5 frontend loop ([`crate::net::frontend::run_frontend_loop`])
//! needs exactly four capabilities from the plane it schedules into:
//! submit a task, refresh queue-length probes, receive the completions of
//! tasks it routed, and exchange sync payloads. [`Transport`] names that
//! surface, and two implementations provide it:
//!
//! * [`LocalTransport`] — in-process channels and atomics: the same
//!   [`WorkerClient`] ingress handles, atomic queue probes, seqlock
//!   [`EstimateTable`], and [`SharedViews`] slots the sharded plane's
//!   native shard threads use;
//! * [`TcpTransport`] — the [`wire`](crate::net::wire) protocol over one
//!   `std::net::TcpStream` per frontend, speaking to a
//!   `rosella plane --listen` pool server.
//!
//! The same loop over either transport is what makes the cross-process
//! topology a *configuration* rather than a second scheduler
//! implementation. The one semantic difference is probe freshness: the
//! local transport reads live atomics at every beat, the TCP transport
//! reads the probe snapshot served with the last `TickReply` (the frontend
//! additionally bumps its cached probe for each task it submits between
//! refreshes, so back-to-back decisions do not dogpile one worker).

use super::wire::{
    self, BatchTrace, DecodeScratch, Estimates, Msg, ReplyTrace, SubmitItem, SubmitTrace,
    TickTrace, WireCompletion, WireSpan,
};
use crate::coordinator::worker::{Completion, LiveTask, WorkerClient};
use crate::learner::EstimateView;
use crate::plane::{CachePadded, EstimateTable, SharedViews};
use crate::types::TaskKind;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default submit-coalescing batch size B (tasks per `SubmitBatch` frame).
pub const DEFAULT_NET_BATCH: usize = 64;

/// Default submit-coalescing flush deadline D in microseconds: how long a
/// buffered task may wait for company before it is flushed anyway.
pub const DEFAULT_NET_FLUSH_US: f64 = 200.0;

/// Trace data one coordination beat brought back: the four-timestamp
/// clock exchange (t0/t3 stamped by the transport, t1/t2 by the server)
/// plus the server's echoed stamps for sampled completions.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BeatTrace {
    /// Local trace-clock stamp when this beat's `Tick` was sent (0 when
    /// no clock exchange rode this beat — e.g. the tick piggybacked on a
    /// batch frame).
    pub t0_ns: u64,
    /// Local trace-clock stamp when the reply arrived.
    pub t3_ns: u64,
    /// The server's half: t1/t2 stamps and completion-trace echoes.
    pub reply: ReplyTrace,
}

/// What one coordination beat reports back to the frontend loop.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TickOutcome {
    /// Live sum of every scheduler's last reported λ̂ₛ (the throttle
    /// bootstrap before the first consensus publish).
    pub lambda_live: f64,
    /// Fresh consensus, present iff the table epoch moved.
    pub estimates: Option<Estimates>,
    /// Stop deciding and start draining.
    pub stop: bool,
    /// Every completion for this shard has been delivered.
    pub drained: bool,
    /// v3 tracing: clock-exchange stamps and completion-trace echoes
    /// (TCP transport with tracing negotiated; `None` otherwise).
    pub trace: Option<BeatTrace>,
}

/// The coordination surface a §5 frontend needs from its plane.
pub trait Transport {
    /// Dispatch one task to `worker` (fire-and-forget).
    fn submit(
        &mut self,
        job: u64,
        worker: usize,
        kind: TaskKind,
        demand: f64,
    ) -> Result<(), String>;

    /// [`Self::submit`] for a trace-sampled task: `origin_ns` is the
    /// task's arrival stamp on the local trace clock. Transports that
    /// cannot carry stamps (the in-process plane records spans at
    /// completion intake instead) just submit.
    fn submit_traced(
        &mut self,
        job: u64,
        worker: usize,
        kind: TaskKind,
        demand: f64,
        origin_ns: u64,
    ) -> Result<(), String> {
        let _ = origin_ns;
        self.submit(job, worker, kind, demand)
    }

    /// Queue one completed span for shipping to the pool server's trace
    /// aggregator on a later beat. No-op default (the in-process plane
    /// aggregates locally).
    fn ship_span(&mut self, span: WireSpan) {
        let _ = span;
    }

    /// Publish the frontend's current clock-offset estimate so it rides
    /// the next traceable beat. No-op default.
    fn set_clock_estimate(&mut self, offset_ns: f64, err_ns: f64) {
        let _ = (offset_ns, err_ns);
    }

    /// One coordination beat: refresh `qlen` probes in place, append this
    /// shard's pending completions to `completions`, and report run state.
    /// `epoch` is the consensus epoch the frontend currently holds;
    /// `lambda_local` its live local arrival estimate λ̂ₛ.
    fn tick(
        &mut self,
        epoch: u64,
        lambda_local: f64,
        qlen: &mut [usize],
        completions: &mut Vec<WireCompletion>,
    ) -> Result<TickOutcome, String>;

    /// Export this scheduler's sync payload (views + λ̂ₛ + the adaptive
    /// policy's divergence flag).
    fn export(
        &mut self,
        views: &[EstimateView],
        lambda_hat: f64,
        diverged: bool,
    ) -> Result<(), String>;

    /// Flush any coalesced submissions whose deadline passed. The frontend
    /// loop calls this from its idle wait so a buffered task never waits
    /// longer than the flush deadline under low load. No-op for transports
    /// that dispatch eagerly (the local plane has no frames to amortize).
    fn flush_due(&mut self) -> Result<(), String> {
        Ok(())
    }
}

/// Frontend-side submit coalescing: buffer dispatches and flush them as
/// one [`Msg::SubmitBatch`] frame at batch size B or flush deadline D,
/// whichever comes first. Probe `Tick`s piggyback on the flush instead of
/// paying their own frame.
///
/// At B=1 the coalescer is bit-compatible with the unbatched protocol: a
/// single pending item flushes as a plain `Submit` frame and an empty
/// flush carrying a beat emits a plain `Tick`, so the byte stream is
/// exactly what an unbatched frontend would have written.
pub struct SubmitCoalescer {
    pending: Vec<SubmitItem>,
    /// `(index into pending, origin_ns, enq_ns)` stamps of the sampled
    /// subset; empty for every batch with no sampled task (the common
    /// case), keeping the flush path allocation-free and v2-compatible.
    stamps: Vec<(u32, u64, u64)>,
    /// When the oldest pending item was buffered (meaningful only while
    /// `pending` is non-empty).
    first_at: Instant,
    batch: usize,
    flush_after: Duration,
}

impl SubmitCoalescer {
    /// A coalescer flushing at `batch` items (clamped to the frame bound)
    /// or `flush_after` after the oldest buffered item, whichever first.
    pub fn new(batch: usize, flush_after: Duration) -> Self {
        let batch = batch.clamp(1, wire::MAX_BATCH_ITEMS);
        Self {
            pending: Vec::with_capacity(batch),
            stamps: Vec::new(),
            first_at: Instant::now(),
            batch,
            flush_after,
        }
    }

    /// Buffer one dispatch; returns `true` when the batch is full and the
    /// caller must flush.
    pub fn push(&mut self, item: SubmitItem) -> bool {
        self.push_traced(item, None)
    }

    /// Buffer one dispatch, carrying `(origin_ns, enq_ns)` lifecycle
    /// stamps when the task is trace-sampled; returns `true` when the
    /// batch is full and the caller must flush.
    pub fn push_traced(&mut self, item: SubmitItem, stamp: Option<(u64, u64)>) -> bool {
        if self.pending.is_empty() {
            self.first_at = Instant::now();
        }
        if let Some((origin_ns, enq_ns)) = stamp {
            self.stamps.push((self.pending.len() as u32, origin_ns, enq_ns));
        }
        self.pending.push(item);
        self.pending.len() >= self.batch
    }

    /// Whether the oldest buffered item has waited past the deadline.
    pub fn due(&self) -> bool {
        !self.pending.is_empty() && self.first_at.elapsed() >= self.flush_after
    }

    /// Buffered dispatch count.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Drain the buffer into the frame to send, piggybacking `tick` when
    /// present. Returns `None` when there is nothing to say (no pending
    /// items and no beat). Single-item tickless flushes degrade to plain
    /// `Submit` and empty beat-only flushes to plain `Tick` — the B=1
    /// bit-compatibility contract.
    pub fn flush_frame(&mut self, tick: Option<(u64, f64)>) -> Option<Msg> {
        if self.pending.is_empty() {
            return tick
                .map(|(epoch, lambda_local)| Msg::Tick { epoch, lambda_local, trace: None });
        }
        let items = std::mem::replace(&mut self.pending, Vec::with_capacity(self.batch));
        let stamps = std::mem::take(&mut self.stamps);
        if items.len() == 1 && tick.is_none() {
            let it = items[0];
            return Some(Msg::Submit {
                job: it.job,
                worker: it.worker,
                kind: it.kind,
                demand: it.demand,
                trace: stamps.first().map(|&(_, origin_ns, enq_ns)| SubmitTrace {
                    origin_ns,
                    enq_ns,
                    send_ns: crate::obs::trace::now_ns(),
                }),
            });
        }
        let trace = if stamps.is_empty() {
            None
        } else {
            Some(BatchTrace { send_ns: crate::obs::trace::now_ns(), stamps })
        };
        Some(Msg::SubmitBatch { tick, items, trace })
    }
}

/// In-process transport: the sharded plane's own shared state, behind the
/// [`Transport`] seam.
pub struct LocalTransport {
    /// Ingress handles, one per worker; cleared once `stop` is observed so
    /// the pool can drain and exit.
    workers: Vec<WorkerClient>,
    /// Per-worker atomic queue probes (outlive the ingress handles).
    probes: Vec<Arc<CachePadded<AtomicUsize>>>,
    /// This shard's completion channel.
    comp_rx: Receiver<Completion>,
    /// Seqlock-published consensus estimates.
    table: Arc<EstimateTable>,
    /// Sync-payload slots (this shard exports into slot `shard`).
    views: Arc<SharedViews>,
    /// Every scheduler's live λ̂ₛ slot (f64 bits).
    lambda_slots: Vec<Arc<AtomicU64>>,
    /// This frontend's shard index.
    shard: usize,
    /// Plane stop flag.
    stop: Arc<AtomicBool>,
    /// Run start (completion timestamps are seconds since this instant).
    start: Instant,
    /// Completion channel disconnected: the pool fully drained.
    disconnected: bool,
    /// Reused estimate read buffer.
    mu_buf: Vec<f64>,
}

impl LocalTransport {
    /// Wire a local transport for shard `shard` over the plane's shared
    /// state. `workers` and `probes` must be index-aligned.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        workers: Vec<WorkerClient>,
        comp_rx: Receiver<Completion>,
        table: Arc<EstimateTable>,
        views: Arc<SharedViews>,
        lambda_slots: Vec<Arc<AtomicU64>>,
        shard: usize,
        stop: Arc<AtomicBool>,
        start: Instant,
    ) -> Self {
        assert!(shard < lambda_slots.len(), "shard index out of range");
        let n = table.n();
        assert_eq!(workers.len(), n, "worker/table size mismatch");
        let probes = workers.iter().map(|w| w.qlen.clone()).collect();
        Self {
            workers,
            probes,
            comp_rx,
            table,
            views,
            lambda_slots,
            shard,
            stop,
            start,
            disconnected: false,
            mu_buf: vec![0.0; n],
        }
    }
}

impl Transport for LocalTransport {
    fn submit(
        &mut self,
        job: u64,
        worker: usize,
        kind: TaskKind,
        demand: f64,
    ) -> Result<(), String> {
        match self.workers.get(worker) {
            Some(w) => {
                w.enqueue(LiveTask {
                    job,
                    kind,
                    demand: demand.max(1e-6),
                    enqueued: Instant::now(),
                });
                Ok(())
            }
            // Ingress already released at stop: drop the straggler.
            None if self.workers.is_empty() => Ok(()),
            None => Err(format!("submit to unknown worker {worker}")),
        }
    }

    fn tick(
        &mut self,
        epoch: u64,
        lambda_local: f64,
        qlen: &mut [usize],
        completions: &mut Vec<WireCompletion>,
    ) -> Result<TickOutcome, String> {
        self.lambda_slots[self.shard].store(lambda_local.to_bits(), Ordering::Relaxed);
        let stop = self.stop.load(Ordering::Relaxed);
        if stop {
            // Release our ingress handles so the pool can drain and exit.
            self.workers.clear();
        }
        for (out, probe) in qlen.iter_mut().zip(self.probes.iter()) {
            *out = probe.load(Ordering::Relaxed);
        }
        drain_completions(&self.comp_rx, &mut self.disconnected, self.start, |c| {
            completions.push(c)
        });
        let estimates = estimates_if_moved(&self.table, epoch, &mut self.mu_buf);
        Ok(TickOutcome {
            lambda_live: lambda_total(&self.lambda_slots),
            estimates,
            stop,
            drained: stop && self.disconnected,
            trace: None,
        })
    }

    fn export(
        &mut self,
        views: &[EstimateView],
        lambda_hat: f64,
        diverged: bool,
    ) -> Result<(), String> {
        self.views.store(self.shard, views, lambda_hat);
        if diverged {
            self.views.request_merge();
        }
        Ok(())
    }
}

// The same live-λ̂ bootstrap the in-process plane computes.
pub(crate) use crate::plane::consensus::lambda_total;

/// Epoch-gated consensus read: a fresh [`Estimates`] iff the table moved
/// past `epoch`. One half of the coordination beat, shared by the local
/// transport and the pool server's `Tick` arm so the two planes cannot
/// drift apart.
pub(crate) fn estimates_if_moved(
    table: &EstimateTable,
    epoch: u64,
    mu_buf: &mut Vec<f64>,
) -> Option<Estimates> {
    if table.epoch() == epoch {
        return None;
    }
    let (lambda, e) = table.read(mu_buf);
    Some(Estimates { mu_hat: mu_buf.clone(), lambda, epoch: e })
}

/// Drain a shard's completion channel into `sink` (converted to wire form
/// on the run clock), latching `disconnected` once the pool has fully
/// exited — the other half of the beat, shared the same way.
pub(crate) fn drain_completions(
    rx: &Receiver<Completion>,
    disconnected: &mut bool,
    start: Instant,
    mut sink: impl FnMut(WireCompletion),
) {
    if *disconnected {
        return;
    }
    loop {
        match rx.try_recv() {
            Ok(c) => sink(to_wire(&c, start)),
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => {
                *disconnected = true;
                break;
            }
        }
    }
}

/// Convert a pool completion into its wire form on the run clock.
pub(crate) fn to_wire(c: &Completion, start: Instant) -> WireCompletion {
    WireCompletion {
        job: c.job,
        worker: c.worker as u32,
        kind: c.kind,
        demand: c.demand,
        duration: c.duration,
        sojourn: c.sojourn,
        at: c.at.saturating_duration_since(start).as_secs_f64(),
    }
}

/// TCP transport: the wire protocol over one stream, speaking to a
/// `rosella plane --listen` pool server. Submissions pass through a
/// [`SubmitCoalescer`] so a saturated frontend amortizes the frame header
/// and write syscall over up to B tasks; the beat flush piggybacks the
/// `Tick` on whatever is buffered.
pub struct TcpTransport {
    stream: TcpStream,
    scratch: Vec<u8>,
    /// Decode scratch: TickReply completion buffers recycle through here,
    /// so the steady-state beat loop stops allocating.
    decode: DecodeScratch,
    /// This frontend's shard index (stamped into `SyncExport` frames; the
    /// server cross-checks it against the connection's claimed identity).
    shard: u32,
    coalescer: SubmitCoalescer,
    /// v3 tracing negotiated for this connection.
    tracing: bool,
    /// Completed spans awaiting shipment to the server's trace aggregator.
    /// Spans ride only plain-`Tick` beats (a tick piggybacked on a batch
    /// frame carries no [`TickTrace`] appendix), so they wait here until
    /// the next beat whose flush is a bare `Tick`.
    outbox: Vec<WireSpan>,
    /// Latest clock-offset estimate (server − frontend), shipped with each
    /// clock exchange so the server can export it as gauges.
    offset_ns: f64,
    /// Half-RTT error bound on `offset_ns`.
    err_ns: f64,
}

/// Spans buffered in the trace outbox beyond this are dropped (newest
/// first) rather than grow without bound when beats keep riding batch
/// frames.
const TRACE_OUTBOX_CAP: usize = 8192;

/// At most this many spans ride one `TickTrace` appendix, bounding the
/// beat frame size.
const TRACE_SPANS_PER_TICK: usize = 512;

impl TcpTransport {
    /// Wrap a connected stream for shard `shard` (the caller performs the
    /// handshake via [`Self::send`]/[`Self::recv`]). Starts unbatched
    /// (B=1, bit-compatible with the eager protocol) until
    /// [`Self::configure_batching`] installs the run's flush policy.
    pub fn new(stream: TcpStream, shard: usize) -> Self {
        Self {
            stream,
            scratch: Vec::with_capacity(4096),
            decode: DecodeScratch::new(),
            shard: shard as u32,
            coalescer: SubmitCoalescer::new(1, Duration::ZERO),
            tracing: false,
            outbox: Vec::new(),
            offset_ns: 0.0,
            err_ns: 0.0,
        }
    }

    /// Install the run's coalescing policy: flush at `batch` buffered
    /// tasks or `flush_after` after the oldest, whichever comes first.
    pub fn configure_batching(&mut self, batch: usize, flush_after: Duration) {
        self.coalescer = SubmitCoalescer::new(batch, flush_after);
    }

    /// Enable v3 tracing for this connection (called after the handshake
    /// when the server's `HelloAck` negotiated a non-zero sample rate).
    /// Beats stamp clock exchanges and the outbox ships spans.
    pub fn configure_tracing(&mut self, enabled: bool) {
        self.tracing = enabled;
    }

    /// Write one message.
    pub fn send(&mut self, msg: &Msg) -> Result<(), String> {
        wire::write_msg(&mut self.stream, msg, &mut self.scratch)
    }

    /// Read one message (blocking, subject to the stream's read timeout).
    /// Hot-path collections draw from the transport's decode scratch.
    pub fn recv(&mut self) -> Result<Msg, String> {
        wire::read_msg_with(&mut self.stream, &mut self.scratch, &mut self.decode)
    }
}

impl Transport for TcpTransport {
    fn submit(
        &mut self,
        job: u64,
        worker: usize,
        kind: TaskKind,
        demand: f64,
    ) -> Result<(), String> {
        let full =
            self.coalescer.push(SubmitItem { job, worker: worker as u32, kind, demand });
        if full {
            if let Some(msg) = self.coalescer.flush_frame(None) {
                self.send(&msg)?;
            }
        }
        Ok(())
    }

    fn submit_traced(
        &mut self,
        job: u64,
        worker: usize,
        kind: TaskKind,
        demand: f64,
        origin_ns: u64,
    ) -> Result<(), String> {
        if !self.tracing {
            return self.submit(job, worker, kind, demand);
        }
        let item = SubmitItem { job, worker: worker as u32, kind, demand };
        let full =
            self.coalescer.push_traced(item, Some((origin_ns, crate::obs::trace::now_ns())));
        if full {
            if let Some(msg) = self.coalescer.flush_frame(None) {
                self.send(&msg)?;
            }
        }
        Ok(())
    }

    fn ship_span(&mut self, span: WireSpan) {
        if self.tracing && self.outbox.len() < TRACE_OUTBOX_CAP {
            self.outbox.push(span);
        }
    }

    fn set_clock_estimate(&mut self, offset_ns: f64, err_ns: f64) {
        self.offset_ns = offset_ns;
        self.err_ns = err_ns;
    }

    fn tick(
        &mut self,
        epoch: u64,
        lambda_local: f64,
        qlen: &mut [usize],
        completions: &mut Vec<WireCompletion>,
    ) -> Result<TickOutcome, String> {
        let mut beat = self
            .coalescer
            .flush_frame(Some((epoch, lambda_local)))
            .expect("a beat-carrying flush always produces a frame");
        // Clock exchanges and span shipment ride only plain-Tick beats:
        // a tick piggybacked on a batch frame has no TickTrace appendix,
        // so the outbox waits for the next bare beat (common at any load
        // where the coalescer flushed before the beat fired).
        let mut sent_t0 = 0u64;
        if self.tracing {
            if let Msg::Tick { trace, .. } = &mut beat {
                let take = self.outbox.len().min(TRACE_SPANS_PER_TICK);
                let spans: Vec<WireSpan> = self.outbox.drain(..take).collect();
                sent_t0 = crate::obs::trace::now_ns();
                *trace = Some(TickTrace {
                    t0_ns: sent_t0,
                    offset_ns: self.offset_ns,
                    err_ns: self.err_ns,
                    spans,
                });
            }
        }
        self.send(&beat)?;
        let mut reply = match self.recv()? {
            Msg::TickReply(r) => r,
            other => return Err(format!("expected TickReply, got {:?}", other.tag())),
        };
        let t3 = if self.tracing { crate::obs::trace::now_ns() } else { 0 };
        if reply.qlen.len() != qlen.len() {
            return Err(format!(
                "probe vector length {} does not match the {}-worker cluster",
                reply.qlen.len(),
                qlen.len()
            ));
        }
        for (out, &p) in qlen.iter_mut().zip(reply.qlen.iter()) {
            *out = p as usize;
        }
        completions.extend_from_slice(&reply.completions);
        let outcome = TickOutcome {
            lambda_live: reply.lambda_live,
            estimates: reply.estimates.take(),
            stop: reply.stop,
            drained: reply.drained,
            trace: reply
                .trace
                .take()
                .map(|r| BeatTrace { t0_ns: sent_t0, t3_ns: t3, reply: r }),
        };
        // Hand the completion buffer back to the decode scratch so the
        // next beat's reply decodes allocation-free.
        self.decode.recycle(Msg::TickReply(reply));
        Ok(outcome)
    }

    fn export(
        &mut self,
        views: &[EstimateView],
        lambda_hat: f64,
        diverged: bool,
    ) -> Result<(), String> {
        self.send(&Msg::SyncExport {
            shard: self.shard,
            diverged,
            lambda_hat,
            views: views.to_vec(),
        })
    }

    fn flush_due(&mut self) -> Result<(), String> {
        if self.coalescer.due() {
            if let Some(msg) = self.coalescer.flush_frame(None) {
                self.send(&msg)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::{self, CompletionSink, PayloadMode};
    use std::time::Duration;

    fn item(job: u64) -> SubmitItem {
        SubmitItem { job, worker: 2, kind: TaskKind::Real, demand: 0.004 }
    }

    #[test]
    fn coalescer_flushes_at_batch_size() {
        let mut c = SubmitCoalescer::new(3, Duration::from_secs(3600));
        assert!(!c.push(item(1)));
        assert!(!c.push(item(2)));
        assert!(c.push(item(3)), "third push fills the batch");
        match c.flush_frame(None) {
            Some(Msg::SubmitBatch { tick: None, items, trace: None }) => {
                assert_eq!(items.iter().map(|i| i.job).collect::<Vec<_>>(), vec![1, 2, 3]);
            }
            other => panic!("expected a tickless batch, got {other:?}"),
        }
        assert!(c.is_empty());
        assert_eq!(c.flush_frame(None), None, "nothing pending, no beat: silence");
    }

    #[test]
    fn coalescer_flushes_at_deadline() {
        let mut c = SubmitCoalescer::new(1024, Duration::from_micros(50));
        assert!(!c.due(), "empty buffer never becomes due");
        c.push(item(9));
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.due(), "oldest item waited past the deadline");
        // A two-item deadline flush is a batch frame.
        c.push(item(10));
        match c.flush_frame(None) {
            Some(Msg::SubmitBatch { tick: None, items, trace: None }) => {
                assert_eq!(items.len(), 2)
            }
            other => panic!("expected a batch, got {other:?}"),
        }
        assert!(!c.due(), "flush rearms the deadline");
    }

    #[test]
    fn coalescer_piggybacks_the_beat() {
        let mut c = SubmitCoalescer::new(8, Duration::from_secs(3600));
        c.push(item(4));
        match c.flush_frame(Some((7, 12.5))) {
            Some(Msg::SubmitBatch { tick: Some((7, l)), items, trace: None }) => {
                assert_eq!(l, 12.5);
                assert_eq!(items.len(), 1);
            }
            other => panic!("expected a beat-carrying batch, got {other:?}"),
        }
        // With nothing buffered the beat degrades to a plain Tick.
        assert_eq!(
            c.flush_frame(Some((8, 1.0))),
            Some(Msg::Tick { epoch: 8, lambda_local: 1.0, trace: None })
        );
    }

    #[test]
    fn batch_of_one_is_bit_compatible_with_the_eager_protocol() {
        // At B=1 the coalescer's byte stream must be exactly what the
        // unbatched transport wrote: plain Submit frames and plain Ticks.
        let mut c = SubmitCoalescer::new(1, Duration::ZERO);
        assert!(c.push(item(77)), "B=1 flushes on every push");
        let flushed = c.flush_frame(None).expect("one item pending");
        let eager = Msg::Submit {
            job: 77,
            worker: 2,
            kind: TaskKind::Real,
            demand: 0.004,
            trace: None,
        };
        assert_eq!(flushed, eager);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        flushed.encode_into(&mut a);
        eager.encode_into(&mut b);
        assert_eq!(a, b, "identical frames on the wire");
        let beat = c.flush_frame(Some((3, 9.0))).expect("beat");
        let (mut a, mut b) = (Vec::new(), Vec::new());
        beat.encode_into(&mut a);
        Msg::Tick { epoch: 3, lambda_local: 9.0, trace: None }.encode_into(&mut b);
        assert_eq!(a, b, "an empty flush carrying a beat is a plain Tick");
    }

    #[test]
    fn coalescer_carries_trace_stamps_for_the_sampled_subset() {
        // Anchor the trace clock before flushing so the send stamps
        // below are strictly positive.
        crate::obs::trace::now_ns();
        // Two of three buffered tasks are trace-sampled: the flushed
        // batch carries exactly their stamps, indexed into the item list,
        // with a send stamp no earlier than either enqueue stamp.
        let mut c = SubmitCoalescer::new(3, Duration::from_secs(3600));
        c.push_traced(item(1), Some((100, 200)));
        c.push(item(2));
        c.push_traced(item(3), Some((300, 400)));
        match c.flush_frame(None) {
            Some(Msg::SubmitBatch { tick: None, items, trace: Some(t) }) => {
                assert_eq!(items.len(), 3);
                assert_eq!(t.stamps, vec![(0, 100, 200), (2, 300, 400)]);
                assert!(t.send_ns > 0, "flush stamps the send instant");
            }
            other => panic!("expected a stamped batch, got {other:?}"),
        }
        assert!(c.is_empty());

        // A sampled single-item tickless flush degrades to Submit and
        // keeps its stamps as a SubmitTrace appendix.
        c.push_traced(item(9), Some((7, 8)));
        match c.flush_frame(None) {
            Some(Msg::Submit { job: 9, trace: Some(t), .. }) => {
                assert_eq!((t.origin_ns, t.enq_ns), (7, 8));
                assert!(t.send_ns > 0, "flush stamps the send instant");
            }
            other => panic!("expected a traced Submit, got {other:?}"),
        }

        // Stamps do not leak across flushes: the next batch is traceless.
        c.push(item(11));
        c.push(item(12));
        match c.flush_frame(None) {
            Some(Msg::SubmitBatch { trace: None, .. }) => {}
            other => panic!("expected a traceless batch, got {other:?}"),
        }
    }

    #[test]
    fn coalescer_clamps_batch_to_the_frame_bound() {
        let c = SubmitCoalescer::new(usize::MAX, Duration::ZERO);
        assert_eq!(c.batch, wire::MAX_BATCH_ITEMS);
        let c = SubmitCoalescer::new(0, Duration::ZERO);
        assert_eq!(c.batch, 1, "B=0 degrades to unbatched, not to a stall");
    }

    #[test]
    fn local_transport_submits_probes_and_drains() {
        let (tx, rx) = std::sync::mpsc::channel();
        let pool: Vec<_> = (0..2)
            .map(|i| {
                let sink = CompletionSink::sharded(vec![tx.clone()]);
                worker::spawn(i, 4.0, PayloadMode::Sleep, sink)
            })
            .collect();
        drop(tx);
        let table = Arc::new(EstimateTable::new(2, 1.0));
        let views = Arc::new(SharedViews::new(1, 2, 1.0));
        let stop = Arc::new(AtomicBool::new(false));
        let slots = vec![Arc::new(AtomicU64::new(0f64.to_bits()))];
        let start = Instant::now();
        let mut t = LocalTransport::new(
            pool.iter().map(|w| w.client.clone()).collect(),
            rx,
            table.clone(),
            views.clone(),
            slots.clone(),
            0,
            stop.clone(),
            start,
        );

        t.submit(5, 0, TaskKind::Real, 0.002).unwrap();
        t.submit(6, 1, TaskKind::Benchmark, 0.002).unwrap();
        assert!(t.submit(9, 7, TaskKind::Real, 0.002).is_err(), "unknown worker");

        // First beat: no consensus published yet, epoch matches.
        let mut qlen = vec![0usize; 2];
        let mut comps = Vec::new();
        let out = t.tick(table.epoch(), 42.0, &mut qlen, &mut comps).unwrap();
        assert!(out.estimates.is_none());
        assert!(!out.stop && !out.drained);
        assert_eq!(out.lambda_live, 42.0, "live λ̂ is the sum of the slots");

        // A publish moves the epoch: the next beat serves fresh estimates.
        table.publish(&[2.0, 0.5], 10.0);
        let out = t.tick(0, 42.0, &mut qlen, &mut comps).unwrap();
        let est = out.estimates.expect("epoch moved");
        assert_eq!(est.mu_hat, vec![2.0, 0.5]);
        assert_eq!(est.lambda, 10.0);

        // Exports land in the shard's slot; divergence raises the flag.
        t.export(&[EstimateView { mu_hat: 2.0, samples: 3 }; 2], 7.0, true).unwrap();
        assert!(views.take_merge_request());
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        assert_eq!(buf[0].lambda_hat, 7.0);

        // Stop: the transport releases its ingress handles; once the pool
        // exits, the beat reports drained with both completions delivered.
        stop.store(true, Ordering::Relaxed);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut drained = false;
        let mut pool = Some(pool);
        while !drained {
            assert!(Instant::now() < deadline, "drain timed out");
            let out = t.tick(table.epoch(), 0.0, &mut qlen, &mut comps).unwrap();
            assert!(out.stop);
            drained = out.drained;
            if let Some(pool) = pool.take() {
                // Shut the pool down after the transport dropped its
                // handles (first post-stop tick above).
                for w in pool {
                    w.shutdown();
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(comps.len(), 2, "both completions delivered: {comps:?}");
        assert!(comps.iter().any(|c| c.job == 5 && c.kind == TaskKind::Real));
        assert!(comps.iter().any(|c| c.job == 6 && c.kind == TaskKind::Benchmark));
        assert!(comps.iter().all(|c| c.at >= 0.0 && c.duration > 0.0));
        // Post-stop submits are dropped silently, not errors.
        t.submit(9, 0, TaskKind::Real, 0.001).unwrap();
    }
}
