//! Kernel-event-driven socket readiness for the net data plane: a
//! dependency-free [`Poller`] over raw `epoll` syscalls, with a portable
//! sweep fallback.
//!
//! The pool server's data plane used to learn about socket readiness by
//! sweeping every connection and napping [`super::server`]'s `IDLE_SLEEP`
//! when nothing moved — cheap to build, but it taxes light load with up to
//! a nap of added latency per frame and taxes saturation with one
//! `read`/`write` attempt per connection per sweep whether or not the
//! socket has anything to say. This module replaces the sweep with the
//! kernel's readiness queue while keeping the repo's "std only, no libc
//! crate" rule:
//!
//! * **epoll backend** (Linux x86_64/aarch64) — `epoll_create1`,
//!   `epoll_ctl`, and `epoll_wait` invoked through inline-asm syscall
//!   stubs, exactly the no-libc pattern [`crate::plane::topo`] established
//!   for `sched_setaffinity`. Level-triggered, one epoll instance per poll
//!   shard, the connection token carried in `epoll_event.data`.
//! * **sweep fallback** (everything else, or when the kernel refuses —
//!   seccomp filters in tight containers return `EPERM`/`ENOSYS`) — the
//!   old readiness sweep behind the same API: `wait` naps for the caller's
//!   timeout and then reports every registered token readable *and*
//!   writable, so the shard loop degenerates to exactly the pre-epoll
//!   sweep + idle-nap behavior.
//!
//! Selection happens at runtime in [`Poller::new`]; the
//! [`FORCE_FALLBACK_ENV`] environment variable (any non-empty value other
//! than `0`) or [`Poller::fallback`] force the portable path, which is how
//! the loopback tests pin both backends to the same conservation
//! contracts.

use std::net::TcpStream;
use std::time::Duration;

/// Set (non-empty, not `"0"`) to force the portable sweep fallback even
/// where the kernel backend is available — the CI/debug lever for
/// comparing the two paths on the same machine.
pub const FORCE_FALLBACK_ENV: &str = "ROSELLA_FORCE_POLL_FALLBACK";

/// Most events one [`Poller::wait`] call can surface (per poll shard; a
/// shard rarely owns more than a handful of connections).
const MAX_EVENTS: usize = 256;

/// One readiness report: the token passed at registration plus which
/// directions the socket is ready for. Error/hangup conditions surface as
/// `readable` so the owner's next read observes the failure directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollEvent {
    /// Caller-chosen registration token (the connection index).
    pub token: usize,
    /// The socket has bytes to read (or an error/hangup to observe).
    pub readable: bool,
    /// The socket would accept a write.
    pub writable: bool,
}

enum Backend {
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    Epoll(sys::Epoll),
    /// The portable readiness sweep: every registered token is reported
    /// ready after the idle nap, reproducing the pre-epoll poll loop.
    Sweep { tokens: Vec<usize> },
}

/// A readiness poller over nonblocking [`TcpStream`]s — kernel-backed
/// where the raw epoll syscalls are available and permitted, a portable
/// sweep otherwise. Same API either way, chosen at runtime.
pub struct Poller {
    backend: Backend,
}

impl Poller {
    /// Build the best poller this process can get: the kernel backend
    /// unless the platform lacks it, the kernel refuses it, or
    /// [`FORCE_FALLBACK_ENV`] demands the sweep.
    pub fn new() -> Self {
        if forced_fallback() {
            return Self::fallback();
        }
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            if let Some(ep) = sys::Epoll::new() {
                return Poller { backend: Backend::Epoll(ep) };
            }
        }
        Self::fallback()
    }

    /// Build the portable sweep poller unconditionally.
    pub fn fallback() -> Self {
        Poller { backend: Backend::Sweep { tokens: Vec::new() } }
    }

    /// Whether this poller waits on the kernel's readiness queue (`false`:
    /// the sweep fallback).
    pub fn is_kernel_backed(&self) -> bool {
        match &self.backend {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backend::Epoll(_) => true,
            Backend::Sweep { .. } => false,
        }
    }

    /// Backend name for logs and reports.
    pub fn backend_name(&self) -> &'static str {
        if self.is_kernel_backed() {
            "epoll"
        } else {
            "sweep"
        }
    }

    /// Register `stream` under `token`. Read interest is always on;
    /// `writable` adds write interest (see [`Poller::set_writable`]).
    pub fn register(
        &mut self,
        stream: &TcpStream,
        token: usize,
        writable: bool,
    ) -> Result<(), String> {
        match &mut self.backend {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backend::Epoll(ep) => ep.add(stream, token, writable),
            Backend::Sweep { tokens } => {
                if !tokens.contains(&token) {
                    tokens.push(token);
                }
                Ok(())
            }
        }
    }

    /// Flip write interest for an already-registered stream. The shard
    /// loop arms this only while a connection has staged bytes the socket
    /// would not accept, so an idle writable socket never spins the wait.
    pub fn set_writable(
        &mut self,
        stream: &TcpStream,
        token: usize,
        writable: bool,
    ) -> Result<(), String> {
        match &mut self.backend {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backend::Epoll(ep) => ep.modify(stream, token, writable),
            // The sweep reports every token writable every pass; interest
            // tracking would change nothing.
            Backend::Sweep { .. } => Ok(()),
        }
    }

    /// Remove a stream from the poller (done connections).
    pub fn deregister(&mut self, stream: &TcpStream, token: usize) -> Result<(), String> {
        match &mut self.backend {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backend::Epoll(ep) => ep.del(stream),
            Backend::Sweep { tokens } => {
                tokens.retain(|&t| t != token);
                Ok(())
            }
        }
    }

    /// Collect readiness into `events` (cleared first), waiting at most
    /// `timeout`. Returns the event count. A zero timeout polls without
    /// blocking; the sweep backend naps the full timeout and then reports
    /// everything ready (the old sweep + idle-nap, bit for bit).
    pub fn wait(
        &mut self,
        events: &mut Vec<PollEvent>,
        timeout: Duration,
    ) -> Result<usize, String> {
        events.clear();
        match &mut self.backend {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backend::Epoll(ep) => ep.wait(events, timeout),
            Backend::Sweep { tokens } => {
                if !timeout.is_zero() {
                    std::thread::sleep(timeout);
                }
                for &token in tokens.iter() {
                    events.push(PollEvent { token, readable: true, writable: true });
                    if events.len() == MAX_EVENTS {
                        break;
                    }
                }
                Ok(events.len())
            }
        }
    }
}

impl Default for Poller {
    fn default() -> Self {
        Self::new()
    }
}

fn forced_fallback() -> bool {
    std::env::var(FORCE_FALLBACK_ENV).map_or(false, |v| !v.is_empty() && v != "0")
}

/// Raw epoll over inline-asm syscalls — no libc crate, the same pattern
/// `plane/topo.rs` uses for `sched_setaffinity`. Everything in here is
/// best-effort at construction ([`Epoll::new`] returns `None` when the
/// kernel refuses) and loud afterwards: a failing `epoll_ctl` on a live
/// run is a bug, not a degradation.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use super::{PollEvent, MAX_EVENTS};
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: usize = 1;
    const EPOLL_CTL_DEL: usize = 2;
    const EPOLL_CTL_MOD: usize = 3;
    const EPOLL_CLOEXEC: usize = 0o2000000;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: usize = 3;
        pub const EPOLL_WAIT: usize = 232;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_CREATE1: usize = 291;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const EPOLL_CREATE1: usize = 20;
        pub const EPOLL_CTL: usize = 21;
        /// aarch64 never had plain `epoll_wait`; `epoll_pwait` with a null
        /// sigmask is the same call.
        pub const EPOLL_PWAIT: usize = 22;
        pub const CLOSE: usize = 57;
    }

    /// The kernel's `struct epoll_event`. Packed on x86_64 only — that
    /// ABI quirk predates 64-bit and every libc reproduces it.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    /// Six-argument raw syscall; unused trailing arguments pass 0. Returns
    /// the kernel's raw result (negative errno on failure).
    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        nr: usize,
        a0: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") nr as isize => ret,
            in("rdi") a0,
            in("rsi") a1,
            in("rdx") a2,
            in("r10") a3,
            in("r8") a4,
            in("r9") a5,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        nr: usize,
        a0: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc #0",
            in("x8") nr,
            inlateout("x0") a0 as isize => ret,
            in("x1") a1,
            in("x2") a2,
            in("x3") a3,
            in("x4") a4,
            in("x5") a5,
            options(nostack),
        );
        ret
    }

    const EINTR: isize = 4;

    pub struct Epoll {
        fd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        /// `epoll_create1(EPOLL_CLOEXEC)`; `None` when the kernel refuses
        /// (seccomp `EPERM`/`ENOSYS`), which degrades the caller to the
        /// sweep backend rather than failing the run.
        pub fn new() -> Option<Epoll> {
            let fd = unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) };
            if fd < 0 {
                return None;
            }
            Some(Epoll {
                fd: fd as i32,
                buf: vec![EpollEvent { events: 0, data: 0 }; MAX_EVENTS],
            })
        }

        fn interest(writable: bool) -> u32 {
            // EPOLLERR/EPOLLHUP are always reported; naming them keeps the
            // intent visible.
            let mut ev = EPOLLIN | EPOLLERR | EPOLLHUP;
            if writable {
                ev |= EPOLLOUT;
            }
            ev
        }

        fn ctl(&self, op: usize, fd: i32, ev: Option<EpollEvent>) -> Result<(), String> {
            let evp = ev
                .as_ref()
                .map_or(std::ptr::null(), |e| e as *const EpollEvent);
            let r = unsafe {
                syscall6(nr::EPOLL_CTL, self.fd as usize, op, fd as usize, evp as usize, 0, 0)
            };
            if r < 0 {
                Err(format!("epoll_ctl op {op} fd {fd}: errno {}", -r))
            } else {
                Ok(())
            }
        }

        pub fn add(
            &mut self,
            stream: &TcpStream,
            token: usize,
            writable: bool,
        ) -> Result<(), String> {
            let ev = EpollEvent { events: Self::interest(writable), data: token as u64 };
            self.ctl(EPOLL_CTL_ADD, stream.as_raw_fd(), Some(ev))
        }

        pub fn modify(
            &mut self,
            stream: &TcpStream,
            token: usize,
            writable: bool,
        ) -> Result<(), String> {
            let ev = EpollEvent { events: Self::interest(writable), data: token as u64 };
            self.ctl(EPOLL_CTL_MOD, stream.as_raw_fd(), Some(ev))
        }

        pub fn del(&mut self, stream: &TcpStream) -> Result<(), String> {
            self.ctl(EPOLL_CTL_DEL, stream.as_raw_fd(), None)
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<PollEvent>,
            timeout: Duration,
        ) -> Result<usize, String> {
            // epoll's timeout granularity is milliseconds; a sub-ms
            // timeout rounds *up* so a "nap" never turns into a busy spin.
            let ms: usize = if timeout.is_zero() {
                0
            } else {
                (timeout.as_millis() as usize).clamp(1, 1000)
            };
            let n = unsafe {
                #[cfg(target_arch = "x86_64")]
                {
                    syscall6(
                        nr::EPOLL_WAIT,
                        self.fd as usize,
                        self.buf.as_mut_ptr() as usize,
                        self.buf.len(),
                        ms,
                        0,
                        0,
                    )
                }
                #[cfg(target_arch = "aarch64")]
                {
                    // epoll_pwait(fd, events, max, timeout, sigmask=NULL,
                    // sigsetsize=0) — identical to epoll_wait.
                    syscall6(
                        nr::EPOLL_PWAIT,
                        self.fd as usize,
                        self.buf.as_mut_ptr() as usize,
                        self.buf.len(),
                        ms,
                        0,
                        0,
                    )
                }
            };
            if n == -EINTR {
                return Ok(0);
            }
            if n < 0 {
                return Err(format!("epoll_wait: errno {}", -n));
            }
            for ev in &self.buf[..(n as usize).min(self.buf.len())] {
                // Copy out of the (possibly packed) struct before use.
                let bits = ev.events;
                let token = ev.data as usize;
                events.push(PollEvent {
                    token,
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                });
            }
            Ok(events.len())
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                syscall6(nr::CLOSE, self.fd as usize, 0, 0, 0, 0, 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    /// A connected nonblocking loopback pair (server side, client side).
    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (server, client)
    }

    #[test]
    fn fallback_sweep_reports_every_registered_token_ready() {
        let (s1, _c1) = pair();
        let (s2, _c2) = pair();
        let mut p = Poller::fallback();
        assert!(!p.is_kernel_backed());
        assert_eq!(p.backend_name(), "sweep");
        p.register(&s1, 0, false).unwrap();
        p.register(&s2, 1, true).unwrap();
        let mut events = Vec::new();
        let n = p.wait(&mut events, Duration::ZERO).unwrap();
        assert_eq!(n, 2);
        // The sweep is the old poll loop: everything is claimed readable
        // and writable every pass, data or not.
        assert!(events.iter().all(|e| e.readable && e.writable));
        let tokens: Vec<usize> = events.iter().map(|e| e.token).collect();
        assert!(tokens.contains(&0) && tokens.contains(&1));
        p.deregister(&s1, 0).unwrap();
        let n = p.wait(&mut events, Duration::ZERO).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token, 1);
    }

    #[test]
    fn kernel_poller_wakes_on_readable_data() {
        let mut p = Poller::new();
        if !p.is_kernel_backed() {
            // Platform or sandbox without epoll: the runtime selection
            // itself is the behavior under test, and it chose the sweep.
            return;
        }
        assert_eq!(p.backend_name(), "epoll");
        let (server, mut client) = pair();
        p.register(&server, 7, false).unwrap();
        let mut events = Vec::new();
        // No data, no write interest: nothing is ready.
        let n = p.wait(&mut events, Duration::from_millis(1)).unwrap();
        assert_eq!(n, 0, "spurious readiness: {events:?}");
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let n = p.wait(&mut events, Duration::from_millis(500)).unwrap();
        assert_eq!(n, 1, "no wakeup for readable data");
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        // Write interest surfaces an idle socket as writable.
        p.set_writable(&server, 7, true).unwrap();
        let n = p.wait(&mut events, Duration::from_millis(500)).unwrap();
        assert!(n >= 1);
        assert!(events.iter().any(|e| e.token == 7 && e.writable));
        p.deregister(&server, 7).unwrap();
        let n = p.wait(&mut events, Duration::from_millis(1)).unwrap();
        assert_eq!(n, 0, "deregistered stream still reported: {events:?}");
    }

    #[test]
    fn kernel_poller_reports_hangup_as_readable() {
        let mut p = Poller::new();
        if !p.is_kernel_backed() {
            return;
        }
        let (server, client) = pair();
        p.register(&server, 3, false).unwrap();
        drop(client);
        let mut events = Vec::new();
        let n = p.wait(&mut events, Duration::from_millis(500)).unwrap();
        assert!(n >= 1, "peer hangup produced no event");
        assert!(
            events.iter().any(|e| e.token == 3 && e.readable),
            "hangup must surface as readable so the owner's read sees EOF"
        );
    }
}
