//! The Rosella net-plane wire protocol: a versioned, length-prefixed binary
//! framing with explicit little-endian encoding, built on `std` only.
//!
//! Every frame is `MAGIC (4) | version u16 | tag u16 | payload_len u32 |
//! payload`, all integers little-endian. Floats travel as their IEEE-754
//! bit patterns (`f64::to_bits`), so encode/decode round-trips are
//! bit-exact — including infinities, subnormals, and negative zero — and a
//! consensus vector read off the wire is the same vector that was
//! published. Payloads are bounded by [`MAX_PAYLOAD`]; a frame claiming
//! more is rejected from its header alone, before any allocation.
//!
//! The message set ([`Msg`]) is exactly the §5 coordination surface plus
//! run management:
//!
//! * `Hello`/`HelloAck`/`Start` — handshake: a frontend claims shard
//!   `i` of `k`, the pool server replies with the shared run
//!   configuration (worker speeds, rates, seeds, sync policy), and
//!   `Start` releases all frontends at once;
//! * `Submit` — one task dispatch (real or benchmark), fire-and-forget;
//! * `SubmitBatch` — N coalesced dispatches in one frame, optionally
//!   piggybacking the `Tick` beat so a saturated frontend pays one frame
//!   header and one write syscall per batch instead of per task;
//! * `Tick`/`TickReply` — the coordination beat: queue-length probes,
//!   routed completions, the live λ̂ bootstrap, fresh consensus estimates
//!   when the seqlock epoch moved, and the stop/drained run-state flags;
//! * `SyncExport` — the scheduler's [`SyncPayload`] half: per-worker
//!   estimate views plus its local arrival share λ̂ₛ (and the adaptive
//!   policy's divergence flag), fire-and-forget;
//! * `Done`/`DoneAck` — final per-frontend statistics for the merged
//!   cross-process report.
//!
//! [`SyncPayload`]: crate::learner::SyncPayload

use crate::learner::EstimateView;
use crate::types::TaskKind;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

/// Frame magic: the four bytes every Rosella net-plane frame starts with.
pub const MAGIC: [u8; 4] = *b"RSNP";

/// Protocol version this build speaks (and the version stamped on frames
/// carrying v3-only fields). v2 added the `SubmitBatch` frame and the
/// submit-coalescing policy fields in `HelloAck`; v3 added the optional
/// tracing/clock appendices (`SubmitTrace`, `BatchTrace`, `TickTrace`,
/// `ReplyTrace`, `AckClock`). A frame's version is decided per message:
/// one with no appendix encodes as [`MIN_VERSION`], byte-identical to a
/// v2 build's output, so a v2 peer interoperates until the first frame
/// that actually carries trace data.
pub const VERSION: u16 = 3;

/// Oldest protocol version this build still accepts (and emits, for
/// appendix-free frames).
pub const MIN_VERSION: u16 = 2;

/// Frame header length: magic + version + tag + payload length.
pub const HEADER_LEN: usize = 12;

/// Upper bound on a frame payload in bytes. Large enough for thousands of
/// workers or completions per frame; a header claiming more is rejected
/// before any payload is read or allocated.
pub const MAX_PAYLOAD: usize = 1 << 20;

const TAG_HELLO: u16 = 1;
const TAG_HELLO_ACK: u16 = 2;
const TAG_START: u16 = 3;
const TAG_SUBMIT: u16 = 4;
const TAG_TICK: u16 = 5;
const TAG_TICK_REPLY: u16 = 6;
const TAG_SYNC_EXPORT: u16 = 7;
const TAG_DONE: u16 = 8;
const TAG_DONE_ACK: u16 = 9;
const TAG_SUBMIT_BATCH: u16 = 10;

/// Why a frame failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the header (or the header's payload length) needs.
    Truncated,
    /// The first four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version field outside [`MIN_VERSION`]`..=`[`VERSION`].
    BadVersion(u16),
    /// Unknown message tag.
    BadTag(u16),
    /// Payload length exceeds [`MAX_PAYLOAD`].
    TooLarge(usize),
    /// Structurally invalid payload.
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic(m) => write!(f, "bad magic {m:?} (not a rosella net frame)"),
            WireError::BadVersion(v) => {
                write!(f, "protocol version {v} (this build speaks {MIN_VERSION}..={VERSION})")
            }
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::TooLarge(n) => {
                write!(f, "frame payload {n} bytes exceeds the {MAX_PAYLOAD}-byte bound")
            }
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

/// A consensus snapshot served to a frontend when the estimate-table epoch
/// moved: the merged μ̂ vector, λ̂_global, and the epoch it corresponds to.
#[derive(Debug, Clone, PartialEq)]
pub struct Estimates {
    /// Merged per-worker speed estimates.
    pub mu_hat: Vec<f64>,
    /// Exchanged-share λ̂_global (tasks/second).
    pub lambda: f64,
    /// Seqlock epoch of this publication.
    pub epoch: u64,
}

/// One completion report shipped back to the scheduler that routed the
/// task. Times are seconds on the pool server's run clock (`at` since run
/// start), so every frontend's learner sees one consistent timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireCompletion {
    /// Job id as submitted (shard bits + local counter).
    pub job: u64,
    /// Worker that served the task.
    pub worker: u32,
    /// Real workload or learner benchmark.
    pub kind: TaskKind,
    /// Task demand in unit-speed seconds.
    pub demand: f64,
    /// Measured service duration (seconds).
    pub duration: f64,
    /// Queueing + service time since the server-side enqueue (seconds).
    pub sojourn: f64,
    /// Completion instant, seconds since run start.
    pub at: f64,
}

/// Encoded size of one [`WireCompletion`]: u64 + u32 + u8 + 4×f64.
const COMPLETION_LEN: usize = 8 + 4 + 1 + 4 * 8;

/// One task dispatch inside a [`Msg::SubmitBatch`] frame: the same fields
/// as a standalone `Submit`, packed back to back so a saturated frontend
/// amortizes the frame header and the write syscall over N tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmitItem {
    /// Job id (shard bits + local counter; benchmark sentinel allowed).
    pub job: u64,
    /// Target worker.
    pub worker: u32,
    /// Real or benchmark.
    pub kind: TaskKind,
    /// Demand in unit-speed seconds.
    pub demand: f64,
}

/// Encoded size of one [`SubmitItem`]: u64 + u32 + u8 + f64.
const SUBMIT_ITEM_LEN: usize = 8 + 4 + 1 + 8;

/// Encoded size of one [`BatchTrace`] stamp: u32 idx + 2×u64.
const BATCH_STAMP_LEN: usize = 4 + 8 + 8;

/// Encoded size of one [`WireSpan`]: u64 job + u64 origin + 6×u32 stages.
const WIRE_SPAN_LEN: usize = 8 + 8 + 6 * 4;

/// Encoded size of one [`CompletionTrace`]: u32 idx + 5×u64 stamps.
const COMPLETION_TRACE_LEN: usize = 4 + 5 * 8;

/// Most tasks a single `SubmitBatch` frame can carry within
/// [`MAX_PAYLOAD`]. Worst case subtracted first: the 17-byte
/// piggyback-tick prefix, the 4-byte item count, the v3 trace appendix
/// header (8-byte send stamp + 4-byte stamp count), and — at 1/1 sampling
/// — one 20-byte trace stamp riding along with every item. Coalescers
/// must flush at or below this bound.
pub const MAX_BATCH_ITEMS: usize =
    (MAX_PAYLOAD - 17 - 4 - 8 - 4) / (SUBMIT_ITEM_LEN + BATCH_STAMP_LEN);

/// Encoded size of one [`EstimateView`]: f64 + u64.
const VIEW_LEN: usize = 16;

/// Server-side handshake clock stamps plus the advertised trace-sampling
/// policy, appended to a v3 `HelloAck`. Together with the frontend's
/// `Hello` send stamp and its `HelloAck` receive stamp these form the
/// first four-timestamp NTP-style exchange seeding
/// [`ClockAlign`](crate::obs::trace::ClockAlign).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AckClock {
    /// Server trace-clock stamp at `Hello` receive.
    pub t1_ns: u64,
    /// Server trace-clock stamp at `HelloAck` send.
    pub t2_ns: u64,
    /// Trace-sampling modulus N the whole run uses (tasks are traced iff
    /// `sampled(job, N)`; 0 = tracing off).
    pub sample_n: u32,
}

/// Frontend-side lifecycle stamps riding a v3 `Submit` of a sampled task:
/// all nanoseconds on the frontend's trace clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitTrace {
    /// Task arrival (span origin).
    pub origin_ns: u64,
    /// Placement decision made / coalescing-buffer enqueue.
    pub enq_ns: u64,
    /// Frame send.
    pub send_ns: u64,
}

/// Trace appendix of a v3 `SubmitBatch`: one shared frame-send stamp plus
/// per-item arrival/enqueue stamps for the sampled subset.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BatchTrace {
    /// Frame send stamp shared by every item (they flush together).
    pub send_ns: u64,
    /// `(item index, origin_ns, enq_ns)` for each sampled item, index
    /// order matching `items`.
    pub stamps: Vec<(u32, u64, u64)>,
}

/// One completed task span shipped frontend → server on a `Tick`, so the
/// pool server's `/metrics` and `/trace` surfaces aggregate the full
/// cross-process decomposition. `origin_us` is pre-mapped onto the
/// *server's* trace timeline via the frontend's clock-offset estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireSpan {
    /// Task id.
    pub job: u64,
    /// Span start in µs on the server trace timeline.
    pub origin_us: u64,
    /// Per-stage durations in µs (see [`crate::obs::trace::STAGES`]).
    pub stages_us: [u32; 6],
}

/// Trace appendix of a v3 `Tick`: a clock-exchange send stamp, the
/// frontend's current offset estimate (exported as gauges server-side),
/// and completed spans since the last beat.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TickTrace {
    /// Frontend trace-clock stamp at `Tick` send (the exchange's t0).
    pub t0_ns: u64,
    /// Frontend's current estimate of server−frontend clock offset, ns.
    pub offset_ns: f64,
    /// Error bound on `offset_ns`, ns.
    pub err_ns: f64,
    /// Completed sampled spans, mapped onto the server timeline.
    pub spans: Vec<WireSpan>,
}

/// Echoed lifecycle stamps for one sampled completion inside a v3
/// `TickReply`: everything the frontend needs to assemble the span
/// without keeping per-task state of its own.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompletionTrace {
    /// Index into the reply's `completions` vector.
    pub idx: u32,
    /// Frontend stamps echoed back from the `Submit`/`SubmitBatch`.
    pub origin_ns: u64,
    /// Frontend enqueue stamp, echoed.
    pub enq_ns: u64,
    /// Frontend frame-send stamp, echoed.
    pub send_ns: u64,
    /// Server trace-clock stamp at submit-frame receive.
    pub recv_ns: u64,
    /// Server trace-clock stamp of the task's completion.
    pub done_ns: u64,
}

/// Trace appendix of a v3 `TickReply`: the server's clock-exchange stamps
/// (t1/t2 of the NTP exchange the `Tick`'s t0 opened) plus echoed stamps
/// for the sampled completions in this reply.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ReplyTrace {
    /// Server trace-clock stamp at `Tick` receive.
    pub t1_ns: u64,
    /// Server trace-clock stamp at `TickReply` send.
    pub t2_ns: u64,
    /// Echoed stamps for sampled completions, `idx`-ascending.
    pub traced: Vec<CompletionTrace>,
}

/// The shared run configuration the pool server hands each frontend at
/// handshake, so `rosella frontend` needs nothing beyond `--connect` and
/// `--shard`: both sides derive identical parameters from one source.
#[derive(Debug, Clone, PartialEq)]
pub struct HelloAck {
    /// Worker count n.
    pub workers: u32,
    /// Arrival ingestion batch size per frontend.
    pub batch: u32,
    /// Submit-coalescing batch size B: tasks buffered per wire frame.
    pub net_batch: u32,
    /// Submit-coalescing flush deadline D in microseconds: the longest a
    /// buffered task may wait before it is flushed regardless of fill.
    pub net_flush_us: f64,
    /// Run seed (per-shard streams derived via `shard_seeds`).
    pub seed: u64,
    /// Prior speed estimate (mean configured speed).
    pub prior: f64,
    /// Mean task demand τ̄ (unit-speed seconds).
    pub mean_demand: f64,
    /// Guaranteed total throughput μ̄ (tasks/second).
    pub mu_bar: f64,
    /// Aggregate arrival rate (jobs/second) to split across shards.
    pub rate: f64,
    /// Run duration in seconds (informational; stop is server-driven).
    pub duration: f64,
    /// Warmup cutoff for response metrics (seconds).
    pub warmup: f64,
    /// Local learner publish/export cadence (seconds).
    pub publish_interval: f64,
    /// Estimate-sync consensus interval (seconds).
    pub sync_interval: f64,
    /// Adaptive sync divergence threshold (unscaled).
    pub sync_threshold: f64,
    /// Whether frontends run their benchmark dispatchers.
    pub fake_jobs: bool,
    /// Scheduling policy, in `PolicyKind::parse` spelling.
    pub policy: String,
    /// Sync strategy, in `SyncKind::parse` spelling.
    pub sync_policy: String,
    /// Configured worker speeds (diagnostics; decisions use estimates).
    pub speeds: Vec<f64>,
    /// v3: handshake clock stamps + trace-sampling policy. `None` when
    /// the server answers a v2 frontend (the ack then encodes as v2).
    pub clock: Option<AckClock>,
}

/// The coordination beat's reply: everything a remote scheduler needs to
/// keep deciding — fresh probes, its routed completions, consensus when the
/// epoch moved, and the run-state flags.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TickReply {
    /// Per-worker queue-length probes.
    pub qlen: Vec<u32>,
    /// Live sum of every shard's last reported λ̂ₛ — the throttle bootstrap
    /// before the first consensus publish carries an exchanged λ̂_global.
    pub lambda_live: f64,
    /// The run passed its deadline: stop deciding, start draining.
    pub stop: bool,
    /// The pool fully drained and every completion for this shard has been
    /// shipped: the frontend may send its final export and `Done`.
    pub drained: bool,
    /// Fresh consensus, present iff the table epoch moved past the epoch
    /// the frontend reported in its `Tick`.
    pub estimates: Option<Estimates>,
    /// Completions of tasks this shard routed, oldest first.
    pub completions: Vec<WireCompletion>,
    /// v3: clock-exchange stamps + echoed stamps for sampled
    /// completions. `None` on the v2 wire or with tracing off.
    pub trace: Option<ReplyTrace>,
}

/// Final per-frontend statistics for the merged cross-process report.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DoneStats {
    /// Scheduling decisions made.
    pub decisions: u64,
    /// Real tasks submitted.
    pub dispatched: u64,
    /// Benchmark tasks submitted.
    pub benchmarks: u64,
    /// Jobs in the latency record (post-warmup).
    pub resp_count: u64,
    /// Mean response time (seconds).
    pub resp_mean: f64,
    /// Median response time (seconds).
    pub resp_p50: f64,
    /// 95th-percentile response time (seconds).
    pub resp_p95: f64,
}

/// One wire message. See the module docs for the protocol roles.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Frontend → server: claim shard `shard` of `shards`.
    Hello {
        /// Shard index in `0..shards`.
        shard: u32,
        /// Total scheduler count k.
        shards: u32,
        /// v3: frontend trace-clock stamp at `Hello` send (t0 of the
        /// handshake clock exchange). `None` encodes a v2 frame.
        t0_ns: Option<u64>,
    },
    /// Server → frontend: the shared run configuration.
    HelloAck(HelloAck),
    /// Server → frontend: all shards connected; the run begins now.
    Start,
    /// Frontend → server: dispatch one task (fire-and-forget).
    Submit {
        /// Job id (shard bits + local counter; benchmark sentinel allowed).
        job: u64,
        /// Target worker.
        worker: u32,
        /// Real or benchmark.
        kind: TaskKind,
        /// Demand in unit-speed seconds.
        demand: f64,
        /// v3: lifecycle stamps of a sampled task. `None` (every
        /// unsampled task) encodes a v2-bit-compatible frame.
        trace: Option<SubmitTrace>,
    },
    /// Frontend → server: N coalesced task dispatches in one frame, with
    /// an optional piggybacked coordination beat. When `tick` is present
    /// the server answers with a `TickReply` exactly as for a standalone
    /// `Tick`; without it the frame is fire-and-forget like `Submit`.
    SubmitBatch {
        /// Piggybacked beat: (consensus epoch held, live local λ̂ₛ).
        tick: Option<(u64, f64)>,
        /// Coalesced dispatches, submission order preserved.
        items: Vec<SubmitItem>,
        /// v3: stamps for the sampled subset of `items`. `None` (no
        /// sampled item in the batch) encodes a v2-bit-compatible frame.
        trace: Option<BatchTrace>,
    },
    /// Frontend → server: one coordination beat.
    Tick {
        /// The consensus epoch the frontend currently holds.
        epoch: u64,
        /// The frontend's live local arrival estimate λ̂ₛ.
        lambda_local: f64,
        /// v3: clock-exchange stamp, offset estimate, and completed
        /// spans. `None` (tracing off) encodes a v2 frame.
        trace: Option<TickTrace>,
    },
    /// Server → frontend: reply to `Tick`.
    TickReply(TickReply),
    /// Frontend → server: sync-payload export (fire-and-forget).
    SyncExport {
        /// Exporting shard (must match the connection's claimed shard).
        shard: u32,
        /// Adaptive policy: local estimates diverged past the threshold.
        diverged: bool,
        /// Local arrival share λ̂ₛ (tasks/second).
        lambda_hat: f64,
        /// Per-worker estimate views with merge weights.
        views: Vec<EstimateView>,
    },
    /// Frontend → server: final statistics; last message on the socket.
    Done(DoneStats),
    /// Server → frontend: statistics recorded, the socket may close.
    DoneAck,
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

fn put_kind(out: &mut Vec<u8>, k: TaskKind) {
    out.push(match k {
        TaskKind::Real => 0,
        TaskKind::Benchmark => 1,
    });
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_f64(out, x);
    }
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        put_u32(out, x);
    }
}

fn put_views(out: &mut Vec<u8>, views: &[EstimateView]) {
    put_u32(out, views.len() as u32);
    for v in views {
        put_f64(out, v.mu_hat);
        put_u64(out, v.samples);
    }
}

fn put_items(out: &mut Vec<u8>, items: &[SubmitItem]) {
    put_u32(out, items.len() as u32);
    for it in items {
        put_u64(out, it.job);
        put_u32(out, it.worker);
        put_kind(out, it.kind);
        put_f64(out, it.demand);
    }
}

fn put_completions(out: &mut Vec<u8>, cs: &[WireCompletion]) {
    put_u32(out, cs.len() as u32);
    for c in cs {
        put_u64(out, c.job);
        put_u32(out, c.worker);
        put_kind(out, c.kind);
        put_f64(out, c.demand);
        put_f64(out, c.duration);
        put_f64(out, c.sojourn);
        put_f64(out, c.at);
    }
}

/// Reusable decode buffers for the hot-path message collections:
/// `SubmitBatch` items and `TickReply` completions. [`Msg::decode_with`]
/// moves these (cleared, capacity retained) into the decoded message;
/// [`DecodeScratch::recycle`] reclaims them once the message is handled,
/// so a connection's steady-state receive loop allocates only until its
/// buffers reach the high-water frame size.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    items: Vec<SubmitItem>,
    completions: Vec<WireCompletion>,
}

impl DecodeScratch {
    /// Empty scratch; buffers grow to the connection's frame sizes on use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reclaim the hot-path buffers from a handled message. Call with the
    /// message a prior [`Msg::decode_with`] produced once its contents are
    /// no longer needed; non-collection messages are a no-op.
    pub fn recycle(&mut self, msg: Msg) {
        match msg {
            Msg::SubmitBatch { items, .. } => {
                if items.capacity() > self.items.capacity() {
                    self.items = items;
                    self.items.clear();
                }
            }
            Msg::TickReply(r) => {
                if r.completions.capacity() > self.completions.capacity() {
                    self.completions = r.completions;
                    self.completions.clear();
                }
            }
            _ => {}
        }
    }
}

/// Bounds-checked little-endian reader over a payload slice.
struct Cur<'a> {
    buf: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() < n {
            return Err(WireError::Truncated);
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    /// Unconsumed payload remains — a v3 appendix follows.
    fn has_more(&self) -> bool {
        !self.buf.is_empty()
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("sized take")))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("sized take")))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn boolean(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bool out of range")),
        }
    }

    fn kind(&mut self) -> Result<TaskKind, WireError> {
        match self.u8()? {
            0 => Ok(TaskKind::Real),
            1 => Ok(TaskKind::Benchmark),
            _ => Err(WireError::Malformed("task kind out of range")),
        }
    }

    /// Read a count and verify the remaining payload can actually hold
    /// that many `elem`-byte elements, so a hostile count never drives an
    /// allocation beyond the (already bounded) frame size. Division, not
    /// `n * elem`: the multiply could wrap on 32-bit targets and defeat
    /// the bound.
    fn count(&mut self, elem: usize) -> Result<usize, WireError> {
        debug_assert!(elem > 0, "zero-sized wire element");
        let n = self.u32()? as usize;
        if n > self.buf.len() / elem {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.count(1)?;
        // Validate against the borrowed slice and copy once; going through
        // `String::from_utf8(to_vec())` would copy before validating and
        // pay twice for every accepted string.
        std::str::from_utf8(self.take(n)?)
            .map(str::to_owned)
            .map_err(|_| WireError::Malformed("string is not utf-8"))
    }

    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.count(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    fn views(&mut self) -> Result<Vec<EstimateView>, WireError> {
        let n = self.count(VIEW_LEN)?;
        (0..n)
            .map(|_| {
                Ok(EstimateView { mu_hat: self.f64()?, samples: self.u64()? })
            })
            .collect()
    }

    /// Decode the item array into `out` (cleared first), reusing its
    /// capacity — the allocation-free half of [`Msg::decode_with`].
    fn items_into(&mut self, out: &mut Vec<SubmitItem>) -> Result<(), WireError> {
        out.clear();
        let n = self.count(SUBMIT_ITEM_LEN)?;
        out.reserve(n);
        for _ in 0..n {
            out.push(SubmitItem {
                job: self.u64()?,
                worker: self.u32()?,
                kind: self.kind()?,
                demand: self.f64()?,
            });
        }
        Ok(())
    }

    /// Decode the completion array into `out` (cleared first), reusing its
    /// capacity.
    fn completions_into(&mut self, out: &mut Vec<WireCompletion>) -> Result<(), WireError> {
        out.clear();
        let n = self.count(COMPLETION_LEN)?;
        out.reserve(n);
        for _ in 0..n {
            out.push(WireCompletion {
                job: self.u64()?,
                worker: self.u32()?,
                kind: self.kind()?,
                demand: self.f64()?,
                duration: self.f64()?,
                sojourn: self.f64()?,
                at: self.f64()?,
            });
        }
        Ok(())
    }

    fn done(&self) -> Result<(), WireError> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes"))
        }
    }
}

/// Validate a frame header and return its payload length.
pub fn header_payload_len(header: &[u8; HEADER_LEN]) -> Result<usize, WireError> {
    let magic: [u8; 4] = header[0..4].try_into().expect("sized slice");
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::BadVersion(version));
    }
    let len = u32::from_le_bytes(header[8..12].try_into().expect("sized slice")) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::TooLarge(len));
    }
    Ok(len)
}

impl Msg {
    /// This message's wire tag.
    pub fn tag(&self) -> u16 {
        match self {
            Msg::Hello { .. } => TAG_HELLO,
            Msg::HelloAck(_) => TAG_HELLO_ACK,
            Msg::Start => TAG_START,
            Msg::Submit { .. } => TAG_SUBMIT,
            Msg::SubmitBatch { .. } => TAG_SUBMIT_BATCH,
            Msg::Tick { .. } => TAG_TICK,
            Msg::TickReply(_) => TAG_TICK_REPLY,
            Msg::SyncExport { .. } => TAG_SYNC_EXPORT,
            Msg::Done(_) => TAG_DONE,
            Msg::DoneAck => TAG_DONE_ACK,
        }
    }

    /// The version this specific message encodes as: [`VERSION`] iff it
    /// carries a v3 trace/clock appendix, [`MIN_VERSION`] otherwise —
    /// so appendix-free frames stay byte-identical to a v2 build's
    /// output and a v2 peer decodes them unchanged.
    pub fn wire_version(&self) -> u16 {
        let v3 = match self {
            Msg::Hello { t0_ns, .. } => t0_ns.is_some(),
            Msg::HelloAck(a) => a.clock.is_some(),
            Msg::Submit { trace, .. } => trace.is_some(),
            Msg::SubmitBatch { trace, .. } => trace.is_some(),
            Msg::Tick { trace, .. } => trace.is_some(),
            Msg::TickReply(r) => r.trace.is_some(),
            _ => false,
        };
        if v3 {
            VERSION
        } else {
            MIN_VERSION
        }
    }

    /// Append one complete frame (header + payload) to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&MAGIC);
        put_u16(out, self.wire_version());
        put_u16(out, self.tag());
        let len_at = out.len();
        put_u32(out, 0);
        let body_start = out.len();
        self.encode_body(out);
        let len = out.len() - body_start;
        debug_assert!(len <= MAX_PAYLOAD, "oversized frame encoded");
        out[len_at..len_at + 4].copy_from_slice(&(len as u32).to_le_bytes());
    }

    fn encode_body(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Hello { shard, shards, t0_ns } => {
                put_u32(out, *shard);
                put_u32(out, *shards);
                if let Some(t0) = t0_ns {
                    put_u64(out, *t0);
                }
            }
            Msg::HelloAck(a) => {
                put_u32(out, a.workers);
                put_u32(out, a.batch);
                put_u32(out, a.net_batch);
                put_f64(out, a.net_flush_us);
                put_u64(out, a.seed);
                put_f64(out, a.prior);
                put_f64(out, a.mean_demand);
                put_f64(out, a.mu_bar);
                put_f64(out, a.rate);
                put_f64(out, a.duration);
                put_f64(out, a.warmup);
                put_f64(out, a.publish_interval);
                put_f64(out, a.sync_interval);
                put_f64(out, a.sync_threshold);
                put_bool(out, a.fake_jobs);
                put_str(out, &a.policy);
                put_str(out, &a.sync_policy);
                put_f64s(out, &a.speeds);
                if let Some(c) = &a.clock {
                    put_u64(out, c.t1_ns);
                    put_u64(out, c.t2_ns);
                    put_u32(out, c.sample_n);
                }
            }
            Msg::Start | Msg::DoneAck => {}
            Msg::Submit { job, worker, kind, demand, trace } => {
                put_u64(out, *job);
                put_u32(out, *worker);
                put_kind(out, *kind);
                put_f64(out, *demand);
                if let Some(t) = trace {
                    put_u64(out, t.origin_ns);
                    put_u64(out, t.enq_ns);
                    put_u64(out, t.send_ns);
                }
            }
            Msg::SubmitBatch { tick, items, trace } => {
                match tick {
                    None => out.push(0),
                    Some((epoch, lambda_local)) => {
                        out.push(1);
                        put_u64(out, *epoch);
                        put_f64(out, *lambda_local);
                    }
                }
                put_items(out, items);
                if let Some(t) = trace {
                    put_u64(out, t.send_ns);
                    put_u32(out, t.stamps.len() as u32);
                    for (idx, origin, enq) in &t.stamps {
                        put_u32(out, *idx);
                        put_u64(out, *origin);
                        put_u64(out, *enq);
                    }
                }
            }
            Msg::Tick { epoch, lambda_local, trace } => {
                put_u64(out, *epoch);
                put_f64(out, *lambda_local);
                if let Some(t) = trace {
                    put_u64(out, t.t0_ns);
                    put_f64(out, t.offset_ns);
                    put_f64(out, t.err_ns);
                    put_u32(out, t.spans.len() as u32);
                    for s in &t.spans {
                        put_u64(out, s.job);
                        put_u64(out, s.origin_us);
                        for &st in &s.stages_us {
                            put_u32(out, st);
                        }
                    }
                }
            }
            Msg::TickReply(r) => {
                put_u32s(out, &r.qlen);
                put_f64(out, r.lambda_live);
                put_bool(out, r.stop);
                put_bool(out, r.drained);
                match &r.estimates {
                    None => out.push(0),
                    Some(e) => {
                        out.push(1);
                        put_f64s(out, &e.mu_hat);
                        put_f64(out, e.lambda);
                        put_u64(out, e.epoch);
                    }
                }
                put_completions(out, &r.completions);
                if let Some(t) = &r.trace {
                    put_u64(out, t.t1_ns);
                    put_u64(out, t.t2_ns);
                    put_u32(out, t.traced.len() as u32);
                    for ct in &t.traced {
                        put_u32(out, ct.idx);
                        put_u64(out, ct.origin_ns);
                        put_u64(out, ct.enq_ns);
                        put_u64(out, ct.send_ns);
                        put_u64(out, ct.recv_ns);
                        put_u64(out, ct.done_ns);
                    }
                }
            }
            Msg::SyncExport { shard, diverged, lambda_hat, views } => {
                put_u32(out, *shard);
                put_bool(out, *diverged);
                put_f64(out, *lambda_hat);
                put_views(out, views);
            }
            Msg::Done(d) => {
                put_u64(out, d.decisions);
                put_u64(out, d.dispatched);
                put_u64(out, d.benchmarks);
                put_u64(out, d.resp_count);
                put_f64(out, d.resp_mean);
                put_f64(out, d.resp_p50);
                put_f64(out, d.resp_p95);
            }
        }
    }

    /// Decode exactly one complete frame from `frame`.
    pub fn decode(frame: &[u8]) -> Result<Msg, WireError> {
        Self::decode_with(frame, &mut DecodeScratch::default())
    }

    /// Decode exactly one complete frame from `frame`, drawing the decoded
    /// message's hot-path collections (`SubmitBatch` items, `TickReply`
    /// completions) from `scratch` instead of fresh allocations. Pair with
    /// [`DecodeScratch::recycle`] after the message is handled and the
    /// steady-state receive path stops allocating entirely. Scratch
    /// buffers are cleared before they are filled, so a reused buffer can
    /// never leak a previous frame's contents — even when this decode
    /// fails partway through a hostile or truncated frame.
    pub fn decode_with(frame: &[u8], scratch: &mut DecodeScratch) -> Result<Msg, WireError> {
        if frame.len() < HEADER_LEN {
            return Err(WireError::Truncated);
        }
        let header: &[u8; HEADER_LEN] =
            frame[..HEADER_LEN].try_into().expect("sized slice");
        let len = header_payload_len(header)?;
        let version = u16::from_le_bytes([frame[4], frame[5]]);
        let tag = u16::from_le_bytes([frame[6], frame[7]]);
        let body = &frame[HEADER_LEN..];
        if body.len() < len {
            return Err(WireError::Truncated);
        }
        if body.len() > len {
            return Err(WireError::Malformed("trailing bytes"));
        }
        Self::decode_body(tag, version, body, scratch)
    }

    fn decode_body(
        tag: u16,
        version: u16,
        body: &[u8],
        scratch: &mut DecodeScratch,
    ) -> Result<Msg, WireError> {
        // A v3 frame's trace/clock appendix is present iff payload bytes
        // remain after the v2 fields; a v2 frame with leftover bytes is
        // malformed (caught by `c.done()` below). A v3 header over an
        // appendix-free payload is accepted and decodes to `None`.
        let v3 = version >= VERSION;
        let mut c = Cur { buf: body };
        let msg = match tag {
            TAG_HELLO => Msg::Hello {
                shard: c.u32()?,
                shards: c.u32()?,
                t0_ns: if v3 && c.has_more() { Some(c.u64()?) } else { None },
            },
            TAG_HELLO_ACK => {
                let mut a = HelloAck {
                    workers: c.u32()?,
                    batch: c.u32()?,
                    net_batch: c.u32()?,
                    net_flush_us: c.f64()?,
                    seed: c.u64()?,
                    prior: c.f64()?,
                    mean_demand: c.f64()?,
                    mu_bar: c.f64()?,
                    rate: c.f64()?,
                    duration: c.f64()?,
                    warmup: c.f64()?,
                    publish_interval: c.f64()?,
                    sync_interval: c.f64()?,
                    sync_threshold: c.f64()?,
                    fake_jobs: c.boolean()?,
                    policy: c.string()?,
                    sync_policy: c.string()?,
                    speeds: c.f64s()?,
                    clock: None,
                };
                if v3 && c.has_more() {
                    a.clock = Some(AckClock {
                        t1_ns: c.u64()?,
                        t2_ns: c.u64()?,
                        sample_n: c.u32()?,
                    });
                }
                Msg::HelloAck(a)
            }
            TAG_START => Msg::Start,
            TAG_SUBMIT => Msg::Submit {
                job: c.u64()?,
                worker: c.u32()?,
                kind: c.kind()?,
                demand: c.f64()?,
                trace: if v3 && c.has_more() {
                    Some(SubmitTrace {
                        origin_ns: c.u64()?,
                        enq_ns: c.u64()?,
                        send_ns: c.u64()?,
                    })
                } else {
                    None
                },
            },
            TAG_SUBMIT_BATCH => {
                let tick = match c.u8()? {
                    0 => None,
                    1 => Some((c.u64()?, c.f64()?)),
                    _ => return Err(WireError::Malformed("tick flag out of range")),
                };
                c.items_into(&mut scratch.items)?;
                let trace = if v3 && c.has_more() {
                    let send_ns = c.u64()?;
                    let n = c.count(BATCH_STAMP_LEN)?;
                    let mut stamps = Vec::with_capacity(n);
                    for _ in 0..n {
                        stamps.push((c.u32()?, c.u64()?, c.u64()?));
                    }
                    Some(BatchTrace { send_ns, stamps })
                } else {
                    None
                };
                Msg::SubmitBatch {
                    tick,
                    items: std::mem::take(&mut scratch.items),
                    trace,
                }
            }
            TAG_TICK => Msg::Tick {
                epoch: c.u64()?,
                lambda_local: c.f64()?,
                trace: if v3 && c.has_more() {
                    let t0_ns = c.u64()?;
                    let offset_ns = c.f64()?;
                    let err_ns = c.f64()?;
                    let n = c.count(WIRE_SPAN_LEN)?;
                    let mut spans = Vec::with_capacity(n);
                    for _ in 0..n {
                        let job = c.u64()?;
                        let origin_us = c.u64()?;
                        let mut stages_us = [0u32; 6];
                        for st in &mut stages_us {
                            *st = c.u32()?;
                        }
                        spans.push(WireSpan { job, origin_us, stages_us });
                    }
                    Some(TickTrace { t0_ns, offset_ns, err_ns, spans })
                } else {
                    None
                },
            },
            TAG_TICK_REPLY => {
                let qlen = c.u32s()?;
                let lambda_live = c.f64()?;
                let stop = c.boolean()?;
                let drained = c.boolean()?;
                let estimates = match c.u8()? {
                    0 => None,
                    1 => Some(Estimates {
                        mu_hat: c.f64s()?,
                        lambda: c.f64()?,
                        epoch: c.u64()?,
                    }),
                    _ => return Err(WireError::Malformed("estimates flag out of range")),
                };
                c.completions_into(&mut scratch.completions)?;
                let completions = std::mem::take(&mut scratch.completions);
                let trace = if v3 && c.has_more() {
                    let t1_ns = c.u64()?;
                    let t2_ns = c.u64()?;
                    let n = c.count(COMPLETION_TRACE_LEN)?;
                    let mut traced = Vec::with_capacity(n);
                    for _ in 0..n {
                        traced.push(CompletionTrace {
                            idx: c.u32()?,
                            origin_ns: c.u64()?,
                            enq_ns: c.u64()?,
                            send_ns: c.u64()?,
                            recv_ns: c.u64()?,
                            done_ns: c.u64()?,
                        });
                    }
                    Some(ReplyTrace { t1_ns, t2_ns, traced })
                } else {
                    None
                };
                Msg::TickReply(TickReply {
                    qlen,
                    lambda_live,
                    stop,
                    drained,
                    estimates,
                    completions,
                    trace,
                })
            }
            TAG_SYNC_EXPORT => Msg::SyncExport {
                shard: c.u32()?,
                diverged: c.boolean()?,
                lambda_hat: c.f64()?,
                views: c.views()?,
            },
            TAG_DONE => Msg::Done(DoneStats {
                decisions: c.u64()?,
                dispatched: c.u64()?,
                benchmarks: c.u64()?,
                resp_count: c.u64()?,
                resp_mean: c.f64()?,
                resp_p50: c.f64()?,
                resp_p95: c.f64()?,
            }),
            TAG_DONE_ACK => Msg::DoneAck,
            other => return Err(WireError::BadTag(other)),
        };
        c.done()?;
        Ok(msg)
    }
}

// Process-global wire traffic counters, bumped on every framed write/read
// regardless of which connection carried it. Globals rather than per-
// transport state because the framing functions below are free functions
// with no context — and "how much wire traffic did this process move" is
// exactly the per-process question the `/metrics` endpoint answers.
static FRAMES_SENT: AtomicU64 = AtomicU64::new(0);
static FRAMES_RECEIVED: AtomicU64 = AtomicU64::new(0);
static BYTES_SENT: AtomicU64 = AtomicU64::new(0);
static BYTES_RECEIVED: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the process-wide wire traffic counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WireTotals {
    /// Frames written by [`write_msg`] since process start.
    pub frames_sent: u64,
    /// Frames fully read and decoded by [`read_msg`].
    pub frames_received: u64,
    /// Bytes written (headers + payloads).
    pub bytes_sent: u64,
    /// Bytes read (headers + payloads).
    pub bytes_received: u64,
}

/// Read the process-wide wire traffic counters (relaxed loads; the four
/// fields are independently monotone, not a consistent snapshot).
pub fn frame_totals() -> WireTotals {
    WireTotals {
        frames_sent: FRAMES_SENT.load(Ordering::Relaxed),
        frames_received: FRAMES_RECEIVED.load(Ordering::Relaxed),
        bytes_sent: BYTES_SENT.load(Ordering::Relaxed),
        bytes_received: BYTES_RECEIVED.load(Ordering::Relaxed),
    }
}

/// Record `n` frames totalling `bytes` bytes written outside [`write_msg`]
/// — the nonblocking pool server frames into its own per-connection write
/// buffers, so it reports traffic here once a frame is fully queued.
pub fn note_frames_sent(n: u64, bytes: u64) {
    FRAMES_SENT.fetch_add(n, Ordering::Relaxed);
    BYTES_SENT.fetch_add(bytes, Ordering::Relaxed);
}

/// Record `n` frames totalling `bytes` bytes read and decoded outside
/// [`read_msg`] (the nonblocking poll loop's reassembly path).
pub fn note_frames_received(n: u64, bytes: u64) {
    FRAMES_RECEIVED.fetch_add(n, Ordering::Relaxed);
    BYTES_RECEIVED.fetch_add(bytes, Ordering::Relaxed);
}

/// Encode `msg` into `scratch` and write the frame to `w`.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg, scratch: &mut Vec<u8>) -> Result<(), String> {
    scratch.clear();
    msg.encode_into(scratch);
    w.write_all(scratch).map_err(|e| format!("net write: {e}"))?;
    FRAMES_SENT.fetch_add(1, Ordering::Relaxed);
    BYTES_SENT.fetch_add(scratch.len() as u64, Ordering::Relaxed);
    Ok(())
}

/// Read one frame from `r` (using `scratch` as the reassembly buffer) and
/// decode it. Header validation happens before the payload is read, so an
/// oversized or alien frame is rejected without buffering it.
pub fn read_msg<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Msg, String> {
    read_msg_with(r, scratch, &mut DecodeScratch::default())
}

/// [`read_msg`] with caller-owned decode scratch: the decoded message's
/// hot-path collections draw from `decode` instead of fresh allocations
/// (pair with [`DecodeScratch::recycle`]), so a transport's steady-state
/// receive loop stops allocating entirely.
pub fn read_msg_with<R: Read>(
    r: &mut R,
    scratch: &mut Vec<u8>,
    decode: &mut DecodeScratch,
) -> Result<Msg, String> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).map_err(|e| format!("net read header: {e}"))?;
    let len = header_payload_len(&header).map_err(|e| format!("net frame: {e}"))?;
    scratch.clear();
    scratch.extend_from_slice(&header);
    scratch.resize(HEADER_LEN + len, 0);
    r.read_exact(&mut scratch[HEADER_LEN..])
        .map_err(|e| format!("net read body: {e}"))?;
    let msg = Msg::decode_with(scratch, decode).map_err(|e| format!("net frame: {e}"))?;
    FRAMES_RECEIVED.fetch_add(1, Ordering::Relaxed);
    BYTES_RECEIVED.fetch_add(scratch.len() as u64, Ordering::Relaxed);
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(mu: f64, s: u64) -> EstimateView {
        EstimateView { mu_hat: mu, samples: s }
    }

    fn sample_completion() -> WireCompletion {
        WireCompletion {
            job: (3u64 << 48) | 41,
            worker: 2,
            kind: TaskKind::Real,
            demand: 0.01,
            duration: 0.02,
            sojourn: 0.05,
            at: 1.25,
        }
    }

    fn sample_ack() -> HelloAck {
        HelloAck {
            workers: 8,
            batch: 64,
            net_batch: 64,
            net_flush_us: 200.0,
            seed: 42,
            prior: 0.8125,
            mean_demand: 0.01,
            mu_bar: 650.0,
            rate: 400.0,
            duration: 3.0,
            warmup: 0.5,
            publish_interval: 0.2,
            sync_interval: 0.2,
            sync_threshold: 0.1,
            fake_jobs: true,
            policy: "ppot".into(),
            sync_policy: "adaptive".into(),
            speeds: vec![2.0, 1.0, 0.5, 0.25],
            clock: None,
        }
    }

    /// One sample message per variant, covering empty and non-empty
    /// collections, both `estimates` arms, and every v3 trace appendix
    /// in both its present and absent form.
    fn every_variant() -> Vec<Msg> {
        vec![
            Msg::Hello { shard: 1, shards: 4, t0_ns: None },
            Msg::Hello { shard: 0, shards: 2, t0_ns: Some(123_456_789) },
            Msg::HelloAck(sample_ack()),
            Msg::HelloAck(HelloAck {
                clock: Some(AckClock { t1_ns: 1_000, t2_ns: 2_000, sample_n: 64 }),
                ..sample_ack()
            }),
            Msg::Start,
            Msg::Submit {
                job: 7,
                worker: 3,
                kind: TaskKind::Benchmark,
                demand: 0.003,
                trace: None,
            },
            Msg::Submit {
                job: 64,
                worker: 1,
                kind: TaskKind::Real,
                demand: 0.007,
                trace: Some(SubmitTrace { origin_ns: 10, enq_ns: 20, send_ns: 30 }),
            },
            Msg::Tick { epoch: 12, lambda_local: 99.5, trace: None },
            Msg::Tick {
                epoch: 13,
                lambda_local: 50.25,
                trace: Some(TickTrace {
                    t0_ns: 5_000,
                    offset_ns: -250.5,
                    err_ns: 80.0,
                    spans: vec![WireSpan {
                        job: (2u64 << 48) | 5,
                        origin_us: 1_000,
                        stages_us: [1, 2, 3, 4, 5, 6],
                    }],
                }),
            },
            Msg::Tick {
                epoch: 14,
                lambda_local: 1.0,
                trace: Some(TickTrace::default()),
            },
            Msg::SubmitBatch {
                tick: Some((12, 99.5)),
                items: vec![
                    SubmitItem { job: 7, worker: 3, kind: TaskKind::Real, demand: 0.003 },
                    SubmitItem {
                        job: (1u64 << 48) | 9,
                        worker: 0,
                        kind: TaskKind::Benchmark,
                        demand: 0.001,
                    },
                ],
                trace: None,
            },
            Msg::SubmitBatch {
                tick: None,
                items: vec![SubmitItem {
                    job: 1,
                    worker: 1,
                    kind: TaskKind::Real,
                    demand: 0.01,
                }],
                trace: None,
            },
            Msg::SubmitBatch {
                tick: Some((3, 10.0)),
                items: vec![
                    SubmitItem { job: 5, worker: 0, kind: TaskKind::Real, demand: 0.02 },
                    SubmitItem { job: 6, worker: 2, kind: TaskKind::Real, demand: 0.03 },
                ],
                trace: Some(BatchTrace {
                    send_ns: 40,
                    stamps: vec![(1, 11, 22)],
                }),
            },
            Msg::SubmitBatch { tick: Some((0, 0.0)), items: vec![], trace: None },
            Msg::TickReply(TickReply {
                qlen: vec![0, 3, 1, 7],
                lambda_live: 123.0,
                stop: false,
                drained: false,
                estimates: Some(Estimates {
                    mu_hat: vec![1.5, 0.75],
                    lambda: 200.0,
                    epoch: 14,
                }),
                completions: vec![sample_completion()],
                trace: None,
            }),
            Msg::TickReply(TickReply {
                completions: vec![sample_completion()],
                trace: Some(ReplyTrace {
                    t1_ns: 7_000,
                    t2_ns: 7_500,
                    traced: vec![CompletionTrace {
                        idx: 0,
                        origin_ns: 10,
                        enq_ns: 20,
                        send_ns: 30,
                        recv_ns: 6_000,
                        done_ns: 6_900,
                    }],
                }),
                ..TickReply::default()
            }),
            Msg::TickReply(TickReply::default()),
            Msg::SyncExport {
                shard: 2,
                diverged: true,
                lambda_hat: 51.25,
                views: vec![v(1.5, 40), v(0.0, 1), v(0.25, 0)],
            },
            Msg::SyncExport { shard: 0, diverged: false, lambda_hat: 0.0, views: vec![] },
            Msg::Done(DoneStats {
                decisions: 1000,
                dispatched: 990,
                benchmarks: 25,
                resp_count: 980,
                resp_mean: 0.012,
                resp_p50: 0.010,
                resp_p95: 0.031,
            }),
            Msg::DoneAck,
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for msg in every_variant() {
            let mut buf = Vec::new();
            msg.encode_into(&mut buf);
            assert!(buf.len() >= HEADER_LEN);
            let back = Msg::decode(&buf).unwrap_or_else(|e| panic!("{msg:?}: {e}"));
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn floats_round_trip_bit_exactly() {
        // Bit patterns survive the wire even where PartialEq is useless:
        // infinities, subnormals, negative zero, and NaN.
        for x in [f64::INFINITY, f64::NEG_INFINITY, -0.0, 5e-324, f64::NAN, 0.1 + 0.2] {
            let msg = Msg::Tick { epoch: 0, lambda_local: x, trace: None };
            let mut buf = Vec::new();
            msg.encode_into(&mut buf);
            match Msg::decode(&buf).unwrap() {
                Msg::Tick { lambda_local, .. } => {
                    assert_eq!(lambda_local.to_bits(), x.to_bits());
                }
                other => panic!("decoded {other:?}"),
            }
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        for msg in every_variant() {
            let mut buf = Vec::new();
            msg.encode_into(&mut buf);
            for cut in 0..buf.len() {
                assert!(
                    Msg::decode(&buf[..cut]).is_err(),
                    "{msg:?} decoded from a {cut}-byte prefix of {} bytes",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut buf = Vec::new();
        Msg::Start.encode_into(&mut buf);
        buf.push(0xFF);
        assert_eq!(Msg::decode(&buf), Err(WireError::Malformed("trailing bytes")));
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut buf = Vec::new();
        Msg::Start.encode_into(&mut buf);
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(Msg::decode(&bad), Err(WireError::BadMagic(_))));
        let mut bad = buf.clone();
        bad[4] = 9;
        assert_eq!(Msg::decode(&bad), Err(WireError::BadVersion(9)));
        let header: [u8; HEADER_LEN] = bad[..HEADER_LEN].try_into().unwrap();
        assert_eq!(header_payload_len(&header), Err(WireError::BadVersion(9)));
    }

    #[test]
    fn unknown_tag_is_rejected() {
        let mut buf = Vec::new();
        Msg::Start.encode_into(&mut buf);
        buf[6..8].copy_from_slice(&999u16.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::BadTag(999)));
    }

    #[test]
    fn oversized_payload_is_rejected_from_the_header_alone() {
        let mut header = [0u8; HEADER_LEN];
        header[0..4].copy_from_slice(&MAGIC);
        header[4..6].copy_from_slice(&VERSION.to_le_bytes());
        header[6..8].copy_from_slice(&TAG_START.to_le_bytes());
        header[8..12].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        assert_eq!(header_payload_len(&header), Err(WireError::TooLarge(MAX_PAYLOAD + 1)));
        // The full decode path rejects it too, before touching the body.
        assert_eq!(Msg::decode(&header), Err(WireError::TooLarge(MAX_PAYLOAD + 1)));
    }

    #[test]
    fn hostile_counts_cannot_drive_allocations() {
        // A SyncExport claiming u32::MAX views must fail as Truncated
        // (the payload cannot hold them), not attempt the allocation.
        let mut buf = Vec::new();
        Msg::SyncExport { shard: 0, diverged: false, lambda_hat: 0.0, views: vec![] }
            .encode_into(&mut buf);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Truncated));
    }

    #[test]
    fn hostile_batch_counts_cannot_drive_allocations() {
        // A SubmitBatch claiming u32::MAX items must fail as Truncated,
        // not attempt the allocation. The count is the last u32 written
        // for an empty batch.
        let mut buf = Vec::new();
        Msg::SubmitBatch { tick: None, items: vec![], trace: None }.encode_into(&mut buf);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Truncated));
    }

    #[test]
    fn batch_capacity_fits_the_payload_bound() {
        // A frame at the documented item ceiling must encode within
        // MAX_PAYLOAD even with the piggyback tick present and every
        // item sampled (one trace stamp per item — the 1/1 worst case).
        let items =
            vec![SubmitItem { job: 0, worker: 0, kind: TaskKind::Real, demand: 0.0 }; 4];
        let stamps: Vec<(u32, u64, u64)> = (0..4).map(|i| (i, 1, 2)).collect();
        let mut buf = Vec::new();
        Msg::SubmitBatch {
            tick: Some((1, 2.0)),
            items,
            trace: Some(BatchTrace { send_ns: 3, stamps }),
        }
        .encode_into(&mut buf);
        let per_item = SUBMIT_ITEM_LEN + BATCH_STAMP_LEN;
        let overhead = buf.len() - HEADER_LEN - 4 * per_item;
        assert!(overhead + MAX_BATCH_ITEMS * per_item <= MAX_PAYLOAD);
    }

    #[test]
    fn traceless_frames_encode_as_v2_bit_compatible() {
        // The compat contract: any message without a trace/clock appendix
        // must put MIN_VERSION on the wire — the exact bytes a v2 build
        // emits — so a v2 peer decodes it unchanged.
        for msg in every_variant() {
            let mut buf = Vec::new();
            msg.encode_into(&mut buf);
            let ver = u16::from_le_bytes([buf[4], buf[5]]);
            assert_eq!(ver, msg.wire_version());
            let has_appendix = ver == VERSION;
            match &msg {
                Msg::Hello { t0_ns, .. } => assert_eq!(t0_ns.is_some(), has_appendix),
                Msg::HelloAck(a) => assert_eq!(a.clock.is_some(), has_appendix),
                Msg::Submit { trace, .. } => assert_eq!(trace.is_some(), has_appendix),
                Msg::SubmitBatch { trace, .. } => assert_eq!(trace.is_some(), has_appendix),
                Msg::Tick { trace, .. } => assert_eq!(trace.is_some(), has_appendix),
                Msg::TickReply(r) => assert_eq!(r.trace.is_some(), has_appendix),
                _ => assert_eq!(ver, MIN_VERSION, "{msg:?} must stay v2"),
            }
        }
    }

    #[test]
    fn v3_header_over_an_appendix_free_payload_decodes_to_none() {
        // A v3 peer that has nothing to append may still stamp v3; the
        // payload is the v2 layout and every optional decodes to None.
        let msgs = [
            Msg::Hello { shard: 1, shards: 4, t0_ns: None },
            Msg::Submit { job: 9, worker: 2, kind: TaskKind::Real, demand: 0.01, trace: None },
            Msg::Tick { epoch: 3, lambda_local: 7.5, trace: None },
        ];
        for msg in msgs {
            let mut buf = Vec::new();
            msg.encode_into(&mut buf);
            buf[4..6].copy_from_slice(&VERSION.to_le_bytes());
            assert_eq!(Msg::decode(&buf).unwrap(), msg);
        }
    }

    #[test]
    fn v2_header_over_a_trace_appendix_is_rejected() {
        // A frame claiming v2 but carrying appendix bytes is malformed —
        // the appendix is only ever parsed under a v3 header.
        let mut buf = Vec::new();
        Msg::Submit {
            job: 1,
            worker: 0,
            kind: TaskKind::Real,
            demand: 0.1,
            trace: Some(SubmitTrace { origin_ns: 1, enq_ns: 2, send_ns: 3 }),
        }
        .encode_into(&mut buf);
        buf[4..6].copy_from_slice(&MIN_VERSION.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Malformed("trailing bytes")));
    }

    #[test]
    fn hostile_truncated_trace_appendix_is_rejected() {
        // A v3 Submit whose appendix is cut mid-stamp (header length
        // patched to match, so the frame is internally consistent) must
        // fail as Truncated from the bounds-checked reader.
        let mut buf = Vec::new();
        Msg::Submit {
            job: 1,
            worker: 0,
            kind: TaskKind::Real,
            demand: 0.1,
            trace: Some(SubmitTrace { origin_ns: 1, enq_ns: 2, send_ns: 3 }),
        }
        .encode_into(&mut buf);
        for chop in 1..24 {
            let mut cut = buf.clone();
            cut.truncate(buf.len() - chop);
            let body_len = (cut.len() - HEADER_LEN) as u32;
            cut[8..12].copy_from_slice(&body_len.to_le_bytes());
            let got = Msg::decode(&cut);
            assert!(
                got == Err(WireError::Truncated) || got == Err(WireError::Malformed("trailing bytes")),
                "chop {chop}: {got:?}"
            );
        }
    }

    #[test]
    fn hostile_span_counts_cannot_drive_allocations() {
        // A Tick trace claiming u32::MAX spans must fail as Truncated
        // before any allocation; same for a TickReply's traced count.
        let mut buf = Vec::new();
        Msg::Tick { epoch: 1, lambda_local: 2.0, trace: Some(TickTrace::default()) }
            .encode_into(&mut buf);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Truncated));

        let mut buf = Vec::new();
        Msg::TickReply(TickReply {
            trace: Some(ReplyTrace::default()),
            ..TickReply::default()
        })
        .encode_into(&mut buf);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Truncated));

        let mut buf = Vec::new();
        Msg::SubmitBatch {
            tick: None,
            items: vec![],
            trace: Some(BatchTrace::default()),
        }
        .encode_into(&mut buf);
        let n = buf.len();
        buf[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Msg::decode(&buf), Err(WireError::Truncated));
    }

    #[test]
    fn out_of_range_enums_are_malformed() {
        let mut buf = Vec::new();
        Msg::Submit { job: 1, worker: 0, kind: TaskKind::Real, demand: 0.1, trace: None }
            .encode_into(&mut buf);
        // The kind byte sits after job (8) + worker (4).
        buf[HEADER_LEN + 12] = 7;
        assert!(matches!(Msg::decode(&buf), Err(WireError::Malformed(_))));
    }

    #[test]
    fn stream_io_round_trips_back_to_back_frames() {
        let msgs = every_variant();
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m, &mut scratch).unwrap();
        }
        let mut cursor = std::io::Cursor::new(wire);
        for m in &msgs {
            let back = read_msg(&mut cursor, &mut scratch).unwrap();
            assert_eq!(&back, m);
        }
        // The stream is exactly consumed: the next read hits EOF.
        assert!(read_msg(&mut cursor, &mut scratch).is_err());
    }

    #[test]
    fn traffic_counters_track_framed_io() {
        // The counters are process-global and other tests use the framing
        // functions concurrently, so assert monotone deltas, not equality.
        let before = frame_totals();
        let mut wire = Vec::new();
        let mut scratch = Vec::new();
        write_msg(&mut wire, &Msg::Start, &mut scratch).unwrap();
        let frame_len = wire.len() as u64;
        let mut cursor = std::io::Cursor::new(wire);
        read_msg(&mut cursor, &mut scratch).unwrap();
        let after = frame_totals();
        assert!(after.frames_sent >= before.frames_sent + 1);
        assert!(after.frames_received >= before.frames_received + 1);
        assert!(after.bytes_sent >= before.bytes_sent + frame_len);
        assert!(after.bytes_received >= before.bytes_received + frame_len);
    }

    fn batch_frame(items: Vec<SubmitItem>) -> Vec<u8> {
        let mut buf = Vec::new();
        Msg::SubmitBatch { tick: None, items, trace: None }.encode_into(&mut buf);
        buf
    }

    #[test]
    fn decode_with_matches_decode_and_reuses_recycled_buffers() {
        let mut scratch = DecodeScratch::new();
        let big: Vec<SubmitItem> = (0..64)
            .map(|i| SubmitItem {
                job: i,
                worker: (i % 4) as u32,
                kind: TaskKind::Real,
                demand: 0.001 * (i + 1) as f64,
            })
            .collect();
        let frame = batch_frame(big.clone());
        let msg = Msg::decode_with(&frame, &mut scratch).unwrap();
        assert_eq!(msg, Msg::decode(&frame).unwrap());
        scratch.recycle(msg);
        assert!(scratch.items.capacity() >= 64, "recycle dropped the buffer");

        // A smaller batch decoded through the same scratch must contain
        // exactly its own items — none of the 64 recycled ones.
        let small = vec![SubmitItem {
            job: 999,
            worker: 1,
            kind: TaskKind::Benchmark,
            demand: 0.5,
        }];
        let frame = batch_frame(small.clone());
        match Msg::decode_with(&frame, &mut scratch).unwrap() {
            Msg::SubmitBatch { items, .. } => assert_eq!(items, small),
            other => panic!("decoded {other:?}"),
        }

        // Same reuse contract on the completions path.
        let reply = TickReply {
            completions: vec![sample_completion(); 32],
            ..TickReply::default()
        };
        let mut frame = Vec::new();
        Msg::TickReply(reply.clone()).encode_into(&mut frame);
        let msg = Msg::decode_with(&frame, &mut scratch).unwrap();
        assert_eq!(msg, Msg::TickReply(reply));
        scratch.recycle(msg);
        let mut frame = Vec::new();
        Msg::TickReply(TickReply::default()).encode_into(&mut frame);
        match Msg::decode_with(&frame, &mut scratch).unwrap() {
            Msg::TickReply(r) => assert!(r.completions.is_empty()),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn reused_scratch_never_leaks_across_hostile_or_truncated_frames() {
        let mut scratch = DecodeScratch::new();
        let filler: Vec<SubmitItem> = (0..16)
            .map(|i| SubmitItem {
                job: 0xDEAD_0000 + i,
                worker: 0,
                kind: TaskKind::Real,
                demand: 1.0,
            })
            .collect();
        let frame = batch_frame(filler);
        let msg = Msg::decode_with(&frame, &mut scratch).unwrap();
        scratch.recycle(msg);

        // Hostile count: claims u32::MAX items; must fail without the
        // allocation and without disturbing the reuse contract.
        let mut hostile = batch_frame(vec![]);
        let n = hostile.len();
        hostile[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(
            Msg::decode_with(&hostile, &mut scratch),
            Err(WireError::Truncated)
        );

        // Truncated frame: every prefix fails.
        let whole = batch_frame(vec![SubmitItem {
            job: 1,
            worker: 0,
            kind: TaskKind::Real,
            demand: 0.1,
        }]);
        for cut in 0..whole.len() {
            assert!(Msg::decode_with(&whole[..cut], &mut scratch).is_err());
        }

        // After the failures, a clean empty batch through the same scratch
        // holds zero items — nothing from the 16-item fill survived.
        let frame = batch_frame(vec![]);
        match Msg::decode_with(&frame, &mut scratch).unwrap() {
            Msg::SubmitBatch { items, .. } => {
                assert!(items.is_empty(), "scratch leaked prior contents: {items:?}");
            }
            other => panic!("decoded {other:?}"),
        }
    }
}
