//! The worker-pool server: `rosella plane --listen ADDR`.
//!
//! Hosts the shared side of the cross-process plane — the live worker pool,
//! the seqlock [`EstimateTable`], the [`SharedViews`] sync-payload slots,
//! and the [`SyncPolicy`] consensus thread (the *same*
//! [`run_sync`](crate::plane::consensus) loop the in-process plane runs;
//! consensus is transport-agnostic because exports land in the same slots
//! whether they arrive from a shard thread or a socket) — and serves `k`
//! remote scheduler frontends over the
//! [`wire`](crate::net::wire) protocol.
//!
//! A sharded, kernel-event-driven data plane: the serving thread
//! handshakes all `k` frontends, then partitions the connections
//! round-robin across `N` poll-shard threads (default `min(packages, 4)`,
//! `--net-poll-shards` to override), each pinned to its package via the
//! [`PlacementPlan`] when `--pin` is on. Every shard runs a
//! [`Poller`](crate::net::poll::Poller) — raw `epoll` where available, the
//! portable readiness sweep otherwise — over nonblocking sockets with
//! per-connection read reassembly and staged write queues, so a slow peer
//! never blocks anyone and an idle plane sleeps in the kernel instead of
//! sweeping. Shards enqueue `Submit`/`SubmitBatch` dispatches into the
//! pool, answer beats with probe snapshots / routed completions / fresh
//! consensus, land `SyncExport`s in the shard's view slot, and record each
//! frontend's `Done` statistics — no per-connection handler threads, and
//! the hot receive/reply path reuses decode scratch and write-queue slots
//! so steady state allocates nothing. The run lifecycle is server-driven:
//! the serving thread stops the run at its deadline, each connection
//! releases its pool ingress on its first post-stop beat, the pool is
//! joined (via a cross-shard drain barrier) once every ingress is
//! released, frontends observe `stop`/`drained` through their beats,
//! export final views, and send `Done`; the drain-time consensus epoch
//! then merges every shard's final view exactly as the in-process plane
//! does, and the merged [`NetReport`] is the cross-process analogue of
//! [`PlaneReport`](crate::plane::PlaneReport).

use super::poll::{PollEvent, Poller};
use super::transport::{drain_completions, estimates_if_moved, lambda_total};
use super::wire::{
    self, AckClock, CompletionTrace, DecodeScratch, DoneStats, HelloAck, Msg, ReplyTrace,
    TickReply, TickTrace, WireCompletion,
};
use crate::config::Json;
use crate::obs::trace::{self as obstrace, Tracer};
use crate::obs::SpanRecord;
use crate::coordinator::worker::{self, Completion, CompletionSink, LiveTask, PayloadMode};
use crate::learner::{SyncPolicy, SyncPolicyConfig};
use crate::plane::consensus::{run_sync, SyncRun};
use crate::plane::{
    default_poll_shards, pin_current_thread, CachePadded, CpuTopology, EstimateTable, PinMode,
    PlacementPlan, SharedViews,
};
use crate::scheduler::PolicyKind;
use crate::types::TaskKind;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::IoSlice;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Completions shipped per `TickReply` at most (keeps frames far below the
/// wire bound; the remainder rides the next beat).
const MAX_COMPLETIONS_PER_REPLY: usize = 8192;

/// Protocol bound on one task's demand in unit-speed seconds. A task
/// longer than this would wedge its worker — and the drain-time pool join
/// — for its whole service time, so it is rejected as a protocol
/// violation rather than clamped.
const MAX_TASK_DEMAND: f64 = 60.0;

/// Bound on a connection's in-flight trace-stamp map: sampled submits
/// whose completions never surface (or a hostile stamp flood) stop
/// accumulating state here instead of growing without bound.
const MAX_INFLIGHT_TRACES: usize = 65_536;

/// Configuration of one pool-server run.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub listen: String,
    /// Remote scheduler count k the run waits for.
    pub frontends: usize,
    /// Worker speed multipliers (one live worker thread per entry).
    pub speeds: Vec<f64>,
    /// Scheduling policy, forwarded verbatim to the frontends
    /// (`PolicyKind::parse` spelling).
    pub policy: String,
    /// Aggregate arrival rate (jobs/second) split across frontends.
    pub rate: f64,
    /// Run duration in seconds (deadline measured from `Start`).
    pub duration: f64,
    /// Mean task demand (unit-speed seconds).
    pub mean_demand: f64,
    /// Arrival ingestion batch size per frontend.
    pub batch: usize,
    /// Submit-coalescing batch size B advertised to frontends: each
    /// frontend flushes its pending dispatches as one `SubmitBatch` frame
    /// once B accumulate (or the flush deadline fires, whichever first).
    pub net_batch: usize,
    /// Submit-coalescing flush deadline D in microseconds advertised to
    /// frontends: a partial batch never waits longer than this, so light
    /// load keeps eager-dispatch latency.
    pub net_flush_us: f64,
    /// Run seed.
    pub seed: u64,
    /// Frontend learner publish/export cadence (seconds).
    pub publish_interval: f64,
    /// Warmup cutoff for response metrics (seconds).
    pub warmup: f64,
    /// Whether frontends run their benchmark dispatchers.
    pub fake_jobs: bool,
    /// Estimate-sync consensus interval (seconds).
    pub sync_interval: f64,
    /// Consensus strategy and knobs.
    pub sync_policy: SyncPolicyConfig,
    /// Per-read socket timeout (handshake and run).
    pub read_timeout: Duration,
    /// Serve a Prometheus `/metrics` endpoint on this address for the
    /// run's duration (`None` disables the scrape listener).
    pub metrics_listen: Option<String>,
    /// Dump the decision flight recorder as JSONL to this path at drain
    /// (`None` disables recording entirely).
    pub flight_record: Option<String>,
    /// Worker-thread pinning: `None` leaves placement to the OS, `Cores`
    /// and `Sockets` pin each worker thread to a discovered CPU
    /// (best-effort; a denied affinity syscall degrades to unpinned).
    pub pin: PinMode,
    /// Poll-shard count for the data plane: `None` picks
    /// `min(packages, 4)` (clamped to the connection count), `Some(p)`
    /// forces exactly `p` shards (`--net-poll-shards`).
    pub poll_shards: Option<usize>,
    /// Force the portable readiness-sweep poller even where epoll is
    /// available — the fallback-parity test hook.
    pub force_poll_fallback: bool,
    /// Lifecycle-trace sampling 1/N, advertised to v3 frontends in the
    /// `HelloAck` clock appendix (0 disables tracing entirely; unsampled
    /// tasks stay on the allocation-free wire path either way).
    pub trace_sample: u32,
    /// Dump the run's sampled spans as Chrome trace-event JSON
    /// (Perfetto-loadable) to this path at drain.
    pub trace_json: Option<String>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            frontends: 2,
            speeds: vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
            policy: "ppot".into(),
            rate: 400.0,
            duration: 3.0,
            mean_demand: 0.01,
            batch: 64,
            net_batch: 64,
            net_flush_us: 200.0,
            seed: 42,
            publish_interval: 0.2,
            warmup: 0.0,
            fake_jobs: true,
            sync_interval: 0.2,
            sync_policy: SyncPolicyConfig::periodic(),
            read_timeout: Duration::from_secs(30),
            metrics_listen: None,
            flight_record: None,
            pin: PinMode::None,
            poll_shards: None,
            force_poll_fallback: false,
            trace_sample: 0,
            trace_json: None,
        }
    }
}

impl NetServerConfig {
    /// Validate every field before binding: the same class of config-time
    /// rejection the in-process plane performs, including the sync
    /// threshold/interval cross-checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.listen.is_empty() {
            return Err("listen address must not be empty".into());
        }
        if self.frontends == 0 {
            return Err("need at least one frontend".into());
        }
        if self.speeds.is_empty() {
            return Err("need at least one worker".into());
        }
        if !(self.rate > 0.0 && self.rate.is_finite()) {
            return Err("rate must be positive and finite".into());
        }
        if !(self.duration > 0.0 && self.duration.is_finite()) {
            return Err("duration must be positive and finite".into());
        }
        if !(self.mean_demand > 0.0 && self.mean_demand.is_finite()) {
            return Err("mean demand must be positive and finite".into());
        }
        if self.batch == 0 {
            return Err("batch must be at least 1".into());
        }
        if self.net_batch == 0 {
            return Err("net batch must be at least 1".into());
        }
        if !(self.net_flush_us >= 0.0 && self.net_flush_us.is_finite()) {
            return Err("net flush deadline must be finite and non-negative".into());
        }
        if !(self.publish_interval > 0.0 && self.publish_interval.is_finite()) {
            return Err("publish interval must be positive and finite".into());
        }
        if !(self.warmup >= 0.0 && self.warmup.is_finite()) {
            return Err("warmup must be finite and non-negative".into());
        }
        if !(self.sync_interval > 0.0 && self.sync_interval.is_finite()) {
            return Err("the net plane needs a positive finite sync interval".into());
        }
        if self.poll_shards == Some(0) {
            return Err("poll shards must be at least 1".into());
        }
        self.sync_policy
            .validate(self.sync_interval)
            .map_err(|e| format!("sync policy: {e}"))?;
        PolicyKind::parse(&self.policy)?;
        Ok(())
    }
}

/// Everything the merged cross-process report carries.
#[derive(Debug)]
pub struct NetReport {
    /// Remote frontend count.
    pub frontends: usize,
    /// Worker count.
    pub workers: usize,
    /// Policy name (as configured).
    pub policy: String,
    /// Seconds from `Start` to the stop instant.
    pub elapsed: f64,
    /// Total scheduling decisions across frontends.
    pub decisions: u64,
    /// Real tasks the server enqueued (its own count of `Submit`s).
    pub dispatched: u64,
    /// Real tasks completed after the full drain (worker counters).
    pub completed: u64,
    /// Benchmark tasks the frontends injected.
    pub benchmarks: u64,
    /// Post-stop submits dropped at the server (should stay 0).
    pub submit_dropped: u64,
    /// Completed real tasks per second of run time.
    pub tasks_per_sec: f64,
    /// Consensus check epochs, including the drain-time epoch.
    pub sync_epochs: u64,
    /// Consensus merge operations, including the one unconditional
    /// drain-time merge (so this alone does not prove wire traffic).
    pub sync_merges: u64,
    /// SyncExport frames received across all frontends — the direct count
    /// of consensus payloads that crossed the wire (every frontend sends
    /// at least its final drain-time export).
    pub sync_exports: u64,
    /// Final consensus estimates vs configured speeds.
    pub estimates: Vec<(f64, f64)>,
    /// Per-frontend final statistics, indexed by shard.
    pub per_frontend: Vec<DoneStats>,
    /// Poll shards the data plane ran (after clamping to the frontend
    /// count).
    pub poll_shards: usize,
    /// Poller wakeups summed across shards — with frames sent/received
    /// this gives events-per-wake, the batching the kernel poller buys.
    pub poll_wakeups: u64,
    /// Lifecycle spans the trace aggregator recorded (0 with tracing off).
    pub traced_spans: u64,
    /// Flight-recorder events overwritten because a ring was full.
    pub flight_dropped: u64,
}

impl NetReport {
    /// Post-warmup latency record count across frontends.
    pub fn resp_count(&self) -> u64 {
        self.per_frontend.iter().map(|d| d.resp_count).sum()
    }

    /// Response-count-weighted mean response time (seconds).
    pub fn mean_response(&self) -> f64 {
        let count = self.resp_count();
        if count == 0 {
            return 0.0;
        }
        let sum: f64 =
            self.per_frontend.iter().map(|d| d.resp_mean * d.resp_count as f64).sum();
        sum / count as f64
    }

    /// Worst per-frontend p95 response time (seconds).
    pub fn worst_p95(&self) -> f64 {
        self.per_frontend.iter().map(|d| d.resp_p95).fold(0.0, f64::max)
    }

    /// Render a human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "net plane: {} remote frontends × {} workers, policy {}\n",
            self.frontends, self.workers, self.policy
        ));
        out.push_str(&format!(
            "tasks      : dispatched {} | completed {} | benchmarks {} — {:.0} tasks/s\n",
            self.dispatched, self.completed, self.benchmarks, self.tasks_per_sec
        ));
        out.push_str(&format!(
            "decisions  : {} in {:.2}s across {} schedulers\n",
            self.decisions, self.elapsed, self.frontends
        ));
        out.push_str(&format!(
            "consensus  : {} epochs, {} merges, {} payload exports over the wire\n",
            self.sync_epochs, self.sync_merges, self.sync_exports
        ));
        out.push_str(&format!(
            "data plane : {} poll shards, {} wakeups\n",
            self.poll_shards, self.poll_wakeups
        ));
        if self.traced_spans > 0 {
            out.push_str(&format!("tracing    : {} lifecycle spans\n", self.traced_spans));
        }
        if self.resp_count() > 0 {
            out.push_str(&format!(
                "latency ms : mean {:.1} | worst p95 {:.1} ({} jobs)\n",
                self.mean_response() * 1e3,
                self.worst_p95() * 1e3,
                self.resp_count()
            ));
        }
        for d in &self.per_frontend {
            out.push_str(&format!(
                "  frontend : {} decisions | {} dispatched | {} benchmarks\n",
                d.decisions, d.dispatched, d.benchmarks
            ));
        }
        out.push_str("worker speed estimates (true → learned):\n");
        for (i, (truth, est)) in self.estimates.iter().enumerate() {
            out.push_str(&format!("  worker {i}: {truth:.2} → {est:.2}\n"));
        }
        if self.submit_dropped > 0 {
            out.push_str(&format!("late submits dropped at stop: {}\n", self.submit_dropped));
        }
        out
    }
}

/// Machine-readable run results (`BENCH_net_smoke.json` in the CI
/// loopback smoke), shaped like `BENCH_plane.json` so within-run ratio
/// gates can read both.
pub fn bench_json(cfg: &NetServerConfig, r: &NetReport) -> Json {
    let per: Vec<Json> = r
        .per_frontend
        .iter()
        .enumerate()
        .map(|(shard, d)| {
            let mut m = BTreeMap::new();
            m.insert("shard".into(), Json::Num(shard as f64));
            m.insert("decisions".into(), Json::Num(d.decisions as f64));
            m.insert("dispatched".into(), Json::Num(d.dispatched as f64));
            m.insert("benchmarks".into(), Json::Num(d.benchmarks as f64));
            m.insert("resp_count".into(), Json::Num(d.resp_count as f64));
            m.insert("mean_ms".into(), Json::Num(d.resp_mean * 1e3));
            m.insert("p50_ms".into(), Json::Num(d.resp_p50 * 1e3));
            m.insert("p95_ms".into(), Json::Num(d.resp_p95 * 1e3));
            Json::Obj(m)
        })
        .collect();
    let mut results = BTreeMap::new();
    results.insert("elapsed".into(), Json::Num(r.elapsed));
    results.insert("tasks_per_sec".into(), Json::Num(r.tasks_per_sec.round()));
    results.insert("decisions".into(), Json::Num(r.decisions as f64));
    results.insert(
        "decisions_per_sec".into(),
        Json::Num((r.decisions as f64 / r.elapsed.max(1e-9)).round()),
    );
    results.insert("dispatched".into(), Json::Num(r.dispatched as f64));
    results.insert("completed".into(), Json::Num(r.completed as f64));
    results.insert("benchmarks".into(), Json::Num(r.benchmarks as f64));
    results.insert("submit_dropped".into(), Json::Num(r.submit_dropped as f64));
    results.insert("sync_epochs".into(), Json::Num(r.sync_epochs as f64));
    results.insert("sync_merges".into(), Json::Num(r.sync_merges as f64));
    results.insert("sync_exports".into(), Json::Num(r.sync_exports as f64));
    results.insert("resp_count".into(), Json::Num(r.resp_count() as f64));
    results.insert("mean_ms".into(), Json::Num(r.mean_response() * 1e3));
    results.insert("worst_p95_ms".into(), Json::Num(r.worst_p95() * 1e3));
    results.insert("poll_wakeups".into(), Json::Num(r.poll_wakeups as f64));
    results.insert("traced_spans".into(), Json::Num(r.traced_spans as f64));
    results.insert("flight_dropped".into(), Json::Num(r.flight_dropped as f64));
    results.insert("per_frontend".into(), Json::Arr(per));
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("net".into()));
    top.insert("frontends".into(), Json::Num(cfg.frontends as f64));
    top.insert("workers".into(), Json::Num(cfg.speeds.len() as f64));
    top.insert("rate".into(), Json::Num(cfg.rate));
    top.insert("duration".into(), Json::Num(cfg.duration));
    top.insert("seed".into(), Json::Num(cfg.seed as f64));
    top.insert("policy".into(), Json::Str(cfg.policy.clone()));
    top.insert("poll_shards".into(), Json::Num(r.poll_shards as f64));
    top.insert("sync_policy".into(), Json::Str(cfg.sync_policy.kind.name().into()));
    top.insert("sync_interval".into(), Json::Num(cfg.sync_interval));
    top.insert("sync_threshold".into(), Json::Num(cfg.sync_policy.threshold));
    top.insert("results".into(), Json::Obj(results));
    Json::Obj(top)
}

/// A bound pool server, not yet serving — split from [`NetServer::serve`]
/// so callers (and tests binding port 0) can learn the address first.
pub struct NetServer {
    cfg: NetServerConfig,
    listener: TcpListener,
}

/// Idle nap between poll sweeps when no socket moved: short enough that a
/// beat never waits a visible while (the old per-thread design slept
/// 10 ms in its accept loop; 500 µs keeps worst-case added latency well
/// under one flush deadline).
const IDLE_SLEEP: Duration = Duration::from_micros(500);

/// Shared run state every connection's message handling reads; owned by
/// the poll loop, one instance per run.
struct PoolCtx {
    n: usize,
    probes: Vec<Arc<CachePadded<AtomicUsize>>>,
    table: Arc<EstimateTable>,
    views: Arc<SharedViews>,
    stop: Arc<AtomicBool>,
    lambda_slots: Vec<Arc<AtomicU64>>,
    start: Instant,
    obs: Arc<crate::obs::Registry>,
    /// Lifecycle-trace aggregator (shared with the scrape endpoint);
    /// `None` with tracing off.
    tracer: Option<Arc<Tracer>>,
}

/// Most staged frames flushed per `write_vectored` call: a beat's worst
/// case (TickReply + DoneAck + leftovers) fits comfortably, and a stack
/// array this size costs nothing to build.
const MAX_WRITE_IOV: usize = 8;

/// Staged outbound frames, one owned slot per frame, flushed with
/// `write_vectored` so a TickReply+completions pair (or several frames
/// that piled up behind a slow socket) costs one syscall. Drained slots
/// recycle through `spare`, so steady state stages without allocating.
struct WriteQueue {
    slots: VecDeque<Vec<u8>>,
    spare: Vec<Vec<u8>>,
    /// Bytes of `slots[0]` already accepted by the socket.
    head_off: usize,
}

impl WriteQueue {
    fn new() -> Self {
        Self { slots: VecDeque::new(), spare: Vec::new(), head_off: 0 }
    }

    fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Encode `msg` into a recycled (or fresh) slot; returns the frame's
    /// encoded length for wire accounting.
    fn queue(&mut self, msg: &Msg) -> u64 {
        let mut slot = self.spare.pop().unwrap_or_default();
        slot.clear();
        msg.encode_into(&mut slot);
        let bytes = slot.len() as u64;
        self.slots.push_back(slot);
        bytes
    }

    /// Push staged frames into the socket until it would block. Returns
    /// whether anything moved; errors keep the caller's pinned wording by
    /// omitting the shard prefix (the caller adds it).
    fn flush(&mut self, stream: &mut TcpStream) -> Result<bool, String> {
        use std::io::Write;
        let mut progress = false;
        while !self.slots.is_empty() {
            let mut iov = [IoSlice::new(&[]); MAX_WRITE_IOV];
            let mut n_iov = 0;
            for (i, slot) in self.slots.iter().take(MAX_WRITE_IOV).enumerate() {
                iov[i] = if i == 0 {
                    IoSlice::new(&slot[self.head_off..])
                } else {
                    IoSlice::new(slot)
                };
                n_iov += 1;
            }
            match stream.write_vectored(&iov[..n_iov]) {
                Ok(0) => return Err("connection closed mid-write".into()),
                Ok(mut sent) => {
                    progress = true;
                    while sent > 0 {
                        let head_left = self.slots[0].len() - self.head_off;
                        if sent >= head_left {
                            sent -= head_left;
                            self.head_off = 0;
                            let done = self.slots.pop_front().expect("nonempty");
                            if self.spare.len() < MAX_WRITE_IOV {
                                self.spare.push(done);
                            }
                        } else {
                            self.head_off += sent;
                            sent = 0;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(format!("net write: {e}")),
            }
        }
        Ok(progress)
    }
}

/// Per-shard working buffers: decode scratch, reply assembly, and the
/// read staging area. One set per shard thread, reused across every
/// frame that shard serves, so the hot path allocates nothing.
struct ShardBufs {
    /// Read staging for `read_available`.
    tmp: Vec<u8>,
    /// Estimate snapshot buffer for `estimates_if_moved`.
    mu: Vec<f64>,
    /// Queue-length snapshot reused across TickReplies.
    qlen: Vec<u32>,
    /// Completion batch reused across TickReplies.
    completions: Vec<WireCompletion>,
    /// Decode scratch: SubmitBatch item buffers recycle through here.
    scratch: DecodeScratch,
}

impl ShardBufs {
    fn new(n: usize) -> Self {
        Self {
            tmp: vec![0u8; 64 * 1024],
            mu: vec![0.0; n],
            qlen: Vec::with_capacity(n),
            completions: Vec::new(),
            scratch: DecodeScratch::new(),
        }
    }
}

/// Per-connection state its poll shard owns — the replacement for the old
/// per-connection handler thread. Reads reassemble frames through
/// `rbuf`/`roff`; replies stage through the write queue so a peer that is
/// slow to read never blocks the shard for anyone else.
struct Conn {
    stream: TcpStream,
    shard: usize,
    /// v3 tracing negotiated for this connection (client sent a clock
    /// stamp and the server samples at a non-zero rate).
    traced: bool,
    /// Trace stamps of sampled tasks awaiting completion, keyed by job id:
    /// `[origin, enq, send, recv]` on the nanosecond trace clocks.
    inflight: HashMap<u64, [u64; 4]>,
    /// Frame reassembly: bytes land at the tail, frames pop at `roff`.
    rbuf: Vec<u8>,
    roff: usize,
    /// Encoded replies not yet accepted by the socket.
    wq: WriteQueue,
    comp_rx: Receiver<Completion>,
    /// Completions drained from the pool, awaiting the next beat's reply.
    pending: VecDeque<WireCompletion>,
    /// Pool ingress; released (set to `None`) on the first post-stop beat.
    clients: Option<Vec<worker::WorkerClient>>,
    disconnected: bool,
    last_activity: Instant,
    /// `Done` received and acked: the connection is finished.
    done: bool,
    /// Whether this connection's ingress release has been counted into the
    /// drain barrier (guards the count against double bumps).
    released: bool,
    /// Whether the socket is currently registered with the shard's poller.
    registered: bool,
    /// Whether the poller is currently armed for write readiness.
    want_write: bool,
    stats: Option<DoneStats>,
    dispatched: u64,
    submit_dropped: u64,
    /// SyncExport frames this connection landed in the view slots — the
    /// direct proof that consensus payloads crossed the wire.
    sync_exports: u64,
}

/// Drain whatever the nonblocking socket has ready into `buf`, returning
/// the bytes read this sweep (0 when the read would block). A clean EOF is
/// an error: every peer announces departure with `Done` first.
fn read_available(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    tmp: &mut [u8],
) -> Result<usize, String> {
    use std::io::Read;
    let mut total = 0usize;
    loop {
        match stream.read(tmp) {
            Ok(0) => return Err("connection closed".into()),
            Ok(got) => {
                buf.extend_from_slice(&tmp[..got]);
                total += got;
                if got < tmp.len() {
                    return Ok(total);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(total),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("net read: {e}")),
        }
    }
}

/// Try to pop one complete frame off the front of `buf`: the decoded
/// message plus the bytes it consumed, or `None` while the frame is still
/// partial. Header validation happens first, so a hostile length field is
/// rejected from 12 bytes without waiting for (or allocating) a payload;
/// batch payloads decode into `scratch`'s recycled buffers.
fn try_frame(buf: &[u8], scratch: &mut DecodeScratch) -> Result<Option<(Msg, usize)>, String> {
    if buf.len() < wire::HEADER_LEN {
        return Ok(None);
    }
    let header: &[u8; wire::HEADER_LEN] =
        buf[..wire::HEADER_LEN].try_into().expect("sized slice");
    let need = wire::HEADER_LEN + wire::header_payload_len(header).map_err(|e| e.to_string())?;
    if buf.len() < need {
        return Ok(None);
    }
    let msg = Msg::decode_with(&buf[..need], scratch).map_err(|e| e.to_string())?;
    wire::note_frames_received(1, need as u64);
    Ok(Some((msg, need)))
}

impl NetServer {
    /// Validate the configuration and bind the listen socket.
    pub fn bind(cfg: NetServerConfig) -> Result<Self, String> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| format!("bind {}: {e}", cfg.listen))?;
        Ok(Self { cfg, listener })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener.local_addr().map_err(|e| format!("local addr: {e}"))
    }

    /// Serve one run to completion: handshake all `k` frontends, release
    /// them with `Start`, host the pool until the deadline, drain, run the
    /// final consensus epoch, and return the merged report.
    pub fn serve(self) -> Result<NetReport, String> {
        let NetServer { cfg, listener } = self;
        let k = cfg.frontends;
        let n = cfg.speeds.len();
        let total: f64 = cfg.speeds.iter().sum();
        let prior = total / n as f64;
        let mu_bar = total / cfg.mean_demand;

        // Handshake phase: accept until every shard is claimed exactly
        // once, serving every in-flight handshake from this one thread.
        // Accepts and Hello reads are both nonblocking with a
        // progress-refreshed deadline, so a frontend that never connects
        // (or stalls mid-Hello) fails the run with a clear error instead
        // of wedging the server — and a stalled greeter cannot delay the
        // accept or handshake of any other frontend.
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("set nonblocking: {e}"))?;
        let mut conns: Vec<Option<(TcpStream, Vec<u8>, bool)>> = (0..k).map(|_| None).collect();
        let mut scratch = Vec::with_capacity(4096);
        let mut dscratch = DecodeScratch::new();
        let mut tmp = vec![0u8; 64 * 1024];
        let mut greeting: Vec<(TcpStream, SocketAddr, Vec<u8>)> = Vec::new();
        let mut claimed = 0usize;
        let mut accept_deadline = Instant::now() + cfg.read_timeout;
        while claimed < k {
            let mut progress = false;
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream
                        .set_nonblocking(true)
                        .map_err(|e| format!("set nonblocking: {e}"))?;
                    stream.set_nodelay(true).map_err(|e| format!("set nodelay: {e}"))?;
                    greeting.push((stream, peer, Vec::new()));
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(format!("accept: {e}")),
            }
            let mut i = 0;
            while i < greeting.len() {
                let claim = {
                    let (stream, peer, rbuf) = &mut greeting[i];
                    let got = read_available(stream, rbuf, &mut tmp)
                        .map_err(|e| format!("handshake with {peer}: {e}"))?;
                    progress |= got > 0;
                    match try_frame(rbuf, &mut dscratch)
                        .map_err(|e| format!("handshake with {peer}: {e}"))?
                    {
                        Some((Msg::Hello { shard, shards, t0_ns }, used)) => {
                            // t1 of the four-timestamp clock exchange:
                            // stamped as close to the frame's arrival as
                            // the handshake loop allows.
                            Some((shard as usize, shards as usize, t0_ns, obstrace::now_ns(), used))
                        }
                        Some((other, _)) => {
                            return Err(format!(
                                "handshake with {peer}: expected Hello, got tag {}",
                                other.tag()
                            ))
                        }
                        None => None,
                    }
                };
                let Some((shard, shards, t0_ns, t1_ns, used)) = claim else {
                    i += 1;
                    continue;
                };
                let (mut stream, peer, rbuf) = greeting.swap_remove(i);
                if shards != k {
                    return Err(format!(
                        "frontend {peer} expects {shards} shards but this server runs {k}"
                    ));
                }
                if shard >= k {
                    return Err(format!("frontend {peer} claimed shard {shard} of {k}"));
                }
                if conns[shard].is_some() {
                    return Err(format!(
                        "shard {shard} claimed twice (second claim from {peer})"
                    ));
                }
                let ack = Msg::HelloAck(HelloAck {
                    workers: n as u32,
                    batch: cfg.batch as u32,
                    net_batch: cfg.net_batch as u32,
                    net_flush_us: cfg.net_flush_us,
                    seed: cfg.seed,
                    prior,
                    mean_demand: cfg.mean_demand,
                    mu_bar,
                    rate: cfg.rate,
                    duration: cfg.duration,
                    warmup: cfg.warmup,
                    publish_interval: cfg.publish_interval,
                    sync_interval: cfg.sync_interval,
                    sync_threshold: cfg.sync_policy.threshold,
                    fake_jobs: cfg.fake_jobs,
                    policy: cfg.policy.clone(),
                    sync_policy: cfg.sync_policy.kind.name().into(),
                    speeds: cfg.speeds.clone(),
                    // Mirror rule: a v2 Hello (no t0) gets a v2 ack (no
                    // clock appendix), so old frontends see bit-identical
                    // bytes. A v3 Hello gets the server's t1/t2 stamps and
                    // the negotiated sampling rate (0 = tracing off).
                    clock: t0_ns.map(|_| AckClock {
                        t1_ns,
                        t2_ns: obstrace::now_ns(),
                        sample_n: cfg.trace_sample,
                    }),
                });
                // The ack is a few hundred bytes into a fresh socket whose
                // send buffer is empty, so it almost always lands in one
                // write — but the stream stays nonblocking end to end: a
                // peer that wedged its receive window gets a bounded retry
                // loop here instead of a blocking write that would stall
                // every other frontend's handshake.
                {
                    use std::io::Write;
                    scratch.clear();
                    ack.encode_into(&mut scratch);
                    let mut off = 0usize;
                    let write_deadline = Instant::now() + cfg.read_timeout;
                    while off < scratch.len() {
                        match stream.write(&scratch[off..]) {
                            Ok(0) => {
                                return Err(format!(
                                    "handshake with {peer}: connection closed mid-write"
                                ))
                            }
                            Ok(sent) => off += sent,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                if Instant::now() >= write_deadline {
                                    return Err(format!(
                                        "handshake with {peer}: ack not accepted within {:.0?}",
                                        cfg.read_timeout
                                    ));
                                }
                                std::thread::sleep(IDLE_SLEEP);
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(e) => {
                                return Err(format!("handshake with {peer}: net write: {e}"))
                            }
                        }
                    }
                    wire::note_frames_sent(1, scratch.len() as u64);
                }
                // A well-behaved frontend sends nothing until Start, but
                // any bytes that did arrive behind the Hello are carried
                // into the connection's reassembly buffer, not dropped.
                let traced = t0_ns.is_some() && cfg.trace_sample > 0;
                conns[shard] = Some((stream, rbuf[used..].to_vec(), traced));
                claimed += 1;
                progress = true;
            }
            if progress {
                accept_deadline = Instant::now() + cfg.read_timeout;
            } else if claimed < k {
                if Instant::now() >= accept_deadline {
                    return Err(format!(
                        "timed out waiting for frontends: {claimed} of {k} connected \
                         within {:.0?}",
                        cfg.read_timeout
                    ));
                }
                std::thread::sleep(IDLE_SLEEP);
            }
        }

        // The shared side: worker pool with per-shard completion routing,
        // seqlock table, sync-payload slots, and the consensus thread.
        let mut shard_rxs: Vec<Receiver<Completion>> = Vec::with_capacity(k);
        let mut txs = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = std::sync::mpsc::channel::<Completion>();
            txs.push(tx);
            shard_rxs.push(rx);
        }
        let sink = CompletionSink::sharded(txs);
        // Data-plane sharding: p poll shards partition the k connections
        // round-robin. The placement plan covers the poll shards and the
        // workers — under `--pin` each poll shard lands on its own package
        // (the scheduler-side shard threads live at the remote frontends).
        let topo = CpuTopology::detect();
        let p = cfg.poll_shards.unwrap_or_else(|| default_poll_shards(&topo, k));
        let plan = match cfg.pin {
            PinMode::None => PlacementPlan::unpinned(p, n),
            mode => PlacementPlan::new(mode, &topo, p, n),
        };
        let workers: Vec<worker::WorkerHandle> = cfg
            .speeds
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                worker::spawn_pinned(i, s, PayloadMode::Sleep, sink.clone(), plan.worker_cpus[i])
            })
            .collect();
        drop(sink);
        let probes: Vec<Arc<CachePadded<AtomicUsize>>> =
            workers.iter().map(|w| w.client.qlen.clone()).collect();
        let completed_counters: Vec<Arc<AtomicU64>> =
            workers.iter().map(|w| w.client.completed_real.clone()).collect();
        let table = Arc::new(EstimateTable::new(n, prior));
        let views = Arc::new(SharedViews::new(k, n, prior));
        let stop = Arc::new(AtomicBool::new(false));
        let sync_stop = Arc::new(AtomicBool::new(false));
        let lambda_slots: Vec<Arc<AtomicU64>> =
            (0..k).map(|_| Arc::new(AtomicU64::new(0f64.to_bits()))).collect();
        let start = Instant::now();

        // Telemetry: one registry for the whole run (the poll loop writes
        // each connection's shard slot), an optional flight recorder (the
        // server only sees consensus events — placements happen at the
        // frontends), and an optional scrape listener sharing the
        // in-process plane's endpoint surface.
        let obs = Arc::new(crate::obs::Registry::with_poll_shards(k, n, p));
        let flight = cfg.flight_record.as_deref().map(|_| {
            Arc::new(crate::obs::FlightRecorder::new(k, crate::obs::flight::DEFAULT_CAPACITY))
        });
        let tracer = (cfg.trace_sample > 0).then(|| Arc::new(Tracer::new(cfg.trace_sample)));
        let metrics = match cfg.metrics_listen.as_deref() {
            Some(addr) => Some(crate::plane::spawn_metrics_server(
                addr,
                obs.clone(),
                flight.clone(),
                probes.clone(),
                tracer.clone(),
            )?),
            None => None,
        };

        let sync_ctx = SyncRun {
            views: views.clone(),
            table: table.clone(),
            stop: sync_stop.clone(),
            policy: SyncPolicy::new(&cfg.sync_policy, cfg.sync_interval, k, cfg.seed ^ 0x57AC_6E55),
            prior,
            start,
            obs: obs.clone(),
            flight: flight.clone(),
        };
        let sync_handle = std::thread::Builder::new()
            .name("rosella-net-sync".into())
            .spawn(move || run_sync(sync_ctx))
            .map_err(|e| format!("spawn sync thread: {e}"))?;

        // Build per-connection poll state; the Start release rides each
        // connection's write buffer through the same loop that serves it.
        let mut rx_iter = shard_rxs.into_iter();
        let mut live: Vec<Conn> = Vec::with_capacity(k);
        for (shard, slot) in conns.into_iter().enumerate() {
            let (stream, rest, traced) = slot.expect("every shard claimed");
            let mut conn = Conn {
                stream,
                shard,
                traced,
                inflight: HashMap::new(),
                rbuf: rest,
                roff: 0,
                wq: WriteQueue::new(),
                comp_rx: rx_iter.next().expect("one channel per shard"),
                pending: VecDeque::new(),
                clients: Some(workers.iter().map(|w| w.client.clone()).collect()),
                disconnected: false,
                last_activity: Instant::now(),
                done: false,
                released: false,
                registered: false,
                want_write: false,
                stats: None,
                dispatched: 0,
                submit_dropped: 0,
                sync_exports: 0,
            };
            conn.queue_frame(&Msg::Start);
            live.push(conn);
        }
        drop(scratch);
        drop(tmp);

        // The run itself: p poll-shard threads serve the partitioned
        // connections until every frontend finishes; the serving thread
        // keeps the clock. The sync thread is stopped unconditionally
        // afterwards — even when a shard failed — so no run leaks it.
        let ctx = PoolCtx {
            n,
            probes,
            table: table.clone(),
            views,
            stop,
            lambda_slots,
            start,
            obs: obs.clone(),
            tracer: tracer.clone(),
        };
        let barrier = DrainBarrier::new(k, workers);
        let mut shard_conns: Vec<Vec<Conn>> = (0..p).map(|_| Vec::new()).collect();
        for conn in live {
            let sid = conn.shard % p;
            shard_conns[sid].push(conn);
        }
        let deadline = start + Duration::from_secs_f64(cfg.duration);
        let mut elapsed = cfg.duration;
        let served: Result<Vec<Conn>, String> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(p);
            for (sid, conns_s) in shard_conns.into_iter().enumerate() {
                let pin_cpu = plan.shard_cpus[sid];
                let cfg = &cfg;
                let ctx = &ctx;
                let barrier = &barrier;
                let h = std::thread::Builder::new()
                    .name(format!("rosella-net-poll-{sid}"))
                    .spawn_scoped(s, move || {
                        shard_loop(sid, cfg, ctx, barrier, conns_s, pin_cpu)
                    })
                    .expect("spawn poll shard thread");
                handles.push(h);
            }
            // Stop the run at its deadline (or as soon as a shard aborts)
            // and let the shards drive the drain from there.
            while Instant::now() < deadline
                && !ctx.stop.load(Ordering::Relaxed)
                && !barrier.abort.load(Ordering::Relaxed)
            {
                std::thread::sleep(Duration::from_millis(1));
            }
            ctx.stop.store(true, Ordering::Relaxed);
            elapsed = ctx.start.elapsed().as_secs_f64();
            let mut out: Result<Vec<Conn>, String> = Ok(Vec::with_capacity(k));
            for h in handles {
                match h.join() {
                    Ok(Ok(conns_s)) => {
                        if let Ok(acc) = out.as_mut() {
                            acc.extend(conns_s);
                        }
                    }
                    Ok(Err(e)) => {
                        if out.is_ok() {
                            out = Err(e);
                        }
                    }
                    Err(_) => {
                        if out.is_ok() {
                            out = Err("poll shard thread panicked".into());
                        }
                    }
                }
            }
            out
        });
        sync_stop.store(true, Ordering::Release);
        let outcome =
            sync_handle.join().map_err(|_| "sync thread panicked".to_string())?;
        // No run leaks worker threads: every shard path joins the pool
        // through the barrier, and this backstop catches a shard that
        // panicked before releasing (shutdown forces the join).
        barrier.shutdown_pool();
        let live = served?;
        let (mu, _lambda) = table.snapshot();
        let estimates: Vec<(f64, f64)> =
            cfg.speeds.iter().zip(mu.iter()).map(|(&t, &e)| (t, e)).collect();

        let completed: u64 = completed_counters.iter().map(|c| c.load(Ordering::Acquire)).sum();
        let mut per_frontend = vec![DoneStats::default(); k];
        let mut dispatched = 0u64;
        let mut submit_dropped = 0u64;
        let mut sync_exports = 0u64;
        for c in live {
            dispatched += c.dispatched;
            submit_dropped += c.submit_dropped;
            sync_exports += c.sync_exports;
            per_frontend[c.shard] =
                c.stats.ok_or_else(|| format!("shard {} closed before Done", c.shard))?;
        }
        let decisions: u64 = per_frontend.iter().map(|d| d.decisions).sum();
        let benchmarks: u64 = per_frontend.iter().map(|d| d.benchmarks).sum();
        let poll_wakeups: u64 = (0..p).map(|s| obs.poll_shard(s).wakeups.get()).sum();
        if let Some(srv) = metrics {
            srv.shutdown();
        }
        if let (Some(path), Some(rec)) = (cfg.flight_record.as_deref(), flight.as_deref()) {
            std::fs::write(path, rec.dump_jsonl())
                .map_err(|e| format!("write flight record {path}: {e}"))?;
        }
        if let (Some(path), Some(tr)) = (cfg.trace_json.as_deref(), tracer.as_deref()) {
            tr.dump_chrome_json(path)
                .map_err(|e| format!("write trace json {path}: {e}"))?;
        }
        Ok(NetReport {
            frontends: k,
            workers: n,
            policy: cfg.policy.clone(),
            elapsed,
            decisions,
            dispatched,
            completed,
            benchmarks,
            submit_dropped,
            tasks_per_sec: completed as f64 / elapsed.max(1e-9),
            sync_epochs: outcome.epochs,
            sync_merges: outcome.merges,
            sync_exports,
            estimates,
            per_frontend,
            poll_shards: p,
            poll_wakeups,
            traced_spans: tracer.as_deref().map_or(0, |t| t.recorded()),
            flight_dropped: flight.as_deref().map_or(0, |r| r.dropped()),
        })
    }
}

impl Conn {
    /// Stage one frame for delivery; the owning poll shard flushes it as
    /// the socket accepts bytes, so queueing never blocks.
    fn queue_frame(&mut self, msg: &Msg) {
        let bytes = self.wq.queue(msg);
        wire::note_frames_sent(1, bytes);
    }

    /// Push staged frames into the socket until it would block. Returns
    /// whether anything moved.
    fn flush_writes(&mut self) -> Result<bool, String> {
        self.wq.flush(&mut self.stream).map_err(|e| format!("shard {}: {e}", self.shard))
    }

    /// Pop the next complete frame from the reassembly buffer, if one has
    /// fully arrived.
    fn next_frame(&mut self, scratch: &mut DecodeScratch) -> Result<Option<Msg>, String> {
        match try_frame(&self.rbuf[self.roff..], scratch)
            .map_err(|e| format!("shard {}: {e}", self.shard))?
        {
            Some((msg, used)) => {
                self.roff += used;
                if self.roff == self.rbuf.len() {
                    self.rbuf.clear();
                    self.roff = 0;
                }
                Ok(Some(msg))
            }
            None => {
                // Partial frame: shift it to the front so consumed bytes
                // cannot accumulate across frames.
                if self.roff > 0 {
                    self.rbuf.drain(..self.roff);
                    self.roff = 0;
                }
                Ok(None)
            }
        }
    }

    /// Enqueue one dispatch into the pool — the shared body of `Submit`
    /// and each `SubmitBatch` item.
    fn enqueue(
        &mut self,
        ctx: &PoolCtx,
        job: u64,
        worker: u32,
        kind: TaskKind,
        demand: f64,
    ) -> Result<(), String> {
        let w = worker as usize;
        if w >= ctx.n {
            return Err(format!("shard {}: submit to unknown worker {w}", self.shard));
        }
        // Wire floats are untrusted: an infinite demand would panic the
        // worker thread in Duration::from_secs_f64, and even a finite huge
        // one would wedge a worker (and the drain join) for the task's
        // whole service time.
        if !(demand.is_finite() && demand > 0.0 && demand <= MAX_TASK_DEMAND) {
            return Err(format!(
                "shard {}: demand {demand} outside (0, {MAX_TASK_DEMAND}]",
                self.shard
            ));
        }
        match self.clients.as_ref() {
            Some(cs) => {
                cs[w].enqueue(LiveTask {
                    job,
                    kind,
                    demand: demand.max(1e-6),
                    enqueued: Instant::now(),
                });
                let slot = ctx.obs.shard(self.shard);
                if kind == TaskKind::Real {
                    self.dispatched += 1;
                    slot.dispatched.inc();
                } else {
                    slot.bench_dispatched.inc();
                }
            }
            // Ingress already released at stop: drop stragglers.
            None => self.submit_dropped += 1,
        }
        Ok(())
    }

    /// Remember the trace stamps of one sampled submit so the completion
    /// echo can carry the full `[origin, enq, send, recv]` chain back.
    fn note_inflight(&mut self, job: u64, origin_ns: u64, enq_ns: u64, send_ns: u64, recv_ns: u64) {
        if self.inflight.len() < MAX_INFLIGHT_TRACES {
            self.inflight.insert(job, [origin_ns, enq_ns, send_ns, recv_ns]);
        }
    }

    /// Absorb a beat's incoming trace appendix: completed spans land in
    /// the run's aggregator, and the frontend's current offset estimate
    /// becomes the exported clock gauges.
    fn absorb_tick_trace(&self, ctx: &PoolCtx, t: &TickTrace) {
        let Some(tracer) = ctx.tracer.as_deref() else { return };
        tracer.set_clock(t.offset_ns, t.err_ns);
        for s in &t.spans {
            tracer.record(SpanRecord {
                job: s.job,
                origin_us: s.origin_us,
                stages_us: s.stages_us,
            });
        }
    }

    /// Serve one coordination beat (a `Tick` or a `SubmitBatch`'s
    /// piggybacked tick): land λ̂ₛ, drain completions, stage the reply.
    /// The reply's qlen/completion vectors borrow the shard's reusable
    /// buffers and are reclaimed after encoding, so a steady-state beat
    /// allocates nothing. `clock_t1` is the receive stamp of a plain
    /// `Tick` that carried a clock exchange; the reply then stamps t2 and
    /// completes the four-timestamp round.
    fn beat(
        &mut self,
        ctx: &PoolCtx,
        epoch: u64,
        lambda_local: f64,
        bufs: &mut ShardBufs,
        clock_t1: Option<u64>,
    ) -> Result<(), String> {
        // A NaN λ̂ₛ stored here would poison the lambda_live sum served to
        // every other frontend.
        if !(lambda_local.is_finite() && lambda_local >= 0.0) {
            return Err(format!(
                "shard {}: non-finite arrival estimate {lambda_local}",
                self.shard
            ));
        }
        ctx.lambda_slots[self.shard].store(lambda_local.to_bits(), Ordering::Relaxed);
        let stopping = ctx.stop.load(Ordering::Relaxed);
        if stopping {
            // Release our pool ingress so the workers can drain; every
            // Submit this frontend sent before observing the stop flag was
            // already processed (the socket is ordered).
            self.clients = None;
        }
        let slot = ctx.obs.shard(self.shard);
        let pending = &mut self.pending;
        drain_completions(&self.comp_rx, &mut self.disconnected, ctx.start, |c| {
            if c.kind == TaskKind::Real {
                slot.completed.inc();
                // The server only knows server-side sojourn (enqueue →
                // completion); end-to-end response lives at the frontends.
                slot.response_us.record((c.sojourn.max(0.0) * 1e6) as u64);
            }
            pending.push_back(c)
        });
        let take = self.pending.len().min(MAX_COMPLETIONS_PER_REPLY);
        let mut completions = std::mem::take(&mut bufs.completions);
        completions.clear();
        completions.extend(self.pending.drain(..take));
        let mut qlen = std::mem::take(&mut bufs.qlen);
        qlen.clear();
        qlen.extend(ctx.probes.iter().map(|q| q.load(Ordering::Relaxed) as u32));
        let estimates = estimates_if_moved(&ctx.table, epoch, &mut bufs.mu);
        // Completion-trace echoes: every completion in this reply whose
        // submit left stamps gets its full chain echoed back, with done_ns
        // recovering the worker's completion instant on the trace clock
        // (completion `at` is seconds since run start).
        let trace = if self.traced {
            let mut traced: Vec<CompletionTrace> = Vec::new();
            if !self.inflight.is_empty() {
                let start_ns = obstrace::ns_of(ctx.start) as f64;
                for (i, c) in completions.iter().enumerate() {
                    if let Some([origin_ns, enq_ns, send_ns, recv_ns]) =
                        self.inflight.remove(&c.job)
                    {
                        traced.push(CompletionTrace {
                            idx: i as u32,
                            origin_ns,
                            enq_ns,
                            send_ns,
                            recv_ns,
                            done_ns: (start_ns + c.at * 1e9) as u64,
                        });
                    }
                }
            }
            let (t1_ns, t2_ns) = match clock_t1 {
                Some(t1) => (t1, obstrace::now_ns()),
                None => (0, 0),
            };
            // An appendix-free reply stays bit-compatible with v2.
            (t1_ns != 0 || !traced.is_empty())
                .then_some(ReplyTrace { t1_ns, t2_ns, traced })
        } else {
            None
        };
        let reply = Msg::TickReply(TickReply {
            qlen,
            lambda_live: lambda_total(&ctx.lambda_slots),
            stop: stopping,
            drained: stopping
                && self.clients.is_none()
                && self.disconnected
                && self.pending.is_empty(),
            estimates,
            completions,
            trace,
        });
        self.queue_frame(&reply);
        if let Msg::TickReply(r) = reply {
            bufs.qlen = r.qlen;
            bufs.completions = r.completions;
        }
        Ok(())
    }

    /// Dispatch one decoded message — the server side of the frontend's
    /// protocol loop, minus the socket I/O the poll shard owns.
    fn handle_msg(
        &mut self,
        ctx: &PoolCtx,
        msg: Msg,
        bufs: &mut ShardBufs,
    ) -> Result<(), String> {
        match msg {
            Msg::Submit { job, worker, kind, demand, trace } => {
                ctx.obs.wire_batch.record(1);
                if self.traced {
                    if let Some(st) = trace {
                        let recv = obstrace::now_ns();
                        self.note_inflight(job, st.origin_ns, st.enq_ns, st.send_ns, recv);
                    }
                }
                self.enqueue(ctx, job, worker, kind, demand)
            }
            Msg::SubmitBatch { tick, items, trace } => {
                if !items.is_empty() {
                    ctx.obs.wire_batch.record(items.len() as u64);
                }
                if self.traced {
                    if let Some(bt) = &trace {
                        let recv = obstrace::now_ns();
                        for &(idx, origin_ns, enq_ns) in &bt.stamps {
                            // Stamp indices come off the wire: a stamp
                            // pointing outside the batch is dropped.
                            if let Some(it) = items.get(idx as usize) {
                                self.note_inflight(it.job, origin_ns, enq_ns, bt.send_ns, recv);
                            }
                        }
                    }
                }
                let mut enq = Ok(());
                for it in &items {
                    enq = self.enqueue(ctx, it.job, it.worker, it.kind, it.demand);
                    if enq.is_err() {
                        break;
                    }
                }
                // Hand the item buffer back to the decode scratch so the
                // next SubmitBatch on this shard decodes allocation-free.
                bufs.scratch.recycle(Msg::SubmitBatch { tick: None, items, trace: None });
                enq?;
                match tick {
                    Some((epoch, lambda_local)) => {
                        // A piggybacked beat carries no TickTrace, so no
                        // clock exchange completes here.
                        self.beat(ctx, epoch, lambda_local, bufs, None)
                    }
                    None => Ok(()),
                }
            }
            Msg::Tick { epoch, lambda_local, trace } => {
                let clock_t1 = trace.as_ref().map(|_| obstrace::now_ns());
                if let Some(t) = &trace {
                    self.absorb_tick_trace(ctx, t);
                }
                self.beat(ctx, epoch, lambda_local, bufs, clock_t1)
            }
            Msg::SyncExport { shard, diverged, lambda_hat, views } => {
                if shard as usize != self.shard {
                    return Err(format!(
                        "shard {} exported a payload claiming shard {shard}",
                        self.shard
                    ));
                }
                if views.len() != ctx.n {
                    return Err(format!(
                        "shard {}: exported {} views over a {}-worker pool",
                        self.shard,
                        views.len(),
                        ctx.n
                    ));
                }
                // Consensus inputs are untrusted wire floats: one NaN μ̂
                // or λ̂ share would propagate through every future merge.
                if !(lambda_hat.is_finite() && lambda_hat >= 0.0)
                    || views.iter().any(|v| !(v.mu_hat.is_finite() && v.mu_hat >= 0.0))
                {
                    return Err(format!(
                        "shard {}: non-finite sync payload (λ̂ₛ {lambda_hat})",
                        self.shard
                    ));
                }
                ctx.views.store(self.shard, &views, lambda_hat);
                self.sync_exports += 1;
                ctx.obs.sync_exports.inc();
                if diverged {
                    ctx.views.request_merge();
                }
                Ok(())
            }
            Msg::Done(stats) => {
                // The frontends make the scheduling decisions; fold their
                // final count into the registry so a post-run scrape shows
                // the whole plane, not just the server's half.
                ctx.obs.shard(self.shard).decisions.add(stats.decisions);
                self.stats = Some(stats);
                self.queue_frame(&Msg::DoneAck);
                self.done = true;
                Ok(())
            }
            other => Err(format!(
                "shard {}: unexpected message tag {}",
                self.shard,
                other.tag()
            )),
        }
    }
}

/// Cross-shard drain coordination. The pool may be joined only after
/// every connection has released its ingress clients (otherwise the join
/// waits on task senders nobody will drop), the release count is spread
/// across shard threads, and exactly one caller gets to perform the join
/// — the `Mutex<Option<..>>` hands the pool out once.
struct DrainBarrier {
    /// Connections whose ingress release has been counted.
    released: AtomicUsize,
    /// Total connections across all shards.
    total: usize,
    /// A shard failed: every other shard releases its ingress and exits.
    abort: AtomicBool,
    /// The worker pool, taken exactly once for the drain join.
    pool: Mutex<Option<Vec<worker::WorkerHandle>>>,
}

impl DrainBarrier {
    fn new(total: usize, workers: Vec<worker::WorkerHandle>) -> Self {
        Self {
            released: AtomicUsize::new(0),
            total,
            abort: AtomicBool::new(false),
            pool: Mutex::new(Some(workers)),
        }
    }

    /// Count a connection's ingress release exactly once (forcing the
    /// release if the connection still holds its clients).
    fn mark_released(&self, c: &mut Conn) {
        if !c.released {
            c.clients = None;
            c.released = true;
            self.released.fetch_add(1, Ordering::AcqRel);
        }
    }

    /// Join the pool once every ingress is released: the join blocks only
    /// for in-flight task payloads, and it must happen before any
    /// connection can report itself drained (the completion channels
    /// disconnect only when the workers exit). Returns whether this call
    /// performed the join.
    fn maybe_join_pool(&self) -> bool {
        if self.released.load(Ordering::Acquire) < self.total {
            return false;
        }
        let taken = self.pool.lock().expect("pool lock").take();
        match taken {
            Some(ws) => {
                for w in ws {
                    w.shutdown();
                }
                true
            }
            None => false,
        }
    }

    /// Force the pool join regardless of the release count — the error
    /// backstop, so no failure path leaks worker threads.
    fn shutdown_pool(&self) {
        let taken = self.pool.lock().expect("pool lock").take();
        if let Some(ws) = taken {
            for w in ws {
                w.shutdown();
            }
        }
    }
}

/// One poll shard: serve its slice of the connections until every one
/// finishes, returning them for stats collection. On failure the shard
/// aborts the run (the other shards release their ingress and exit) and
/// force-releases its own, so the pool join can never wait on it.
fn shard_loop(
    sid: usize,
    cfg: &NetServerConfig,
    ctx: &PoolCtx,
    barrier: &DrainBarrier,
    mut conns: Vec<Conn>,
    pin_cpu: Option<usize>,
) -> Result<Vec<Conn>, String> {
    match shard_loop_inner(sid, cfg, ctx, barrier, &mut conns, pin_cpu) {
        Ok(()) => Ok(conns),
        Err(e) => {
            barrier.abort.store(true, Ordering::Release);
            ctx.stop.store(true, Ordering::Relaxed);
            for c in conns.iter_mut() {
                barrier.mark_released(c);
            }
            barrier.maybe_join_pool();
            Err(e)
        }
    }
}

fn shard_loop_inner(
    sid: usize,
    cfg: &NetServerConfig,
    ctx: &PoolCtx,
    barrier: &DrainBarrier,
    conns: &mut [Conn],
    pin_cpu: Option<usize>,
) -> Result<(), String> {
    if let Some(cpu) = pin_cpu {
        // Best-effort, exactly like worker pinning: a denied affinity
        // syscall leaves the shard unpinned rather than failing the run.
        pin_current_thread(cpu);
    }
    let mut poller =
        if cfg.force_poll_fallback { Poller::fallback() } else { Poller::new() };
    let mut bufs = ShardBufs::new(ctx.n);
    let mut events: Vec<PollEvent> = Vec::new();
    for (token, c) in conns.iter_mut().enumerate() {
        poller
            .register(&c.stream, token, false)
            .map_err(|e| format!("poll shard {sid}: {e}"))?;
        c.registered = true;
    }
    let slot = ctx.obs.poll_shard(sid);
    // Initial service pass, forced readable+writable: the Start frames
    // queued at build time (and any bytes carried over from the
    // handshake) must be served now — the frontends send nothing until
    // they see Start, so waiting for socket events first would deadlock
    // the kernel-backed poller.
    let mut progress = true;
    for token in 0..conns.len() {
        service_conn(&mut conns[token], token, true, true, &mut poller, ctx, &mut bufs)?;
    }
    loop {
        for c in conns.iter_mut() {
            if c.done || c.clients.is_none() {
                barrier.mark_released(c);
            }
        }
        if barrier.maybe_join_pool() {
            progress = true;
        }
        if conns.iter().all(|c| c.done && c.wq.is_empty()) {
            return Ok(());
        }
        if barrier.abort.load(Ordering::Acquire) {
            for c in conns.iter_mut() {
                barrier.mark_released(c);
            }
            barrier.maybe_join_pool();
            return Ok(());
        }
        if !progress {
            let now = Instant::now();
            for c in conns.iter() {
                if !c.done && now.duration_since(c.last_activity) > cfg.read_timeout {
                    return Err(format!(
                        "shard {}: no frame within {:.0?}",
                        c.shard, cfg.read_timeout
                    ));
                }
            }
        }
        // A productive pass polls again immediately; an idle one parks in
        // the kernel for the nap interval, which also bounds how stale
        // the stop/abort/drain bookkeeping above can get.
        let timeout = if progress { Duration::ZERO } else { IDLE_SLEEP };
        let nev = poller
            .wait(&mut events, timeout)
            .map_err(|e| format!("poll shard {sid}: {e}"))?;
        slot.wakeups.inc();
        slot.events_per_wake.record(nev as u64);
        progress = false;
        for i in 0..nev {
            let ev = events[i];
            progress |= service_conn(
                &mut conns[ev.token],
                ev.token,
                ev.readable,
                ev.writable,
                &mut poller,
                ctx,
                &mut bufs,
            )?;
        }
    }
}

/// Serve one connection after a readiness event (or during a forced
/// pass): flush staged writes, drain readable bytes into frames, and keep
/// the poller's interest set in sync with the connection's state. Returns
/// whether anything moved.
fn service_conn(
    c: &mut Conn,
    token: usize,
    readable: bool,
    writable: bool,
    poller: &mut Poller,
    ctx: &PoolCtx,
    bufs: &mut ShardBufs,
) -> Result<bool, String> {
    let mut progress = false;
    if c.done {
        // Only the DoneAck can still be in flight; push it out and
        // otherwise leave the socket alone.
        if !c.wq.is_empty() {
            progress |= c.flush_writes()?;
        }
    } else {
        if writable || !c.wq.is_empty() {
            progress |= c.flush_writes()?;
        }
        if readable {
            let got = read_available(&mut c.stream, &mut c.rbuf, &mut bufs.tmp)
                .map_err(|e| format!("shard {}: {e}", c.shard))?;
            if got > 0 {
                progress = true;
                c.last_activity = Instant::now();
            }
            while let Some(msg) = c.next_frame(&mut bufs.scratch)? {
                progress = true;
                c.handle_msg(ctx, msg, bufs)?;
                if c.done {
                    break;
                }
            }
            // Flush replies staged this pass so they leave now instead of
            // waiting out the next wakeup.
            progress |= c.flush_writes()?;
        }
    }
    // Keep the poller in sync: a finished connection stops producing
    // events entirely (a closed peer would otherwise hang up and spin the
    // level-triggered poller), and write interest tracks whether staged
    // bytes survived the flush (a nonempty queue means the socket pushed
    // back, so EPOLLOUT is the wakeup that matters).
    if c.done && c.wq.is_empty() {
        if c.registered {
            poller
                .deregister(&c.stream, token)
                .map_err(|e| format!("shard {}: {e}", c.shard))?;
            c.registered = false;
        }
    } else if c.registered {
        let want = !c.wq.is_empty();
        if want != c.want_write {
            poller
                .set_writable(&c.stream, token, want)
                .map_err(|e| format!("shard {}: {e}", c.shard))?;
            c.want_write = want;
        }
    }
    Ok(progress)
}

/// CLI adapter for `rosella plane --listen`: the pool-server side of the
/// cross-process plane, sharing the `plane` subcommand's flag surface.
pub fn server_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let mut cfg = NetServerConfig::default();
    if let Some(l) = p.get("listen") {
        cfg.listen = l.to_string();
    }
    if let Some(f) = p.get("frontends") {
        cfg.frontends = f.trim().parse().map_err(|_| {
            format!(
                "with --listen, --frontends must be a single remote scheduler count \
                 (got '{f}')"
            )
        })?;
    }
    cfg.speeds = crate::plane::speeds_from_cli(p)?;
    if let Some(pol) = p.get("policy") {
        cfg.policy = pol.to_string();
    }
    if let Some(v) = p.parse_as("rate")? {
        cfg.rate = v;
    }
    if let Some(v) = p.parse_as("duration")? {
        cfg.duration = v;
    }
    if let Some(v) = p.parse_as("demand")? {
        cfg.mean_demand = v;
    }
    if let Some(v) = p.parse_as("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = p.parse_as("net-batch")? {
        cfg.net_batch = v;
    }
    if let Some(v) = p.parse_as("net-flush-us")? {
        cfg.net_flush_us = v;
    }
    if let Some(v) = p.parse_as("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = p.parse_as("sync-interval")? {
        cfg.sync_interval = v;
    }
    cfg.sync_policy = SyncPolicyConfig {
        kind: crate::learner::SyncKind::parse(p.get("sync-policy").unwrap_or("periodic"))?,
        ..SyncPolicyConfig::default()
    };
    if let Some(t) = p.parse_as("sync-threshold")? {
        cfg.sync_policy.threshold = t;
    }
    cfg.fake_jobs = !p.flag("no-fake-jobs");
    if let Some(v) = p.parse_as("net-poll-shards")? {
        cfg.poll_shards = Some(v);
    }
    cfg.metrics_listen = p.get("metrics-listen").map(str::to_string);
    cfg.flight_record = p.get("flight-record").map(str::to_string);
    if let Some(spec) = p.get("trace-sample") {
        cfg.trace_sample = obstrace::parse_sample(spec)?;
    }
    cfg.trace_json = p.get("trace-json").map(str::to_string);
    cfg.pin = PinMode::parse(p.get("pin").unwrap_or("none"))?;
    if let Some(path) = p.get("net-config") {
        let opts = crate::config::net_options_from_file(path).map_err(|e| e.to_string())?;
        opts.apply_server(&mut cfg);
    }
    let cfg_json = cfg.clone();
    let server = NetServer::bind(cfg)?;
    let addr = server.local_addr()?;
    // Logged eagerly: an operator who needs the resolved address (port 0)
    // while the server blocks in serve() runs with `ROSELLA_LOG=info`.
    crate::log_info!("listening on {addr}, waiting for {} frontends", cfg_json.frontends);
    let report = server.serve()?;
    let mut out = report.render();
    if let Some(path) = p.get("json") {
        let doc = crate::config::to_string(&bench_json(&cfg_json, &report));
        std::fs::write(path, doc).map_err(|e| format!("write {path}: {e}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_degenerate_runs() {
        assert!(NetServerConfig::default().validate().is_ok());
        let bad = |f: fn(&mut NetServerConfig)| {
            let mut c = NetServerConfig::default();
            f(&mut c);
            c.validate()
        };
        assert!(bad(|c| c.frontends = 0).is_err());
        assert!(bad(|c| c.speeds.clear()).is_err());
        assert!(bad(|c| c.rate = 0.0).is_err());
        assert!(bad(|c| c.duration = f64::INFINITY).is_err());
        assert!(bad(|c| c.batch = 0).is_err());
        assert!(bad(|c| c.net_batch = 0).is_err());
        assert!(bad(|c| c.net_flush_us = f64::NAN).is_err());
        assert!(bad(|c| c.net_flush_us = -1.0).is_err());
        assert!(bad(|c| c.sync_interval = 0.0).is_err());
        assert!(bad(|c| c.policy = "nonsense".into()).is_err());
        assert!(bad(|c| c.listen.clear()).is_err());
        // The satellite rejects: NaN / negative sync thresholds must fail
        // at config time, not produce a policy that never or always merges.
        assert!(bad(|c| c.sync_policy.threshold = f64::NAN).is_err());
        assert!(bad(|c| c.sync_policy.threshold = -0.5).is_err());
        // Zero poll shards is degenerate; None (auto) and any positive
        // count are fine.
        assert!(bad(|c| c.poll_shards = Some(0)).is_err());
        assert!(bad(|c| c.poll_shards = Some(3)).is_ok());
    }

    #[test]
    fn bench_json_round_trips() {
        let cfg = NetServerConfig::default();
        let report = NetReport {
            frontends: 2,
            workers: 4,
            policy: "ppot".into(),
            elapsed: 1.5,
            decisions: 600,
            dispatched: 590,
            completed: 590,
            benchmarks: 12,
            submit_dropped: 0,
            tasks_per_sec: 393.3,
            sync_epochs: 7,
            sync_merges: 7,
            sync_exports: 14,
            estimates: vec![(2.0, 1.8), (1.0, 0.9)],
            per_frontend: vec![
                DoneStats {
                    decisions: 300,
                    dispatched: 295,
                    benchmarks: 6,
                    resp_count: 295,
                    resp_mean: 0.012,
                    resp_p50: 0.01,
                    resp_p95: 0.03,
                },
                DoneStats {
                    decisions: 300,
                    dispatched: 295,
                    benchmarks: 6,
                    resp_count: 295,
                    resp_mean: 0.014,
                    resp_p50: 0.011,
                    resp_p95: 0.04,
                },
            ],
            poll_shards: 2,
            poll_wakeups: 1234,
            traced_spans: 17,
            flight_dropped: 3,
        };
        assert_eq!(report.resp_count(), 590);
        assert!((report.mean_response() - 0.013).abs() < 1e-12);
        assert_eq!(report.worst_p95(), 0.04);
        let doc = crate::config::to_string(&bench_json(&cfg, &report));
        let back = crate::config::parse(&doc).expect("bench json must round-trip");
        let results = back.get("results").expect("results object");
        assert!(results.get("tasks_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(results.get("sync_merges").and_then(Json::as_f64), Some(7.0));
        assert_eq!(results.get("sync_exports").and_then(Json::as_f64), Some(14.0));
        assert_eq!(results.get("poll_wakeups").and_then(Json::as_f64), Some(1234.0));
        assert_eq!(results.get("traced_spans").and_then(Json::as_f64), Some(17.0));
        assert_eq!(results.get("flight_dropped").and_then(Json::as_f64), Some(3.0));
        assert_eq!(back.get("poll_shards").and_then(Json::as_f64), Some(2.0));
        let per = results.get("per_frontend").and_then(Json::as_arr).unwrap();
        assert_eq!(per.len(), 2);
        let rendered = report.render();
        assert!(rendered.contains("2 remote frontends"));
        assert!(rendered.contains("payload exports over the wire"));
        assert!(rendered.contains("2 poll shards"));
        assert!(rendered.contains("17 lifecycle spans"));
    }
}
