//! The worker-pool server: `rosella plane --listen ADDR`.
//!
//! Hosts the shared side of the cross-process plane — the live worker pool,
//! the seqlock [`EstimateTable`], the [`SharedViews`] sync-payload slots,
//! and the [`SyncPolicy`] consensus thread (the *same*
//! [`run_sync`](crate::plane::consensus) loop the in-process plane runs;
//! consensus is transport-agnostic because exports land in the same slots
//! whether they arrive from a shard thread or a socket) — and serves `k`
//! remote scheduler frontends over the
//! [`wire`](crate::net::wire) protocol.
//!
//! One data-plane thread, all connections: the serving thread runs a
//! single nonblocking poll loop (`set_nonblocking` + readiness sweep over
//! per-connection read/write buffers, `std::net` only) that accepts and
//! handshakes frontends, enqueues `Submit`/`SubmitBatch` dispatches into
//! the pool, answers beats with probe snapshots / routed completions /
//! fresh consensus, lands `SyncExport`s in the shard's view slot, and
//! records each frontend's `Done` statistics — no per-connection handler
//! threads, so one pool thread serves dozens of frontends without
//! context-switch storms. The run lifecycle is server-driven: the loop
//! stops the run at its deadline, each connection releases its pool
//! ingress on its first post-stop beat, the pool is joined once every
//! ingress is released, frontends observe `stop`/`drained` through their
//! beats, export final views, and send `Done`; the drain-time consensus
//! epoch then merges every shard's final view exactly as the in-process
//! plane does, and the merged [`NetReport`] is the cross-process analogue
//! of [`PlaneReport`](crate::plane::PlaneReport).

use super::transport::{drain_completions, estimates_if_moved, lambda_total};
use super::wire::{self, DoneStats, HelloAck, Msg, TickReply, WireCompletion};
use crate::config::Json;
use crate::coordinator::worker::{self, Completion, CompletionSink, LiveTask, PayloadMode};
use crate::learner::{SyncPolicy, SyncPolicyConfig};
use crate::plane::consensus::{run_sync, SyncRun};
use crate::plane::{CachePadded, CpuTopology, EstimateTable, PinMode, PlacementPlan, SharedViews};
use crate::scheduler::PolicyKind;
use crate::types::TaskKind;
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Completions shipped per `TickReply` at most (keeps frames far below the
/// wire bound; the remainder rides the next beat).
const MAX_COMPLETIONS_PER_REPLY: usize = 8192;

/// Protocol bound on one task's demand in unit-speed seconds. A task
/// longer than this would wedge its worker — and the drain-time pool join
/// — for its whole service time, so it is rejected as a protocol
/// violation rather than clamped.
const MAX_TASK_DEMAND: f64 = 60.0;

/// Configuration of one pool-server run.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub listen: String,
    /// Remote scheduler count k the run waits for.
    pub frontends: usize,
    /// Worker speed multipliers (one live worker thread per entry).
    pub speeds: Vec<f64>,
    /// Scheduling policy, forwarded verbatim to the frontends
    /// (`PolicyKind::parse` spelling).
    pub policy: String,
    /// Aggregate arrival rate (jobs/second) split across frontends.
    pub rate: f64,
    /// Run duration in seconds (deadline measured from `Start`).
    pub duration: f64,
    /// Mean task demand (unit-speed seconds).
    pub mean_demand: f64,
    /// Arrival ingestion batch size per frontend.
    pub batch: usize,
    /// Submit-coalescing batch size B advertised to frontends: each
    /// frontend flushes its pending dispatches as one `SubmitBatch` frame
    /// once B accumulate (or the flush deadline fires, whichever first).
    pub net_batch: usize,
    /// Submit-coalescing flush deadline D in microseconds advertised to
    /// frontends: a partial batch never waits longer than this, so light
    /// load keeps eager-dispatch latency.
    pub net_flush_us: f64,
    /// Run seed.
    pub seed: u64,
    /// Frontend learner publish/export cadence (seconds).
    pub publish_interval: f64,
    /// Warmup cutoff for response metrics (seconds).
    pub warmup: f64,
    /// Whether frontends run their benchmark dispatchers.
    pub fake_jobs: bool,
    /// Estimate-sync consensus interval (seconds).
    pub sync_interval: f64,
    /// Consensus strategy and knobs.
    pub sync_policy: SyncPolicyConfig,
    /// Per-read socket timeout (handshake and run).
    pub read_timeout: Duration,
    /// Serve a Prometheus `/metrics` endpoint on this address for the
    /// run's duration (`None` disables the scrape listener).
    pub metrics_listen: Option<String>,
    /// Dump the decision flight recorder as JSONL to this path at drain
    /// (`None` disables recording entirely).
    pub flight_record: Option<String>,
    /// Worker-thread pinning: `None` leaves placement to the OS, `Cores`
    /// and `Sockets` pin each worker thread to a discovered CPU
    /// (best-effort; a denied affinity syscall degrades to unpinned).
    pub pin: PinMode,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            frontends: 2,
            speeds: vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
            policy: "ppot".into(),
            rate: 400.0,
            duration: 3.0,
            mean_demand: 0.01,
            batch: 64,
            net_batch: 64,
            net_flush_us: 200.0,
            seed: 42,
            publish_interval: 0.2,
            warmup: 0.0,
            fake_jobs: true,
            sync_interval: 0.2,
            sync_policy: SyncPolicyConfig::periodic(),
            read_timeout: Duration::from_secs(30),
            metrics_listen: None,
            flight_record: None,
            pin: PinMode::None,
        }
    }
}

impl NetServerConfig {
    /// Validate every field before binding: the same class of config-time
    /// rejection the in-process plane performs, including the sync
    /// threshold/interval cross-checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.listen.is_empty() {
            return Err("listen address must not be empty".into());
        }
        if self.frontends == 0 {
            return Err("need at least one frontend".into());
        }
        if self.speeds.is_empty() {
            return Err("need at least one worker".into());
        }
        if !(self.rate > 0.0 && self.rate.is_finite()) {
            return Err("rate must be positive and finite".into());
        }
        if !(self.duration > 0.0 && self.duration.is_finite()) {
            return Err("duration must be positive and finite".into());
        }
        if !(self.mean_demand > 0.0 && self.mean_demand.is_finite()) {
            return Err("mean demand must be positive and finite".into());
        }
        if self.batch == 0 {
            return Err("batch must be at least 1".into());
        }
        if self.net_batch == 0 {
            return Err("net batch must be at least 1".into());
        }
        if !(self.net_flush_us >= 0.0 && self.net_flush_us.is_finite()) {
            return Err("net flush deadline must be finite and non-negative".into());
        }
        if !(self.publish_interval > 0.0 && self.publish_interval.is_finite()) {
            return Err("publish interval must be positive and finite".into());
        }
        if !(self.warmup >= 0.0 && self.warmup.is_finite()) {
            return Err("warmup must be finite and non-negative".into());
        }
        if !(self.sync_interval > 0.0 && self.sync_interval.is_finite()) {
            return Err("the net plane needs a positive finite sync interval".into());
        }
        self.sync_policy
            .validate(self.sync_interval)
            .map_err(|e| format!("sync policy: {e}"))?;
        PolicyKind::parse(&self.policy)?;
        Ok(())
    }
}

/// Everything the merged cross-process report carries.
#[derive(Debug)]
pub struct NetReport {
    /// Remote frontend count.
    pub frontends: usize,
    /// Worker count.
    pub workers: usize,
    /// Policy name (as configured).
    pub policy: String,
    /// Seconds from `Start` to the stop instant.
    pub elapsed: f64,
    /// Total scheduling decisions across frontends.
    pub decisions: u64,
    /// Real tasks the server enqueued (its own count of `Submit`s).
    pub dispatched: u64,
    /// Real tasks completed after the full drain (worker counters).
    pub completed: u64,
    /// Benchmark tasks the frontends injected.
    pub benchmarks: u64,
    /// Post-stop submits dropped at the server (should stay 0).
    pub submit_dropped: u64,
    /// Completed real tasks per second of run time.
    pub tasks_per_sec: f64,
    /// Consensus check epochs, including the drain-time epoch.
    pub sync_epochs: u64,
    /// Consensus merge operations, including the one unconditional
    /// drain-time merge (so this alone does not prove wire traffic).
    pub sync_merges: u64,
    /// SyncExport frames received across all frontends — the direct count
    /// of consensus payloads that crossed the wire (every frontend sends
    /// at least its final drain-time export).
    pub sync_exports: u64,
    /// Final consensus estimates vs configured speeds.
    pub estimates: Vec<(f64, f64)>,
    /// Per-frontend final statistics, indexed by shard.
    pub per_frontend: Vec<DoneStats>,
}

impl NetReport {
    /// Post-warmup latency record count across frontends.
    pub fn resp_count(&self) -> u64 {
        self.per_frontend.iter().map(|d| d.resp_count).sum()
    }

    /// Response-count-weighted mean response time (seconds).
    pub fn mean_response(&self) -> f64 {
        let count = self.resp_count();
        if count == 0 {
            return 0.0;
        }
        let sum: f64 =
            self.per_frontend.iter().map(|d| d.resp_mean * d.resp_count as f64).sum();
        sum / count as f64
    }

    /// Worst per-frontend p95 response time (seconds).
    pub fn worst_p95(&self) -> f64 {
        self.per_frontend.iter().map(|d| d.resp_p95).fold(0.0, f64::max)
    }

    /// Render a human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "net plane: {} remote frontends × {} workers, policy {}\n",
            self.frontends, self.workers, self.policy
        ));
        out.push_str(&format!(
            "tasks      : dispatched {} | completed {} | benchmarks {} — {:.0} tasks/s\n",
            self.dispatched, self.completed, self.benchmarks, self.tasks_per_sec
        ));
        out.push_str(&format!(
            "decisions  : {} in {:.2}s across {} schedulers\n",
            self.decisions, self.elapsed, self.frontends
        ));
        out.push_str(&format!(
            "consensus  : {} epochs, {} merges, {} payload exports over the wire\n",
            self.sync_epochs, self.sync_merges, self.sync_exports
        ));
        if self.resp_count() > 0 {
            out.push_str(&format!(
                "latency ms : mean {:.1} | worst p95 {:.1} ({} jobs)\n",
                self.mean_response() * 1e3,
                self.worst_p95() * 1e3,
                self.resp_count()
            ));
        }
        for d in &self.per_frontend {
            out.push_str(&format!(
                "  frontend : {} decisions | {} dispatched | {} benchmarks\n",
                d.decisions, d.dispatched, d.benchmarks
            ));
        }
        out.push_str("worker speed estimates (true → learned):\n");
        for (i, (truth, est)) in self.estimates.iter().enumerate() {
            out.push_str(&format!("  worker {i}: {truth:.2} → {est:.2}\n"));
        }
        if self.submit_dropped > 0 {
            out.push_str(&format!("late submits dropped at stop: {}\n", self.submit_dropped));
        }
        out
    }
}

/// Machine-readable run results (`BENCH_net_smoke.json` in the CI
/// loopback smoke), shaped like `BENCH_plane.json` so within-run ratio
/// gates can read both.
pub fn bench_json(cfg: &NetServerConfig, r: &NetReport) -> Json {
    let per: Vec<Json> = r
        .per_frontend
        .iter()
        .enumerate()
        .map(|(shard, d)| {
            let mut m = BTreeMap::new();
            m.insert("shard".into(), Json::Num(shard as f64));
            m.insert("decisions".into(), Json::Num(d.decisions as f64));
            m.insert("dispatched".into(), Json::Num(d.dispatched as f64));
            m.insert("benchmarks".into(), Json::Num(d.benchmarks as f64));
            m.insert("resp_count".into(), Json::Num(d.resp_count as f64));
            m.insert("mean_ms".into(), Json::Num(d.resp_mean * 1e3));
            m.insert("p50_ms".into(), Json::Num(d.resp_p50 * 1e3));
            m.insert("p95_ms".into(), Json::Num(d.resp_p95 * 1e3));
            Json::Obj(m)
        })
        .collect();
    let mut results = BTreeMap::new();
    results.insert("elapsed".into(), Json::Num(r.elapsed));
    results.insert("tasks_per_sec".into(), Json::Num(r.tasks_per_sec.round()));
    results.insert("decisions".into(), Json::Num(r.decisions as f64));
    results.insert(
        "decisions_per_sec".into(),
        Json::Num((r.decisions as f64 / r.elapsed.max(1e-9)).round()),
    );
    results.insert("dispatched".into(), Json::Num(r.dispatched as f64));
    results.insert("completed".into(), Json::Num(r.completed as f64));
    results.insert("benchmarks".into(), Json::Num(r.benchmarks as f64));
    results.insert("submit_dropped".into(), Json::Num(r.submit_dropped as f64));
    results.insert("sync_epochs".into(), Json::Num(r.sync_epochs as f64));
    results.insert("sync_merges".into(), Json::Num(r.sync_merges as f64));
    results.insert("sync_exports".into(), Json::Num(r.sync_exports as f64));
    results.insert("resp_count".into(), Json::Num(r.resp_count() as f64));
    results.insert("mean_ms".into(), Json::Num(r.mean_response() * 1e3));
    results.insert("worst_p95_ms".into(), Json::Num(r.worst_p95() * 1e3));
    results.insert("per_frontend".into(), Json::Arr(per));
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("net".into()));
    top.insert("frontends".into(), Json::Num(cfg.frontends as f64));
    top.insert("workers".into(), Json::Num(cfg.speeds.len() as f64));
    top.insert("rate".into(), Json::Num(cfg.rate));
    top.insert("duration".into(), Json::Num(cfg.duration));
    top.insert("seed".into(), Json::Num(cfg.seed as f64));
    top.insert("policy".into(), Json::Str(cfg.policy.clone()));
    top.insert("sync_policy".into(), Json::Str(cfg.sync_policy.kind.name().into()));
    top.insert("sync_interval".into(), Json::Num(cfg.sync_interval));
    top.insert("sync_threshold".into(), Json::Num(cfg.sync_policy.threshold));
    top.insert("results".into(), Json::Obj(results));
    Json::Obj(top)
}

/// A bound pool server, not yet serving — split from [`NetServer::serve`]
/// so callers (and tests binding port 0) can learn the address first.
pub struct NetServer {
    cfg: NetServerConfig,
    listener: TcpListener,
}

/// Idle nap between poll sweeps when no socket moved: short enough that a
/// beat never waits a visible while (the old per-thread design slept
/// 10 ms in its accept loop; 500 µs keeps worst-case added latency well
/// under one flush deadline).
const IDLE_SLEEP: Duration = Duration::from_micros(500);

/// Shared run state every connection's message handling reads; owned by
/// the poll loop, one instance per run.
struct PoolCtx {
    n: usize,
    probes: Vec<Arc<CachePadded<AtomicUsize>>>,
    table: Arc<EstimateTable>,
    views: Arc<SharedViews>,
    stop: Arc<AtomicBool>,
    lambda_slots: Vec<Arc<AtomicU64>>,
    start: Instant,
    obs: Arc<crate::obs::Registry>,
}

/// Per-connection state the poll loop owns — the replacement for the old
/// per-connection handler thread. Reads reassemble frames through
/// `rbuf`/`roff`; replies stage through `wbuf`/`woff` so a peer that is
/// slow to read never blocks the loop for anyone else.
struct Conn {
    stream: TcpStream,
    shard: usize,
    /// Frame reassembly: bytes land at the tail, frames pop at `roff`.
    rbuf: Vec<u8>,
    roff: usize,
    /// Encoded replies not yet accepted by the socket (`woff` sent so far).
    wbuf: Vec<u8>,
    woff: usize,
    comp_rx: Receiver<Completion>,
    /// Completions drained from the pool, awaiting the next beat's reply.
    pending: VecDeque<WireCompletion>,
    /// Pool ingress; released (set to `None`) on the first post-stop beat.
    clients: Option<Vec<worker::WorkerClient>>,
    disconnected: bool,
    last_activity: Instant,
    /// `Done` received and acked: the connection is finished.
    done: bool,
    stats: Option<DoneStats>,
    dispatched: u64,
    submit_dropped: u64,
    /// SyncExport frames this connection landed in the view slots — the
    /// direct proof that consensus payloads crossed the wire.
    sync_exports: u64,
}

/// Drain whatever the nonblocking socket has ready into `buf`, returning
/// the bytes read this sweep (0 when the read would block). A clean EOF is
/// an error: every peer announces departure with `Done` first.
fn read_available(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    tmp: &mut [u8],
) -> Result<usize, String> {
    use std::io::Read;
    let mut total = 0usize;
    loop {
        match stream.read(tmp) {
            Ok(0) => return Err("connection closed".into()),
            Ok(got) => {
                buf.extend_from_slice(&tmp[..got]);
                total += got;
                if got < tmp.len() {
                    return Ok(total);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(total),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("net read: {e}")),
        }
    }
}

/// Try to pop one complete frame off the front of `buf`: the decoded
/// message plus the bytes it consumed, or `None` while the frame is still
/// partial. Header validation happens first, so a hostile length field is
/// rejected from 12 bytes without waiting for (or allocating) a payload.
fn try_frame(buf: &[u8]) -> Result<Option<(Msg, usize)>, String> {
    if buf.len() < wire::HEADER_LEN {
        return Ok(None);
    }
    let header: &[u8; wire::HEADER_LEN] =
        buf[..wire::HEADER_LEN].try_into().expect("sized slice");
    let need = wire::HEADER_LEN + wire::header_payload_len(header).map_err(|e| e.to_string())?;
    if buf.len() < need {
        return Ok(None);
    }
    let msg = Msg::decode(&buf[..need]).map_err(|e| e.to_string())?;
    wire::note_frames_received(1, need as u64);
    Ok(Some((msg, need)))
}

impl NetServer {
    /// Validate the configuration and bind the listen socket.
    pub fn bind(cfg: NetServerConfig) -> Result<Self, String> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| format!("bind {}: {e}", cfg.listen))?;
        Ok(Self { cfg, listener })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener.local_addr().map_err(|e| format!("local addr: {e}"))
    }

    /// Serve one run to completion: handshake all `k` frontends, release
    /// them with `Start`, host the pool until the deadline, drain, run the
    /// final consensus epoch, and return the merged report.
    pub fn serve(self) -> Result<NetReport, String> {
        let NetServer { cfg, listener } = self;
        let k = cfg.frontends;
        let n = cfg.speeds.len();
        let total: f64 = cfg.speeds.iter().sum();
        let prior = total / n as f64;
        let mu_bar = total / cfg.mean_demand;

        // Handshake phase: accept until every shard is claimed exactly
        // once, serving every in-flight handshake from this one thread.
        // Accepts and Hello reads are both nonblocking with a
        // progress-refreshed deadline, so a frontend that never connects
        // (or stalls mid-Hello) fails the run with a clear error instead
        // of wedging the server — and a stalled greeter cannot delay the
        // accept or handshake of any other frontend.
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("set nonblocking: {e}"))?;
        let mut conns: Vec<Option<(TcpStream, Vec<u8>)>> = (0..k).map(|_| None).collect();
        let mut scratch = Vec::with_capacity(4096);
        let mut tmp = vec![0u8; 64 * 1024];
        let mut greeting: Vec<(TcpStream, SocketAddr, Vec<u8>)> = Vec::new();
        let mut claimed = 0usize;
        let mut accept_deadline = Instant::now() + cfg.read_timeout;
        while claimed < k {
            let mut progress = false;
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream
                        .set_nonblocking(true)
                        .map_err(|e| format!("set nonblocking: {e}"))?;
                    stream.set_nodelay(true).map_err(|e| format!("set nodelay: {e}"))?;
                    greeting.push((stream, peer, Vec::new()));
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(format!("accept: {e}")),
            }
            let mut i = 0;
            while i < greeting.len() {
                let claim = {
                    let (stream, peer, rbuf) = &mut greeting[i];
                    let got = read_available(stream, rbuf, &mut tmp)
                        .map_err(|e| format!("handshake with {peer}: {e}"))?;
                    progress |= got > 0;
                    match try_frame(rbuf).map_err(|e| format!("handshake with {peer}: {e}"))? {
                        Some((Msg::Hello { shard, shards }, used)) => {
                            Some((shard as usize, shards as usize, used))
                        }
                        Some((other, _)) => {
                            return Err(format!(
                                "handshake with {peer}: expected Hello, got tag {}",
                                other.tag()
                            ))
                        }
                        None => None,
                    }
                };
                let Some((shard, shards, used)) = claim else {
                    i += 1;
                    continue;
                };
                let (mut stream, peer, rbuf) = greeting.swap_remove(i);
                if shards != k {
                    return Err(format!(
                        "frontend {peer} expects {shards} shards but this server runs {k}"
                    ));
                }
                if shard >= k {
                    return Err(format!("frontend {peer} claimed shard {shard} of {k}"));
                }
                if conns[shard].is_some() {
                    return Err(format!(
                        "shard {shard} claimed twice (second claim from {peer})"
                    ));
                }
                let ack = Msg::HelloAck(HelloAck {
                    workers: n as u32,
                    batch: cfg.batch as u32,
                    net_batch: cfg.net_batch as u32,
                    net_flush_us: cfg.net_flush_us,
                    seed: cfg.seed,
                    prior,
                    mean_demand: cfg.mean_demand,
                    mu_bar,
                    rate: cfg.rate,
                    duration: cfg.duration,
                    warmup: cfg.warmup,
                    publish_interval: cfg.publish_interval,
                    sync_interval: cfg.sync_interval,
                    sync_threshold: cfg.sync_policy.threshold,
                    fake_jobs: cfg.fake_jobs,
                    policy: cfg.policy.clone(),
                    sync_policy: cfg.sync_policy.kind.name().into(),
                    speeds: cfg.speeds.clone(),
                });
                // The ack is a few hundred bytes into a fresh socket whose
                // send buffer is empty, so a short blocking write keeps the
                // handshake simple without risking a stall.
                stream.set_nonblocking(false).map_err(|e| format!("set blocking: {e}"))?;
                wire::write_msg(&mut stream, &ack, &mut scratch)
                    .map_err(|e| format!("handshake with {peer}: {e}"))?;
                stream
                    .set_nonblocking(true)
                    .map_err(|e| format!("set nonblocking: {e}"))?;
                // A well-behaved frontend sends nothing until Start, but
                // any bytes that did arrive behind the Hello are carried
                // into the connection's reassembly buffer, not dropped.
                conns[shard] = Some((stream, rbuf[used..].to_vec()));
                claimed += 1;
                progress = true;
            }
            if progress {
                accept_deadline = Instant::now() + cfg.read_timeout;
            } else if claimed < k {
                if Instant::now() >= accept_deadline {
                    return Err(format!(
                        "timed out waiting for frontends: {claimed} of {k} connected \
                         within {:.0?}",
                        cfg.read_timeout
                    ));
                }
                std::thread::sleep(IDLE_SLEEP);
            }
        }

        // The shared side: worker pool with per-shard completion routing,
        // seqlock table, sync-payload slots, and the consensus thread.
        let mut shard_rxs: Vec<Receiver<Completion>> = Vec::with_capacity(k);
        let mut txs = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = std::sync::mpsc::channel::<Completion>();
            txs.push(tx);
            shard_rxs.push(rx);
        }
        let sink = CompletionSink::sharded(txs);
        // Worker placement: the pool server hosts no shard threads (those
        // live at the remote frontends), so the plan covers workers only.
        let plan = match cfg.pin {
            PinMode::None => PlacementPlan::unpinned(0, n),
            mode => PlacementPlan::new(mode, &CpuTopology::detect(), 0, n),
        };
        let workers: Vec<worker::WorkerHandle> = cfg
            .speeds
            .iter()
            .enumerate()
            .map(|(i, &s)| {
                worker::spawn_pinned(i, s, PayloadMode::Sleep, sink.clone(), plan.worker_cpus[i])
            })
            .collect();
        drop(sink);
        let probes: Vec<Arc<CachePadded<AtomicUsize>>> =
            workers.iter().map(|w| w.client.qlen.clone()).collect();
        let completed_counters: Vec<Arc<AtomicU64>> =
            workers.iter().map(|w| w.client.completed_real.clone()).collect();
        let table = Arc::new(EstimateTable::new(n, prior));
        let views = Arc::new(SharedViews::new(k, n, prior));
        let stop = Arc::new(AtomicBool::new(false));
        let sync_stop = Arc::new(AtomicBool::new(false));
        let lambda_slots: Vec<Arc<AtomicU64>> =
            (0..k).map(|_| Arc::new(AtomicU64::new(0f64.to_bits()))).collect();
        let start = Instant::now();

        // Telemetry: one registry for the whole run (the poll loop writes
        // each connection's shard slot), an optional flight recorder (the
        // server only sees consensus events — placements happen at the
        // frontends), and an optional scrape listener sharing the
        // in-process plane's endpoint surface.
        let obs = Arc::new(crate::obs::Registry::new(k, n));
        let flight = cfg.flight_record.as_deref().map(|_| {
            Arc::new(crate::obs::FlightRecorder::new(k, crate::obs::flight::DEFAULT_CAPACITY))
        });
        let metrics = match cfg.metrics_listen.as_deref() {
            Some(addr) => Some(crate::plane::spawn_metrics_server(
                addr,
                obs.clone(),
                flight.clone(),
                probes.clone(),
            )?),
            None => None,
        };

        let sync_ctx = SyncRun {
            views: views.clone(),
            table: table.clone(),
            stop: sync_stop.clone(),
            policy: SyncPolicy::new(&cfg.sync_policy, cfg.sync_interval, k, cfg.seed ^ 0x57AC_6E55),
            prior,
            start,
            obs: obs.clone(),
            flight: flight.clone(),
        };
        let sync_handle = std::thread::Builder::new()
            .name("rosella-net-sync".into())
            .spawn(move || run_sync(sync_ctx))
            .map_err(|e| format!("spawn sync thread: {e}"))?;

        // Build per-connection poll state; the Start release rides each
        // connection's write buffer through the same loop that serves it.
        let mut rx_iter = shard_rxs.into_iter();
        let mut live: Vec<Conn> = Vec::with_capacity(k);
        for (shard, slot) in conns.into_iter().enumerate() {
            let (stream, rest) = slot.expect("every shard claimed");
            let mut conn = Conn {
                stream,
                shard,
                rbuf: rest,
                roff: 0,
                wbuf: Vec::with_capacity(4096),
                woff: 0,
                comp_rx: rx_iter.next().expect("one channel per shard"),
                pending: VecDeque::new(),
                clients: Some(workers.iter().map(|w| w.client.clone()).collect()),
                disconnected: false,
                last_activity: Instant::now(),
                done: false,
                stats: None,
                dispatched: 0,
                submit_dropped: 0,
                sync_exports: 0,
            };
            conn.queue_frame(&Msg::Start);
            live.push(conn);
        }
        drop(scratch);

        // The run itself: one nonblocking poll loop over every connection
        // — the serving thread IS the whole data plane. The sync thread is
        // stopped unconditionally afterwards — even when the loop failed —
        // so no run leaks it.
        let ctx = PoolCtx {
            n,
            probes,
            table: table.clone(),
            views,
            stop,
            lambda_slots,
            start,
            obs,
        };
        let served = poll_loop(&cfg, &ctx, &mut live, workers, &mut tmp);
        sync_stop.store(true, Ordering::Release);
        let outcome =
            sync_handle.join().map_err(|_| "sync thread panicked".to_string())?;
        let elapsed = served?;
        let (mu, _lambda) = table.snapshot();
        let estimates: Vec<(f64, f64)> =
            cfg.speeds.iter().zip(mu.iter()).map(|(&t, &e)| (t, e)).collect();

        let completed: u64 = completed_counters.iter().map(|c| c.load(Ordering::Acquire)).sum();
        let mut per_frontend = vec![DoneStats::default(); k];
        let mut dispatched = 0u64;
        let mut submit_dropped = 0u64;
        let mut sync_exports = 0u64;
        for c in live {
            dispatched += c.dispatched;
            submit_dropped += c.submit_dropped;
            sync_exports += c.sync_exports;
            per_frontend[c.shard] =
                c.stats.ok_or_else(|| format!("shard {} closed before Done", c.shard))?;
        }
        let decisions: u64 = per_frontend.iter().map(|d| d.decisions).sum();
        let benchmarks: u64 = per_frontend.iter().map(|d| d.benchmarks).sum();
        if let Some(srv) = metrics {
            srv.shutdown();
        }
        if let (Some(path), Some(rec)) = (cfg.flight_record.as_deref(), flight.as_deref()) {
            std::fs::write(path, rec.dump_jsonl())
                .map_err(|e| format!("write flight record {path}: {e}"))?;
        }
        Ok(NetReport {
            frontends: k,
            workers: n,
            policy: cfg.policy.clone(),
            elapsed,
            decisions,
            dispatched,
            completed,
            benchmarks,
            submit_dropped,
            tasks_per_sec: completed as f64 / elapsed.max(1e-9),
            sync_epochs: outcome.epochs,
            sync_merges: outcome.merges,
            sync_exports,
            estimates,
            per_frontend,
        })
    }
}

impl Conn {
    /// Stage one frame for delivery; the poll loop flushes it as the
    /// socket accepts bytes, so queueing never blocks.
    fn queue_frame(&mut self, msg: &Msg) {
        let before = self.wbuf.len();
        msg.encode_into(&mut self.wbuf);
        wire::note_frames_sent(1, (self.wbuf.len() - before) as u64);
    }

    /// Push staged bytes into the socket until it would block. Returns
    /// whether anything moved.
    fn flush_writes(&mut self) -> Result<bool, String> {
        use std::io::Write;
        let mut progress = false;
        while self.woff < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.woff..]) {
                Ok(0) => {
                    return Err(format!("shard {}: connection closed mid-write", self.shard))
                }
                Ok(sent) => {
                    self.woff += sent;
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(format!("shard {}: net write: {e}", self.shard)),
            }
        }
        if self.woff > 0 && self.woff == self.wbuf.len() {
            self.wbuf.clear();
            self.woff = 0;
        }
        Ok(progress)
    }

    /// Pop the next complete frame from the reassembly buffer, if one has
    /// fully arrived.
    fn next_frame(&mut self) -> Result<Option<Msg>, String> {
        match try_frame(&self.rbuf[self.roff..])
            .map_err(|e| format!("shard {}: {e}", self.shard))?
        {
            Some((msg, used)) => {
                self.roff += used;
                if self.roff == self.rbuf.len() {
                    self.rbuf.clear();
                    self.roff = 0;
                }
                Ok(Some(msg))
            }
            None => {
                // Partial frame: shift it to the front so consumed bytes
                // cannot accumulate across frames.
                if self.roff > 0 {
                    self.rbuf.drain(..self.roff);
                    self.roff = 0;
                }
                Ok(None)
            }
        }
    }

    /// Enqueue one dispatch into the pool — the shared body of `Submit`
    /// and each `SubmitBatch` item.
    fn enqueue(
        &mut self,
        ctx: &PoolCtx,
        job: u64,
        worker: u32,
        kind: TaskKind,
        demand: f64,
    ) -> Result<(), String> {
        let w = worker as usize;
        if w >= ctx.n {
            return Err(format!("shard {}: submit to unknown worker {w}", self.shard));
        }
        // Wire floats are untrusted: an infinite demand would panic the
        // worker thread in Duration::from_secs_f64, and even a finite huge
        // one would wedge a worker (and the drain join) for the task's
        // whole service time.
        if !(demand.is_finite() && demand > 0.0 && demand <= MAX_TASK_DEMAND) {
            return Err(format!(
                "shard {}: demand {demand} outside (0, {MAX_TASK_DEMAND}]",
                self.shard
            ));
        }
        match self.clients.as_ref() {
            Some(cs) => {
                cs[w].enqueue(LiveTask {
                    job,
                    kind,
                    demand: demand.max(1e-6),
                    enqueued: Instant::now(),
                });
                let slot = ctx.obs.shard(self.shard);
                if kind == TaskKind::Real {
                    self.dispatched += 1;
                    slot.dispatched.inc();
                } else {
                    slot.bench_dispatched.inc();
                }
            }
            // Ingress already released at stop: drop stragglers.
            None => self.submit_dropped += 1,
        }
        Ok(())
    }

    /// Serve one coordination beat (a `Tick` or a `SubmitBatch`'s
    /// piggybacked tick): land λ̂ₛ, drain completions, stage the reply.
    fn beat(
        &mut self,
        ctx: &PoolCtx,
        epoch: u64,
        lambda_local: f64,
        mu_buf: &mut Vec<f64>,
    ) -> Result<(), String> {
        // A NaN λ̂ₛ stored here would poison the lambda_live sum served to
        // every other frontend.
        if !(lambda_local.is_finite() && lambda_local >= 0.0) {
            return Err(format!(
                "shard {}: non-finite arrival estimate {lambda_local}",
                self.shard
            ));
        }
        ctx.lambda_slots[self.shard].store(lambda_local.to_bits(), Ordering::Relaxed);
        let stopping = ctx.stop.load(Ordering::Relaxed);
        if stopping {
            // Release our pool ingress so the workers can drain; every
            // Submit this frontend sent before observing the stop flag was
            // already processed (the socket is ordered).
            self.clients = None;
        }
        let slot = ctx.obs.shard(self.shard);
        let pending = &mut self.pending;
        drain_completions(&self.comp_rx, &mut self.disconnected, ctx.start, |c| {
            if c.kind == TaskKind::Real {
                slot.completed.inc();
                // The server only knows server-side sojourn (enqueue →
                // completion); end-to-end response lives at the frontends.
                slot.response_us.record((c.sojourn.max(0.0) * 1e6) as u64);
            }
            pending.push_back(c)
        });
        let take = self.pending.len().min(MAX_COMPLETIONS_PER_REPLY);
        let completions: Vec<WireCompletion> = self.pending.drain(..take).collect();
        let estimates = estimates_if_moved(&ctx.table, epoch, mu_buf);
        let reply = Msg::TickReply(TickReply {
            qlen: ctx.probes.iter().map(|q| q.load(Ordering::Relaxed) as u32).collect(),
            lambda_live: lambda_total(&ctx.lambda_slots),
            stop: stopping,
            drained: stopping
                && self.clients.is_none()
                && self.disconnected
                && self.pending.is_empty(),
            estimates,
            completions,
        });
        self.queue_frame(&reply);
        Ok(())
    }

    /// Dispatch one decoded message — the server side of the frontend's
    /// protocol loop, minus the socket I/O the poll loop owns.
    fn handle_msg(
        &mut self,
        ctx: &PoolCtx,
        msg: Msg,
        mu_buf: &mut Vec<f64>,
    ) -> Result<(), String> {
        match msg {
            Msg::Submit { job, worker, kind, demand } => {
                ctx.obs.wire_batch.record(1);
                self.enqueue(ctx, job, worker, kind, demand)
            }
            Msg::SubmitBatch { tick, items } => {
                if !items.is_empty() {
                    ctx.obs.wire_batch.record(items.len() as u64);
                }
                for it in items {
                    self.enqueue(ctx, it.job, it.worker, it.kind, it.demand)?;
                }
                match tick {
                    Some((epoch, lambda_local)) => self.beat(ctx, epoch, lambda_local, mu_buf),
                    None => Ok(()),
                }
            }
            Msg::Tick { epoch, lambda_local } => self.beat(ctx, epoch, lambda_local, mu_buf),
            Msg::SyncExport { shard, diverged, lambda_hat, views } => {
                if shard as usize != self.shard {
                    return Err(format!(
                        "shard {} exported a payload claiming shard {shard}",
                        self.shard
                    ));
                }
                if views.len() != ctx.n {
                    return Err(format!(
                        "shard {}: exported {} views over a {}-worker pool",
                        self.shard,
                        views.len(),
                        ctx.n
                    ));
                }
                // Consensus inputs are untrusted wire floats: one NaN μ̂
                // or λ̂ share would propagate through every future merge.
                if !(lambda_hat.is_finite() && lambda_hat >= 0.0)
                    || views.iter().any(|v| !(v.mu_hat.is_finite() && v.mu_hat >= 0.0))
                {
                    return Err(format!(
                        "shard {}: non-finite sync payload (λ̂ₛ {lambda_hat})",
                        self.shard
                    ));
                }
                ctx.views.store(self.shard, &views, lambda_hat);
                self.sync_exports += 1;
                ctx.obs.sync_exports.inc();
                if diverged {
                    ctx.views.request_merge();
                }
                Ok(())
            }
            Msg::Done(stats) => {
                // The frontends make the scheduling decisions; fold their
                // final count into the registry so a post-run scrape shows
                // the whole plane, not just the server's half.
                ctx.obs.shard(self.shard).decisions.add(stats.decisions);
                self.stats = Some(stats);
                self.queue_frame(&Msg::DoneAck);
                self.done = true;
                Ok(())
            }
            other => Err(format!(
                "shard {}: unexpected message tag {}",
                self.shard,
                other.tag()
            )),
        }
    }
}

/// The data plane: serve every connection from the caller's thread until
/// all of them finish, returning the measured run elapsed. On failure the
/// pool is still released and joined before the error propagates, so no
/// run leaks worker threads.
fn poll_loop(
    cfg: &NetServerConfig,
    ctx: &PoolCtx,
    conns: &mut [Conn],
    workers: Vec<worker::WorkerHandle>,
    tmp: &mut [u8],
) -> Result<f64, String> {
    let mut pool = Some(workers);
    let served = poll_loop_inner(cfg, ctx, conns, &mut pool, tmp);
    if served.is_err() {
        // Release every ingress before joining: the failing connections
        // never observed the stop, and the join would otherwise wait on
        // clients nobody will release.
        ctx.stop.store(true, Ordering::Relaxed);
        for c in conns.iter_mut() {
            c.clients = None;
        }
        if let Some(ws) = pool.take() {
            for w in ws {
                w.shutdown();
            }
        }
    }
    served
}

fn poll_loop_inner(
    cfg: &NetServerConfig,
    ctx: &PoolCtx,
    conns: &mut [Conn],
    pool: &mut Option<Vec<worker::WorkerHandle>>,
    tmp: &mut [u8],
) -> Result<f64, String> {
    let deadline = ctx.start + Duration::from_secs_f64(cfg.duration);
    let mut mu_buf = vec![0.0; ctx.n];
    let mut elapsed = cfg.duration;
    let mut stopped = false;
    loop {
        let mut progress = false;
        if !stopped && Instant::now() >= deadline {
            ctx.stop.store(true, Ordering::Relaxed);
            elapsed = ctx.start.elapsed().as_secs_f64();
            stopped = true;
        }
        for c in conns.iter_mut() {
            if c.done {
                // Only the DoneAck can still be in flight; push it out and
                // otherwise leave the socket alone.
                if c.woff < c.wbuf.len() {
                    progress |= c.flush_writes()?;
                }
                continue;
            }
            progress |= c.flush_writes()?;
            let got = read_available(&mut c.stream, &mut c.rbuf, tmp)
                .map_err(|e| format!("shard {}: {e}", c.shard))?;
            if got > 0 {
                progress = true;
                c.last_activity = Instant::now();
            }
            while let Some(msg) = c.next_frame()? {
                progress = true;
                c.handle_msg(ctx, msg, &mut mu_buf)?;
                if c.done {
                    break;
                }
            }
            // Flush once more so replies staged this sweep leave now
            // instead of waiting out the idle nap.
            progress |= c.flush_writes()?;
        }
        // Join the pool once every connection has released its ingress:
        // the join blocks only for in-flight task payloads, and it must
        // happen before any connection can report itself drained (the
        // completion channels disconnect only when the workers exit).
        if stopped && pool.is_some() && conns.iter().all(|c| c.done || c.clients.is_none()) {
            for w in pool.take().expect("checked is_some") {
                w.shutdown();
            }
            progress = true;
        }
        if conns.iter().all(|c| c.done && c.woff >= c.wbuf.len()) {
            return Ok(elapsed);
        }
        if !progress {
            let now = Instant::now();
            for c in conns.iter() {
                if !c.done && now.duration_since(c.last_activity) > cfg.read_timeout {
                    return Err(format!(
                        "shard {}: no frame within {:.0?}",
                        c.shard, cfg.read_timeout
                    ));
                }
            }
            std::thread::sleep(IDLE_SLEEP);
        }
    }
}

/// CLI adapter for `rosella plane --listen`: the pool-server side of the
/// cross-process plane, sharing the `plane` subcommand's flag surface.
pub fn server_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let mut cfg = NetServerConfig::default();
    if let Some(l) = p.get("listen") {
        cfg.listen = l.to_string();
    }
    if let Some(f) = p.get("frontends") {
        cfg.frontends = f.trim().parse().map_err(|_| {
            format!(
                "with --listen, --frontends must be a single remote scheduler count \
                 (got '{f}')"
            )
        })?;
    }
    cfg.speeds = crate::plane::speeds_from_cli(p)?;
    if let Some(pol) = p.get("policy") {
        cfg.policy = pol.to_string();
    }
    if let Some(v) = p.parse_as("rate")? {
        cfg.rate = v;
    }
    if let Some(v) = p.parse_as("duration")? {
        cfg.duration = v;
    }
    if let Some(v) = p.parse_as("demand")? {
        cfg.mean_demand = v;
    }
    if let Some(v) = p.parse_as("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = p.parse_as("net-batch")? {
        cfg.net_batch = v;
    }
    if let Some(v) = p.parse_as("net-flush-us")? {
        cfg.net_flush_us = v;
    }
    if let Some(v) = p.parse_as("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = p.parse_as("sync-interval")? {
        cfg.sync_interval = v;
    }
    cfg.sync_policy = SyncPolicyConfig {
        kind: crate::learner::SyncKind::parse(p.get("sync-policy").unwrap_or("periodic"))?,
        ..SyncPolicyConfig::default()
    };
    if let Some(t) = p.parse_as("sync-threshold")? {
        cfg.sync_policy.threshold = t;
    }
    cfg.fake_jobs = !p.flag("no-fake-jobs");
    cfg.metrics_listen = p.get("metrics-listen").map(str::to_string);
    cfg.flight_record = p.get("flight-record").map(str::to_string);
    cfg.pin = PinMode::parse(p.get("pin").unwrap_or("none"))?;
    if let Some(path) = p.get("net-config") {
        let opts = crate::config::net_options_from_file(path).map_err(|e| e.to_string())?;
        opts.apply_server(&mut cfg);
    }
    let cfg_json = cfg.clone();
    let server = NetServer::bind(cfg)?;
    let addr = server.local_addr()?;
    // Logged eagerly: an operator who needs the resolved address (port 0)
    // while the server blocks in serve() runs with `ROSELLA_LOG=info`.
    crate::log_info!("listening on {addr}, waiting for {} frontends", cfg_json.frontends);
    let report = server.serve()?;
    let mut out = report.render();
    if let Some(path) = p.get("json") {
        let doc = crate::config::to_string(&bench_json(&cfg_json, &report));
        std::fs::write(path, doc).map_err(|e| format!("write {path}: {e}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_degenerate_runs() {
        assert!(NetServerConfig::default().validate().is_ok());
        let bad = |f: fn(&mut NetServerConfig)| {
            let mut c = NetServerConfig::default();
            f(&mut c);
            c.validate()
        };
        assert!(bad(|c| c.frontends = 0).is_err());
        assert!(bad(|c| c.speeds.clear()).is_err());
        assert!(bad(|c| c.rate = 0.0).is_err());
        assert!(bad(|c| c.duration = f64::INFINITY).is_err());
        assert!(bad(|c| c.batch = 0).is_err());
        assert!(bad(|c| c.net_batch = 0).is_err());
        assert!(bad(|c| c.net_flush_us = f64::NAN).is_err());
        assert!(bad(|c| c.net_flush_us = -1.0).is_err());
        assert!(bad(|c| c.sync_interval = 0.0).is_err());
        assert!(bad(|c| c.policy = "nonsense".into()).is_err());
        assert!(bad(|c| c.listen.clear()).is_err());
        // The satellite rejects: NaN / negative sync thresholds must fail
        // at config time, not produce a policy that never or always merges.
        assert!(bad(|c| c.sync_policy.threshold = f64::NAN).is_err());
        assert!(bad(|c| c.sync_policy.threshold = -0.5).is_err());
    }

    #[test]
    fn bench_json_round_trips() {
        let cfg = NetServerConfig::default();
        let report = NetReport {
            frontends: 2,
            workers: 4,
            policy: "ppot".into(),
            elapsed: 1.5,
            decisions: 600,
            dispatched: 590,
            completed: 590,
            benchmarks: 12,
            submit_dropped: 0,
            tasks_per_sec: 393.3,
            sync_epochs: 7,
            sync_merges: 7,
            sync_exports: 14,
            estimates: vec![(2.0, 1.8), (1.0, 0.9)],
            per_frontend: vec![
                DoneStats {
                    decisions: 300,
                    dispatched: 295,
                    benchmarks: 6,
                    resp_count: 295,
                    resp_mean: 0.012,
                    resp_p50: 0.01,
                    resp_p95: 0.03,
                },
                DoneStats {
                    decisions: 300,
                    dispatched: 295,
                    benchmarks: 6,
                    resp_count: 295,
                    resp_mean: 0.014,
                    resp_p50: 0.011,
                    resp_p95: 0.04,
                },
            ],
        };
        assert_eq!(report.resp_count(), 590);
        assert!((report.mean_response() - 0.013).abs() < 1e-12);
        assert_eq!(report.worst_p95(), 0.04);
        let doc = crate::config::to_string(&bench_json(&cfg, &report));
        let back = crate::config::parse(&doc).expect("bench json must round-trip");
        let results = back.get("results").expect("results object");
        assert!(results.get("tasks_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(results.get("sync_merges").and_then(Json::as_f64), Some(7.0));
        assert_eq!(results.get("sync_exports").and_then(Json::as_f64), Some(14.0));
        let per = results.get("per_frontend").and_then(Json::as_arr).unwrap();
        assert_eq!(per.len(), 2);
        let rendered = report.render();
        assert!(rendered.contains("2 remote frontends"));
        assert!(rendered.contains("payload exports over the wire"));
    }
}
