//! The cross-process scheduling plane: a dependency-free RPC/wire layer
//! for remote frontends and consensus transport.
//!
//! Everything landed so far — the sharded plane, per-scheduler learners,
//! the pluggable consensus layer — runs inside one process. This module is
//! the step the paper actually describes (§2: Rosella "runs in parallel on
//! multiple machines with minimum coordination"): scheduler frontends as
//! *separate OS processes*, coordinating with a shared worker pool over a
//! compact binary protocol built on `std::net::TcpStream` alone.
//!
//! Four layers:
//!
//! * [`wire`] — the versioned, length-prefixed little-endian framing: task
//!   submissions and completions (single `Submit` frames or coalesced
//!   `SubmitBatch` frames that amortize the header and the write syscall
//!   across many dispatches, optionally piggybacking the beat), queue-
//!   probe/consensus tick exchanges,
//!   [`SyncPayload`](crate::learner::SyncPayload) exports, and run
//!   handshake/teardown, with hard frame-size bounds and bit-exact float
//!   round-trips. Decoding is allocation-free on the steady state: a
//!   [`wire::DecodeScratch`] pool recycles item/completion vectors and
//!   string buffers across frames ([`Msg::decode_with`]);
//! * [`poll`] — a dependency-free readiness-event facility:
//!   [`poll::Poller`] drives raw `epoll` syscalls (inline asm, same
//!   no-libc pattern as [`crate::plane`]'s topology probing) so an idle
//!   shard parks in the kernel instead of sweeping sockets, with a
//!   portable timed-sweep fallback selected at runtime (or forced via
//!   `ROSELLA_FORCE_POLL_FALLBACK=1`) behind the identical API;
//! * [`transport`] — the [`Transport`] seam the §5 frontend loop runs
//!   over: [`LocalTransport`] (the plane's own in-process channels and
//!   atomics) or [`TcpTransport`] (the wire protocol, with an adaptive
//!   flush policy: a pending batch is sent once it reaches B tasks
//!   (`--net-batch`) or D microseconds of age (`--net-flush-us`),
//!   whichever first — saturation gets syscall amortization, light load
//!   keeps eager-dispatch latency). The consensus side needs no seam at
//!   all: remote `SyncExport`s land in the same
//!   [`SharedViews`](crate::plane::SharedViews) slots the in-process
//!   shards use, so the sync thread is byte-for-byte the plane's;
//! * [`server`]/[`frontend`] — the two processes: `rosella plane --listen
//!   ADDR` hosts the pool, seqlock state, and consensus thread, serving
//!   frontend connections from **N topology-pinned poll shards** (default
//!   one per CPU package, capped at 4; `--net-poll-shards` overrides).
//!   Connections are partitioned round-robin at handshake; each shard
//!   thread owns its connections outright — private read/write buffers,
//!   decode scratch, and an epoll instance — so shards share nothing but
//!   the worker pool and the seqlock state, and completion routing stays
//!   per-shard. A drain barrier preserves the stop → drain → final-export
//!   teardown across shards. `rosella frontend --connect ADDR --shard
//!   i/k` runs the complete §5 scheduler stack (private learner,
//!   throttled benchmark dispatcher, local decisions over served probes)
//!   and participates in consensus by shipping its payloads over the
//!   wire.
//!
//! A loopback run (`1` server + `k` frontends on one machine) is the
//! first end-to-end demonstration of the paper's distributed topology;
//! CI smoke-tests it with real OS processes (`BENCH_net_smoke.json`),
//! and `benches/bench_net.rs` (`BENCH_net.json`) gates the
//! net-vs-in-process throughput ratio on a paced workload, the
//! coalescing speedup (batched vs eager framing) at saturation, and the
//! sharded-vs-single-shard headline ratio of the epoll data plane.
//!
//! ## Protocol v3: trace/clock appendices
//!
//! Protocol version 3 adds optional *appendices* to four frames for
//! lifecycle tracing ([`crate::obs::Tracer`]): `Hello` may carry a `t0`
//! origin timestamp (opening the NTP-style four-timestamp clock exchange),
//! `HelloAck` mirrors it with `(t1, t2)` receive/transmit stamps plus the
//! server's sampling modulus, `Tick`/`TickReply` refresh the offset
//! estimate mid-run, and batched submissions/completions append per-task
//! send/receive/done timestamps for tasks selected by the deterministic
//! task-id-hash sampler. The appendix is *version-iff-present*: a frame
//! encodes as v3 exactly when its trace appendix is `Some`, and an
//! appendix-free frame is **byte-identical to v2** — tracing off means the
//! wire is bit-compatible with the previous release, not merely
//! semantically compatible.
//!
//! Compatibility matrix (`MIN_VERSION` = 2, [`VERSION`] = 3):
//!
//! | client \ server | v2 server | v3 server |
//! |---|---|---|
//! | v2 client | native | **works** — a `Hello` without `t0` gets a `HelloAck` without a clock appendix (the ack mirrors the hello's version), and the run proceeds untraced |
//! | v3 client, tracing off | works — emits pure-v2 bytes | native, untraced |
//! | v3 client, tracing on | **fails at the handshake** — the v2 server rejects the version in the `Hello` header; restart the client with `--trace-sample off` (documented limitation: no version negotiation round, by design one RTT cheaper) |
//!
//! Decoders bound-check appendices like any other payload bytes: a
//! truncated or length-mismatched trace appendix is a
//! [`WireError`], rejected at the handshake or frame boundary rather
//! than misread as task data (`tests/net_loopback.rs` pins both
//! directions of this matrix over real sockets).

pub mod frontend;
pub mod poll;
pub mod server;
pub mod transport;
pub mod wire;

pub use frontend::{
    frontend_cli, parse_shard_spec, run_frontend_loop, run_remote_frontend, ConnectConfig,
    FrontendReport, RunParams,
};
pub use poll::{PollEvent, Poller};
pub use server::{bench_json, server_cli, NetReport, NetServer, NetServerConfig};
pub use transport::{LocalTransport, TcpTransport, TickOutcome, Transport};
pub use wire::{
    DoneStats, Estimates, HelloAck, Msg, TickReply, WireCompletion, WireError, HEADER_LEN,
    MAGIC, MAX_PAYLOAD, VERSION,
};
