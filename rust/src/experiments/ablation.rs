//! Ablations of Rosella's design choices (DESIGN.md §5: beyond the paper's
//! own fake-job ablation of Fig. 12):
//!
//! 1. **Tie rule** — SQ(2) vs LL(2) end-to-end response time across loads
//!    (the paper argues for SQ(2) via Example 3 and Fig. 13's queue
//!    distributions; this measures the response-time consequence).
//! 2. **Probe count d** — PPoT generalizes to power-of-d; the paper fixes
//!    d = 2. More probes help marginally but cost probe traffic.
//! 3. **Publish interval** — how stale the estimates/alias table may get
//!    before response times suffer (bounds the learner's required rate).
//! 4. **Arrival window S** — the §3.3 accuracy/reactivity tradeoff under
//!    volatile speeds.

use super::harness::{ms, Bench, Scale};
use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::LearnerConfig;
use crate::metrics::report::{format_table, Row};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig};

fn run_one(bench: &Bench, policy: PolicyKind, learner: LearnerConfig) -> f64 {
    let r = sim_run(SimConfig {
        seed: bench.seed,
        duration: bench.duration,
        warmup: bench.warmup,
        speeds: bench.speeds.clone(),
        volatility: bench.volatility.clone(),
        workload: bench.workload.clone(),
        load: bench.load,
        policy,
        learner,
        queue_sample: None,
        timeline: None,
    });
    ms(r.responses.mean())
}

/// Ablation 1: SQ(2) vs LL(2) mean response across loads (oracle speeds).
pub fn tie_rule(scale: Scale, seed: u64) -> (Vec<f64>, Vec<(String, Vec<f64>)>) {
    let loads = vec![0.5, 0.7, 0.8, 0.9];
    let mut rows = Vec::new();
    for (name, tie) in [("sq2", TieRule::Sq2), ("ll2", TieRule::Ll2)] {
        let series: Vec<f64> = loads
            .iter()
            .map(|&load| {
                let mut b = Bench::synthetic(scale, SpeedProfile::S1, load);
                b.seed = seed;
                run_one(
                    &b,
                    PolicyKind::PPoT { tie, late_binding: false },
                    LearnerConfig::oracle(),
                )
            })
            .collect();
        rows.push((name.to_string(), series));
    }
    (loads, rows)
}

/// Ablation 2: probe count d ∈ {1, 2, 3} for uniform PoT and, via PSS + d
/// proportional probes, the d=1 (pure PSS) vs d=2 (PPoT) comparison.
pub fn probe_count(scale: Scale, seed: u64) -> (Vec<f64>, Vec<(String, Vec<f64>)>) {
    let loads = vec![0.5, 0.8, 0.9];
    let mut rows = Vec::new();
    let mut push = |name: &str, policy: PolicyKind| {
        let series: Vec<f64> = loads
            .iter()
            .map(|&load| {
                let mut b = Bench::synthetic(scale, SpeedProfile::S1, load);
                b.seed = seed;
                run_one(&b, policy.clone(), LearnerConfig::oracle())
            })
            .collect();
        rows.push((name.to_string(), series));
    };
    push("pss (d=1)", PolicyKind::Pss);
    push("ppot (d=2)", PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false });
    push("pot (d=2 uniform)", PolicyKind::PoT { d: 2 });
    push("pot (d=3 uniform)", PolicyKind::PoT { d: 3 });
    (loads, rows)
}

/// Ablation 3: estimate publish interval under volatile speeds.
pub fn publish_interval(scale: Scale, seed: u64) -> Vec<(f64, f64)> {
    [0.05, 0.2, 1.0, 5.0]
        .iter()
        .map(|&interval| {
            let mut b = Bench::synthetic(scale, SpeedProfile::S2, 0.8);
            b.seed = seed;
            b.volatility = Volatility::Permute { period: scale.t(60.0) };
            let learner = LearnerConfig { publish_interval: interval, ..LearnerConfig::default() };
            let mean = run_one(
                &b,
                PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
                learner,
            );
            (interval, mean)
        })
        .collect()
}

/// Ablation 4: arrival-estimator window S under volatile speeds.
pub fn arrival_window(scale: Scale, seed: u64) -> Vec<(f64, f64)> {
    [20usize, 200, 2000]
        .iter()
        .map(|&s| {
            let mut b = Bench::synthetic(scale, SpeedProfile::S2, 0.8);
            b.seed = seed;
            b.volatility = Volatility::Permute { period: scale.t(60.0) };
            let learner = LearnerConfig { arrival_window: s, ..LearnerConfig::default() };
            let mean = run_one(
                &b,
                PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
                learner,
            );
            (s as f64, mean)
        })
        .collect()
}

/// Run all ablations and render.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    let (loads, rows) = tie_rule(scale, 20200417);
    let headers: Vec<String> = loads.iter().map(|l| format!("load {l}")).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    out.push_str(&format_table(
        "Ablation 1 — SQ(2) vs LL(2), mean response (ms), S1, oracle",
        &headers_ref,
        &rows.iter().map(|(n, s)| Row::new(n.clone(), s.clone())).collect::<Vec<_>>(),
        1,
    ));
    let (loads, rows) = probe_count(scale, 20200417);
    let headers: Vec<String> = loads.iter().map(|l| format!("load {l}")).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    out.push_str(&format_table(
        "Ablation 2 — probe count, mean response (ms), S1, oracle",
        &headers_ref,
        &rows.iter().map(|(n, s)| Row::new(n.clone(), s.clone())).collect::<Vec<_>>(),
        1,
    ));
    let rows: Vec<Row> = publish_interval(scale, 20200417)
        .into_iter()
        .map(|(i, m)| Row::new(format!("publish {i}s"), vec![m]))
        .collect();
    out.push_str(&format_table(
        "Ablation 3 — publish interval, mean response (ms), S2 volatile",
        &["mean_ms"],
        &rows,
        1,
    ));
    let rows: Vec<Row> = arrival_window(scale, 20200417)
        .into_iter()
        .map(|(s, m)| Row::new(format!("S = {s}"), vec![m]))
        .collect();
    out.push_str(&format_table(
        "Ablation 4 — arrival window S, mean response (ms), S2 volatile",
        &["mean_ms"],
        &rows,
        1,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq2_no_worse_than_ll2_at_high_load() {
        let (_, rows) = tie_rule(Scale::Quick, 21);
        let sq2 = &rows[0].1;
        let ll2 = &rows[1].1;
        // The paper's argument: LL(2) congests fast workers; SQ(2) should
        // win (or at least tie) at the highest load.
        assert!(
            sq2.last().unwrap() <= &(ll2.last().unwrap() * 1.15),
            "sq2 {sq2:?} vs ll2 {ll2:?}"
        );
    }

    #[test]
    fn second_proportional_probe_helps() {
        let (_, rows) = probe_count(Scale::Quick, 22);
        let pss = &rows[0].1; // d = 1
        let ppot = &rows[1].1; // d = 2
        assert!(
            ppot.last().unwrap() < pss.last().unwrap(),
            "ppot {ppot:?} should beat pss {pss:?} at load 0.9"
        );
    }

    #[test]
    fn stale_estimates_hurt() {
        let series = publish_interval(Scale::Quick, 23);
        let fresh = series.first().unwrap().1;
        let stale = series.last().unwrap().1;
        assert!(
            stale > fresh * 0.9,
            "5s-stale estimates should not beat 50ms-fresh ones: {series:?}"
        );
    }
}
