//! Figure 8: distribution of response times for unconstrained requests,
//! Rosella vs Sparrow, under (a) a static environment and (b) a volatile
//! environment (worker speeds permuted every two minutes).
//!
//! The paper's observation: Rosella's distribution "decays exponentially
//! before 2,000 ms" while Sparrow leaves "a much larger portion of jobs
//! that cannot be completed in 2,000 ms". We report each scheduler's
//! response-time PDF, the tail mass beyond 2 s, and the means (paper:
//! Sparrow 1,901 ms vs Rosella 675 ms — a 65% improvement).

use super::harness::{ms, Baseline, Bench, Scale};
use crate::cluster::Volatility;
use crate::metrics::report::format_series;
use crate::workload::tpch::Query;

/// Result of one Figure 8 panel.
#[derive(Debug)]
pub struct Fig8Panel {
    pub volatile: bool,
    /// (scheduler name, mean ms, tail fraction > 2000 ms, pdf points).
    pub rows: Vec<(String, f64, f64, Vec<(f64, f64)>)>,
}

/// Run one panel (static or volatile).
pub fn run_panel(scale: Scale, volatile: bool, seed: u64) -> Fig8Panel {
    let mut bench = Bench::tpch(scale, Query::Q3);
    bench.seed = seed;
    if volatile {
        bench.volatility = Volatility::Permute { period: scale.t(120.0) };
    }
    let mut rows = Vec::new();
    for b in [Baseline::Rosella, Baseline::Sparrow] {
        let r = bench.run(b);
        let pdf: Vec<(f64, f64)> =
            r.responses.histogram().pdf().iter().map(|&(v, p)| (ms(v), p)).collect();
        rows.push((b.name().to_string(), ms(r.responses.mean()), r.responses.tail_fraction(2.0), pdf));
    }
    Fig8Panel { volatile, rows }
}

/// Run both panels and render the report.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    for volatile in [false, true] {
        let panel = run_panel(scale, volatile, 20200417);
        let env = if volatile { "volatile" } else { "static" };
        out.push_str(&format!(
            "== Fig 8{} — response-time distribution ({env} environment) ==\n",
            if volatile { 'b' } else { 'a' }
        ));
        for (name, mean, tail, _) in &panel.rows {
            out.push_str(&format!(
                "{name:>10}: mean = {mean:8.1} ms, P[response > 2000 ms] = {:.3}\n",
                tail
            ));
        }
        for (name, _, _, pdf) in &panel.rows {
            out.push_str(&format_series(
                &format!("Fig 8 PDF [{env}] {name}"),
                "response_ms",
                "fraction",
                &pdf.iter().cloned().take(40).collect::<Vec<_>>(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rosella_beats_sparrow_static() {
        let p = run_panel(Scale::Quick, false, 1);
        let rosella = &p.rows[0];
        let sparrow = &p.rows[1];
        assert!(
            rosella.1 < sparrow.1,
            "rosella mean {} !< sparrow mean {}",
            rosella.1,
            sparrow.1
        );
        // Rosella's >2s tail must be smaller.
        assert!(rosella.2 <= sparrow.2 + 1e-9, "tails: {} vs {}", rosella.2, sparrow.2);
    }

    #[test]
    fn volatile_panel_still_favors_rosella() {
        // Quick-mode volatile runs see only ~3 shock cycles, so the mean is
        // dominated by a single post-shock transient; the >2 s tail mass is
        // the stable discriminator (it is also the paper's headline for
        // Fig. 8). Full-scale runs (EXPERIMENTS.md) compare means directly.
        let p = run_panel(Scale::Quick, true, 2);
        let (rosella_tail, sparrow_tail) = (p.rows[0].2, p.rows[1].2);
        assert!(
            rosella_tail <= sparrow_tail + 0.05,
            "rosella tail {rosella_tail} vs sparrow tail {sparrow_tail}"
        );
    }
}
