//! Figure 11: scheduler performance when worker speeds *change* (random
//! permutation every minute), for speed sets S1 (mild heterogeneity) and
//! S2 (strong heterogeneity), across load ratios.
//!
//! Expected shape: Rosella best across all loads for both sets; the gap
//! grows with load and with heterogeneity (S2 > S1).

use super::harness::{ms, Baseline, Bench, Scale};
use crate::cluster::{SpeedProfile, Volatility};
use crate::metrics::report::{format_table, Row};

/// One panel: a speed set swept over loads.
#[derive(Debug)]
pub struct Fig11Panel {
    pub set_name: &'static str,
    pub loads: Vec<f64>,
    /// (policy name, mean response ms per load).
    pub rows: Vec<(String, Vec<f64>)>,
}

/// Baselines shown in Figure 11.
pub fn baselines() -> Vec<Baseline> {
    vec![Baseline::PoT, Baseline::Bandit02, Baseline::PssLearning, Baseline::RosellaNoLb]
}

/// Run one panel.
pub fn run_panel(scale: Scale, set: SpeedProfile, set_name: &'static str, seed: u64) -> Fig11Panel {
    let loads = vec![0.5, 0.6, 0.7, 0.8, 0.9];
    let mut rows = Vec::new();
    for b in baselines() {
        let mut series = Vec::new();
        for &load in &loads {
            let mut bench = Bench::synthetic(scale, set.clone(), load);
            bench.seed = seed;
            bench.volatility = Volatility::Permute { period: scale.t(60.0) };
            let r = bench.run(b);
            series.push(ms(r.responses.mean()));
        }
        rows.push((b.name().to_string(), series));
    }
    Fig11Panel { set_name, loads, rows }
}

/// Run both panels and render.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    for (set, name, tag) in
        [(SpeedProfile::S1, "S1", 'a'), (SpeedProfile::S2, "S2", 'b')]
    {
        let p = run_panel(scale, set, name, 20200417);
        let rows: Vec<Row> =
            p.rows.iter().map(|(n, s)| Row::new(n.clone(), s.clone())).collect();
        let headers: Vec<String> = p.loads.iter().map(|l| format!("load {l}")).collect();
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        out.push_str(&format_table(
            &format!("Fig 11{tag} — mean response (ms), volatile speeds, set {name}"),
            &headers_ref,
            &rows,
            1,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rosella_best_at_high_load_s1() {
        let p = run_panel(Scale::Quick, SpeedProfile::S1, "S1", 8);
        let rosella = p.rows.iter().find(|(n, _)| n == "rosella-nolb").unwrap();
        let last = p.loads.len() - 1;
        for (name, series) in &p.rows {
            if name != "rosella-nolb" {
                assert!(
                    rosella.1[last] <= series[last] * 1.2,
                    "rosella {} should beat {name} {}",
                    rosella.1[last],
                    series[last]
                );
            }
        }
    }

    #[test]
    fn response_grows_with_load() {
        let p = run_panel(Scale::Quick, SpeedProfile::S1, "S1", 9);
        for (name, series) in &p.rows {
            assert!(
                series.last().unwrap() > series.first().unwrap(),
                "{name}: response must grow with load: {series:?}"
            );
        }
    }
}
