//! Shared experiment harness: baseline sets, scaled durations, and the
//! common run helpers every figure driver uses.

use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::LearnerConfig;
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run, SimConfig, SimResult};
use crate::workload::WorkloadKind;

/// Time scaling for experiments: `Full` reproduces the paper's horizons,
/// `Quick` shrinks them ~10x for CI/test runs (shapes survive, absolute
/// noise grows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Full,
    Quick,
}

impl Scale {
    /// Scale a duration. Quick mode divides by 5 with a 30 s floor: the
    /// floor keeps shock periods from collapsing below the learner's
    /// re-learning time, which would measure a permanent transient rather
    /// than the paper's steady-state-with-shocks regime.
    pub fn t(&self, full: f64) -> f64 {
        match self {
            Scale::Full => full,
            Scale::Quick => (full / 5.0).max(30.0),
        }
    }
}

/// The named baselines of §6 with the learner wiring each one needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    Sparrow,
    PoT,
    Bandit02,
    Bandit03,
    PssLearning,
    PPoTLearning,
    /// Full Rosella: PPoT + learning + fake jobs + late binding.
    Rosella,
    /// Rosella without late binding (the §6.2 synthetic configuration).
    RosellaNoLb,
    Uniform,
    Halo,
    /// PPoT with the LL(2) tie rule (Figure 13).
    PPoTLl2,
}

impl Baseline {
    /// Display name matching the paper's legends.
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Sparrow => "sparrow",
            Baseline::PoT => "pot",
            Baseline::Bandit02 => "bandit-0.2",
            Baseline::Bandit03 => "bandit-0.3",
            Baseline::PssLearning => "pss+learning",
            Baseline::PPoTLearning => "ppot+learning",
            Baseline::Rosella => "rosella",
            Baseline::RosellaNoLb => "rosella-nolb",
            Baseline::Uniform => "uniform",
            Baseline::Halo => "halo",
            Baseline::PPoTLl2 => "ppot-ll2",
        }
    }

    /// Policy + learner configuration for this baseline.
    pub fn wire(&self) -> (PolicyKind, LearnerConfig) {
        match self {
            Baseline::Sparrow => {
                (PolicyKind::Sparrow { probes_per_task: 2 }, LearnerConfig::oracle())
            }
            Baseline::PoT => (PolicyKind::PoT { d: 2 }, LearnerConfig::oracle()),
            Baseline::Uniform => (PolicyKind::Uniform, LearnerConfig::oracle()),
            Baseline::Bandit02 => (PolicyKind::Bandit { eta: 0.2 }, LearnerConfig::default()),
            Baseline::Bandit03 => (PolicyKind::Bandit { eta: 0.3 }, LearnerConfig::default()),
            Baseline::PssLearning => (PolicyKind::Pss, LearnerConfig::default()),
            Baseline::PPoTLearning => (
                PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
                LearnerConfig::default(),
            ),
            Baseline::Rosella => (
                PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true },
                LearnerConfig::default(),
            ),
            Baseline::RosellaNoLb => (
                PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
                LearnerConfig::default(),
            ),
            Baseline::Halo => (PolicyKind::Halo, LearnerConfig::oracle()),
            Baseline::PPoTLl2 => (
                PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false },
                LearnerConfig::oracle(),
            ),
        }
    }

    /// Oracle variant: same policy, true speeds known (for the Fig. 10
    /// "speeds known" settings).
    pub fn wire_oracle(&self) -> (PolicyKind, LearnerConfig) {
        let (policy, _) = self.wire();
        (policy, LearnerConfig::oracle())
    }
}

/// Base config shared by one figure's runs.
#[derive(Debug, Clone)]
pub struct Bench {
    pub seed: u64,
    pub duration: f64,
    pub warmup: f64,
    pub speeds: SpeedProfile,
    pub volatility: Volatility,
    pub workload: WorkloadKind,
    pub load: f64,
    pub queue_sample: Option<f64>,
}

impl Bench {
    /// §6.1 TPC-H setting: 30 workers, squared speeds, load 0.8.
    pub fn tpch(scale: Scale, query: crate::workload::tpch::Query) -> Self {
        Self {
            seed: 20200417,
            duration: scale.t(600.0),
            warmup: scale.t(120.0),
            speeds: SpeedProfile::TpchSquares { n: 30 },
            volatility: Volatility::Static,
            workload: WorkloadKind::Tpch { query },
            load: 0.8,
            queue_sample: None,
        }
    }

    /// §6.2 synthetic setting: 15 workers, load specified per-experiment.
    pub fn synthetic(scale: Scale, speeds: SpeedProfile, load: f64) -> Self {
        Self {
            seed: 20200417,
            duration: scale.t(600.0),
            warmup: scale.t(120.0),
            speeds,
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load,
            queue_sample: None,
        }
    }

    /// Run one baseline under this setting.
    pub fn run(&self, baseline: Baseline) -> SimResult {
        let (policy, learner) = baseline.wire();
        self.run_wired(baseline, policy, learner)
    }

    /// Run one baseline with oracle speed knowledge.
    pub fn run_oracle(&self, baseline: Baseline) -> SimResult {
        let (policy, learner) = baseline.wire_oracle();
        self.run_wired(baseline, policy, learner)
    }

    fn run_wired(
        &self,
        _baseline: Baseline,
        policy: PolicyKind,
        learner: LearnerConfig,
    ) -> SimResult {
        run(SimConfig {
            seed: self.seed,
            duration: self.duration,
            warmup: self.warmup,
            speeds: self.speeds.clone(),
            volatility: self.volatility.clone(),
            workload: self.workload.clone(),
            load: self.load,
            policy,
            learner,
            queue_sample: self.queue_sample,
            timeline: None,
        })
    }
}

/// Milliseconds helper for reports (the paper reports response times in ms).
pub fn ms(seconds: f64) -> f64 {
    seconds * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_quick_shrinks() {
        assert_eq!(Scale::Full.t(600.0), 600.0);
        assert!(Scale::Quick.t(600.0) <= 150.0);
        assert!(Scale::Quick.t(50.0) >= 30.0);
    }

    #[test]
    fn all_baselines_have_distinct_names() {
        let all = [
            Baseline::Sparrow,
            Baseline::PoT,
            Baseline::Bandit02,
            Baseline::Bandit03,
            Baseline::PssLearning,
            Baseline::PPoTLearning,
            Baseline::Rosella,
            Baseline::RosellaNoLb,
            Baseline::Uniform,
            Baseline::Halo,
            Baseline::PPoTLl2,
        ];
        let mut names: Vec<_> = all.iter().map(|b| b.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn learning_baselines_enable_learner() {
        let (_, l) = Baseline::Rosella.wire();
        assert!(l.enabled && l.fake_jobs);
        let (_, l) = Baseline::Sparrow.wire();
        assert!(!l.enabled);
    }

    #[test]
    fn quick_tpch_run_completes() {
        let b = Bench::tpch(Scale::Quick, crate::workload::tpch::Query::Q6);
        let r = b.run(Baseline::Sparrow);
        assert!(r.responses.count() > 20, "count={}", r.responses.count());
    }
}
