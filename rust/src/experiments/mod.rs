//! Experiment drivers regenerating every figure of the paper's evaluation
//! (§6). Each module reproduces one figure; DESIGN.md §5 maps figures to
//! modules and bench targets.

pub mod ablation;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig8;
pub mod fig9;
pub mod harness;
pub mod multi_sched;
pub mod theory;

pub use harness::{Baseline, Bench, Scale};

/// All experiment names accepted by `rosella experiment <name>`.
pub const ALL: &[&str] = &[
    "fig8",
    "fig9",
    "fig10",
    "fig11",
    "fig12",
    "fig13",
    "theory",
    "ablation",
    "multisched",
    "all",
];

/// Run one experiment by name and return its rendered report.
pub fn run_by_name(name: &str, scale: Scale) -> Result<String, String> {
    run_by_name_with(name, scale, None)
}

/// [`run_by_name`] with an optional machine-readable JSON output path
/// (currently supported by `multisched`, which emits its sweep grid in the
/// `BENCH_plane.json` shape conventions).
pub fn run_by_name_with(name: &str, scale: Scale, json: Option<&str>) -> Result<String, String> {
    if json.is_some() && name != "multisched" {
        return Err(format!("--json is only supported by 'multisched' (got '{name}')"));
    }
    match name {
        "fig8" => Ok(fig8::run(scale)),
        "fig9" => Ok(fig9::run(scale)),
        "fig10" => Ok(fig10::run(scale)),
        "fig11" => Ok(fig11::run(scale)),
        "fig12" => Ok(fig12::run(scale)),
        "fig13" => Ok(fig13::run(scale)),
        "theory" => Ok(theory::run(scale)),
        "ablation" => Ok(ablation::run(scale)),
        "multisched" => multi_sched::run_with_json(scale, json),
        "all" => {
            let mut out = String::new();
            for n in ALL.iter().filter(|&&n| n != "all") {
                out.push_str(&run_by_name(n, scale)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => Err(format!("unknown experiment '{other}'; expected one of {ALL:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_is_rejected() {
        assert!(run_by_name("fig99", Scale::Quick).is_err());
    }

    #[test]
    fn json_flag_only_applies_to_multisched() {
        let err = run_by_name_with("fig8", Scale::Quick, Some("out.json")).unwrap_err();
        assert!(err.contains("multisched"), "{err}");
    }
}
