//! §5 distributed-learning sweep: per-scheduler learners with estimate-sync
//! consensus.
//!
//! The paper leaves one knob open in its "schedulers need only synchronize
//! the estimates of worker speeds regularly" claim: how *regularly*? This
//! experiment sweeps the scheduler count `k` against the sync interval on a
//! volatile cluster (periodic speed permutations — the regime where stale
//! estimates actually cost latency) and reports mean response time per
//! cell, plus the degradation relative to the centralized shared-learner
//! baseline (`k = 1`, consensus at every publish). The expected shape:
//! near-flat across `k` when sync is tight (distributing the learner is
//! ~free, the paper's claim), growing with the sync interval as every
//! scheduler schedules against increasingly stale speed estimates.

use super::harness::{ms, Scale};
use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::LearnerConfig;
use crate::metrics::{format_table, Row};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig, SimResult};
use crate::workload::WorkloadKind;

/// Scheduler counts swept.
pub const KS: &[usize] = &[1, 2, 4, 8];
/// Sync intervals swept (seconds; 0 = consensus at every publish).
pub const SYNCS: &[f64] = &[0.0, 1.0, 5.0, 20.0];

/// One cell of the sweep.
pub fn run_one(scale: Scale, schedulers: usize, sync_interval: f64) -> SimResult {
    sim_run(SimConfig {
        seed: 20200417,
        duration: scale.t(300.0),
        warmup: scale.t(60.0),
        speeds: SpeedProfile::S2,
        volatility: Volatility::Permute { period: scale.t(75.0) },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig { schedulers, sync_interval, ..LearnerConfig::default() },
        queue_sample: None,
    })
}

/// Render the sweep report.
pub fn run(scale: Scale) -> String {
    let mut means = vec![vec![0.0f64; KS.len()]; SYNCS.len()];
    let mut p95s = vec![vec![0.0f64; KS.len()]; SYNCS.len()];
    for (si, &sync) in SYNCS.iter().enumerate() {
        for (ki, &k) in KS.iter().enumerate() {
            let r = run_one(scale, k, sync);
            means[si][ki] = ms(r.responses.mean());
            p95s[si][ki] = ms(r.responses.five_num().p95);
        }
    }
    let baseline = means[0][0];
    let header: Vec<String> = KS.iter().map(|k| format!("k={k}")).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();

    let mut out = String::new();
    let rows: Vec<Row> = SYNCS
        .iter()
        .zip(means.iter())
        .map(|(sync, cells)| Row::new(format!("sync={sync}s"), cells.clone()))
        .collect();
    out.push_str(&format_table(
        "MultiSched — mean response (ms), k schedulers × sync interval (volatile S2)",
        &header_refs,
        &rows,
        1,
    ));
    let rows: Vec<Row> = SYNCS
        .iter()
        .zip(p95s.iter())
        .map(|(sync, cells)| Row::new(format!("sync={sync}s"), cells.clone()))
        .collect();
    out.push_str(&format_table(
        "MultiSched — p95 response (ms)",
        &header_refs,
        &rows,
        1,
    ));
    let rows: Vec<Row> = SYNCS
        .iter()
        .zip(means.iter())
        .map(|(sync, cells)| {
            Row::new(
                format!("sync={sync}s"),
                cells.iter().map(|m| 100.0 * (m / baseline - 1.0)).collect(),
            )
        })
        .collect();
    out.push_str(&format_table(
        "MultiSched — mean degradation vs shared-learner baseline (%)",
        &header_refs,
        &rows,
        1,
    ));
    out.push_str(
        "Reading: k=1/sync=0 is the centralized baseline; cost of distributing the\n\
         learner shows in the k direction, cost of lazier consensus in the sync\n\
         direction (stale estimates on a volatile cluster).\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_cell_completes_and_stays_near_baseline() {
        let base = run_one(Scale::Quick, 1, 0.0);
        let split = run_one(Scale::Quick, 4, 1.0);
        assert!(base.responses.count() > 500, "baseline {}", base.responses.count());
        assert!(split.responses.count() > 500, "split {}", split.responses.count());
        let ratio = split.responses.mean() / base.responses.mean();
        assert!(
            (0.3..3.0).contains(&ratio),
            "k=4 sync=1s mean drifted {ratio}x off the k=1 baseline"
        );
    }

    #[test]
    fn sweep_report_renders_every_cell() {
        let report = run(Scale::Quick);
        assert!(report.contains("mean response"));
        assert!(report.contains("degradation"));
        for k in KS {
            assert!(report.contains(&format!("k={k}")));
        }
        for s in SYNCS {
            assert!(report.contains(&format!("sync={s}s")));
        }
    }
}
