//! §5 distributed-learning sweep: per-scheduler learners with estimate-sync
//! consensus.
//!
//! The paper leaves one knob open in its "schedulers need only synchronize
//! the estimates of worker speeds regularly" claim: how *regularly* — and,
//! with the pluggable sync layer, *with whom*? This experiment maps the
//! coordination/quality frontier on a volatile cluster (periodic speed
//! permutations — the regime where stale estimates actually cost latency):
//!
//! * the **staleness sweep** — scheduler count `k` × periodic sync interval,
//!   mean/p95 response time and degradation vs the centralized baseline
//!   (`k = 1`, consensus at every publish);
//! * the **policy frontier** — periodic vs adaptive (divergence threshold
//!   sweep) vs gossip at a fixed interval, reporting *merges performed*
//!   against degradation: how much consensus traffic each policy spends for
//!   the response time it gets. The expected shape: adaptive buys most of
//!   periodic's quality for a fraction of the merges (it syncs when shocks
//!   make estimates diverge, idles otherwise); gossip pays O(k/2) pairwise
//!   merges per round but never runs an all-to-all epoch.
//!
//! `rosella experiment multisched --json <path>` additionally emits the
//! whole grid as machine-readable JSON (same shape conventions as
//! `BENCH_plane.json`: a top-level object with the run parameters and one
//! flat `results` array) so CI can track the frontier across PRs.

use super::harness::{ms, Scale};
use crate::cluster::{SpeedProfile, Volatility};
use crate::config::Json;
use crate::learner::{LearnerConfig, SyncPolicyConfig};
use crate::metrics::{format_table, Row};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig, SimResult};
use crate::workload::WorkloadKind;
use std::collections::BTreeMap;

/// Scheduler counts swept.
pub const KS: &[usize] = &[1, 2, 4, 8];
/// Sync intervals swept (seconds; 0 = consensus at every publish).
pub const SYNCS: &[f64] = &[0.0, 1.0, 5.0, 20.0];
/// Scheduler counts of the sync-policy frontier.
pub const FRONTIER_KS: &[usize] = &[2, 4, 8];
/// Adaptive divergence thresholds swept on the frontier.
pub const THRESHOLDS: &[f64] = &[0.05, 0.1, 0.2];
/// Sync interval every frontier cell shares (seconds).
pub const FRONTIER_SYNC: f64 = 1.0;

/// One cell of the sweep: `k` schedulers syncing periodically every
/// `sync_interval` seconds.
pub fn run_one(scale: Scale, schedulers: usize, sync_interval: f64) -> SimResult {
    run_policy(scale, schedulers, sync_interval, SyncPolicyConfig::periodic())
}

/// One cell with an explicit sync policy (the frontier axis).
pub fn run_policy(
    scale: Scale,
    schedulers: usize,
    sync_interval: f64,
    sync: SyncPolicyConfig,
) -> SimResult {
    sim_run(SimConfig {
        seed: 20200417,
        duration: scale.t(300.0),
        warmup: scale.t(60.0),
        speeds: SpeedProfile::S2,
        volatility: Volatility::Permute { period: scale.t(75.0) },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig { schedulers, sync_interval, sync, ..LearnerConfig::default() },
        queue_sample: None,
        timeline: None,
    })
}

/// One measured cell of the grid (both sweeps share this shape).
#[derive(Clone)]
struct Cell {
    policy: &'static str,
    threshold: Option<f64>,
    k: usize,
    sync_interval: f64,
    mean_ms: f64,
    p95_ms: f64,
    merges: u64,
    epochs: u64,
    completed: u64,
}

impl Cell {
    fn from_result(
        r: &SimResult,
        policy: &'static str,
        threshold: Option<f64>,
        k: usize,
        sync: f64,
    ) -> Self {
        Self {
            policy,
            threshold,
            k,
            sync_interval: sync,
            mean_ms: ms(r.responses.mean()),
            p95_ms: ms(r.responses.five_num().p95),
            merges: r.sync_merges,
            epochs: r.sync_epochs,
            completed: r.completed_real,
        }
    }

    fn label(&self) -> String {
        match self.threshold {
            Some(t) => format!("{}:{t}", self.policy),
            None => self.policy.to_string(),
        }
    }
}

/// The frontier's non-periodic policy rows (the periodic row is reused
/// from the staleness grid, which already ran those exact cells).
fn frontier_policies() -> Vec<(&'static str, Option<f64>, SyncPolicyConfig)> {
    let mut rows: Vec<(&'static str, Option<f64>, SyncPolicyConfig)> = Vec::new();
    for &t in THRESHOLDS {
        rows.push(("adaptive", Some(t), SyncPolicyConfig::adaptive(t)));
    }
    rows.push(("gossip", None, SyncPolicyConfig::gossip()));
    rows
}

struct Sweep {
    /// Periodic staleness grid, indexed `[sync][k]`.
    grid: Vec<Vec<Cell>>,
    /// Policy frontier cells, row-major: the grid's periodic row first
    /// (shared cells, not re-run), then `frontier_policies() × FRONTIER_KS`.
    frontier: Vec<Vec<Cell>>,
    /// Centralized baseline mean (k = 1, consensus at every publish).
    baseline_ms: f64,
}

fn sweep(scale: Scale) -> Sweep {
    let grid: Vec<Vec<Cell>> = SYNCS
        .iter()
        .map(|&sync| {
            KS.iter()
                .map(|&k| Cell::from_result(&run_one(scale, k, sync), "periodic", None, k, sync))
                .collect()
        })
        .collect();
    let baseline_ms = grid[0][0].mean_ms;
    // The frontier's periodic row is the grid's FRONTIER_SYNC row at the
    // frontier's k values — identical configurations, so the cells are
    // shared rather than simulated twice (and emitted once in the JSON).
    let si = SYNCS
        .iter()
        .position(|&s| s == FRONTIER_SYNC)
        .expect("FRONTIER_SYNC must be one of the swept intervals");
    let periodic_row: Vec<Cell> = FRONTIER_KS
        .iter()
        .map(|&k| {
            let ki = KS.iter().position(|&g| g == k).expect("frontier k must be a swept k");
            grid[si][ki].clone()
        })
        .collect();
    let mut frontier = vec![periodic_row];
    frontier.extend(frontier_policies().into_iter().map(|(name, threshold, sp)| {
        FRONTIER_KS
            .iter()
            .map(|&k| {
                let r = run_policy(scale, k, FRONTIER_SYNC, sp);
                Cell::from_result(&r, name, threshold, k, FRONTIER_SYNC)
            })
            .collect()
    }));
    Sweep { grid, frontier, baseline_ms }
}

fn render(s: &Sweep) -> String {
    let header: Vec<String> = KS.iter().map(|k| format!("k={k}")).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();

    let mut out = String::new();
    let rows: Vec<Row> = s
        .grid
        .iter()
        .zip(SYNCS)
        .map(|(cells, sync)| {
            Row::new(format!("sync={sync}s"), cells.iter().map(|c| c.mean_ms).collect())
        })
        .collect();
    out.push_str(&format_table(
        "MultiSched — mean response (ms), k schedulers × sync interval (volatile S2)",
        &header_refs,
        &rows,
        1,
    ));
    let rows: Vec<Row> = s
        .grid
        .iter()
        .zip(SYNCS)
        .map(|(cells, sync)| {
            Row::new(format!("sync={sync}s"), cells.iter().map(|c| c.p95_ms).collect())
        })
        .collect();
    out.push_str(&format_table("MultiSched — p95 response (ms)", &header_refs, &rows, 1));
    let rows: Vec<Row> = s
        .grid
        .iter()
        .zip(SYNCS)
        .map(|(cells, sync)| {
            Row::new(
                format!("sync={sync}s"),
                cells.iter().map(|c| 100.0 * (c.mean_ms / s.baseline_ms - 1.0)).collect(),
            )
        })
        .collect();
    out.push_str(&format_table(
        "MultiSched — mean degradation vs shared-learner baseline (%)",
        &header_refs,
        &rows,
        1,
    ));

    // The coordination/quality frontier: merges spent vs quality lost.
    let fheader: Vec<String> = FRONTIER_KS.iter().map(|k| format!("k={k}")).collect();
    let fheader_refs: Vec<&str> = fheader.iter().map(|s| s.as_str()).collect();
    let rows: Vec<Row> = s
        .frontier
        .iter()
        .map(|cells| {
            Row::new(cells[0].label(), cells.iter().map(|c| c.merges as f64).collect())
        })
        .collect();
    out.push_str(&format_table(
        &format!("MultiSched — consensus merges performed (policy × k, sync={FRONTIER_SYNC}s)"),
        &fheader_refs,
        &rows,
        0,
    ));
    let rows: Vec<Row> = s
        .frontier
        .iter()
        .map(|cells| {
            Row::new(
                cells[0].label(),
                cells.iter().map(|c| 100.0 * (c.mean_ms / s.baseline_ms - 1.0)).collect(),
            )
        })
        .collect();
    out.push_str(&format_table(
        "MultiSched — frontier mean degradation vs baseline (%)",
        &fheader_refs,
        &rows,
        1,
    ));
    out.push_str(
        "Reading: k=1/sync=0 is the centralized baseline; cost of distributing the\n\
         learner shows in the k direction, cost of lazier consensus in the sync\n\
         direction (stale estimates on a volatile cluster). The frontier tables\n\
         weigh the same quality axis against merges performed: adaptive should\n\
         match periodic's response times with far fewer merges (it syncs on shock-\n\
         induced divergence, idles on quiet stretches); gossip trades all-to-all\n\
         epochs for O(k/2) pairwise merges per round.\n",
    );
    out
}

fn json_doc(s: &Sweep, scale: Scale) -> Json {
    let cell_json = |c: &Cell| {
        let mut m = BTreeMap::new();
        m.insert("policy".into(), Json::Str(c.policy.into()));
        m.insert("threshold".into(), c.threshold.map_or(Json::Null, Json::Num));
        m.insert("k".into(), Json::Num(c.k as f64));
        m.insert("sync_interval".into(), Json::Num(c.sync_interval));
        m.insert("mean_ms".into(), Json::Num(c.mean_ms));
        m.insert("p95_ms".into(), Json::Num(c.p95_ms));
        m.insert("degradation_pct".into(), Json::Num(100.0 * (c.mean_ms / s.baseline_ms - 1.0)));
        m.insert("merges".into(), Json::Num(c.merges as f64));
        m.insert("sync_epochs".into(), Json::Num(c.epochs as f64));
        m.insert("completed".into(), Json::Num(c.completed as f64));
        Json::Obj(m)
    };
    // The frontier's periodic row is shared with the grid — skip it here
    // so no (policy, k, sync_interval) key appears twice in the results.
    let results: Vec<Json> =
        s.grid.iter().chain(s.frontier.iter().skip(1)).flatten().map(cell_json).collect();
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("multisched".into()));
    top.insert(
        "scale".into(),
        Json::Str(if scale == Scale::Quick { "quick" } else { "full" }.into()),
    );
    top.insert("seed".into(), Json::Num(20200417.0));
    top.insert("speeds".into(), Json::Str("s2".into()));
    top.insert("load".into(), Json::Num(0.8));
    top.insert("frontier_sync_interval".into(), Json::Num(FRONTIER_SYNC));
    top.insert("baseline_mean_ms".into(), Json::Num(s.baseline_ms));
    top.insert("results".into(), Json::Arr(results));
    Json::Obj(top)
}

/// Render the sweep report, optionally writing the grid as JSON.
pub fn run_with_json(scale: Scale, json_path: Option<&str>) -> Result<String, String> {
    let s = sweep(scale);
    let mut out = render(&s);
    if let Some(path) = json_path {
        let doc = crate::config::to_string(&json_doc(&s, scale));
        std::fs::write(path, doc).map_err(|e| format!("write {path}: {e}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

/// Render the sweep report.
pub fn run(scale: Scale) -> String {
    run_with_json(scale, None).expect("no json path, nothing can fail")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_cell_completes_and_stays_near_baseline() {
        let base = run_one(Scale::Quick, 1, 0.0);
        let split = run_one(Scale::Quick, 4, 1.0);
        assert!(base.responses.count() > 500, "baseline {}", base.responses.count());
        assert!(split.responses.count() > 500, "split {}", split.responses.count());
        let ratio = split.responses.mean() / base.responses.mean();
        assert!(
            (0.3..3.0).contains(&ratio),
            "k=4 sync=1s mean drifted {ratio}x off the k=1 baseline"
        );
    }

    #[test]
    fn adaptive_cell_spends_fewer_merges_than_periodic() {
        let periodic = run_one(Scale::Quick, 4, FRONTIER_SYNC);
        let adaptive =
            run_policy(Scale::Quick, 4, FRONTIER_SYNC, SyncPolicyConfig::adaptive(0.1));
        assert!(adaptive.responses.count() > 500);
        assert!(
            adaptive.sync_merges < periodic.sync_merges,
            "adaptive {} vs periodic {} merges",
            adaptive.sync_merges,
            periodic.sync_merges
        );
    }

    #[test]
    fn sweep_report_renders_every_cell() {
        let report = run(Scale::Quick);
        assert!(report.contains("mean response"));
        assert!(report.contains("degradation"));
        assert!(report.contains("merges performed"));
        for k in KS {
            assert!(report.contains(&format!("k={k}")));
        }
        for s in SYNCS {
            assert!(report.contains(&format!("sync={s}s")));
        }
        for t in THRESHOLDS {
            assert!(report.contains(&format!("adaptive:{t}")));
        }
        assert!(report.contains("gossip"));
    }

    #[test]
    fn json_emission_is_parseable_and_complete() {
        let dir = std::env::temp_dir();
        let path = dir.join("rosella_multisched_test.json");
        let path = path.to_str().unwrap();
        let report = run_with_json(Scale::Quick, Some(path)).unwrap();
        assert!(report.contains("wrote "), "{report}");
        let doc = std::fs::read_to_string(path).unwrap();
        let back = crate::config::parse(&doc).expect("multisched json must round-trip");
        let results = back.get("results").and_then(|r| r.as_arr()).expect("results array");
        // Grid cells plus the non-periodic frontier rows (the frontier's
        // periodic row is shared with the grid, emitted once).
        let expect = KS.len() * SYNCS.len() + (THRESHOLDS.len() + 1) * FRONTIER_KS.len();
        assert_eq!(results.len(), expect, "every swept cell must be emitted exactly once");
        // No duplicate (policy, k, sync_interval) keys survive.
        let keys: std::collections::BTreeSet<String> = results
            .iter()
            .map(|r| {
                format!(
                    "{}|{}|{}|{}",
                    r.get("policy").and_then(Json::as_str).unwrap(),
                    r.get("k").and_then(Json::as_f64).unwrap(),
                    r.get("sync_interval").and_then(Json::as_f64).unwrap(),
                    r.get("threshold").and_then(Json::as_f64).unwrap_or(-1.0),
                )
            })
            .collect();
        assert_eq!(keys.len(), results.len(), "duplicate sweep cells in the JSON");
        assert!(back.get("baseline_mean_ms").and_then(Json::as_f64).unwrap() > 0.0);
        // Every adaptive cell carries its threshold; CI's jq filter keys
        // off these fields.
        let adaptive: Vec<&Json> = results
            .iter()
            .filter(|r| r.get("policy").and_then(Json::as_str) == Some("adaptive"))
            .collect();
        assert_eq!(adaptive.len(), THRESHOLDS.len() * FRONTIER_KS.len());
        for cell in adaptive {
            assert!(cell.get("threshold").and_then(Json::as_f64).is_some());
            assert!(cell.get("merges").and_then(Json::as_f64).is_some());
            assert!(cell.get("degradation_pct").and_then(Json::as_f64).is_some());
        }
        let _ = std::fs::remove_file(path);
    }
}
