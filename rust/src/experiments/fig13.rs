//! Figure 13: queue-length distributions under SQ(2) vs LL(2).
//!
//! Static speed set {0.2, …, 1.6} (S1), speeds known. Four workers of
//! different speeds are sampled and their queue-length histograms compared:
//!
//! * under **SQ(2)** the distributions coincide across speeds (the §4.2
//!   stationary-distribution result: the marginal law is the same for all
//!   workers regardless of processing power);
//! * under **LL(2)** fast workers develop long-tailed queues (Example 3:
//!   everybody ends up as slow as the slowest server).

use super::harness::{Baseline, Bench, Scale};
use crate::cluster::SpeedProfile;
use crate::metrics::report::{format_table, Row};
use crate::scheduler::TieRule;

/// Queue distributions for the four sampled workers under one tie rule.
#[derive(Debug)]
pub struct Fig13Panel {
    pub tie: TieRule,
    /// (worker speed, queue-length PMF, mean queue length, tail P[q >= 8]).
    pub workers: Vec<(f64, Vec<f64>, f64, f64)>,
}

/// Workers plotted (indices into the sorted S1 set: fastest → slowest).
pub const SAMPLED: [usize; 4] = [14, 9, 4, 0];

/// Run one panel at the given load.
pub fn run_panel(scale: Scale, tie: TieRule, load: f64, seed: u64) -> Fig13Panel {
    let mut bench = Bench::synthetic(scale, SpeedProfile::S1, load);
    bench.seed = seed;
    bench.queue_sample = Some(0.05);
    let baseline = match tie {
        TieRule::Sq2 => Baseline::PPoTLearning,
        TieRule::Ll2 => Baseline::PPoTLl2,
    };
    let r = bench.run_oracle(baseline);
    let queues = r.queues.expect("queue sampling enabled");
    let speeds = SpeedProfile::S1.speeds(&mut crate::stats::Rng::new(0));
    let workers = SAMPLED
        .iter()
        .map(|&w| (speeds[w], queues.pmf(w), queues.mean_len(w), queues.tail(w, 8)))
        .collect();
    Fig13Panel { tie, workers }
}

/// Run both panels and render.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    for (tie, tag) in [(TieRule::Sq2, 'a'), (TieRule::Ll2, 'b')] {
        let p = run_panel(scale, tie, 0.9, 20200417);
        let rows: Vec<Row> = p
            .workers
            .iter()
            .map(|(speed, pmf, mean, tail)| {
                let mut cells = vec![*mean, *tail];
                cells.extend(pmf.iter().take(8).cloned());
                Row::new(format!("speed {speed:.1}"), cells)
            })
            .collect();
        out.push_str(&format_table(
            &format!("Fig 13{tag} — queue lengths under {tie:?} (load 0.9, static)"),
            &["mean_q", "P[q>=8]", "P0", "P1", "P2", "P3", "P4", "P5", "P6", "P7"],
            &rows,
            3,
        ));
    }
    out
}

/// Total-variation distance between two PMFs (padded to equal length).
pub fn tv_distance(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().max(b.len());
    let get = |v: &[f64], i: usize| v.get(i).copied().unwrap_or(0.0);
    (0..n).map(|i| (get(a, i) - get(b, i)).abs()).sum::<f64>() / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq2_distributions_similar_across_speeds() {
        let p = run_panel(Scale::Quick, TieRule::Sq2, 0.9, 12);
        // Fastest vs slowest sampled worker: PMFs should be close.
        let d = tv_distance(&p.workers[0].1, &p.workers[3].1);
        assert!(d < 0.45, "SQ2 TV distance fastest-vs-slowest = {d}");
    }

    #[test]
    fn ll2_prefers_fast_workers() {
        let sq = run_panel(Scale::Quick, TieRule::Sq2, 0.9, 13);
        let ll = run_panel(Scale::Quick, TieRule::Ll2, 0.9, 13);
        // The fastest worker's mean queue is longer under LL(2)...
        assert!(
            ll.workers[0].2 > sq.workers[0].2,
            "LL2 fast-worker queue {} should exceed SQ2 {}",
            ll.workers[0].2,
            sq.workers[0].2
        );
        // ...and the slowest worker's queue is shorter (or no longer).
        assert!(
            ll.workers[3].2 <= sq.workers[3].2 * 1.5 + 0.5,
            "LL2 slow-worker queue {} vs SQ2 {}",
            ll.workers[3].2,
            sq.workers[3].2
        );
    }

    #[test]
    fn tv_distance_bounds() {
        assert_eq!(tv_distance(&[1.0], &[1.0]), 0.0);
        assert_eq!(tv_distance(&[1.0, 0.0], &[0.0, 1.0]), 1.0);
        let d = tv_distance(&[0.5, 0.5], &[0.5, 0.25, 0.25]);
        assert!((d - 0.25).abs() < 1e-12);
    }
}
