//! Figure 9: response-time percentiles (5th/25th/50th/75th/95th) for TPC-H
//! queries q3 and q6 at load 0.8, for every baseline, in (a) static and
//! (b) volatile environments.
//!
//! Expected shape (paper): Rosella uniformly best; bandit worst; PSS
//! improves over Sparrow; PoT and late binding improve further; learning
//! baselines degrade under volatility while Sparrow/PoT do not.

use super::harness::{ms, Baseline, Bench, Scale};
use crate::cluster::Volatility;
use crate::metrics::report::{format_table, Row};
use crate::workload::tpch::Query;

/// All percentile rows for one (query, environment) cell.
#[derive(Debug)]
pub struct Fig9Cell {
    pub query: Query,
    pub volatile: bool,
    /// (baseline name, [p5, p25, p50, p75, p95] in ms, mean ms).
    pub rows: Vec<(String, [f64; 5], f64)>,
}

/// Baselines shown in Figure 9.
pub fn baselines() -> Vec<Baseline> {
    vec![
        Baseline::Sparrow,
        Baseline::PoT,
        Baseline::Bandit02,
        Baseline::Bandit03,
        Baseline::PssLearning,
        Baseline::PPoTLearning,
        Baseline::Rosella,
    ]
}

/// Run one cell of the figure.
pub fn run_cell(scale: Scale, query: Query, volatile: bool, seed: u64) -> Fig9Cell {
    let mut bench = Bench::tpch(scale, query);
    bench.seed = seed;
    if volatile {
        bench.volatility = Volatility::Permute { period: scale.t(120.0) };
    }
    let mut rows = Vec::new();
    for b in baselines() {
        let r = bench.run(b);
        let f = r.responses.five_num();
        rows.push((
            b.name().to_string(),
            [ms(f.p5), ms(f.p25), ms(f.p50), ms(f.p75), ms(f.p95)],
            ms(r.responses.mean()),
        ));
    }
    Fig9Cell { query, volatile, rows }
}

/// Run the full figure (2 queries × 2 environments).
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    for volatile in [false, true] {
        for query in [Query::Q3, Query::Q6] {
            let cell = run_cell(scale, query, volatile, 20200417);
            let rows: Vec<Row> = cell
                .rows
                .iter()
                .map(|(name, p, mean)| {
                    let mut cells = p.to_vec();
                    cells.push(*mean);
                    Row::new(name.clone(), cells)
                })
                .collect();
            out.push_str(&format_table(
                &format!(
                    "Fig 9{} — {:?} response time (ms), load 0.8, {}",
                    if volatile { 'b' } else { 'a' },
                    query,
                    if volatile { "volatile" } else { "static" }
                ),
                &["p5", "p25", "p50", "p75", "p95", "mean"],
                &rows,
                1,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn median_of<'a>(cell: &'a Fig9Cell, name: &str) -> f64 {
        cell.rows.iter().find(|(n, _, _)| n == name).unwrap().1[2]
    }

    #[test]
    fn rosella_best_median_static_q3() {
        let cell = run_cell(Scale::Quick, Query::Q3, false, 3);
        let rosella = median_of(&cell, "rosella");
        for (name, p, _) in &cell.rows {
            if name != "rosella" {
                assert!(
                    rosella <= p[2] * 1.05,
                    "rosella p50 {rosella} should beat {name} p50 {}",
                    p[2]
                );
            }
        }
    }

    #[test]
    fn percentiles_are_monotone() {
        let cell = run_cell(Scale::Quick, Query::Q6, false, 4);
        for (name, p, _) in &cell.rows {
            for w in p.windows(2) {
                assert!(w[0] <= w[1] + 1e-9, "{name} percentiles not monotone: {p:?}");
            }
        }
    }
}
