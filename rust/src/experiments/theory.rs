//! Empirical checks of the paper's §4 theory:
//!
//! * **Result 1 / Lemma 4** — max queue length under PPoT is O(log log n)
//!   vs O(log n) for single-sample policies: we sweep the cluster size and
//!   report the mean per-snapshot maximum queue length.
//! * **Result 2** — learning time is essentially independent of n: we sweep
//!   n and report the time for the learner's mean relative error to drop
//!   below a threshold.
//! * **Result 3 / Proposition 1** — recovery after a shock is fast: we
//!   report the estimate-error trace around a permutation shock.

use super::harness::Scale;
use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::LearnerConfig;
use crate::metrics::report::{format_table, Row};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig};
use crate::workload::WorkloadKind;

/// Mean per-snapshot max queue length for a policy on a homogeneous
/// cluster of n workers at the given load.
pub fn max_queue(n: usize, load: f64, policy: PolicyKind, duration: f64, seed: u64) -> f64 {
    let r = sim_run(SimConfig {
        seed,
        duration,
        warmup: duration * 0.25,
        speeds: SpeedProfile::Homogeneous { n, speed: 1.0 },
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load,
        policy,
        learner: LearnerConfig::oracle(),
        queue_sample: Some(0.1),
        timeline: None,
    });
    r.queues.unwrap().mean_max()
}

/// Result 1 sweep: max queue vs n for uniform (log n) and PPoT (log log n).
pub fn max_queue_scaling(scale: Scale, seed: u64) -> Vec<(usize, f64, f64)> {
    let duration = scale.t(200.0);
    let mut out = Vec::new();
    for &n in &[8usize, 32, 128] {
        let uni = max_queue(n, 0.9, PolicyKind::Uniform, duration, seed);
        let ppot = max_queue(
            n,
            0.9,
            PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            duration,
            seed,
        );
        out.push((n, uni, ppot));
    }
    out
}

/// Result 2: time for the learner's mean relative estimation error to fall
/// below `threshold`, as a function of cluster size. Returns
/// `(n, learn_time_secs)`; `f64::INFINITY` if never reached.
pub fn learning_time(n: usize, threshold: f64, scale: Scale, seed: u64) -> f64 {
    // Heterogeneous cluster: half slow (0.5), half fast (1.5).
    let speeds: Vec<f64> =
        (0..n).map(|i| if i % 2 == 0 { 0.5 } else { 1.5 }).collect();
    let r = sim_run(SimConfig {
        seed,
        duration: scale.t(300.0),
        warmup: 0.0,
        speeds: SpeedProfile::Explicit(speeds),
        volatility: Volatility::Static,
        workload: WorkloadKind::Synthetic,
        load: 0.7,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::default(),
        queue_sample: None,
        timeline: None,
    });
    r.estimate_error
        .iter()
        .find(|(_, e)| *e < threshold)
        .map(|(t, _)| *t)
        .unwrap_or(f64::INFINITY)
}

/// Result 2 sweep over n.
pub fn learning_time_scaling(scale: Scale, seed: u64) -> Vec<(usize, f64)> {
    [10usize, 20, 40, 80]
        .iter()
        .map(|&n| (n, learning_time(n, 0.25, scale, seed)))
        .collect()
}

/// Result 3: estimate-error trace around a mid-run permutation shock.
pub fn shock_recovery_trace(scale: Scale, seed: u64) -> Vec<(f64, f64)> {
    let shock_at = scale.t(150.0);
    let r = sim_run(SimConfig {
        seed,
        duration: shock_at * 2.0,
        warmup: 0.0,
        speeds: SpeedProfile::S2,
        volatility: Volatility::Permute { period: shock_at },
        workload: WorkloadKind::Synthetic,
        load: 0.7,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::default(),
        queue_sample: None,
        timeline: None,
    });
    r.estimate_error
}

/// Render the theory report.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    let sweep = max_queue_scaling(scale, 20200417);
    let rows: Vec<Row> = sweep
        .iter()
        .map(|(n, uni, ppot)| Row::new(format!("n={n}"), vec![*uni, *ppot]))
        .collect();
    out.push_str(&format_table(
        "Theory R1 — mean max queue length (load 0.9, homogeneous)",
        &["uniform (log n)", "ppot (log log n)"],
        &rows,
        2,
    ));
    let lt = learning_time_scaling(scale, 20200417);
    let rows: Vec<Row> =
        lt.iter().map(|(n, t)| Row::new(format!("n={n}"), vec![*t])).collect();
    out.push_str(&format_table(
        "Theory R2 — learning time (secs to error < 0.25)",
        &["learn_time_s"],
        &rows,
        2,
    ));
    let trace = shock_recovery_trace(scale, 20200417);
    let shock_at = scale.t(150.0);
    let pre: Vec<f64> = trace
        .iter()
        .filter(|(t, _)| *t > shock_at * 0.5 && *t < shock_at)
        .map(|(_, e)| *e)
        .collect();
    let post_late: Vec<f64> = trace
        .iter()
        .filter(|(t, _)| *t > shock_at * 1.5)
        .map(|(_, e)| *e)
        .collect();
    out.push_str(&format!(
        "== Theory R3 — shock recovery ==\npre-shock error {:.3}, post-shock (after re-learning) {:.3}\n",
        crate::stats::mean(&pre),
        crate::stats::mean(&post_late),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_queue_grows_slower_under_ppot() {
        let sweep = max_queue_scaling(Scale::Quick, 14);
        // Growth factor from the smallest to the largest n.
        let uni_growth = sweep.last().unwrap().1 / sweep[0].1.max(0.1);
        let ppot_growth = sweep.last().unwrap().2 / sweep[0].2.max(0.1);
        assert!(
            ppot_growth < uni_growth,
            "ppot growth {ppot_growth} should be below uniform growth {uni_growth} ({sweep:?})"
        );
        // And PPoT's absolute max queue is smaller at the largest n.
        assert!(sweep.last().unwrap().2 < sweep.last().unwrap().1);
    }

    #[test]
    fn learning_time_nearly_size_independent() {
        let lt = learning_time_scaling(Scale::Quick, 15);
        let t_small = lt[0].1;
        let t_large = lt.last().unwrap().1;
        assert!(t_small.is_finite(), "learner never converged on small cluster: {lt:?}");
        assert!(t_large.is_finite(), "learner never converged on large cluster: {lt:?}");
        // Doubling n three times should not even double the learning time
        // (Result 2: log(n) growth at worst).
        assert!(t_large < t_small * 4.0 + 5.0, "{lt:?}");
    }

    #[test]
    fn shock_spikes_then_recovers() {
        let trace = shock_recovery_trace(Scale::Quick, 16);
        assert!(!trace.is_empty());
        let shock_at = Scale::Quick.t(150.0);
        let just_after: Vec<f64> = trace
            .iter()
            .filter(|(t, _)| *t > shock_at && *t < shock_at * 1.2)
            .map(|(_, e)| *e)
            .collect();
        let later: Vec<f64> = trace
            .iter()
            .filter(|(t, _)| *t > shock_at * 1.7)
            .map(|(_, e)| *e)
            .collect();
        // Error right after the shock exceeds the eventual recovered error.
        assert!(
            crate::stats::mean(&just_after) > crate::stats::mean(&later),
            "after={:?} later={:?}",
            crate::stats::mean(&just_after),
            crate::stats::mean(&later)
        );
    }
}
