//! Figure 10: schedulers with *known* worker speeds on the synthetic
//! workload (§6.2, Zipf-flavoured heterogeneity, 15 workers).
//!
//! (a) At load 0.9, PoT's response time *grows with the job index*
//! (non-stationary: the slow majority absorbs more than its capacity)
//! while PSS/PPoT stay flat. Uniform random grows even faster (the paper
//! removes it from the chart).
//!
//! (b) Mean response time vs load ratio for PoT, PSS, PPoT, and Halo:
//! PPoT best across all loads, gap widening with load; Halo's benefit over
//! PSS is limited.

use super::harness::{ms, Baseline, Bench, Scale};
use crate::cluster::SpeedProfile;
use crate::metrics::report::{format_series, format_table, Row};
use crate::stats::linreg_slope;

/// The heterogeneous speed set used for Figure 10 (Zipf-like: a small
/// number of powerful servers). Fixed (not resampled) so all policies see
/// the identical cluster.
pub fn speeds() -> SpeedProfile {
    SpeedProfile::Explicit(vec![
        0.25, 0.25, 0.25, 0.25, 0.25, 0.25, 0.25, 0.5, 0.5, 0.5, 0.5, 1.0, 1.0, 2.0, 4.0,
    ])
}

/// Panel (a): binned mean response time by job index at load 0.9.
#[derive(Debug)]
pub struct Fig10a {
    /// (policy, per-bin mean response ms, linear trend slope ms/bin).
    pub rows: Vec<(String, Vec<f64>, f64)>,
}

/// Run panel (a).
pub fn run_a(scale: Scale, seed: u64) -> Fig10a {
    let mut bench = Bench::synthetic(scale, speeds(), 0.9);
    bench.seed = seed;
    bench.warmup = 0.0; // panel (a) *wants* the transient growth visible
    let mut rows = Vec::new();
    for b in [Baseline::PoT, Baseline::PssLearning, Baseline::PPoTLearning] {
        let r = bench.run_oracle(b);
        let bins: Vec<f64> = r.responses.binned_means(20).iter().map(|&v| ms(v)).collect();
        let slope = linreg_slope(&bins);
        rows.push((b.name().to_string(), bins, slope));
    }
    Fig10a { rows }
}

/// Panel (b): mean response vs load for each policy, speeds known.
#[derive(Debug)]
pub struct Fig10b {
    pub loads: Vec<f64>,
    /// (policy, mean response ms per load).
    pub rows: Vec<(String, Vec<f64>)>,
}

/// Run panel (b).
pub fn run_b(scale: Scale, seed: u64) -> Fig10b {
    let loads = vec![0.3, 0.5, 0.7, 0.8, 0.9];
    let mut rows = Vec::new();
    for b in [Baseline::PoT, Baseline::PssLearning, Baseline::Halo, Baseline::PPoTLearning] {
        let mut series = Vec::new();
        for &load in &loads {
            let mut bench = Bench::synthetic(scale, speeds(), load);
            bench.seed = seed;
            let r = bench.run_oracle(b);
            series.push(ms(r.responses.mean()));
        }
        rows.push((b.name().to_string(), series));
    }
    Fig10b { loads, rows }
}

/// Run both panels and render.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    let a = run_a(scale, 20200417);
    out.push_str("== Fig 10a — response vs job index, load 0.9, speeds known ==\n");
    for (name, bins, slope) in &a.rows {
        out.push_str(&format!("{name:>14}: trend slope {slope:+9.3} ms/bin\n"));
        let pts: Vec<(f64, f64)> =
            bins.iter().enumerate().map(|(i, &v)| (i as f64, v)).collect();
        out.push_str(&format_series(
            &format!("Fig 10a {name}"),
            "job_bin",
            "mean_resp_ms",
            &pts,
        ));
    }
    let b = run_b(scale, 20200417);
    let rows: Vec<Row> =
        b.rows.iter().map(|(n, s)| Row::new(n.clone(), s.clone())).collect();
    let headers: Vec<String> = b.loads.iter().map(|l| format!("load {l}")).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    out.push_str(&format_table(
        "Fig 10b — mean response (ms) vs load, speeds known",
        &headers_ref,
        &rows,
        1,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pot_grows_ppot_does_not() {
        let a = run_a(Scale::Quick, 5);
        let pot = a.rows.iter().find(|(n, _, _)| n == "pot").unwrap();
        let ppot = a.rows.iter().find(|(n, _, _)| n == "ppot+learning").unwrap();
        // PoT is non-stationary at load 0.9 on this cluster: strong
        // positive trend. PPoT stays roughly flat.
        assert!(pot.2 > 0.0, "pot slope {} should be positive", pot.2);
        assert!(
            ppot.2.abs() < pot.2 / 2.0,
            "ppot slope {} should be flat vs pot {}",
            ppot.2,
            pot.2
        );
    }

    #[test]
    fn ppot_best_across_loads() {
        let b = run_b(Scale::Quick, 6);
        let ppot = &b.rows.iter().find(|(n, _)| n == "ppot+learning").unwrap().1;
        let pot = &b.rows.iter().find(|(n, _)| n == "pot").unwrap().1;
        // At the highest load PPoT must clearly beat PoT.
        assert!(
            ppot.last().unwrap() < pot.last().unwrap(),
            "ppot {:?} vs pot {:?}",
            ppot,
            pot
        );
    }

    #[test]
    fn halo_benefit_over_pss_is_limited() {
        let b = run_b(Scale::Quick, 7);
        let pss = &b.rows.iter().find(|(n, _)| n == "pss+learning").unwrap().1;
        let halo = &b.rows.iter().find(|(n, _)| n == "halo").unwrap().1;
        // Halo should be in the same ballpark as PSS (within 3x either way)
        // — the paper's point is that its gain is moderate.
        for (h, p) in halo.iter().zip(pss.iter()) {
            assert!(*h < p * 3.0 && *p < h * 3.0, "halo {h} vs pss {p}");
        }
    }
}
