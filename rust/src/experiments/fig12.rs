//! Figure 12: the impact of benchmark ("fake") jobs.
//!
//! Rosella (fake jobs on, dynamic window c=10) is compared against
//! PSS+PoT+Learning *without* fake jobs using sliding windows
//! `c/(1−α)` for c ∈ {10, 20, 30, 40} (labelled w10..w40), under volatile
//! speeds (permute every minute) for sets S1 and S2.
//!
//! Expected shape: longer windows do not buy better response time, while
//! fake jobs consistently help — increasingly so at high load and high
//! heterogeneity.

use super::harness::{ms, Baseline, Bench, Scale};
use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::LearnerConfig;
use crate::metrics::report::{format_table, Row};
use crate::scheduler::{PolicyKind, TieRule};
use crate::simulator::{run as sim_run, SimConfig};

/// One panel of the ablation.
#[derive(Debug)]
pub struct Fig12Panel {
    pub set_name: &'static str,
    pub loads: Vec<f64>,
    /// ("rosella" | "w10".."w40", mean response ms per load).
    pub rows: Vec<(String, Vec<f64>)>,
}

fn run_no_fake(bench: &Bench, window_c: f64) -> f64 {
    let r = sim_run(SimConfig {
        seed: bench.seed,
        duration: bench.duration,
        warmup: bench.warmup,
        speeds: bench.speeds.clone(),
        volatility: bench.volatility.clone(),
        workload: bench.workload.clone(),
        load: bench.load,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::no_fake_jobs(window_c),
        queue_sample: None,
        timeline: None,
    });
    ms(r.responses.mean())
}

/// Run one panel.
pub fn run_panel(scale: Scale, set: SpeedProfile, set_name: &'static str, seed: u64) -> Fig12Panel {
    let loads = vec![0.5, 0.7, 0.8, 0.9];
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    // Rosella with fake jobs.
    let mut rosella = Vec::new();
    for &load in &loads {
        let mut bench = Bench::synthetic(scale, set.clone(), load);
        bench.seed = seed;
        bench.volatility = Volatility::Permute { period: scale.t(60.0) };
        let r = bench.run(Baseline::RosellaNoLb);
        rosella.push(ms(r.responses.mean()));
    }
    rows.push(("rosella".to_string(), rosella));
    // Window baselines without fake jobs.
    for c in [10.0, 20.0, 30.0, 40.0] {
        let mut series = Vec::new();
        for &load in &loads {
            let mut bench = Bench::synthetic(scale, set.clone(), load);
            bench.seed = seed;
            bench.volatility = Volatility::Permute { period: scale.t(60.0) };
            series.push(run_no_fake(&bench, c));
        }
        rows.push((format!("w{}", c as u32), series));
    }
    Fig12Panel { set_name, loads, rows }
}

/// Run both panels and render.
pub fn run(scale: Scale) -> String {
    let mut out = String::new();
    for (set, name, tag) in
        [(SpeedProfile::S1, "S1", 'a'), (SpeedProfile::S2, "S2", 'b')]
    {
        let p = run_panel(scale, set, name, 20200417);
        let rows: Vec<Row> =
            p.rows.iter().map(|(n, s)| Row::new(n.clone(), s.clone())).collect();
        let headers: Vec<String> = p.loads.iter().map(|l| format!("load {l}")).collect();
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        out.push_str(&format_table(
            &format!("Fig 12{tag} — fake-job ablation, mean response (ms), set {name}"),
            &headers_ref,
            &rows,
            1,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_jobs_help_at_high_load() {
        let p = run_panel(Scale::Quick, SpeedProfile::S2, "S2", 10);
        let rosella = &p.rows[0].1;
        let last = p.loads.len() - 1;
        // Rosella (with fake jobs) should beat at least 3 of the 4 window
        // baselines at the highest load.
        let beaten = p.rows[1..]
            .iter()
            .filter(|(_, s)| rosella[last] <= s[last] * 1.1)
            .count();
        assert!(beaten >= 3, "rosella {} beaten only {beaten}: {:?}", rosella[last], p.rows);
    }

    #[test]
    fn longer_windows_do_not_dominate() {
        let p = run_panel(Scale::Quick, SpeedProfile::S1, "S1", 11);
        let w10 = &p.rows.iter().find(|(n, _)| n == "w10").unwrap().1;
        let w40 = &p.rows.iter().find(|(n, _)| n == "w40").unwrap().1;
        // The paper: longer windows improve estimates but not response
        // times. Check w40 is not dramatically better than w10 everywhere.
        let w40_dominates = w10.iter().zip(w40.iter()).all(|(a, b)| b < &(a * 0.7));
        assert!(!w40_dominates, "w40 unexpectedly dominates: w10={w10:?} w40={w40:?}");
    }
}
