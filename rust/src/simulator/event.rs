//! Event types and the time-ordered event queue of the discrete-event
//! simulator.
//!
//! The queue is a binary heap keyed by `(time, seq)`; the sequence number
//! breaks ties deterministically (FIFO among simultaneous events), which
//! keeps every experiment bit-reproducible for a fixed seed.

use crate::types::WorkerId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation events.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A new job arrives at the scheduler.
    JobArrival,
    /// Worker `worker` finishes its in-service task. `generation` guards
    /// against stale completions after a speed shock rescheduled the
    /// in-flight task (see `engine.rs`).
    TaskCompletion { worker: WorkerId, generation: u64 },
    /// The learner's dispatcher wakes up to inject benchmark jobs
    /// (LEARNER-DISPATCHER, paper Fig. 6).
    BenchmarkDispatch,
    /// The learner publishes fresh estimates and the proportional sampler
    /// is rebuilt.
    EstimatePublish,
    /// The environment shocks: worker speeds are randomly permuted
    /// (§6.1/§6.2: "randomly permute the worker speeds every X minutes").
    SpeedShock,
    /// Periodic queue-length sampling for Figure 13-style distributions.
    QueueSample,
    /// Hard stop.
    EndOfSimulation,
}

/// A scheduled event.
#[derive(Debug, Clone)]
struct Scheduled {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue ordered by time, FIFO among equal times.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `time`.
    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        self.heap.push(Scheduled { time, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|s| (s.time, s.event))
    }

    /// Time of the next event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.time)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop everything (used between experiment repetitions).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::JobArrival);
        q.push(1.0, Event::EndOfSimulation);
        q.push(2.0, Event::BenchmarkDispatch);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::TaskCompletion { worker: 0, generation: 0 });
        q.push(1.0, Event::TaskCompletion { worker: 1, generation: 0 });
        q.push(1.0, Event::TaskCompletion { worker: 2, generation: 0 });
        for expect in 0..3 {
            match q.pop().unwrap().1 {
                Event::TaskCompletion { worker, .. } => assert_eq!(worker, expect),
                e => panic!("unexpected {e:?}"),
            }
        }
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::SpeedShock);
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, Event::JobArrival);
        q.push(1.0, Event::JobArrival);
        assert_eq!(q.pop().unwrap().0, 1.0);
        q.push(5.0, Event::JobArrival);
        q.push(0.5, Event::JobArrival);
        assert_eq!(q.pop().unwrap().0, 0.5);
        assert_eq!(q.pop().unwrap().0, 5.0);
        assert_eq!(q.pop().unwrap().0, 10.0);
    }

    #[test]
    fn clear_empties() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::JobArrival);
        q.clear();
        assert!(q.is_empty());
    }
}
