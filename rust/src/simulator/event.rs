//! Event types and the time-ordered event queue of the discrete-event
//! simulator.
//!
//! The queue is a binary heap keyed by `(time, seq)`; the sequence number
//! breaks ties deterministically (FIFO among simultaneous events), which
//! keeps every experiment bit-reproducible for a fixed seed.
//!
//! Two properties keep the event loop allocation-free and O(log m) per
//! event regardless of cluster size:
//!
//! * heap entries are a compact `Copy` triple `(time, seq, packed event)` —
//!   a packed event is one `u64` (tag + worker id), so pushing or popping
//!   never clones an [`Event`] or touches the heap's buffer beyond the
//!   amortized in-place sift;
//! * completion events are **keyed per worker**: each worker serves at most
//!   one task at a time, so the queue tracks the sequence number of the one
//!   live completion per worker. Rescheduling a completion (a speed shock
//!   re-basing an in-flight task) cancels the previous event *at the
//!   source*; cancelled entries are skimmed off inside [`EventQueue::pop`]
//!   and never reach the engine, and [`EventQueue::len`] counts live events
//!   only. This replaces the old lazily-filtered generation counters and
//!   bounds the queue at (live events + not-yet-skimmed cancellations).

use crate::types::WorkerId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A new job arrives at the scheduler.
    JobArrival,
    /// Worker `worker` finishes its in-service task. At most one completion
    /// per worker is live at any time; rescheduling (a speed shock) cancels
    /// the stale event inside the queue.
    TaskCompletion { worker: WorkerId },
    /// The learner's dispatcher wakes up to inject benchmark jobs
    /// (LEARNER-DISPATCHER, paper Fig. 6).
    BenchmarkDispatch,
    /// The learner publishes fresh estimates and the proportional sampler
    /// is rebuilt.
    EstimatePublish,
    /// Multi-scheduler estimate-sync *check epoch* (§5): the sync policy
    /// decides what to exchange — an all-to-all merge (periodic, or
    /// adaptive past its divergence trigger / staleness deadline), nothing
    /// (adaptive below threshold), or deterministic scheduler pairs
    /// (gossip). Only scheduled when `sync_interval > 0` decouples
    /// consensus from the publish cadence.
    EstimateSync,
    /// The environment shocks: worker speeds are randomly permuted
    /// (§6.1/§6.2: "randomly permute the worker speeds every X minutes").
    SpeedShock,
    /// Periodic queue-length sampling for Figure 13-style distributions.
    QueueSample,
    /// Periodic telemetry timeline sampling (λ̂, per-worker μ̂ vs true
    /// speed, queue p99, backlog). Read-only against engine state: never
    /// draws from an RNG or perturbs the decision stream.
    TimelineSample,
    /// Hard stop.
    EndOfSimulation,
}

// Packed-event tags (high 32 bits); the low 32 bits carry the worker id
// for completions and are zero otherwise.
const T_JOB_ARRIVAL: u64 = 0;
const T_COMPLETION: u64 = 1;
const T_BENCH_DISPATCH: u64 = 2;
const T_ESTIMATE_PUBLISH: u64 = 3;
const T_SPEED_SHOCK: u64 = 4;
const T_QUEUE_SAMPLE: u64 = 5;
const T_END: u64 = 6;
const T_ESTIMATE_SYNC: u64 = 7;
const T_TIMELINE_SAMPLE: u64 = 8;

#[inline]
fn pack_tag(ev: &Event) -> u64 {
    match ev {
        Event::JobArrival => T_JOB_ARRIVAL << 32,
        Event::TaskCompletion { worker } => (T_COMPLETION << 32) | *worker as u64,
        Event::BenchmarkDispatch => T_BENCH_DISPATCH << 32,
        Event::EstimatePublish => T_ESTIMATE_PUBLISH << 32,
        Event::EstimateSync => T_ESTIMATE_SYNC << 32,
        Event::SpeedShock => T_SPEED_SHOCK << 32,
        Event::QueueSample => T_QUEUE_SAMPLE << 32,
        Event::TimelineSample => T_TIMELINE_SAMPLE << 32,
        Event::EndOfSimulation => T_END << 32,
    }
}

#[inline]
fn unpack(bits: u64) -> Event {
    let worker = (bits & 0xFFFF_FFFF) as usize;
    match bits >> 32 {
        T_JOB_ARRIVAL => Event::JobArrival,
        T_COMPLETION => Event::TaskCompletion { worker },
        T_BENCH_DISPATCH => Event::BenchmarkDispatch,
        T_ESTIMATE_PUBLISH => Event::EstimatePublish,
        T_ESTIMATE_SYNC => Event::EstimateSync,
        T_SPEED_SHOCK => Event::SpeedShock,
        T_QUEUE_SAMPLE => Event::QueueSample,
        T_TIMELINE_SAMPLE => Event::TimelineSample,
        T_END => Event::EndOfSimulation,
        other => unreachable!("corrupt packed event tag {other}"),
    }
}

#[inline]
fn is_completion(bits: u64) -> bool {
    bits >> 32 == T_COMPLETION
}

/// A scheduled event: 24 bytes, `Copy`, no indirection.
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    time: f64,
    seq: u64,
    ev: u64,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Sentinel: no live completion scheduled for this worker.
const NO_COMPLETION: u64 = u64::MAX;

/// Min-heap event queue ordered by time, FIFO among equal times.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    /// Per-worker sequence number of the one live completion event
    /// ([`NO_COMPLETION`] when none). Grown on demand.
    completion_seq: Vec<u64>,
    /// Cancelled completion events still physically in the heap; they are
    /// skimmed off during `pop`/`peek_time` and never surface.
    stale: usize,
}

impl EventQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty queue with the per-worker completion slots preallocated.
    pub fn with_workers(n: usize) -> Self {
        Self { completion_seq: vec![NO_COMPLETION; n], ..Self::default() }
    }

    #[inline]
    fn ensure_worker(&mut self, worker: WorkerId) {
        debug_assert!((worker as u64) < (1u64 << 32), "worker id overflows packed event");
        if worker >= self.completion_seq.len() {
            self.completion_seq.resize(worker + 1, NO_COMPLETION);
        }
    }

    #[inline]
    fn push_raw(&mut self, time: f64, ev: u64) {
        debug_assert!(time.is_finite() && time >= 0.0, "bad event time {time}");
        self.heap.push(Scheduled { time, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Schedule `event` at absolute time `time`. Completions are routed
    /// through [`Self::push_completion`] so the per-worker keying invariant
    /// holds no matter which entry point the caller uses.
    pub fn push(&mut self, time: f64, event: Event) {
        match event {
            Event::TaskCompletion { worker } => self.push_completion(time, worker),
            other => self.push_raw(time, pack_tag(&other)),
        }
    }

    /// Schedule (or reschedule) `worker`'s completion at `time`. Any
    /// previously scheduled completion for the same worker is cancelled at
    /// the source: it will be dropped inside the queue, never returned.
    pub fn push_completion(&mut self, time: f64, worker: WorkerId) {
        self.ensure_worker(worker);
        if self.completion_seq[worker] != NO_COMPLETION {
            self.stale += 1;
        }
        self.completion_seq[worker] = self.seq;
        self.push_raw(time, (T_COMPLETION << 32) | worker as u64);
    }

    /// Cancel `worker`'s pending completion, if any. Returns whether one
    /// was live.
    pub fn cancel_completion(&mut self, worker: WorkerId) -> bool {
        match self.completion_seq.get_mut(worker) {
            Some(slot) if *slot != NO_COMPLETION => {
                *slot = NO_COMPLETION;
                self.stale += 1;
                true
            }
            _ => false,
        }
    }

    /// Drop cancelled completions sitting at the top of the heap.
    fn skim_stale(&mut self) {
        while let Some(&s) = self.heap.peek() {
            if is_completion(s.ev) {
                let w = (s.ev & 0xFFFF_FFFF) as usize;
                if self.completion_seq[w] != s.seq {
                    self.heap.pop();
                    self.stale -= 1;
                    continue;
                }
            }
            break;
        }
    }

    /// Pop the earliest live event, if any. Cancelled completions are
    /// consumed silently.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        while let Some(s) = self.heap.pop() {
            if is_completion(s.ev) {
                let w = (s.ev & 0xFFFF_FFFF) as usize;
                if self.completion_seq[w] != s.seq {
                    self.stale -= 1;
                    continue; // cancelled at source
                }
                self.completion_seq[w] = NO_COMPLETION;
            }
            return Some((s.time, unpack(s.ev)));
        }
        None
    }

    /// Time of the next live event without removing it.
    pub fn peek_time(&mut self) -> Option<f64> {
        self.skim_stale();
        self.heap.peek().map(|s| s.time)
    }

    /// Number of pending *live* events (cancelled completions excluded).
    pub fn len(&self) -> usize {
        self.heap.len() - self.stale
    }

    /// True if no live events are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop everything (used between experiment repetitions). Keeps the
    /// heap's and the completion table's capacity — the recycled-queue
    /// path for repeated runs.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.stale = 0;
        for slot in &mut self.completion_seq {
            *slot = NO_COMPLETION;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::JobArrival);
        q.push(1.0, Event::EndOfSimulation);
        q.push(2.0, Event::BenchmarkDispatch);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 3.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn estimate_sync_round_trips_through_packing() {
        let mut q = EventQueue::new();
        q.push(1.5, Event::EstimateSync);
        q.push(1.0, Event::EstimatePublish);
        assert_eq!(q.pop(), Some((1.0, Event::EstimatePublish)));
        assert_eq!(q.pop(), Some((1.5, Event::EstimateSync)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::with_workers(3);
        q.push(1.0, Event::TaskCompletion { worker: 0 });
        q.push(1.0, Event::TaskCompletion { worker: 1 });
        q.push(1.0, Event::TaskCompletion { worker: 2 });
        for expect in 0..3 {
            match q.pop().unwrap().1 {
                Event::TaskCompletion { worker } => assert_eq!(worker, expect),
                e => panic!("unexpected {e:?}"),
            }
        }
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::SpeedShock);
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, Event::JobArrival);
        q.push(1.0, Event::JobArrival);
        assert_eq!(q.pop().unwrap().0, 1.0);
        q.push(5.0, Event::JobArrival);
        q.push(0.5, Event::JobArrival);
        assert_eq!(q.pop().unwrap().0, 0.5);
        assert_eq!(q.pop().unwrap().0, 5.0);
        assert_eq!(q.pop().unwrap().0, 10.0);
    }

    #[test]
    fn clear_empties() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::JobArrival);
        q.push_completion(2.0, 0);
        q.clear();
        assert!(q.is_empty());
        // A post-clear completion must not be confused with the dropped one.
        q.push_completion(3.0, 0);
        assert_eq!(q.pop(), Some((3.0, Event::TaskCompletion { worker: 0 })));
    }

    #[test]
    fn reschedule_cancels_previous_completion_at_source() {
        let mut q = EventQueue::with_workers(2);
        q.push_completion(1.0, 0);
        // Speed shock: the in-flight task now finishes earlier.
        q.push_completion(0.5, 0);
        assert_eq!(q.len(), 1, "cancelled event must not count as live");
        assert_eq!(q.pop(), Some((0.5, Event::TaskCompletion { worker: 0 })));
        assert!(q.pop().is_none(), "stale completion must never surface");
        assert!(q.is_empty());
    }

    #[test]
    fn reschedule_later_also_cancels_the_earlier_event() {
        let mut q = EventQueue::with_workers(1);
        q.push_completion(0.5, 0);
        // Slow-down shock: completion moves later; the earlier event is
        // now stale and must be skimmed, not surfaced at t=0.5.
        q.push_completion(2.0, 0);
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.pop(), Some((2.0, Event::TaskCompletion { worker: 0 })));
        assert!(q.pop().is_none());
    }

    #[test]
    fn completions_keyed_per_worker_do_not_interfere() {
        let mut q = EventQueue::with_workers(2);
        q.push_completion(1.0, 0);
        q.push_completion(2.0, 1);
        q.push_completion(1.5, 0); // reschedule worker 0 only
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((1.5, Event::TaskCompletion { worker: 0 })));
        assert_eq!(q.pop(), Some((2.0, Event::TaskCompletion { worker: 1 })));
        assert!(q.pop().is_none());
    }

    #[test]
    fn explicit_cancellation() {
        let mut q = EventQueue::with_workers(1);
        q.push_completion(1.0, 0);
        assert!(q.cancel_completion(0));
        assert!(!q.cancel_completion(0), "double cancel must be a no-op");
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn live_count_stays_bounded_across_many_reschedules() {
        // A volatile cluster reschedules the same worker's completion over
        // and over; the queue must neither grow its live count nor leak
        // the cancelled events past their pop.
        let mut q = EventQueue::with_workers(1);
        for k in 0..1_000 {
            q.push_completion(1.0 + k as f64 * 1e-3, 0);
            assert_eq!(q.len(), 1, "live count grew at reschedule {k}");
        }
        let (t, ev) = q.pop().expect("one live completion");
        assert_eq!(ev, Event::TaskCompletion { worker: 0 });
        assert!((t - 1.999).abs() < 1e-9, "surviving event must be the last reschedule");
        assert!(q.pop().is_none(), "every stale event must be consumed internally");
        assert!(q.is_empty());
    }

    #[test]
    fn natural_completion_then_new_task_reuses_the_slot() {
        let mut q = EventQueue::with_workers(1);
        q.push_completion(1.0, 0);
        assert_eq!(q.pop(), Some((1.0, Event::TaskCompletion { worker: 0 })));
        // Worker starts its next task: a fresh completion, not a stale one.
        q.push_completion(2.0, 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((2.0, Event::TaskCompletion { worker: 0 })));
        assert!(q.is_empty());
    }

    #[test]
    fn worker_slots_grow_on_demand() {
        let mut q = EventQueue::new(); // no preallocated slots
        q.push_completion(1.0, 7);
        q.push_completion(0.5, 7);
        assert_eq!(q.pop(), Some((0.5, Event::TaskCompletion { worker: 7 })));
        assert!(q.pop().is_none());
    }
}
