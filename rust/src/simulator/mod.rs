//! Discrete-event simulation of the full Rosella system.

pub mod engine;
pub mod event;

pub use engine::{run, timeline_json, SimConfig, SimResult, Simulation, TimelinePoint};
pub use event::{Event, EventQueue};
