//! Discrete-event simulation of the full Rosella system.

pub mod engine;
pub mod event;

pub use engine::{run, SimConfig, SimResult, Simulation};
pub use event::{Event, EventQueue};
