//! The discrete-event simulation engine.
//!
//! Couples the cluster model (workers with dual queues), a scheduling
//! policy, the learning stack (arrival estimator, performance learner,
//! benchmark dispatcher), a workload stream, and the volatility model into
//! the paper's full system (Figure 1). Time is continuous (f64 seconds);
//! events are processed in timestamp order with deterministic tie-breaking,
//! so a fixed seed reproduces a run exactly.
//!
//! The engine replaces the paper's 31-node EC2 testbed (§6.1): worker
//! speeds act exactly like the paper's slowed-down Spark executors (a task
//! with demand τ takes τ/s seconds on a speed-s worker), and the node
//! monitor's two-queue priority discipline is implemented verbatim.
//!
//! The steady-state path mirrors the constant-work profile of the paper's
//! scheduler (§3: each decision "only performs simple operations"): queue
//! lengths are maintained incrementally (O(1) per enqueue/start/complete,
//! no per-arrival sweep), the arrival path reuses one job buffer and the
//! proportional sampler rebuilds in place, and completion events are keyed
//! per worker so speed shocks cancel stale events inside the queue instead
//! of leaking them to the handler.
//!
//! **Multi-scheduler learning** (§5, `LearnerConfig::schedulers = k`): the
//! engine models `k` distributed schedulers by hash-splitting the
//! completion stream — task `t` belongs to scheduler `t.id mod k`, whose
//! private [`PerfLearner`] alone sees the sample. The policy never reads a
//! private learner: it sees only the
//! [`merge_estimates`](crate::learner::merge_estimates) consensus,
//! installed either at every publish (`sync_interval = 0`) or on its own
//! [`Event::EstimateSync`] cadence — which is exactly the staleness knob
//! the paper's "synchronize the estimates ... regularly" leaves open, and
//! what the `multisched` experiment sweeps. The arrival stream, the
//! benchmark dispatch stream (a superposition of `k` throttled
//! `c0(μ̄ − λ̂)/k` processes is one Poisson process at the aggregate rate),
//! and every RNG draw are identical for all `k`, so runs differ only
//! through what the learners saw.
//!
//! *When* and *with whom* state is exchanged is pluggable
//! ([`LearnerConfig::sync`] → [`crate::learner::SyncPolicy`]):
//! `Event::EstimateSync` is a policy *check epoch* that may run an
//! all-to-all merge (periodic — bit-compatible with the original fixed
//! timer), skip entirely (adaptive — merges fire only when some learner's
//! local estimates diverge from the last adopted consensus beyond a
//! relative-error threshold, with a staleness deadline forcing one), or
//! merge deterministic-RNG scheduler *pairs* (gossip — pairings drawn from
//! a dedicated stream forked off the sim seed, so runs stay
//! bit-reproducible). Arrivals round-robin across `k` per-scheduler
//! [`ArrivalEstimator`]s and the λ̂ shares travel with the consensus
//! payload ([`crate::learner::LambdaShares`] under gossip), so the learner
//! window, the benchmark throttle, and the policy's λ̂ all run on
//! *exchanged* arrival estimates once `k > 1`.

use crate::cluster::{SpeedProfile, Volatility, Worker};
use crate::learner::{
    merge_estimates_into, relative_error_of, ArrivalEstimator, EstimateView, FakeJobDispatcher,
    LambdaShares, LearnerConfig, PerfLearner, SyncDecision, SyncKind, SyncPolicy,
};
use crate::metrics::{QueueStats, ResponseRecorder};
use crate::scheduler::{Policy, PolicyKind};
use crate::simulator::event::{Event, EventQueue};
use crate::stats::{AliasTable, Rng};
use crate::types::{JobPlacement, JobSpec, LocalView, Task, TaskKind};
use crate::workload::WorkloadKind;
use std::collections::HashMap;
use std::collections::VecDeque;

/// Sentinel job id for single-task jobs tracked without a `jobs` map entry
/// (hot-path optimization; see `on_job_arrival`).
const SINGLE_JOB: u64 = u64::MAX - 1;

/// Complete configuration of one simulation run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// RNG seed; every stream (arrivals, service, policy, shocks) is forked
    /// from it.
    pub seed: u64,
    /// Simulated duration in seconds.
    pub duration: f64,
    /// Jobs arriving before this time are excluded from metrics.
    pub warmup: f64,
    /// Worker speed profile.
    pub speeds: SpeedProfile,
    /// Speed volatility model.
    pub volatility: Volatility,
    /// Workload shape.
    pub workload: WorkloadKind,
    /// Target load ratio α = λ/μ.
    pub load: f64,
    /// Scheduling policy.
    pub policy: PolicyKind,
    /// Learning stack configuration.
    pub learner: LearnerConfig,
    /// Queue-length snapshot interval (None disables queue stats).
    pub queue_sample: Option<f64>,
    /// Telemetry timeline sampling interval in simulated seconds (`None`
    /// disables the timeline). Each sample captures λ̂, the installed μ̂
    /// consensus vs the true speeds, the cross-worker queue-length p99, and
    /// the job backlog — the registry's gauges as a per-window time series.
    /// Sampling reads engine state only (no RNG draws, no event
    /// reordering), so enabling it never perturbs a run's decisions.
    pub timeline: Option<f64>,
}

impl SimConfig {
    /// Sensible defaults for the §6.2 synthetic setting: 15 workers (S1),
    /// load 0.8, static speeds, Rosella policy with learning.
    pub fn synthetic_default() -> Self {
        Self {
            seed: 42,
            duration: 300.0,
            warmup: 30.0,
            speeds: SpeedProfile::S1,
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load: 0.8,
            policy: PolicyKind::PPoT {
                tie: crate::scheduler::TieRule::Sq2,
                late_binding: false,
            },
            learner: LearnerConfig::default(),
            queue_sample: None,
            timeline: None,
        }
    }
}

/// One sampled point of the run's telemetry timeline
/// ([`SimConfig::timeline`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TimelinePoint {
    /// Simulated time of the sample (seconds).
    pub t: f64,
    /// λ̂ the learning stack and policy were running on at this instant.
    pub lambda_hat: f64,
    /// Installed μ̂ consensus (what the policy decides with).
    pub mu_hat: Vec<f64>,
    /// True worker speeds at this instant (volatility moves them).
    pub speeds: Vec<f64>,
    /// p99 queue length across workers, through the registry's log2
    /// histogram geometry (bucket upper bound, like the scrape endpoint).
    pub queue_p99: u64,
    /// Jobs in flight (arrived, not yet fully completed).
    pub backlog: usize,
    /// Median task queue-wait in µs over all completions so far (the DES
    /// counterpart of the live tracer's `queue` stage; log2 bucket upper
    /// bound, like `queue_p99`).
    pub queue_wait_us_p50: u64,
    /// p99 task queue-wait in µs over all completions so far.
    pub queue_wait_us_p99: u64,
    /// Median task service time in µs over all completions so far (the
    /// `service` stage).
    pub service_us_p50: u64,
    /// p99 task service time in µs over all completions so far.
    pub service_us_p99: u64,
}

impl TimelinePoint {
    /// This point as a JSON object.
    pub fn to_json(&self) -> crate::config::Json {
        use crate::config::Json;
        let nums = |xs: &[f64]| Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect());
        let mut m = std::collections::BTreeMap::new();
        m.insert("t".into(), Json::Num(self.t));
        m.insert("lambda_hat".into(), Json::Num(self.lambda_hat));
        m.insert("mu_hat".into(), nums(&self.mu_hat));
        m.insert("speeds".into(), nums(&self.speeds));
        m.insert("queue_p99".into(), Json::Num(self.queue_p99 as f64));
        m.insert("backlog".into(), Json::Num(self.backlog as f64));
        m.insert("queue_wait_us_p50".into(), Json::Num(self.queue_wait_us_p50 as f64));
        m.insert("queue_wait_us_p99".into(), Json::Num(self.queue_wait_us_p99 as f64));
        m.insert("service_us_p50".into(), Json::Num(self.service_us_p50 as f64));
        m.insert("service_us_p99".into(), Json::Num(self.service_us_p99 as f64));
        Json::Obj(m)
    }
}

/// A whole timeline as a JSON array (`simulate --timeline-json`).
pub fn timeline_json(points: &[TimelinePoint]) -> crate::config::Json {
    crate::config::Json::Arr(points.iter().map(TimelinePoint::to_json).collect())
}

/// Bookkeeping for an in-flight job.
#[derive(Debug)]
struct JobState {
    arrival: f64,
    remaining: usize,
}

/// Everything measured during a run.
#[derive(Debug)]
pub struct SimResult {
    /// Policy name.
    pub policy: String,
    /// Response-time recorder (real jobs only, post-warmup).
    pub responses: ResponseRecorder,
    /// Queue-length snapshots, if sampling was enabled.
    pub queues: Option<QueueStats>,
    /// `(time, mean relative estimation error)` trace at publish instants.
    pub estimate_error: Vec<(f64, f64)>,
    /// Completed real tasks.
    pub completed_real: u64,
    /// Completed benchmark tasks.
    pub completed_bench: u64,
    /// Mean worker utilization (busy fraction) over the run.
    pub utilization: f64,
    /// Jobs still incomplete at the end (backlog indicator).
    pub incomplete_jobs: usize,
    /// Total simulated time.
    pub duration: f64,
    /// Estimate-sync check epochs evaluated (periodic: every one merges;
    /// adaptive: most may skip; gossip: one pairing round each).
    pub sync_epochs: u64,
    /// Sampled telemetry timeline (empty unless [`SimConfig::timeline`]
    /// set an interval).
    pub timeline: Vec<TimelinePoint>,
    /// Consensus merge operations performed: all-to-all installs (including
    /// publish-fused ones at `sync_interval = 0`) count one each, every
    /// gossip pair counts one — the coordination-cost axis of the
    /// `multisched` frontier.
    pub sync_merges: u64,
}

impl SimResult {
    /// Fraction of served tasks that were benchmark jobs (overhead of the
    /// learner's active exploration).
    pub fn benchmark_fraction(&self) -> f64 {
        let total = self.completed_real + self.completed_bench;
        if total == 0 {
            0.0
        } else {
            self.completed_bench as f64 / total as f64
        }
    }
}

/// The engine itself. Construct with [`Simulation::new`], run with
/// [`Simulation::run`].
pub struct Simulation {
    cfg: SimConfig,
    now: f64,
    events: EventQueue,
    workers: Vec<Worker>,
    speeds: Vec<f64>,
    qlen: Vec<usize>,
    policy: Box<dyn Policy>,
    workload: Box<dyn crate::workload::Workload>,
    /// One per logical scheduler (§5): arrivals round-robin across them, so
    /// each estimator sees only the share its scheduler routed. Length 1 is
    /// the centralized baseline, read live.
    arrival_ests: Vec<ArrivalEstimator>,
    /// Round-robin cursor splitting job arrivals across the estimators.
    arrival_rr: u64,
    /// λ̂_global installed at the last consensus: the sum of *exchanged*
    /// per-scheduler shares (`k > 1` only; the centralized engine reads its
    /// lone estimator live, bit-compatible with the pre-policy engine).
    lambda_global: f64,
    /// Whether any λ̂ exchange has happened yet (`k > 1`): before the first
    /// one the stack bootstraps from the live share sum — the pre-refactor
    /// engine's behavior — instead of assuming zero load, which would run
    /// the benchmark dispatcher unthrottled until the first sync epoch.
    lambda_exchanged: bool,
    /// Per-scheduler knowledge of everyone's λ̂ share (gossip exchanges
    /// these pairwise; all-to-all merges refresh every entry).
    lambda_shares: Vec<LambdaShares>,
    /// One per logical scheduler (§5); `learners.len() == 1` is the
    /// centralized shared-learner baseline.
    learners: Vec<PerfLearner>,
    /// Reused per-scheduler view buffers for estimate-sync consensus.
    views_buf: Vec<Vec<EstimateView>>,
    /// Reused pair-consensus buffer for gossip merges.
    pair_consensus: Vec<f64>,
    /// Mean relative speed: the consensus fallback for unsampled workers.
    prior: f64,
    dispatcher: FakeJobDispatcher,
    /// The installed consensus the policy decides with.
    mu_hat: Vec<f64>,
    sampler: AliasTable,
    // RNG streams.
    rng_arrival: Rng,
    rng_policy: Rng,
    rng_shock: Rng,
    rng_dispatch: Rng,
    /// When/with whom estimate-sync consensus runs (`Event::EstimateSync`
    /// is this policy's check epoch). Owns the merge counters for every
    /// policy-driven merge.
    sync: SyncPolicy,
    /// Consensus installs fused into the publish event (`sync_interval <=
    /// 0`) — the one merge path the policy cannot see; added to
    /// [`SyncPolicy::merges`] for [`SimResult::sync_merges`].
    fused_merges: u64,
    // Job bookkeeping.
    /// Reusable arrival buffer (filled by `Workload::next_job_into`).
    job_buf: JobSpec,
    jobs: HashMap<u64, JobState>,
    /// Single-task jobs in flight (tracked by a counter instead of a map
    /// entry — the dominant case in the §4 model and serving workloads).
    singles_in_flight: usize,
    unlaunched: HashMap<u64, VecDeque<Task>>,
    next_job: u64,
    next_task: u64,
    // Metrics.
    responses: ResponseRecorder,
    queues: Option<QueueStats>,
    estimate_error: Vec<(f64, f64)>,
    timeline: Vec<TimelinePoint>,
    /// Per-completion stage decomposition (queue-wait µs, service µs),
    /// recorded only when the timeline is on — the DES counterpart of the
    /// live tracer's stage histograms.
    stage_hists: Option<(crate::obs::Log2Histogram, crate::obs::Log2Histogram)>,
    /// Minimum guaranteed total service throughput μ̄ (tasks/sec).
    pub mu_bar_tasks: f64,
}

impl Simulation {
    /// Build a simulation from a config.
    pub fn new(cfg: SimConfig) -> Self {
        let mut seed_rng = Rng::new(cfg.seed);
        let mut rng_profile = seed_rng.fork();
        let speeds = cfg.speeds.speeds(&mut rng_profile);
        let n = speeds.len();
        assert!(n > 0, "cluster must have workers");
        let workers: Vec<Worker> = speeds.iter().map(|&s| Worker::new(s)).collect();
        let total_speed: f64 = speeds.iter().sum();
        let workload = cfg.workload.build(cfg.load, total_speed, n);
        let mean_demand = workload.mean_demand();
        let mu_bar_tasks = total_speed / mean_demand;
        let prior = total_speed / n as f64;
        let k = cfg.learner.schedulers.max(1);
        // Each learner samples ~1/k of the completion stream, so it runs
        // with the k-aware window (⌈L/k⌉ within the full-L horizon).
        let learners: Vec<PerfLearner> = (0..k)
            .map(|_| {
                PerfLearner::new(n, cfg.learner.window_c, mean_demand, mu_bar_tasks, prior, 0.0)
                    .shared_among(k)
            })
            .collect();
        // One aggregate dispatch stream: k distributed dispatchers at the
        // throttled rate c0(μ̄ − λ̂)/k superpose to exactly this process, so
        // the event stream is bit-identical for every k.
        let dispatcher = FakeJobDispatcher::new(
            cfg.learner.c0,
            mu_bar_tasks,
            cfg.learner.enabled && cfg.learner.fake_jobs,
        );
        let mu_hat: Vec<f64> =
            if cfg.learner.oracle { speeds.clone() } else { vec![prior; n] };
        let sampler = AliasTable::new(&mu_hat);
        let mut policy = cfg.policy.build(n);
        // Policies receive λ̂ in *service-rate units* (tasks/s × mean
        // demand), the same units as μ̂, so rate-aware policies (Halo) can
        // compare them directly.
        policy.on_estimates(&mu_hat, workload.lambda_tasks() * mean_demand);
        Self {
            now: 0.0,
            events: EventQueue::with_workers(n),
            qlen: vec![0; n],
            workers,
            speeds,
            policy,
            arrival_ests: (0..k)
                .map(|_| ArrivalEstimator::new(cfg.learner.arrival_window))
                .collect(),
            arrival_rr: 0,
            lambda_global: 0.0,
            lambda_exchanged: false,
            lambda_shares: (0..k).map(|_| LambdaShares::new(k)).collect(),
            learners,
            views_buf: (0..k).map(|_| Vec::with_capacity(n)).collect(),
            pair_consensus: vec![0.0; n],
            prior,
            dispatcher,
            mu_hat,
            sampler,
            rng_arrival: seed_rng.fork(),
            rng_policy: seed_rng.fork(),
            rng_shock: seed_rng.fork(),
            rng_dispatch: seed_rng.fork(),
            // Drawn *after* the four original forks, so adding the sync
            // stream perturbs none of the pre-policy RNG schedules.
            sync: SyncPolicy::new(
                &cfg.learner.sync,
                cfg.learner.sync_interval,
                k,
                seed_rng.next_u64(),
            ),
            fused_merges: 0,
            job_buf: JobSpec::default(),
            jobs: HashMap::new(),
            singles_in_flight: 0,
            unlaunched: HashMap::new(),
            next_job: 0,
            next_task: 0,
            responses: ResponseRecorder::new(cfg.warmup),
            queues: cfg.queue_sample.map(|_| QueueStats::new(n)),
            estimate_error: Vec::new(),
            timeline: Vec::new(),
            stage_hists: cfg
                .timeline
                .map(|_| (crate::obs::Log2Histogram::new(), crate::obs::Log2Histogram::new())),
            mu_bar_tasks,
            workload,
            cfg,
        }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.workers.len()
    }

    /// Current true speeds (tests/diagnostics).
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// Current published estimates.
    pub fn mu_hat(&self) -> &[f64] {
        &self.mu_hat
    }

    /// Run to completion and return the measurements.
    pub fn run(mut self) -> SimResult {
        // Seed the event streams.
        let first_gap = self.workload.next_gap(&mut self.rng_arrival);
        self.events.push(first_gap, Event::JobArrival);
        if let Some(period) = self.cfg.volatility.period() {
            self.events.push(period, Event::SpeedShock);
        }
        if self.dispatcher.enabled() {
            let lam = self.lambda_learn();
            if let Some(gap) = self.dispatcher.next_gap(lam, &mut self.rng_dispatch) {
                self.events.push(gap, Event::BenchmarkDispatch);
            }
        }
        if self.cfg.learner.enabled && !self.cfg.learner.oracle {
            self.events.push(self.cfg.learner.publish_interval, Event::EstimatePublish);
            if self.cfg.learner.sync_interval > 0.0 {
                // Policy check epochs: the sync interval for periodic and
                // gossip, the resolved minimum merge spacing for adaptive.
                self.events.push(self.sync.check_interval(), Event::EstimateSync);
            }
        }
        if let Some(interval) = self.cfg.queue_sample {
            self.events.push(self.cfg.warmup.max(interval), Event::QueueSample);
        }
        if let Some(interval) = self.cfg.timeline {
            self.events.push(interval, Event::TimelineSample);
        }
        self.events.push(self.cfg.duration, Event::EndOfSimulation);

        while let Some((t, ev)) = self.events.pop() {
            self.now = t;
            match ev {
                Event::EndOfSimulation => break,
                Event::JobArrival => self.on_job_arrival(),
                Event::TaskCompletion { worker } => self.on_completion(worker),
                Event::BenchmarkDispatch => self.on_benchmark_dispatch(),
                Event::EstimatePublish => self.on_publish(),
                Event::EstimateSync => self.on_sync(),
                Event::SpeedShock => self.on_shock(),
                Event::QueueSample => self.on_queue_sample(),
                Event::TimelineSample => self.on_timeline_sample(),
            }
        }

        let utilization = {
            let total: f64 = self.workers.iter().map(|w| w.busy_time(self.cfg.duration)).sum();
            total / (self.cfg.duration * self.workers.len() as f64)
        };
        SimResult {
            policy: self.policy.name(),
            responses: self.responses,
            queues: self.queues,
            estimate_error: self.estimate_error,
            completed_real: self.workers.iter().map(|w| w.completed_real()).sum(),
            completed_bench: self.workers.iter().map(|w| w.completed_bench()).sum(),
            utilization,
            incomplete_jobs: self.jobs.len() + self.singles_in_flight,
            duration: self.cfg.duration,
            sync_epochs: self.sync.epochs(),
            sync_merges: self.sync.merges() + self.fused_merges,
            timeline: self.timeline,
        }
    }

    /// λ̂ the learning stack and the policy run on: the lone estimator's
    /// live estimate in the centralized case, the exchanged global estimate
    /// (sum of synced shares, stale up to one consensus epoch) when the
    /// arrival stream is split across `k` schedulers. Until the first
    /// exchange the live sum bootstraps it, matching the pre-refactor
    /// engine's live-aggregate λ̂.
    fn lambda_learn(&self) -> f64 {
        if self.arrival_ests.len() == 1 {
            self.arrival_ests[0].lambda_or(0.0)
        } else if self.lambda_exchanged {
            self.lambda_global
        } else {
            self.lambda_live_sum()
        }
    }

    /// Sum of every scheduler's live arrival share — what an all-to-all
    /// λ̂ exchange yields at this instant.
    fn lambda_live_sum(&self) -> f64 {
        self.arrival_ests.iter().map(|e| e.lambda_or(0.0)).sum()
    }

    /// Test-mode guard for the incremental queue mirror: `qlen[w]` must
    /// equal the full O(n) recompute the seed engine performed before every
    /// decision — equality here is what makes the incremental engine's
    /// decision stream bit-identical to the seed engine's. Compiled out in
    /// release builds.
    #[cfg(debug_assertions)]
    fn assert_qlen_mirror(&self) {
        for (w, (q, worker)) in self.qlen.iter().zip(self.workers.iter()).enumerate() {
            debug_assert_eq!(*q, worker.probe_len(), "qlen mirror diverged at worker {w}");
        }
    }

    fn on_job_arrival(&mut self) {
        // Schedule the next arrival first (keeps the stream independent of
        // scheduling decisions).
        let gap = self.workload.next_gap(&mut self.rng_arrival);
        self.events.push(self.now + gap, Event::JobArrival);

        // Refill the reusable job buffer: the steady-state arrival path
        // allocates nothing.
        let mut spec = std::mem::take(&mut self.job_buf);
        self.workload.next_job_into(&mut self.rng_arrival, &mut spec);
        // §5: each arrival is routed by exactly one scheduler, which alone
        // feeds its arrival estimator — round-robin models an even split
        // (k = 1 degenerates to the single centralized estimator).
        let owner = (self.arrival_rr % self.arrival_ests.len() as u64) as usize;
        self.arrival_rr += 1;
        self.arrival_ests[owner].on_arrival(self.now, spec.len());
        self.place_job(&spec);
        self.job_buf = spec;
    }

    fn place_job(&mut self, spec: &JobSpec) {
        // The seed engine rejected empty jobs at the source (JobSpec::new);
        // the buffered path must uphold the same invariant or a
        // `remaining: 0` job entry would leak forever.
        assert!(!spec.is_empty(), "workload produced an empty job");
        // Hot path: a fully unconstrained single-task job needs no map
        // entry — its response time is (completion − task.arrival).
        if spec.len() == 1 && spec.tasks[0].constrained_to.is_none() {
            #[cfg(debug_assertions)]
            self.assert_qlen_mirror();
            let placement = {
                let view = LocalView {
                    queue_len: &self.qlen,
                    mu_hat: &self.mu_hat,
                    sampler: &self.sampler,
                    lambda_hat: self.lambda_learn(),
                };
                self.policy.schedule_job(spec, &view, &mut self.rng_policy)
            };
            let w = match placement {
                JobPlacement::Single(w) => w,
                JobPlacement::PerTask(ws) => ws[0],
                JobPlacement::Reservations(ws) => {
                    // Late binding for a single task: reserve everywhere.
                    let task = self.make_task(SINGLE_JOB, TaskKind::Real, spec.tasks[0].demand);
                    let job_id = self.next_job;
                    self.next_job += 1;
                    // Late binding still needs the unlaunched pool; fall
                    // back to the general path for this placement.
                    self.jobs.insert(job_id, JobState { arrival: self.now, remaining: 1 });
                    let mut pool = VecDeque::with_capacity(1);
                    pool.push_back(Task { job: job_id, ..task });
                    self.unlaunched.insert(job_id, pool);
                    for &w in &ws {
                        self.workers[w].enqueue_reservation(job_id, self.now);
                        self.kick(w);
                    }
                    return;
                }
            };
            let task = self.make_task(SINGLE_JOB, TaskKind::Real, spec.tasks[0].demand);
            self.singles_in_flight += 1;
            self.workers[w].enqueue(task, self.now);
            self.kick(w);
            return;
        }
        let job_id = self.next_job;
        self.next_job += 1;
        self.jobs.insert(job_id, JobState { arrival: self.now, remaining: spec.len() });

        // Constrained tasks bypass the policy entirely (§6.1).
        for ts in spec.tasks.iter().filter(|t| t.constrained_to.is_some()) {
            let w = ts.constrained_to.unwrap();
            let task = self.make_task(job_id, TaskKind::Real, ts.demand);
            self.workers[w].enqueue(task, self.now);
            self.kick(w);
        }

        let m = spec.unconstrained();
        if m == 0 {
            return;
        }
        #[cfg(debug_assertions)]
        self.assert_qlen_mirror();
        let placement = {
            let view = LocalView {
                queue_len: &self.qlen,
                mu_hat: &self.mu_hat,
                sampler: &self.sampler,
                lambda_hat: self.lambda_learn(),
            };
            self.policy.schedule_job(spec, &view, &mut self.rng_policy)
        };
        match placement {
            JobPlacement::Single(w) => {
                // Allocation-free path for the dominant single-task case.
                debug_assert_eq!(m, 1);
                let demand = spec
                    .tasks
                    .iter()
                    .find(|t| t.constrained_to.is_none())
                    .map(|t| t.demand)
                    .expect("unconstrained task exists");
                let task = self.make_task(job_id, TaskKind::Real, demand);
                self.workers[w].enqueue(task, self.now);
                self.kick(w);
                return;
            }
            JobPlacement::PerTask(ws) => {
                assert_eq!(ws.len(), m, "policy must place every unconstrained task");
                // Pair the k-th placement with the k-th unconstrained task
                // directly — no intermediate demand vector.
                let unconstrained =
                    spec.tasks.iter().filter(|t| t.constrained_to.is_none());
                for (&w, ts) in ws.iter().zip(unconstrained) {
                    let task = self.make_task(job_id, TaskKind::Real, ts.demand);
                    self.workers[w].enqueue(task, self.now);
                    self.kick(w);
                }
            }
            JobPlacement::Reservations(ws) => {
                assert!(ws.len() >= m, "need at least one reservation per task");
                let pool: VecDeque<Task> = spec
                    .tasks
                    .iter()
                    .filter(|t| t.constrained_to.is_none())
                    .map(|t| self.make_task(job_id, TaskKind::Real, t.demand))
                    .collect();
                self.unlaunched.insert(job_id, pool);
                for &w in &ws {
                    self.workers[w].enqueue_reservation(job_id, self.now);
                    self.kick(w);
                }
            }
        }
    }

    fn make_task(&mut self, job: u64, kind: TaskKind, demand: f64) -> Task {
        let id = self.next_task;
        self.next_task += 1;
        Task { id, job, kind, demand, arrival: self.now }
    }

    /// Let `worker` pick up work if idle, resolving reservations, then
    /// re-sync the worker's O(1) queue-length mirror. Every mutation of a
    /// worker's queue state (enqueue, reservation, start, complete) is
    /// followed by a `kick`, so this is the single place the mirror is
    /// maintained — the seed engine's O(n) pre-decision sweep is gone.
    fn kick(&mut self, w: usize) {
        if self.workers[w].is_idle() {
            self.kick_idle(w);
        }
        self.qlen[w] = self.workers[w].probe_len();
    }

    fn kick_idle(&mut self, w: usize) {
        loop {
            let entry = match self.workers[w].next_entry() {
                None => return,
                Some(e) => e,
            };
            match entry {
                (crate::cluster::QueueEntry::Task(t), at) => {
                    let completion = self.workers[w].start(t, at, self.now);
                    self.events.push_completion(completion, w);
                    return;
                }
                (crate::cluster::QueueEntry::Reservation { job }, at) => {
                    // Late binding: fetch the next unlaunched task of the
                    // job, or discard the reservation if the job is dry.
                    let task = self.unlaunched.get_mut(&job).and_then(|q| q.pop_front());
                    if let Some(t) = task {
                        let completion = self.workers[w].start(t, at, self.now);
                        self.events.push_completion(completion, w);
                        return;
                    }
                    // else: reservation void; keep draining the queue.
                }
            }
        }
    }

    fn on_completion(&mut self, w: usize) {
        // Stale completions (from before a speed shock) are cancelled at
        // the source inside `EventQueue`; whatever arrives here is live.
        let (task, duration, wait) = self.workers[w].complete(self.now);
        // Stage decomposition for the telemetry timeline: queue-wait and
        // service per completion. Read-only against the decision state —
        // no RNG draw, no queue mutation — so determinism is unaffected.
        if let Some((qh, sh)) = self.stage_hists.as_ref() {
            qh.record((wait.max(0.0) * 1e6) as u64);
            sh.record((duration.max(0.0) * 1e6) as u64);
        }
        // Every completion (real or benchmark) is a service sample (§5:
        // "when a benchmark or real task completes, the node monitor
        // reports an updated estimation of worker speed"), reported to the
        // scheduler that routed the task — task id hash-splits the stream
        // across the k logical schedulers.
        if self.cfg.learner.enabled && !self.cfg.learner.oracle {
            let owner = (task.id % self.learners.len() as u64) as usize;
            self.learners[owner].on_completion(w, self.now, duration.max(1e-9), task.demand);
        }
        if task.kind == TaskKind::Real {
            if task.job == SINGLE_JOB {
                self.singles_in_flight -= 1;
                self.responses.record(task.arrival, self.now);
                self.kick(w);
                return;
            }
            if let Some(js) = self.jobs.get_mut(&task.job) {
                js.remaining -= 1;
                if js.remaining == 0 {
                    let arrival = js.arrival;
                    self.jobs.remove(&task.job);
                    self.unlaunched.remove(&task.job);
                    self.responses.record(arrival, self.now);
                }
            }
        }
        self.kick(w);
    }

    fn on_benchmark_dispatch(&mut self) {
        let lam = self.lambda_learn();
        if let Some(gap) = self.dispatcher.next_gap(lam, &mut self.rng_dispatch) {
            self.events.push(self.now + gap, Event::BenchmarkDispatch);
        }
        let w = self.dispatcher.pick_worker(self.workers.len(), &mut self.rng_dispatch);
        let demand = self.workload.benchmark_demand(&mut self.rng_dispatch);
        // Throttle: never queue more than a handful of benchmarks at one
        // worker (§5 "setting priorities ... and implementing throttling").
        if self.workers[w].bench_backlog() >= 4 {
            return;
        }
        let task = self.make_task(u64::MAX, TaskKind::Benchmark, demand);
        self.workers[w].enqueue(task, self.now);
        self.kick(w);
    }

    fn on_publish(&mut self) {
        self.events.push(self.now + self.cfg.learner.publish_interval, Event::EstimatePublish);
        let lam = self.lambda_learn();
        // Every scheduler re-derives its local estimates from its own
        // samples (all share the synchronized global λ̂).
        let mut params = None;
        for l in &mut self.learners {
            params = Some(l.publish(self.now, lam));
        }
        let params = params.expect("at least one scheduler");
        if self.cfg.learner.sync_interval <= 0.0 {
            // Tight coupling: consensus at every publish (a merge the sync
            // policy never sees — counted here).
            self.install_consensus();
            self.fused_merges += 1;
        }
        // Ground-truth error trace of what the policy actually decides
        // with — the installed consensus, which under a decoupled sync
        // cadence is stale by up to the policy's merge spacing (the effect
        // the multisched experiment measures).
        let err = relative_error_of(&self.mu_hat, &self.speeds, params.mu_star);
        self.estimate_error.push((self.now, err));
    }

    /// Decoupled sync-policy check epoch (`sync_interval > 0`): ask the
    /// policy what to exchange — everything (periodic, or adaptive past its
    /// trigger/deadline), nothing, or deterministic scheduler pairs.
    fn on_sync(&mut self) {
        self.events.push(self.now + self.sync.check_interval(), Event::EstimateSync);
        let diverged = self.sync.kind() == SyncKind::Adaptive
            && self.max_divergence() > self.sync.threshold();
        match self.sync.on_epoch(self.now, diverged) {
            SyncDecision::Skip => {}
            SyncDecision::MergeAll => self.install_consensus(),
            SyncDecision::MergePairs(pairs) => self.gossip_step(&pairs),
        }
    }

    /// Worst drift of any scheduler's local estimates off the last adopted
    /// consensus — the adaptive policy's merge trigger.
    fn max_divergence(&self) -> f64 {
        self.learners.iter().map(|l| l.divergence_from(&self.mu_hat)).fold(0.0, f64::max)
    }

    /// §5 all-to-all consensus: merge the per-scheduler views, adopt the
    /// result into every learner, refresh λ̂_global from everyone's
    /// exchanged share, and install it all as what the policy sees.
    fn install_consensus(&mut self) {
        let k = self.learners.len();
        if k == 1 {
            // Trivial partition: the lone view *is* the consensus. Copy it
            // directly — the weighted merge computes (μ·s)/s, which can
            // differ from μ by one ulp, and the default engine must stay
            // bit-identical to the pre-distributed shared-learner path.
            // No adopt either: there is nothing foreign to inherit, and the
            // centralized learner's cold-start fallback stays the prior.
            self.mu_hat.copy_from_slice(self.learners[0].mu_hat());
        } else {
            for (l, buf) in self.learners.iter().zip(self.views_buf.iter_mut()) {
                l.export_views_into(buf);
            }
            merge_estimates_into(&self.views_buf, self.prior, &mut self.mu_hat);
            for l in &mut self.learners {
                l.adopt(&self.mu_hat);
            }
            // All-to-all λ̂ exchange: every scheduler now knows every live
            // share, so λ̂_global is simply their sum. (The per-scheduler
            // `lambda_shares` tables are gossip state — a gossip policy
            // never takes this MergeAll path with k > 1, so they need no
            // refresh here.)
            self.lambda_global = self.lambda_live_sum();
            self.lambda_exchanged = true;
        }
        let lam = self.lambda_learn();
        self.sampler.rebuild(&self.mu_hat);
        self.policy.on_estimates(&self.mu_hat, lam * self.workload.mean_demand());
    }

    /// One gossip round: each pair merges its two views (both adopt the
    /// pair consensus) and exchanges λ̂ shares (fresher entry wins). The
    /// decision stream then runs on one scheduler's view, rotating with the
    /// round counter, so every scheduler's staleness is sampled equally.
    fn gossip_step(&mut self, pairs: &[(usize, usize)]) {
        for &(a, b) in pairs {
            self.learners[a].export_views_into(&mut self.views_buf[0]);
            self.learners[b].export_views_into(&mut self.views_buf[1]);
            merge_estimates_into(&self.views_buf[..2], self.prior, &mut self.pair_consensus);
            self.learners[a].adopt(&self.pair_consensus);
            self.learners[b].adopt(&self.pair_consensus);
            let la = self.arrival_ests[a].lambda_or(0.0);
            let lb = self.arrival_ests[b].lambda_or(0.0);
            self.lambda_shares[a].learn(a, la, self.now);
            self.lambda_shares[b].learn(b, lb, self.now);
            let (sa, sb) = pair_mut(&mut self.lambda_shares, a, b);
            LambdaShares::exchange(sa, sb);
        }
        let k = self.learners.len() as u64;
        let s = (self.sync.round() % k) as usize;
        self.mu_hat.copy_from_slice(self.learners[s].mu_hat());
        // Early rounds know only a few shares: extrapolate over coverage
        // rather than installing a badly incomplete partial sum, and keep
        // the live bootstrap for a scheduler that has heard nothing (it
        // sat out every round so far).
        match self.lambda_shares[s].extrapolated_total() {
            Some(lambda) => {
                self.lambda_global = lambda;
                self.lambda_exchanged = true;
            }
            None => self.lambda_global = self.lambda_live_sum(),
        }
        self.sampler.rebuild(&self.mu_hat);
        self.policy.on_estimates(&self.mu_hat, self.lambda_global * self.workload.mean_demand());
    }

    fn on_shock(&mut self) {
        if let Some(period) = self.cfg.volatility.period() {
            self.events.push(self.now + period, Event::SpeedShock);
        }
        if !self.cfg.volatility.shock(&mut self.speeds, &mut self.rng_shock) {
            return;
        }
        // Re-base in-flight tasks under the new speeds. Iterate by index —
        // the seed engine cloned the whole speed vector per shock — and let
        // the event queue cancel each worker's superseded completion at
        // the source.
        for w in 0..self.workers.len() {
            let s = self.speeds[w];
            if let Some(new_completion) = self.workers[w].set_speed(s, self.now) {
                self.events.push_completion(new_completion, w);
            }
        }
        if self.cfg.learner.oracle {
            // Oracle scheduler instantly knows the new speeds.
            self.mu_hat.copy_from_slice(&self.speeds);
            self.sampler.rebuild(&self.mu_hat);
            self.policy
                .on_estimates(&self.mu_hat, self.workload.lambda_tasks() * self.workload.mean_demand());
        }
    }

    fn on_queue_sample(&mut self) {
        if let Some(interval) = self.cfg.queue_sample {
            self.events.push(self.now + interval, Event::QueueSample);
        }
        // The mirror is maintained incrementally; nothing to recompute.
        #[cfg(debug_assertions)]
        self.assert_qlen_mirror();
        if let Some(q) = self.queues.as_mut() {
            q.record(&self.qlen);
        }
    }

    /// One telemetry timeline sample: read-only against engine state (no
    /// RNG draws, no queue mutation), so the decision stream is identical
    /// with the timeline on or off.
    fn on_timeline_sample(&mut self) {
        if let Some(interval) = self.cfg.timeline {
            self.events.push(self.now + interval, Event::TimelineSample);
        }
        // Cross-worker queue distribution at this instant, through the
        // same log2 bucket geometry the live registry exposes on /metrics.
        let hist = crate::obs::Log2Histogram::new();
        for &q in &self.qlen {
            hist.record(q as u64);
        }
        let (queue_wait_us_p50, queue_wait_us_p99, service_us_p50, service_us_p99) =
            match self.stage_hists.as_ref() {
                Some((qh, sh)) => {
                    let (q, s) = (qh.snapshot(), sh.snapshot());
                    (q.quantile(0.5), q.quantile(0.99), s.quantile(0.5), s.quantile(0.99))
                }
                None => (0, 0, 0, 0),
            };
        self.timeline.push(TimelinePoint {
            t: self.now,
            lambda_hat: self.lambda_learn(),
            mu_hat: self.mu_hat.clone(),
            speeds: self.speeds.clone(),
            queue_p99: hist.snapshot().quantile(0.99),
            backlog: self.jobs.len() + self.singles_in_flight,
            queue_wait_us_p50,
            queue_wait_us_p99,
            service_us_p50,
            service_us_p99,
        });
    }
}

/// Disjoint mutable references to two distinct slice elements.
fn pair_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    assert!(a != b, "gossip pair must be two distinct schedulers");
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Convenience: build + run in one call.
pub fn run(cfg: SimConfig) -> SimResult {
    Simulation::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::TieRule;

    fn base() -> SimConfig {
        SimConfig {
            seed: 7,
            duration: 120.0,
            warmup: 20.0,
            speeds: SpeedProfile::S1,
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load: 0.5,
            policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            learner: LearnerConfig::oracle(),
            queue_sample: Some(0.5),
            timeline: None,
        }
    }

    #[test]
    fn stable_system_completes_most_jobs() {
        let r = run(base());
        assert!(r.responses.count() > 1000, "completed {}", r.responses.count());
        assert!(r.incomplete_jobs < 50, "backlog {}", r.incomplete_jobs);
        // Load 0.5 -> utilization near 0.5.
        assert!((r.utilization - 0.5).abs() < 0.1, "util {}", r.utilization);
    }

    #[test]
    fn response_time_at_least_service_time() {
        let r = run(base());
        // Mean demand 0.1, mean speed 0.9 -> mean pure service ≈ 0.11.
        assert!(r.responses.mean() > 0.05, "mean {}", r.responses.mean());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(base());
        let b = run(base());
        assert_eq!(a.responses.count(), b.responses.count());
        assert!((a.responses.mean() - b.responses.mean()).abs() < 1e-12);
        assert_eq!(a.completed_real, b.completed_real);
    }

    #[test]
    fn different_seeds_differ() {
        let mut cfg = base();
        cfg.seed = 8;
        let a = run(base());
        let b = run(cfg);
        assert_ne!(a.completed_real, b.completed_real);
    }

    #[test]
    fn learning_mode_produces_benchmarks_and_estimates() {
        let mut cfg = base();
        cfg.learner = LearnerConfig::default();
        let r = run(cfg);
        assert!(r.completed_bench > 0, "no benchmark jobs ran");
        assert!(!r.estimate_error.is_empty());
        // After warm-up the estimates should be decent.
        let final_err = r.estimate_error.last().unwrap().1;
        assert!(final_err < 0.25, "final estimate error {final_err}");
    }

    #[test]
    fn multi_scheduler_learning_completes_and_converges() {
        // Four logical schedulers, consensus at every publish: the split
        // completion stream still has to order the cluster correctly.
        let mut cfg = base();
        cfg.learner = LearnerConfig { schedulers: 4, ..LearnerConfig::default() };
        let r = run(cfg);
        assert!(r.responses.count() > 1000, "completed {}", r.responses.count());
        assert!(r.completed_bench > 0, "no benchmark jobs ran");
        let final_err = r.estimate_error.last().unwrap().1;
        assert!(final_err < 0.3, "consensus estimate error {final_err}");
    }

    #[test]
    fn multi_scheduler_runs_are_bit_reproducible() {
        let mut cfg = base();
        cfg.learner =
            LearnerConfig { schedulers: 3, sync_interval: 0.7, ..LearnerConfig::default() };
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(a.completed_real, b.completed_real);
        assert_eq!(a.completed_bench, b.completed_bench);
        assert_eq!(a.responses.mean().to_bits(), b.responses.mean().to_bits());
    }

    #[test]
    fn stale_sync_interval_still_keeps_the_system_stable() {
        // Consensus only every 2 s of sim time: the policy runs on stale
        // estimates between epochs but the system must not degenerate.
        let mut cfg = base();
        cfg.learner =
            LearnerConfig { schedulers: 4, sync_interval: 2.0, ..LearnerConfig::default() };
        let r = run(cfg);
        assert!(r.responses.count() > 1000, "completed {}", r.responses.count());
        assert!(r.incomplete_jobs < 100, "backlog {}", r.incomplete_jobs);
    }

    #[test]
    fn periodic_policy_merges_at_every_check_epoch() {
        let mut cfg = base();
        cfg.learner =
            LearnerConfig { schedulers: 4, sync_interval: 1.0, ..LearnerConfig::default() };
        let r = run(cfg);
        assert!(r.sync_epochs > 50, "epochs {}", r.sync_epochs);
        // Fixed-timer all-to-all: every check epoch is a merge.
        assert_eq!(r.sync_merges, r.sync_epochs);
    }

    #[test]
    fn adaptive_policy_completes_with_fewer_merges() {
        use crate::learner::SyncPolicyConfig;
        let mut cfg = base();
        cfg.learner = LearnerConfig {
            schedulers: 4,
            sync_interval: 1.0,
            sync: SyncPolicyConfig::adaptive(0.1),
            ..LearnerConfig::default()
        };
        let r = run(cfg.clone());
        assert!(r.responses.count() > 1000, "completed {}", r.responses.count());
        assert!(r.sync_merges < r.sync_epochs, "adaptive never skipped a merge");
        // The staleness deadline (10 × interval by default) still forces
        // periodic consolidation on a static cluster.
        assert!(r.sync_merges >= 1, "deadline never forced a merge");
        // Deterministic like every other mode.
        let b = run(cfg);
        assert_eq!(r.completed_real, b.completed_real);
        assert_eq!(r.sync_merges, b.sync_merges);
    }

    #[test]
    fn gossip_policy_runs_pairwise_and_reproduces_bitwise() {
        use crate::learner::SyncPolicyConfig;
        let mut cfg = base();
        cfg.learner = LearnerConfig {
            schedulers: 4,
            sync_interval: 0.5,
            sync: SyncPolicyConfig::gossip(),
            ..LearnerConfig::default()
        };
        let a = run(cfg.clone());
        assert!(a.responses.count() > 1000, "completed {}", a.responses.count());
        // 4 schedulers: every round merges exactly 2 disjoint pairs.
        assert_eq!(a.sync_merges, 2 * a.sync_epochs, "pairing shape broke");
        assert!(a.estimate_error.last().unwrap().1 < 0.5, "gossip consensus diverged");
        // Pairings come from a dedicated seed-forked stream: bit-stable.
        let b = run(cfg);
        assert_eq!(a.completed_real, b.completed_real);
        assert_eq!(a.completed_bench, b.completed_bench);
        assert_eq!(a.responses.mean().to_bits(), b.responses.mean().to_bits());
    }

    #[test]
    fn split_learning_stays_close_to_the_shared_learner() {
        // §5 convergence claim: with consensus at every publish, k
        // schedulers' merged view steers response times close to the
        // centralized single-learner baseline.
        let shared = run(SimConfig { learner: LearnerConfig::default(), ..base() });
        let mut cfg = base();
        cfg.learner = LearnerConfig { schedulers: 4, ..LearnerConfig::default() };
        let split = run(cfg);
        assert!(split.responses.count() > 1000);
        let ratio = split.responses.mean() / shared.responses.mean();
        assert!(
            (0.5..2.0).contains(&ratio),
            "split-learner mean drifted {ratio}x off the shared baseline"
        );
    }

    #[test]
    fn fake_jobs_disabled_means_no_benchmarks() {
        let mut cfg = base();
        cfg.learner = LearnerConfig::no_fake_jobs(10.0);
        let r = run(cfg);
        assert_eq!(r.completed_bench, 0);
    }

    #[test]
    fn permutation_shock_keeps_system_running() {
        let mut cfg = base();
        cfg.volatility = Volatility::Permute { period: 15.0 };
        cfg.learner = LearnerConfig::default();
        let r = run(cfg);
        assert!(r.responses.count() > 1000);
    }

    #[test]
    fn sparrow_late_binding_completes_jobs() {
        let mut cfg = base();
        cfg.policy = PolicyKind::Sparrow { probes_per_task: 2 };
        cfg.workload = WorkloadKind::Tpch { query: crate::workload::tpch::Query::Q6 };
        cfg.load = 0.5;
        let r = run(cfg);
        assert!(r.responses.count() > 200, "completed {}", r.responses.count());
        assert!(r.incomplete_jobs < 100, "backlog {}", r.incomplete_jobs);
    }

    #[test]
    fn rosella_late_binding_completes_jobs() {
        let mut cfg = base();
        cfg.policy = PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true };
        cfg.workload = WorkloadKind::Tpch { query: crate::workload::tpch::Query::Q3 };
        let r = run(cfg);
        assert!(r.responses.count() > 200);
        assert!(r.incomplete_jobs < 100, "backlog {}", r.incomplete_jobs);
    }

    #[test]
    fn rapid_shocks_neither_double_complete_nor_diverge() {
        // A shock mid-service reschedules the in-flight completion; the
        // stale event must be cancelled inside the queue. A double
        // completion would panic in `Worker::complete` (nothing in
        // service), so a clean run is itself the assertion; determinism
        // across two runs guards the cancellation order.
        let mut cfg = base();
        cfg.volatility = Volatility::Permute { period: 0.25 };
        cfg.learner = LearnerConfig::default();
        let a = run(cfg.clone());
        let b = run(cfg);
        assert!(a.responses.count() > 500, "completed {}", a.responses.count());
        assert_eq!(a.completed_real, b.completed_real);
        assert_eq!(a.completed_bench, b.completed_bench);
        assert!((a.responses.mean() - b.responses.mean()).abs() < 1e-12);
    }

    #[test]
    fn multi_task_per_task_placement_completes_jobs() {
        // Exercises the PerTask dispatch path (multi-task jobs, direct
        // placement — no late binding) end to end.
        let mut cfg = base();
        cfg.policy = PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false };
        cfg.workload = WorkloadKind::Tpch { query: crate::workload::tpch::Query::Q6 };
        cfg.load = 0.5;
        let r = run(cfg);
        assert!(r.responses.count() > 200, "completed {}", r.responses.count());
        assert!(r.incomplete_jobs < 100, "backlog {}", r.incomplete_jobs);
    }

    #[test]
    fn queue_sampling_collects_snapshots() {
        let r = run(base());
        let q = r.queues.unwrap();
        assert!(q.snapshots() > 100);
        assert!(q.mean_max() > 0.0);
    }

    #[test]
    fn timeline_sampling_collects_points_without_perturbing_the_run() {
        let base_run = run(base());
        let mut cfg = base();
        cfg.timeline = Some(1.0);
        let sampled = run(cfg);
        // Sampling is read-only: the decision stream is bit-identical.
        assert_eq!(base_run.completed_real, sampled.completed_real);
        assert_eq!(base_run.completed_bench, sampled.completed_bench);
        assert!((base_run.responses.mean() - sampled.responses.mean()).abs() < 1e-12);
        assert!(base_run.timeline.is_empty());
        // 120 sim-secs at 1 Hz -> ~120 points, each internally consistent.
        assert!(sampled.timeline.len() >= 100, "points {}", sampled.timeline.len());
        let n = sampled.timeline[0].mu_hat.len();
        let mut last_t = -1.0;
        for p in &sampled.timeline {
            assert!(p.t > last_t, "timeline must be strictly ordered");
            last_t = p.t;
            assert_eq!(p.mu_hat.len(), n);
            assert_eq!(p.speeds.len(), n);
            assert!(p.lambda_hat >= 0.0);
            // Log2 bucket upper bounds are monotone in the quantile.
            assert!(p.queue_wait_us_p99 >= p.queue_wait_us_p50);
            assert!(p.service_us_p99 >= p.service_us_p50);
        }
        // By the end of a 120 s run the stage decomposition has samples:
        // service time is never zero for a completed task.
        let last = sampled.timeline.last().unwrap();
        assert!(last.service_us_p50 > 0, "no service-stage samples: {last:?}");
        // JSON rendering round-trips through the hand-rolled parser.
        let rendered = crate::config::to_string(&timeline_json(&sampled.timeline));
        let parsed = crate::config::parse(&rendered).expect("timeline JSON parses");
        match parsed {
            crate::config::Json::Arr(items) => {
                assert_eq!(items.len(), sampled.timeline.len());
                let p0 = &items[0];
                assert!(p0.get("service_us_p50").is_some(), "stage keys missing from JSON");
                assert!(p0.get("queue_wait_us_p99").is_some());
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn overload_grows_backlog() {
        let mut cfg = base();
        cfg.load = 1.5; // deliberately unstable
        cfg.duration = 60.0;
        let r = run(cfg);
        assert!(r.incomplete_jobs > 100, "overload should leave a backlog");
    }
}
