//! Configuration system: JSON experiment configs mapped onto [`SimConfig`]
//! and the live coordinator's settings.
//!
//! A config file looks like:
//!
//! ```json
//! {
//!   "seed": 7,
//!   "duration": 300.0,
//!   "warmup": 30.0,
//!   "speeds": "s1",
//!   "volatility": "permute:60",
//!   "workload": "synthetic",
//!   "load": 0.8,
//!   "policy": "rosella",
//!   "learner": {
//!     "enabled": true, "oracle": false, "fake_jobs": true,
//!     "c0": 0.1, "window_c": 10.0,
//!     "arrival_window": 200, "publish_interval": 0.1,
//!     "schedulers": 1, "sync_interval": 0.0,
//!     "sync": {
//!       "policy": "periodic", "threshold": 0.1,
//!       "min_interval": 0.0, "max_interval": 0.0
//!     }
//!   },
//!   "queue_sample": 0.1
//! }
//! ```
//!
//! String fields reuse the CLI parsers (`SpeedProfile::parse`,
//! `Volatility::parse`, `WorkloadKind::parse`, `PolicyKind::parse`), so CLI
//! flags and config files accept identical syntax.

pub mod json;

pub use json::{parse, to_string, Json, JsonError};

use crate::cluster::{SpeedProfile, Volatility};
use crate::learner::{LearnerConfig, SyncKind, SyncPolicyConfig};
use crate::scheduler::PolicyKind;
use crate::simulator::SimConfig;
use crate::workload::WorkloadKind;

/// Config-level error.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn bad(msg: impl Into<String>) -> ConfigError {
    ConfigError(msg.into())
}

fn f64_field(v: &Json, key: &str, default: f64) -> Result<f64, ConfigError> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_f64().ok_or_else(|| bad(format!("'{key}' must be a number"))),
    }
}

fn bool_field(v: &Json, key: &str, default: bool) -> Result<bool, ConfigError> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_bool().ok_or_else(|| bad(format!("'{key}' must be a bool"))),
    }
}

/// Parse the `learner.sync` sub-object (all fields optional, defaults =
/// the bit-compatible periodic policy).
pub fn sync_policy_from_json(v: &Json) -> Result<SyncPolicyConfig, ConfigError> {
    let d = SyncPolicyConfig::default();
    Ok(SyncPolicyConfig {
        kind: match v.get("policy") {
            None => d.kind,
            Some(x) => SyncKind::parse(
                x.as_str().ok_or_else(|| bad("'sync.policy' must be a string"))?,
            )
            .map_err(bad)?,
        },
        threshold: f64_field(v, "threshold", d.threshold)?,
        min_interval: f64_field(v, "min_interval", d.min_interval)?,
        max_interval: f64_field(v, "max_interval", d.max_interval)?,
    })
}

/// Parse the learner sub-object (all fields optional, defaults from
/// [`LearnerConfig::default`]).
pub fn learner_from_json(v: &Json) -> Result<LearnerConfig, ConfigError> {
    let d = LearnerConfig::default();
    Ok(LearnerConfig {
        enabled: bool_field(v, "enabled", d.enabled)?,
        oracle: bool_field(v, "oracle", d.oracle)?,
        fake_jobs: bool_field(v, "fake_jobs", d.fake_jobs)?,
        c0: f64_field(v, "c0", d.c0)?,
        window_c: f64_field(v, "window_c", d.window_c)?,
        arrival_window: v
            .get("arrival_window")
            .map(|x| x.as_u64().ok_or_else(|| bad("'arrival_window' must be an integer")))
            .transpose()?
            .map(|x| x as usize)
            .unwrap_or(d.arrival_window),
        publish_interval: f64_field(v, "publish_interval", d.publish_interval)?,
        schedulers: v
            .get("schedulers")
            .map(|x| x.as_u64().ok_or_else(|| bad("'schedulers' must be an integer")))
            .transpose()?
            .map(|x| x as usize)
            .unwrap_or(d.schedulers),
        sync_interval: f64_field(v, "sync_interval", d.sync_interval)?,
        sync: match v.get("sync") {
            None => d.sync,
            Some(sub) => sync_policy_from_json(sub)?,
        },
    })
}

/// Build a [`SimConfig`] from a parsed JSON document.
pub fn sim_config_from_json(v: &Json) -> Result<SimConfig, ConfigError> {
    let base = SimConfig::synthetic_default();
    let speeds = match v.get("speeds") {
        None => base.speeds.clone(),
        Some(x) => SpeedProfile::parse(
            x.as_str().ok_or_else(|| bad("'speeds' must be a string"))?,
        )
        .map_err(bad)?,
    };
    let volatility = match v.get("volatility") {
        None => base.volatility.clone(),
        Some(x) => Volatility::parse(
            x.as_str().ok_or_else(|| bad("'volatility' must be a string"))?,
        )
        .map_err(bad)?,
    };
    let workload = match v.get("workload") {
        None => base.workload.clone(),
        Some(x) => WorkloadKind::parse(
            x.as_str().ok_or_else(|| bad("'workload' must be a string"))?,
        )
        .map_err(bad)?,
    };
    let policy = match v.get("policy") {
        None => base.policy.clone(),
        Some(x) => {
            PolicyKind::parse(x.as_str().ok_or_else(|| bad("'policy' must be a string"))?)
                .map_err(bad)?
        }
    };
    let learner = match v.get("learner") {
        None => base.learner.clone(),
        Some(sub) => learner_from_json(sub)?,
    };
    let cfg = SimConfig {
        seed: v
            .get("seed")
            .map(|x| x.as_u64().ok_or_else(|| bad("'seed' must be an integer")))
            .transpose()?
            .unwrap_or(base.seed),
        duration: f64_field(v, "duration", base.duration)?,
        warmup: f64_field(v, "warmup", base.warmup)?,
        speeds,
        volatility,
        workload,
        load: f64_field(v, "load", base.load)?,
        policy,
        learner,
        queue_sample: match v.get("queue_sample") {
            None | Some(Json::Null) => None,
            Some(x) => {
                Some(x.as_f64().ok_or_else(|| bad("'queue_sample' must be a number"))?)
            }
        },
        timeline: match v.get("timeline") {
            None | Some(Json::Null) => None,
            Some(x) => {
                Some(x.as_f64().ok_or_else(|| bad("'timeline' must be a number"))?)
            }
        },
    };
    validate(&cfg)?;
    Ok(cfg)
}

/// Load a [`SimConfig`] from a JSON string.
pub fn sim_config_from_str(s: &str) -> Result<SimConfig, ConfigError> {
    let v = parse(s).map_err(|e| bad(e.to_string()))?;
    sim_config_from_json(&v)
}

/// Load a [`SimConfig`] from a file path.
pub fn sim_config_from_file(path: &str) -> Result<SimConfig, ConfigError> {
    let s = std::fs::read_to_string(path).map_err(|e| bad(format!("read {path}: {e}")))?;
    sim_config_from_str(&s)
}

/// Options of the `net` JSON block configuring the cross-process plane
/// (`rosella plane --listen` / `rosella frontend --config`). All fields
/// are optional so one file can configure either side:
///
/// ```json
/// { "net": { "listen": "127.0.0.1:7411", "frontends": 2,
///            "connect": "127.0.0.1:7411", "shard": "0/2",
///            "read_timeout": 30.0,
///            "batch": 64, "flush_us": 200.0 } }
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetOptions {
    /// Pool-server listen address (`host:port`).
    pub listen: Option<String>,
    /// Remote scheduler count the pool server waits for.
    pub frontends: Option<usize>,
    /// Frontend connect address (`host:port`).
    pub connect: Option<String>,
    /// Frontend shard identity `(i, k)`.
    pub shard: Option<(usize, usize)>,
    /// Per-read socket timeout in seconds.
    pub read_timeout: Option<f64>,
    /// Submit-coalescing batch size B (tasks per wire frame).
    pub batch: Option<usize>,
    /// Submit-coalescing flush deadline D in microseconds.
    pub flush_us: Option<f64>,
    /// Thread pinning mode (`"none"`, `"cores"`, `"sockets"`): worker
    /// placement on the pool server, decision-thread placement on a
    /// frontend.
    pub pin: Option<crate::plane::PinMode>,
    /// Poll-shard count for the pool server's data plane (absent = auto:
    /// one per package, capped at 4).
    pub poll_shards: Option<usize>,
    /// Lifecycle-trace sampling modulus N (trace 1 task in N; 0 = off).
    /// Accepts `"1/64"`, `64`, or `"off"` in JSON.
    pub trace_sample: Option<u32>,
    /// Server-side Chrome trace-event JSON dump path (Perfetto-loadable).
    pub trace_json: Option<String>,
}

impl NetOptions {
    /// Overlay these options onto a pool-server configuration.
    pub fn apply_server(&self, cfg: &mut crate::net::NetServerConfig) {
        if let Some(l) = &self.listen {
            cfg.listen = l.clone();
        }
        if let Some(f) = self.frontends {
            cfg.frontends = f;
        }
        if let Some(t) = self.read_timeout {
            cfg.read_timeout = std::time::Duration::from_secs_f64(t);
        }
        if let Some(b) = self.batch {
            cfg.net_batch = b;
        }
        if let Some(us) = self.flush_us {
            cfg.net_flush_us = us;
        }
        if let Some(pin) = self.pin {
            cfg.pin = pin;
        }
        if let Some(p) = self.poll_shards {
            cfg.poll_shards = Some(p);
        }
        if let Some(n) = self.trace_sample {
            cfg.trace_sample = n;
        }
        if let Some(path) = &self.trace_json {
            cfg.trace_json = Some(path.clone());
        }
    }

    /// Overlay these options onto a frontend connection configuration.
    pub fn apply_frontend(&self, cfg: &mut crate::net::ConnectConfig) {
        if let Some(c) = &self.connect {
            cfg.addr = c.clone();
        }
        if let Some((shard, shards)) = self.shard {
            cfg.shard = shard;
            cfg.shards = shards;
        }
        if let Some(t) = self.read_timeout {
            cfg.read_timeout = std::time::Duration::from_secs_f64(t);
        }
        if let Some(b) = self.batch {
            cfg.net_batch = Some(b);
        }
        if let Some(us) = self.flush_us {
            cfg.net_flush_us = Some(us);
        }
        if let Some(pin) = self.pin {
            cfg.pin = pin;
        }
    }
}

fn net_addr(v: &Json, key: &str) -> Result<Option<String>, ConfigError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => {
            let s = x
                .as_str()
                .ok_or_else(|| bad(format!("'net.{key}' must be a string")))?;
            if s.is_empty() || !s.contains(':') {
                return Err(bad(format!(
                    "'net.{key}' must be a host:port address (got '{s}')"
                )));
            }
            Ok(Some(s.to_string()))
        }
    }
}

/// Parse and validate a `net` block. Accepts either the block itself or a
/// document containing it under the `"net"` key.
pub fn net_from_json(v: &Json) -> Result<NetOptions, ConfigError> {
    let v = v.get("net").unwrap_or(v);
    let frontends = match v.get("frontends") {
        None => None,
        Some(x) => {
            let f = x
                .as_u64()
                .ok_or_else(|| bad("'net.frontends' must be an integer"))?
                as usize;
            if f == 0 {
                return Err(bad("'net.frontends' must be at least 1"));
            }
            Some(f)
        }
    };
    let shard = match v.get("shard") {
        None => None,
        Some(x) => {
            let s = x.as_str().ok_or_else(|| bad("'net.shard' must be a string like \"0/2\""))?;
            Some(crate::net::parse_shard_spec(s).map_err(bad)?)
        }
    };
    let read_timeout = match v.get("read_timeout") {
        None => None,
        Some(x) => {
            let t = x.as_f64().ok_or_else(|| bad("'net.read_timeout' must be a number"))?;
            if !(t > 0.0 && t.is_finite()) {
                return Err(bad("'net.read_timeout' must be positive and finite"));
            }
            Some(t)
        }
    };
    let batch = match v.get("batch") {
        None => None,
        Some(x) => {
            let b = x.as_u64().ok_or_else(|| bad("'net.batch' must be an integer"))? as usize;
            if b == 0 {
                return Err(bad("'net.batch' must be at least 1"));
            }
            Some(b)
        }
    };
    let flush_us = match v.get("flush_us") {
        None => None,
        Some(x) => {
            let us = x.as_f64().ok_or_else(|| bad("'net.flush_us' must be a number"))?;
            if !(us.is_finite() && us >= 0.0) {
                return Err(bad("'net.flush_us' must be finite and non-negative"));
            }
            Some(us)
        }
    };
    let pin = match v.get("pin") {
        None => None,
        Some(x) => {
            let s = x.as_str().ok_or_else(|| bad("'net.pin' must be a string"))?;
            Some(crate::plane::PinMode::parse(s).map_err(|e| bad(format!("'net.pin': {e}")))?)
        }
    };
    let poll_shards = match v.get("poll_shards") {
        None => None,
        Some(x) => {
            let p = x.as_u64().ok_or_else(|| bad("'net.poll_shards' must be an integer"))?
                as usize;
            if p == 0 {
                return Err(bad("'net.poll_shards' must be at least 1"));
            }
            Some(p)
        }
    };
    let trace_sample = match v.get("trace_sample") {
        None => None,
        Some(x) => {
            // Accept both the CLI spelling ("1/64", "off") and a bare
            // integer modulus.
            let n = if let Some(s) = x.as_str() {
                crate::obs::trace::parse_sample(s)
                    .map_err(|e| bad(format!("'net.trace_sample': {e}")))?
            } else {
                x.as_u64().ok_or_else(|| {
                    bad("'net.trace_sample' must be a string like \"1/64\" or an integer")
                })? as u32
            };
            Some(n)
        }
    };
    let trace_json = match v.get("trace_json") {
        None => None,
        Some(x) => Some(
            x.as_str()
                .ok_or_else(|| bad("'net.trace_json' must be a string path"))?
                .to_string(),
        ),
    };
    let opts = NetOptions {
        listen: net_addr(v, "listen")?,
        frontends,
        connect: net_addr(v, "connect")?,
        shard,
        read_timeout,
        batch,
        flush_us,
        pin,
        poll_shards,
        trace_sample,
        trace_json,
    };
    if let (Some((_, k)), Some(f)) = (opts.shard, opts.frontends) {
        if k != f {
            return Err(bad(format!(
                "'net.shard' names {k} schedulers but 'net.frontends' is {f}"
            )));
        }
    }
    Ok(opts)
}

/// Load a [`NetOptions`] from a JSON string.
pub fn net_options_from_str(s: &str) -> Result<NetOptions, ConfigError> {
    let v = parse(s).map_err(|e| bad(e.to_string()))?;
    net_from_json(&v)
}

/// Load a [`NetOptions`] from a file path.
pub fn net_options_from_file(path: &str) -> Result<NetOptions, ConfigError> {
    let s = std::fs::read_to_string(path).map_err(|e| bad(format!("read {path}: {e}")))?;
    net_options_from_str(&s)
}

/// Validate cross-field constraints.
pub fn validate(cfg: &SimConfig) -> Result<(), ConfigError> {
    if !(cfg.duration > 0.0) {
        return Err(bad("duration must be positive"));
    }
    if cfg.warmup < 0.0 || cfg.warmup >= cfg.duration {
        return Err(bad("warmup must be in [0, duration)"));
    }
    if !(cfg.load > 0.0) {
        return Err(bad("load must be positive"));
    }
    if cfg.load >= 2.0 {
        return Err(bad("load >= 2.0 is certainly a mistake"));
    }
    if let Some(q) = cfg.queue_sample {
        if !(q > 0.0) {
            return Err(bad("queue_sample must be positive"));
        }
    }
    if let Some(t) = cfg.timeline {
        if !(t > 0.0) {
            return Err(bad("timeline must be positive"));
        }
    }
    if cfg.learner.enabled && cfg.learner.oracle {
        return Err(bad("learner.enabled and learner.oracle are mutually exclusive"));
    }
    if cfg.learner.schedulers == 0 {
        // Caught here rather than downstream, where a zero scheduler count
        // would mean an empty learner set (consensus panics) or a modulo
        // by zero on the completion split.
        return Err(bad("learner.schedulers must be at least 1"));
    }
    if !(cfg.learner.sync_interval >= 0.0 && cfg.learner.sync_interval.is_finite()) {
        return Err(bad("learner.sync_interval must be a finite non-negative number"));
    }
    // Sync-policy cross-field constraints: adaptive/gossip need a real
    // epoch cadence (sync_interval > 0), thresholds/bounds must be sane.
    cfg.learner
        .sync
        .validate(cfg.learner.sync_interval)
        .map_err(|e| bad(format!("learner.sync: {e}")))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_config_uses_defaults() {
        let cfg = sim_config_from_str("{}").unwrap();
        assert_eq!(cfg.seed, SimConfig::synthetic_default().seed);
        assert_eq!(cfg.load, 0.8);
    }

    #[test]
    fn full_config_round_trip() {
        let cfg = sim_config_from_str(
            r#"{
                "seed": 7, "duration": 100.0, "warmup": 10.0,
                "speeds": "s2", "volatility": "permute:60",
                "workload": "tpch:q3", "load": 0.7, "policy": "rosella",
                "learner": {"fake_jobs": false, "window_c": 30.0},
                "queue_sample": 0.5, "timeline": 2.0
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.speeds, crate::cluster::SpeedProfile::S2);
        assert_eq!(cfg.volatility, crate::cluster::Volatility::Permute { period: 60.0 });
        assert!(!cfg.learner.fake_jobs);
        assert_eq!(cfg.learner.window_c, 30.0);
        assert_eq!(cfg.queue_sample, Some(0.5));
        assert_eq!(cfg.timeline, Some(2.0));
    }

    #[test]
    fn rejects_bad_types() {
        assert!(sim_config_from_str(r#"{"seed": "x"}"#).is_err());
        assert!(sim_config_from_str(r#"{"load": true}"#).is_err());
        assert!(sim_config_from_str(r#"{"policy": "nope"}"#).is_err());
        assert!(sim_config_from_str("not json").is_err());
    }

    #[test]
    fn rejects_inconsistent_fields() {
        assert!(sim_config_from_str(r#"{"duration": -5}"#).is_err());
        assert!(sim_config_from_str(r#"{"duration": 10, "warmup": 20}"#).is_err());
        assert!(sim_config_from_str(r#"{"load": 5.0}"#).is_err());
        assert!(
            sim_config_from_str(r#"{"learner": {"enabled": true, "oracle": true}}"#).is_err()
        );
        assert!(sim_config_from_str(r#"{"learner": {"schedulers": 0}}"#).is_err());
        assert!(sim_config_from_str(r#"{"learner": {"sync_interval": -1.0}}"#).is_err());
        assert!(sim_config_from_str(r#"{"timeline": 0.0}"#).is_err());
        assert!(sim_config_from_str(r#"{"timeline": -1.0}"#).is_err());
    }

    #[test]
    fn zero_schedulers_rejected_at_validation_time() {
        // Downstream this would be an empty learner set / modulo-by-zero
        // completion split; the config layer must refuse it up front.
        let err = sim_config_from_str(r#"{"learner": {"schedulers": 0}}"#).unwrap_err();
        assert!(err.0.contains("schedulers"), "{err}");
    }

    #[test]
    fn non_periodic_sync_with_zero_interval_rejected_at_validation_time() {
        // A per-shard topology syncing adaptively (or via gossip) with
        // sync_interval <= 0 has no check cadence to ride — previously the
        // engine would have had nothing to schedule; now it is a config
        // error with both rejects covered.
        for policy in ["adaptive", "gossip"] {
            let doc = format!(
                r#"{{"learner": {{"schedulers": 4, "sync_interval": 0.0,
                     "sync": {{"policy": "{policy}"}}}}}}"#
            );
            let err = sim_config_from_str(&doc).unwrap_err();
            assert!(err.0.contains("sync"), "{policy}: {err}");
        }
        // Negative intervals stay rejected independent of the policy.
        assert!(sim_config_from_str(r#"{"learner": {"sync_interval": -1.0}}"#).is_err());
    }

    #[test]
    fn sync_policy_block_parses_and_validates() {
        let cfg = sim_config_from_str(
            r#"{"learner": {"schedulers": 4, "sync_interval": 1.5,
                 "sync": {"policy": "adaptive", "threshold": 0.2,
                          "min_interval": 0.5, "max_interval": 6.0}}}"#,
        )
        .unwrap();
        assert_eq!(cfg.learner.sync.kind, SyncKind::Adaptive);
        assert_eq!(cfg.learner.sync.threshold, 0.2);
        assert_eq!(cfg.learner.sync.min_interval, 0.5);
        assert_eq!(cfg.learner.sync.max_interval, 6.0);
        // Defaults: periodic, bit-compatible with the pre-policy engine.
        let d = sim_config_from_str("{}").unwrap();
        assert_eq!(d.learner.sync, SyncPolicyConfig::periodic());
        // Bad blocks are rejected with a config error, not a panic.
        assert!(sim_config_from_str(r#"{"learner": {"sync": {"policy": "nope"}}}"#).is_err());
        assert!(sim_config_from_str(
            r#"{"learner": {"sync_interval": 1.0, "sync": {"policy": "adaptive", "threshold": 0}}}"#
        )
        .is_err());
        assert!(sim_config_from_str(
            r#"{"learner": {"sync_interval": 1.0,
                 "sync": {"policy": "adaptive", "min_interval": 9.0, "max_interval": 2.0}}}"#
        )
        .is_err());
    }

    #[test]
    fn negative_and_zero_sync_thresholds_rejected_at_config_time() {
        // Satellite pin: a NaN cannot be written in JSON, but negative and
        // zero thresholds can — and must fail validation with a message
        // naming the constraint, instead of yielding a policy that always
        // (negative) or never merges.
        for bad in ["-0.1", "0", "-1e9"] {
            let doc = format!(
                r#"{{"learner": {{"schedulers": 4, "sync_interval": 1.0,
                     "sync": {{"policy": "adaptive", "threshold": {bad}}}}}}}"#
            );
            let err = sim_config_from_str(&doc).unwrap_err();
            assert!(err.0.contains("positive and finite"), "{bad}: {err}");
        }
        // The threshold field is checked under every policy, not just
        // adaptive: a poisoned field must not ride along silently.
        let err = sim_config_from_str(
            r#"{"learner": {"sync": {"policy": "periodic", "threshold": -0.5}}}"#,
        )
        .unwrap_err();
        assert!(err.0.contains("positive and finite"), "{err}");
    }

    #[test]
    fn net_block_parses_and_validates() {
        let opts = net_options_from_str(
            r#"{"net": {"listen": "127.0.0.1:7411", "frontends": 2,
                        "connect": "127.0.0.1:7411", "shard": "1/2",
                        "read_timeout": 10.0, "batch": 128, "flush_us": 50.0,
                        "pin": "sockets"}}"#,
        )
        .unwrap();
        assert_eq!(opts.listen.as_deref(), Some("127.0.0.1:7411"));
        assert_eq!(opts.frontends, Some(2));
        assert_eq!(opts.shard, Some((1, 2)));
        assert_eq!(opts.read_timeout, Some(10.0));
        assert_eq!(opts.batch, Some(128));
        assert_eq!(opts.flush_us, Some(50.0));
        assert_eq!(opts.pin, Some(crate::plane::PinMode::Sockets));
        // Trace sampling accepts the CLI spelling, a bare modulus, or off.
        let traced = net_options_from_str(
            r#"{"net": {"trace_sample": "1/64", "trace_json": "t.json"}}"#,
        )
        .unwrap();
        assert_eq!(traced.trace_sample, Some(64));
        assert_eq!(traced.trace_json.as_deref(), Some("t.json"));
        let n = net_options_from_str(r#"{"net": {"trace_sample": 32}}"#).unwrap();
        assert_eq!(n.trace_sample, Some(32));
        let off = net_options_from_str(r#"{"net": {"trace_sample": "off"}}"#).unwrap();
        assert_eq!(off.trace_sample, Some(0));
        // The bare block (no "net" wrapper) parses identically.
        let bare = net_options_from_str(r#"{"listen": "0.0.0.0:9000"}"#).unwrap();
        assert_eq!(bare.listen.as_deref(), Some("0.0.0.0:9000"));
        assert_eq!(bare.frontends, None);
        // An empty document is a valid, all-default block.
        assert_eq!(net_options_from_str("{}").unwrap(), NetOptions::default());
    }

    #[test]
    fn net_block_rejects_bad_fields() {
        assert!(net_options_from_str(r#"{"net": {"listen": "no-port"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"listen": ""}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"listen": 7}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"frontends": 0}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"shard": "2/2"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"shard": "0-2"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"read_timeout": 0}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"read_timeout": -5}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"batch": 0}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"batch": "many"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"flush_us": -1.0}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"flush_us": "soon"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"pin": "banana"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"pin": 3}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"poll_shards": 0}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"poll_shards": "all"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"trace_sample": "2/64"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"trace_sample": "sometimes"}}"#).is_err());
        assert!(net_options_from_str(r#"{"net": {"trace_json": 7}}"#).is_err());
        // Cross-field: the shard's k must agree with the frontend count.
        assert!(
            net_options_from_str(r#"{"net": {"frontends": 4, "shard": "0/2"}}"#).is_err()
        );
    }

    #[test]
    fn net_options_overlay_both_sides() {
        let opts = net_options_from_str(
            r#"{"net": {"listen": "127.0.0.1:7500", "frontends": 3,
                        "connect": "127.0.0.1:7500", "shard": "2/3",
                        "read_timeout": 5.0, "batch": 256, "flush_us": 75.0,
                        "pin": "cores", "poll_shards": 2,
                        "trace_sample": "1/128", "trace_json": "spans.json"}}"#,
        )
        .unwrap();
        let mut server = crate::net::NetServerConfig::default();
        opts.apply_server(&mut server);
        assert_eq!(server.listen, "127.0.0.1:7500");
        assert_eq!(server.frontends, 3);
        assert_eq!(server.read_timeout, std::time::Duration::from_secs_f64(5.0));
        assert_eq!(server.net_batch, 256);
        assert_eq!(server.net_flush_us, 75.0);
        assert_eq!(server.pin, crate::plane::PinMode::Cores);
        assert_eq!(server.poll_shards, Some(2));
        assert_eq!(server.trace_sample, 128);
        assert_eq!(server.trace_json.as_deref(), Some("spans.json"));
        let mut fe = crate::net::ConnectConfig::new("x:1", 0, 1);
        opts.apply_frontend(&mut fe);
        assert_eq!(fe.addr, "127.0.0.1:7500");
        assert_eq!((fe.shard, fe.shards), (2, 3));
        assert_eq!(fe.read_timeout, std::time::Duration::from_secs_f64(5.0));
        assert_eq!(fe.net_batch, Some(256));
        assert_eq!(fe.net_flush_us, Some(75.0));
        assert_eq!(fe.pin, crate::plane::PinMode::Cores);
    }

    #[test]
    fn scheduler_topology_fields_parse() {
        let cfg = sim_config_from_str(
            r#"{"learner": {"schedulers": 4, "sync_interval": 2.5}}"#,
        )
        .unwrap();
        assert_eq!(cfg.learner.schedulers, 4);
        assert_eq!(cfg.learner.sync_interval, 2.5);
        // Defaults: centralized, consensus at every publish.
        let d = sim_config_from_str("{}").unwrap();
        assert_eq!(d.learner.schedulers, 1);
        assert_eq!(d.learner.sync_interval, 0.0);
    }
}
