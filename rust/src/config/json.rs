//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; errors carry
//! byte offsets. Used by the experiment config loader and the metrics
//! exporters.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Fetch an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// As u64, if an integral number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    /// As str, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            None => self.err("unexpected end of input"),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or(JsonError {
                                    offset: self.pos,
                                    message: "bad \\u escape".into(),
                                })?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 sequences.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

/// Serialize a value to a compact JSON string.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Json::Str(k.clone()), out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"μ̂ → α\"").unwrap();
        assert_eq!(v.as_str(), Some("μ̂ → α"));
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset > 0);
        assert!(parse("[1, 2").is_err());
        assert!(parse("12x").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":-3}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "f": 3.5, "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_u64(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(3.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(to_string(&parse("[]").unwrap()), "[]");
    }
}
