//! Vose alias method for O(1) multinomial sampling.
//!
//! This is the hot path of Rosella's proportional sampling schedule (PSS,
//! paper §3.1): each scheduling decision samples two workers from the
//! multinomial `(p_1, ..., p_n)` with `p_i = μ̂_i / Σ μ̂`. A naive CDF walk is
//! O(n) per task; with millions of tasks per second that dominates the
//! scheduler. The alias table gives exact O(1) draws after an O(n) build.
//!
//! The table is rebuilt only when the learner publishes new estimates (a
//! rate-limited background event), never per task. To keep that publish
//! path allocation-free as well, [`AliasTable::rebuild`] reconstructs the
//! table *in place*, recycling the column arrays and the two work lists —
//! after the first build, a publish performs zero heap allocations.

use super::rng::Rng;

/// Precomputed alias table for a fixed discrete distribution.
#[derive(Debug, Clone)]
pub struct AliasTable {
    /// `prob[i]` is the probability of keeping column `i` (scaled to [0,1]).
    prob: Vec<f64>,
    /// `alias[i]` is the alternative outcome for column `i`.
    alias: Vec<u32>,
    /// Scratch: weights scaled to mean 1 (recycled across rebuilds).
    scaled: Vec<f64>,
    /// Scratch: under-full work list (recycled across rebuilds).
    small: Vec<u32>,
    /// Scratch: over-full work list (recycled across rebuilds).
    large: Vec<u32>,
}

impl AliasTable {
    /// Build an alias table from non-negative weights.
    ///
    /// Weights need not be normalized. If every weight is zero (e.g. the
    /// learner has zeroed all estimates), the table degenerates to the
    /// uniform distribution — the same fallback Rosella's scheduler uses
    /// before any estimate is learned.
    pub fn new(weights: &[f64]) -> Self {
        let mut t = Self {
            prob: Vec::new(),
            alias: Vec::new(),
            scaled: Vec::new(),
            small: Vec::new(),
            large: Vec::new(),
        };
        t.rebuild(weights);
        t
    }

    /// Rebuild the table in place from fresh weights, reusing every
    /// internal buffer. This is the estimate-publish hot path: after the
    /// first build (or whenever `weights.len()` grows) it allocates
    /// nothing.
    pub fn rebuild(&mut self, weights: &[f64]) {
        let n = weights.len();
        assert!(n > 0, "alias table over empty support");
        assert!(
            weights.iter().all(|&w| w >= 0.0 && w.is_finite()),
            "weights must be non-negative and finite: {weights:?}"
        );
        let total: f64 = weights.iter().sum();
        self.scaled.clear();
        if total <= 0.0 {
            self.scaled.resize(n, 1.0);
        } else {
            self.scaled.extend(weights.iter().map(|&w| w * n as f64 / total));
        }

        self.prob.clear();
        self.prob.resize(n, 0.0);
        self.alias.clear();
        self.alias.resize(n, 0);
        // Partition columns into under-full and over-full work lists.
        self.small.clear();
        self.large.clear();
        for (i, &v) in self.scaled.iter().enumerate() {
            if v < 1.0 {
                self.small.push(i as u32);
            } else {
                self.large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (self.small.last(), self.large.last()) {
            self.small.pop();
            self.prob[s as usize] = self.scaled[s as usize];
            self.alias[s as usize] = l;
            self.scaled[l as usize] = (self.scaled[l as usize] + self.scaled[s as usize]) - 1.0;
            if self.scaled[l as usize] < 1.0 {
                self.large.pop();
                self.small.push(l);
            }
        }
        // Leftovers are numerically == 1.
        for &i in self.small.iter().chain(self.large.iter()) {
            self.prob[i as usize] = 1.0;
            self.alias[i as usize] = i;
        }
    }

    /// Number of outcomes.
    #[inline]
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True if the table has a single outcome.
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome index in O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.gen_index(self.prob.len());
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// Draw two outcomes (with replacement) — the power-of-two-choices probe.
    #[inline]
    pub fn sample_pair(&self, rng: &mut Rng) -> (usize, usize) {
        (self.sample(rng), self.sample(rng))
    }

    /// Exact probability assigned to outcome `i` (for tests/diagnostics).
    pub fn probability(&self, i: usize) -> f64 {
        let n = self.prob.len() as f64;
        let mut p = self.prob[i] / n;
        for (j, &a) in self.alias.iter().enumerate() {
            if a as usize == i && j != i {
                p += (1.0 - self.prob[j]) / n;
            }
        }
        // Self-alias leftover contributes its own (1 - prob) mass too.
        if self.alias[i] as usize == i {
            p += (1.0 - self.prob[i]) / n;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    #[test]
    fn uniform_weights_give_uniform_probs() {
        let t = AliasTable::new(&[1.0, 1.0, 1.0, 1.0]);
        for i in 0..4 {
            assert!((t.probability(i) - 0.25).abs() < 1e-12);
        }
    }

    #[test]
    fn probabilities_match_weights() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let t = AliasTable::new(&w);
        let total: f64 = w.iter().sum();
        for i in 0..4 {
            assert!((t.probability(i) - w[i] / total).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    fn empirical_frequencies_match() {
        // The paper's running example: 9 slow workers (μ=1), 1 fast (μ=6).
        let mut w = vec![1.0; 9];
        w.push(6.0);
        let t = AliasTable::new(&w);
        let mut r = Rng::new(99);
        let n = 300_000;
        let mut counts = vec![0usize; 10];
        for _ in 0..n {
            counts[t.sample(&mut r)] += 1;
        }
        // Fast worker should get 6/15 = 0.4 of probes.
        let fast = counts[9] as f64 / n as f64;
        assert!((fast - 0.4).abs() < 0.005, "fast frac {fast}");
        for i in 0..9 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - 1.0 / 15.0).abs() < 0.005, "slow {i} frac {f}");
        }
    }

    #[test]
    fn zero_weight_outcome_never_sampled() {
        let t = AliasTable::new(&[0.0, 1.0, 0.0, 1.0]);
        let mut r = Rng::new(3);
        for _ in 0..50_000 {
            let s = t.sample(&mut r);
            assert!(s == 1 || s == 3, "sampled zero-weight outcome {s}");
        }
    }

    #[test]
    fn all_zero_weights_fall_back_to_uniform() {
        let t = AliasTable::new(&[0.0, 0.0, 0.0]);
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..90_000 {
            counts[t.sample(&mut r)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / 90_000.0 - 1.0 / 3.0).abs() < 0.01);
        }
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[5.0]);
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut r), 0);
        }
    }

    #[test]
    fn highly_skewed_distribution() {
        let t = AliasTable::new(&[1e-6, 1.0]);
        let mut r = Rng::new(6);
        let n = 200_000;
        let rare = (0..n).filter(|_| t.sample(&mut r) == 0).count();
        assert!(rare < 20, "rare outcome drawn {rare} times");
    }

    #[test]
    fn sample_pair_draws_independent() {
        let t = AliasTable::new(&[1.0, 1.0]);
        let mut r = Rng::new(8);
        let mut same = 0;
        let n = 100_000;
        for _ in 0..n {
            let (a, b) = t.sample_pair(&mut r);
            if a == b {
                same += 1;
            }
        }
        // P(same) = 0.5 for two fair outcomes with replacement.
        assert!((same as f64 / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    #[should_panic]
    fn rejects_negative_weights() {
        AliasTable::new(&[1.0, -1.0]);
    }

    #[test]
    fn rebuild_matches_fresh_build() {
        let mut t = AliasTable::new(&[1.0; 4]);
        for weights in [
            vec![1.0, 2.0, 3.0, 4.0],
            vec![0.0, 1.0, 0.0, 1.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![5.0],
            vec![1e-6, 1.0, 1e6],
        ] {
            t.rebuild(&weights);
            let fresh = AliasTable::new(&weights);
            assert_eq!(t.len(), fresh.len());
            for i in 0..weights.len() {
                assert!(
                    (t.probability(i) - fresh.probability(i)).abs() < 1e-12,
                    "rebuild diverged from fresh build at {i} for {weights:?}"
                );
            }
        }
    }

    #[test]
    fn rebuild_is_deterministic_and_reusable() {
        // Same weights → the same draws, no matter how many rebuilds the
        // table has been through (the publish path reuses one table).
        let w = [1.0, 2.0, 3.0, 4.0, 0.5];
        let mut recycled = AliasTable::new(&[9.0; 5]);
        for _ in 0..100 {
            recycled.rebuild(&[2.0, 2.0, 2.0, 2.0, 2.0]);
            recycled.rebuild(&w);
        }
        let fresh = AliasTable::new(&w);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for _ in 0..10_000 {
            assert_eq!(recycled.sample(&mut r1), fresh.sample(&mut r2));
        }
    }

    #[test]
    fn rebuild_handles_size_changes() {
        let mut t = AliasTable::new(&[1.0, 1.0]);
        t.rebuild(&[1.0; 8]);
        assert_eq!(t.len(), 8);
        for i in 0..8 {
            assert!((t.probability(i) - 0.125).abs() < 1e-12);
        }
        t.rebuild(&[3.0]);
        assert_eq!(t.len(), 1);
        let mut r = Rng::new(1);
        assert_eq!(t.sample(&mut r), 0);
    }
}
