//! Statistical substrate: PRNG, distributions, O(1) multinomial sampling,
//! descriptive statistics, histograms, and moving averages.
//!
//! None of the usual crates (`rand`, `rand_distr`, `hdrhistogram`) are
//! available in this offline build, so everything the paper's model needs is
//! implemented and tested here from first principles.

pub mod alias;
pub mod descriptive;
pub mod dist;
pub mod ewma;
pub mod histogram;
pub mod rng;

pub use alias::AliasTable;
pub use descriptive::{linreg_slope, mean, percentile, stddev, variance, FiveNum, Summary};
pub use dist::{Exponential, Poisson, Zipf};
pub use ewma::{Ewma, SlidingMean};
pub use histogram::{IntHistogram, LogHistogram};
pub use rng::{Rng, SplitMix64};
