//! Exponentially weighted moving average + a fixed-size sliding-window mean.
//!
//! The paper's arrival estimator (§3.3) is a sliding-window mean over the
//! inter-arrival times of the last `S` jobs; the EWMA is provided as the
//! classical alternative (§7 cites stochastic approximation / EMA [42]) and
//! is used by the live coordinator's metrics.

/// Exponentially weighted moving average with smoothing factor `alpha`.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha ∈ (0, 1]`: weight of each new observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "bad ewma alpha {alpha}");
        Self { alpha, value: None }
    }

    /// Feed one observation and return the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// Current average (`None` before the first observation).
    pub fn value(&self) -> Option<f64> {
        self.value
    }

    /// Current average or the provided default.
    pub fn value_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    /// Forget all history.
    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Fixed-capacity sliding-window mean over the most recent `cap` samples,
/// with O(1) update. This is the estimator primitive behind both the
/// arrival estimator (window `S`) and the performance learner (window `L`).
#[derive(Debug, Clone)]
pub struct SlidingMean {
    buf: Vec<f64>,
    cap: usize,
    head: usize,
    len: usize,
    sum: f64,
}

impl SlidingMean {
    /// Window of the most recent `cap >= 1` samples.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Self { buf: vec![0.0; cap], cap, head: 0, len: 0, sum: 0.0 }
    }

    /// Push a sample, evicting the oldest when full.
    pub fn push(&mut self, x: f64) {
        if self.len == self.cap {
            self.sum -= self.buf[self.head];
        } else {
            self.len += 1;
        }
        self.buf[self.head] = x;
        self.sum += x;
        self.head = (self.head + 1) % self.cap;
    }

    /// Number of samples currently in the window.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no samples have been pushed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when the window holds `cap` samples.
    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// Mean of the current window (`None` when empty).
    pub fn mean(&self) -> Option<f64> {
        if self.len == 0 {
            None
        } else {
            Some(self.sum / self.len as f64)
        }
    }

    /// Change the window capacity, keeping the most recent samples.
    /// Used when the learner's dynamic window `L = c/(1−α̂)` changes.
    pub fn resize(&mut self, new_cap: usize) {
        assert!(new_cap >= 1);
        if new_cap == self.cap {
            return;
        }
        let keep = self.len.min(new_cap);
        let mut recent = Vec::with_capacity(keep);
        // Oldest-to-newest order of the kept suffix.
        for k in (0..keep).rev() {
            let idx = (self.head + self.cap - 1 - k) % self.cap;
            recent.push(self.buf[idx]);
        }
        self.buf = vec![0.0; new_cap];
        self.cap = new_cap;
        self.head = 0;
        self.len = 0;
        self.sum = 0.0;
        for x in recent {
            self.push(x);
        }
    }

    /// Drop all samples.
    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
        self.sum = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_sample_is_exact() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.update(10.0), 10.0);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.2);
        for _ in 0..200 {
            e.update(5.0);
        }
        assert!((e.value().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_step_change() {
        let mut e = Ewma::new(0.5);
        for _ in 0..50 {
            e.update(1.0);
        }
        for _ in 0..20 {
            e.update(3.0);
        }
        assert!((e.value().unwrap() - 3.0).abs() < 1e-3);
    }

    #[test]
    fn ewma_reset() {
        let mut e = Ewma::new(0.5);
        e.update(1.0);
        e.reset();
        assert!(e.value().is_none());
        assert_eq!(e.value_or(7.0), 7.0);
    }

    #[test]
    fn sliding_mean_partial_window() {
        let mut w = SlidingMean::new(4);
        assert!(w.mean().is_none());
        w.push(2.0);
        w.push(4.0);
        assert_eq!(w.mean(), Some(3.0));
        assert_eq!(w.len(), 2);
        assert!(!w.is_full());
    }

    #[test]
    fn sliding_mean_evicts_oldest() {
        let mut w = SlidingMean::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        // Window now holds [2, 3, 4].
        assert_eq!(w.mean(), Some(3.0));
        assert!(w.is_full());
    }

    #[test]
    fn sliding_mean_long_stream_no_drift() {
        let mut w = SlidingMean::new(10);
        for i in 0..100_000 {
            w.push((i % 7) as f64);
        }
        let expect: f64 = (99_990..100_000).map(|i| (i % 7) as f64).sum::<f64>() / 10.0;
        assert!((w.mean().unwrap() - expect).abs() < 1e-9);
    }

    #[test]
    fn resize_grow_keeps_samples() {
        let mut w = SlidingMean::new(2);
        w.push(1.0);
        w.push(2.0);
        w.resize(4);
        assert_eq!(w.len(), 2);
        assert_eq!(w.mean(), Some(1.5));
        w.push(3.0);
        w.push(4.0);
        assert_eq!(w.mean(), Some(2.5));
    }

    #[test]
    fn resize_shrink_keeps_most_recent() {
        let mut w = SlidingMean::new(4);
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        w.resize(2);
        assert_eq!(w.len(), 2);
        assert_eq!(w.mean(), Some(3.5)); // keeps [3, 4]
    }

    #[test]
    fn clear_empties_window() {
        let mut w = SlidingMean::new(3);
        w.push(9.0);
        w.clear();
        assert!(w.mean().is_none());
        assert_eq!(w.len(), 0);
    }
}
