//! Log-bucketed latency histogram (an HDR-histogram-lite).
//!
//! Response-time distributions in the paper span three orders of magnitude
//! (tens of ms to multiple seconds, Figure 8). Storing every sample is fine
//! for offline experiments, but the live coordinator needs bounded-memory
//! recording on the hot path; this histogram gives ~2.5% relative error with
//! a few KB of state and O(1) inserts.

/// Histogram with logarithmically spaced buckets over `(0, +inf)`.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Left edge of the first bucket (values below land in bucket 0).
    min_value: f64,
    /// Multiplicative bucket width, e.g. 1.05 for ~2.5% median error.
    growth: f64,
    /// ln(growth), cached.
    inv_ln_growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max_seen: f64,
}

impl LogHistogram {
    /// Create a histogram covering `[min_value, max_value]` with the given
    /// per-bucket growth factor.
    pub fn new(min_value: f64, max_value: f64, growth: f64) -> Self {
        assert!(min_value > 0.0 && max_value > min_value && growth > 1.0);
        let nbuckets = ((max_value / min_value).ln() / growth.ln()).ceil() as usize + 2;
        Self {
            min_value,
            growth,
            inv_ln_growth: 1.0 / growth.ln(),
            counts: vec![0; nbuckets],
            total: 0,
            sum: 0.0,
            max_seen: 0.0,
        }
    }

    /// Default latency histogram: 0.1 ms .. 1000 s, 5% buckets.
    pub fn latency() -> Self {
        Self::new(1e-4, 1e3, 1.05)
    }

    #[inline]
    fn bucket_of(&self, v: f64) -> usize {
        if v <= self.min_value {
            return 0;
        }
        let b = ((v / self.min_value).ln() * self.inv_ln_growth) as usize + 1;
        b.min(self.counts.len() - 1)
    }

    /// Representative (geometric-mean) value of a bucket.
    fn bucket_value(&self, b: usize) -> f64 {
        if b == 0 {
            return self.min_value;
        }
        self.min_value * self.growth.powf(b as f64 - 0.5)
    }

    /// Record one sample. O(1).
    #[inline]
    pub fn record(&mut self, v: f64) {
        debug_assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
        let b = self.bucket_of(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max_seen {
            self.max_seen = v;
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact running mean.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Largest recorded sample (exact).
    pub fn max(&self) -> f64 {
        self.max_seen
    }

    /// Approximate quantile `q ∈ [0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bucket_value(b);
            }
        }
        self.max_seen
    }

    /// Merge another histogram with identical geometry.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "geometry mismatch");
        assert!((self.growth - other.growth).abs() < 1e-12);
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max_seen = self.max_seen.max(other.max_seen);
    }

    /// CCDF series `(value, P[X > value])` — the curve in Figure 8.
    pub fn ccdf(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        if self.total == 0 {
            return out;
        }
        let mut above = self.total;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            above -= c;
            out.push((self.bucket_value(b), above as f64 / self.total as f64));
        }
        out
    }

    /// PDF series `(value, fraction)` over non-empty buckets.
    pub fn pdf(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        if self.total == 0 {
            return out;
        }
        for (b, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                out.push((self.bucket_value(b), c as f64 / self.total as f64));
            }
        }
        out
    }

    /// Reset all counters, keeping geometry.
    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.sum = 0.0;
        self.max_seen = 0.0;
    }
}

/// Dense histogram over small non-negative integers — queue lengths
/// (Figure 13 plots queue-length distributions per worker).
#[derive(Debug, Clone, Default)]
pub struct IntHistogram {
    counts: Vec<u64>,
    total: u64,
}

impl IntHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation of value `v`.
    pub fn record(&mut self, v: usize) {
        if v >= self.counts.len() {
            self.counts.resize(v + 1, 0);
        }
        self.counts[v] += 1;
        self.total += 1;
    }

    /// Record `v` with multiplicity `w` (used for time-weighted sampling).
    pub fn record_weighted(&mut self, v: usize, w: u64) {
        if v >= self.counts.len() {
            self.counts.resize(v + 1, 0);
        }
        self.counts[v] += w;
        self.total += w;
    }

    /// Total weight recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Normalized distribution `P[X = k]` for `k = 0..`.
    pub fn pmf(&self) -> Vec<f64> {
        if self.total == 0 {
            return Vec::new();
        }
        self.counts.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// Mean value.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.counts
            .iter()
            .enumerate()
            .map(|(v, &c)| v as f64 * c as f64)
            .sum::<f64>()
            / self.total as f64
    }

    /// Largest value with non-zero count.
    pub fn max(&self) -> usize {
        self.counts.iter().rposition(|&c| c > 0).unwrap_or(0)
    }

    /// Fraction of mass at or above `k` (tail weight).
    pub fn tail(&self, k: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let above: u64 = self.counts.iter().skip(k).sum();
        above as f64 / self.total as f64
    }

    /// Absorb another histogram's counts (cross-shard metric merges).
    pub fn merge(&mut self, other: &IntHistogram) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, &b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_known_distribution() {
        let mut h = LogHistogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1ms .. 1s uniform
        }
        let q50 = h.quantile(0.5);
        assert!((q50 - 0.5).abs() / 0.5 < 0.06, "q50={q50}");
        let q95 = h.quantile(0.95);
        assert!((q95 - 0.95).abs() / 0.95 < 0.06, "q95={q95}");
    }

    #[test]
    fn mean_is_exact() {
        let mut h = LogHistogram::latency();
        h.record(0.1);
        h.record(0.3);
        assert!((h.mean() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn max_is_exact() {
        let mut h = LogHistogram::latency();
        h.record(0.42);
        h.record(7.5);
        assert_eq!(h.max(), 7.5);
    }

    #[test]
    fn empty_histogram() {
        let h = LogHistogram::latency();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
        assert!(h.ccdf().is_empty());
    }

    #[test]
    fn out_of_range_values_clamped() {
        let mut h = LogHistogram::new(1.0, 10.0, 1.5);
        h.record(0.001); // below range -> bucket 0
        h.record(1e9); // above range -> last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= 10.0 || h.max() == 1e9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LogHistogram::latency();
        let mut b = LogHistogram::latency();
        for i in 1..=100 {
            a.record(i as f64 * 1e-3);
            b.record(i as f64 * 2e-3);
        }
        let mean_before = (a.mean() * 100.0 + b.mean() * 100.0) / 200.0;
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!((a.mean() - mean_before).abs() < 1e-12);
    }

    #[test]
    fn merged_quantiles_equal_whole_stream_quantiles() {
        // Split a known stream across two histograms, merge, and compare
        // against one histogram that saw the whole stream: identical
        // geometry means identical bucket counts, so every quantile must
        // agree exactly (bucket representative values, not approximately).
        let mut whole = LogHistogram::latency();
        let mut a = LogHistogram::latency();
        let mut b = LogHistogram::latency();
        for i in 1..=2000u32 {
            let v = 1e-3 * 1.004f64.powi(i as i32); // geometric sweep 1ms..~3s
            whole.record(v);
            if i % 3 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let qa = a.quantile(q);
            let qw = whole.quantile(q);
            assert_eq!(
                qa.to_bits(),
                qw.to_bits(),
                "q{q}: merged {qa} != whole-stream {qw}"
            );
        }
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn merging_empty_is_identity_both_ways() {
        let mut a = LogHistogram::latency();
        a.record(0.25);
        let before = (a.count(), a.quantile(0.5), a.mean(), a.max());
        a.merge(&LogHistogram::latency());
        assert_eq!((a.count(), a.quantile(0.5), a.mean(), a.max()), before);
        let mut empty = LogHistogram::latency();
        empty.merge(&a);
        assert_eq!(empty.count(), 1);
        assert_eq!(empty.quantile(0.5).to_bits(), a.quantile(0.5).to_bits());
    }

    #[test]
    fn single_bucket_stream_puts_every_quantile_in_that_bucket() {
        // All samples identical: every quantile is the one occupied
        // bucket's representative, for the direct and the merged path.
        let mut a = LogHistogram::latency();
        let mut b = LogHistogram::latency();
        for _ in 0..10 {
            a.record(0.042);
            b.record(0.042);
        }
        let q_lo = a.quantile(0.001);
        let q_hi = a.quantile(1.0);
        assert_eq!(q_lo.to_bits(), q_hi.to_bits(), "single bucket: {q_lo} vs {q_hi}");
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert_eq!(a.quantile(0.5).to_bits(), q_lo.to_bits());
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_geometry() {
        let mut a = LogHistogram::new(1e-4, 1e3, 1.05);
        let b = LogHistogram::new(1e-4, 1e3, 1.10);
        a.merge(&b);
    }

    #[test]
    fn ccdf_is_monotone_decreasing() {
        let mut h = LogHistogram::latency();
        let mut x = 0.001;
        for _ in 0..500 {
            h.record(x);
            x *= 1.01;
        }
        let c = h.ccdf();
        for w in c.windows(2) {
            assert!(w[1].1 <= w[0].1);
            assert!(w[1].0 >= w[0].0);
        }
        assert!((c[0].1 - 1.0).abs() < 0.05);
        assert!(c.last().unwrap().1 < 0.01);
    }

    #[test]
    fn clear_resets() {
        let mut h = LogHistogram::latency();
        h.record(1.0);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn int_histogram_pmf() {
        let mut h = IntHistogram::new();
        for v in [0, 0, 1, 2, 2, 2] {
            h.record(v);
        }
        let p = h.pmf();
        assert!((p[0] - 2.0 / 6.0).abs() < 1e-12);
        assert!((p[1] - 1.0 / 6.0).abs() < 1e-12);
        assert!((p[2] - 3.0 / 6.0).abs() < 1e-12);
        assert_eq!(h.max(), 2);
        assert!((h.mean() - 7.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn int_histogram_tail() {
        let mut h = IntHistogram::new();
        for v in 0..10 {
            h.record(v);
        }
        assert!((h.tail(5) - 0.5).abs() < 1e-12);
        assert_eq!(h.tail(0), 1.0);
        assert_eq!(h.tail(100), 0.0);
    }

    #[test]
    fn int_histogram_weighted() {
        let mut h = IntHistogram::new();
        h.record_weighted(3, 10);
        h.record_weighted(1, 30);
        assert_eq!(h.count(), 40);
        assert!((h.mean() - (30.0 + 30.0) / 40.0).abs() < 1e-12);
    }

    #[test]
    fn int_histogram_merge_handles_different_supports() {
        let mut a = IntHistogram::new();
        let mut b = IntHistogram::new();
        a.record(1);
        a.record(1);
        b.record(5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 5);
        assert!((a.mean() - 7.0 / 3.0).abs() < 1e-12);
        // Merging an empty histogram is a no-op.
        a.merge(&IntHistogram::new());
        assert_eq!(a.count(), 3);
    }
}
