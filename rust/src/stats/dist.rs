//! Probability distributions used by the paper's model and experiments.
//!
//! The paper's analytical model (§4) is an M/M system: Poisson job arrivals
//! with rate λ and exponential service demands. The synthetic evaluation
//! (§6.2) samples per-task demands from an exponential with mean 100 ms and
//! worker speeds from a Zipf law. All three are implemented here, plus a
//! Poisson *counting* sampler used by the fake-job dispatcher
//! (LEARNER-DISPATCHER draws `t ~ Poisson(c0 · (μ̄ − λ̂))` events per tick).

use super::rng::Rng;

/// Exponential distribution with rate `lambda` (mean `1/lambda`).
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    rate: f64,
}

impl Exponential {
    /// Create an exponential distribution with the given rate (events/sec).
    ///
    /// Panics if `rate` is not strictly positive and finite.
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0 && rate.is_finite(), "invalid exponential rate {rate}");
        Self { rate }
    }

    /// Exponential with the given *mean* instead of rate.
    pub fn with_mean(mean: f64) -> Self {
        Self::new(1.0 / mean)
    }

    /// The rate parameter λ.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Draw a sample by inversion: `-ln(U)/λ`.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        -rng.next_f64_open().ln() / self.rate
    }
}

/// Poisson distribution with mean `lambda`.
///
/// Uses Knuth's multiplication method for small means and a
/// normal approximation with continuity correction for large means
/// (the dispatcher only needs counts, so the approximation for
/// `lambda > 30` is more than adequate and keeps sampling O(1)).
#[derive(Debug, Clone, Copy)]
pub struct Poisson {
    lambda: f64,
}

impl Poisson {
    /// Create a Poisson distribution with the given mean. Zero is allowed
    /// (the sampler then always returns 0), negative is not.
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0 && lambda.is_finite(), "invalid poisson mean {lambda}");
        Self { lambda }
    }

    /// The mean λ.
    pub fn mean(&self) -> f64 {
        self.lambda
    }

    /// Draw a count.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.lambda == 0.0 {
            return 0;
        }
        if self.lambda < 30.0 {
            // Knuth: count uniforms until their product drops below e^-λ.
            let l = (-self.lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= rng.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation N(λ, λ), clamped at zero.
            let x = self.lambda + self.lambda.sqrt() * rng.next_gaussian() + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }
}

/// Zipf distribution over ranks `1..=n` with exponent `s`:
/// `P(k) ∝ k^-s`. Used to sample heterogeneous worker speed *profiles*
/// ("a small number of powerful servers", §6.2).
///
/// `n` is small in every experiment (tens of workers), so a precomputed
/// cumulative table with binary search is both exact and fast.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the distribution for ranks `1..=n` and exponent `s > 0`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty support");
        assert!(s > 0.0 && s.is_finite(), "invalid zipf exponent {s}");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    /// Number of ranks.
    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Probability mass of rank `k` (1-based).
    pub fn pmf(&self, k: usize) -> f64 {
        assert!((1..=self.n()).contains(&k));
        if k == 1 {
            self.cdf[0]
        } else {
            self.cdf[k - 1] - self.cdf[k - 2]
        }
    }

    /// Draw a rank in `1..=n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // First index whose cdf exceeds u.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => (i + 1).min(self.cdf.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(0xC0FFEE)
    }

    #[test]
    fn exponential_mean_and_var() {
        let mut r = rng();
        let d = Exponential::new(4.0);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut r)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.005, "mean={mean}");
        assert!((var - 0.0625).abs() < 0.005, "var={var}");
    }

    #[test]
    fn exponential_with_mean() {
        let d = Exponential::with_mean(0.1);
        assert!((d.rate() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn exponential_nonnegative() {
        let mut r = rng();
        let d = Exponential::new(0.5);
        for _ in 0..10_000 {
            assert!(d.sample(&mut r) >= 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn exponential_rejects_zero_rate() {
        Exponential::new(0.0);
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = rng();
        let d = Poisson::new(3.0);
        let n = 100_000;
        let xs: Vec<u64> = (0..n).map(|_| d.sample(&mut r)).collect();
        let mean = xs.iter().sum::<u64>() as f64 / n as f64;
        let var = xs
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 3.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn poisson_zero_mean_is_always_zero() {
        let mut r = rng();
        let d = Poisson::new(0.0);
        for _ in 0..100 {
            assert_eq!(d.sample(&mut r), 0);
        }
    }

    #[test]
    fn poisson_large_mean_normal_branch() {
        let mut r = rng();
        let d = Poisson::new(200.0);
        let n = 50_000;
        let mean = (0..n).map(|_| d.sample(&mut r)).sum::<u64>() as f64 / n as f64;
        assert!((mean - 200.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(15, 1.1);
        let total: f64 = (1..=15).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zipf_rank_one_is_most_likely() {
        let z = Zipf::new(10, 1.5);
        for k in 2..=10 {
            assert!(z.pmf(1) > z.pmf(k));
        }
    }

    #[test]
    fn zipf_empirical_matches_pmf() {
        let mut r = rng();
        let z = Zipf::new(5, 1.0);
        let n = 200_000;
        let mut counts = [0usize; 5];
        for _ in 0..n {
            counts[z.sample(&mut r) - 1] += 1;
        }
        for k in 1..=5 {
            let emp = counts[k - 1] as f64 / n as f64;
            assert!((emp - z.pmf(k)).abs() < 0.005, "k={k} emp={emp} pmf={}", z.pmf(k));
        }
    }

    #[test]
    fn zipf_samples_in_range() {
        let mut r = rng();
        let z = Zipf::new(7, 2.0);
        for _ in 0..10_000 {
            let k = z.sample(&mut r);
            assert!((1..=7).contains(&k));
        }
    }
}
