//! Deterministic pseudo-random number generation.
//!
//! The public `rand` crate is not available in this build environment, so we
//! implement the generators the paper's experiments need from first
//! principles:
//!
//! * [`SplitMix64`] — a tiny, well-distributed generator used only for
//!   seeding (recommended by the xoshiro authors).
//! * [`Rng`] — xoshiro256++, the workhorse generator. It is fast (sub-ns per
//!   u64 on modern hardware), has a 2^256 − 1 period, and passes BigCrush.
//!
//! All experiment drivers take explicit seeds so every figure in
//! EXPERIMENTS.md is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
///
/// Reference: Sebastiano Vigna, <https://prng.di.unimi.it/splitmix64.c>.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the main PRNG used across the simulator, the workload
/// generators, and the live coordinator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is the one invalid state; SplitMix64 cannot emit
        // four consecutive zeros, but guard anyway.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent child generator. Used to give each component
    /// (arrival process, service process, learner, shocks) its own stream so
    /// adding one component never perturbs another's sequence.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in the open interval `(0, 1)`; safe for `ln()`.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        loop {
            let v = self.next_f64();
            if v > 0.0 {
                return v;
            }
        }
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased multiply-shift
    /// rejection method.
    #[inline]
    pub fn gen_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "gen_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        self.gen_below(n as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    /// Order of the returned indices is random.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Standard normal via the polar (Marsaglia) method.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 0 from the reference implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_below_unbiased_small_n() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        let n = 90_000;
        for _ in 0..n {
            counts[r.gen_below(3) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 1.0 / 3.0).abs() < 0.01, "counts={counts:?}");
        }
    }

    #[test]
    fn gen_below_upper_bound() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.gen_below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_actually_moves_things() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_has_no_duplicates() {
        let mut r = Rng::new(17);
        for _ in 0..100 {
            let s = r.sample_distinct(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(23);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(3);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
