//! Descriptive statistics: percentiles, summaries, and the five-number
//! report the paper uses in Figure 9 (5th/25th/50th/75th/95th percentiles).

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Unbiased sample variance (0.0 for fewer than two samples).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile `p ∈ [0, 100]` with linear interpolation between order
/// statistics (the "linear" / type-7 method). Input need not be sorted.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (no allocation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// The five-number summary reported throughout the paper's Figure 9.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FiveNum {
    pub p5: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
}

impl FiveNum {
    /// Compute the summary; sorts a copy of the input once.
    pub fn of(xs: &[f64]) -> Self {
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            p5: percentile_sorted(&v, 5.0),
            p25: percentile_sorted(&v, 25.0),
            p50: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            p95: percentile_sorted(&v, 95.0),
        }
    }
}

/// Full summary used in experiment reports.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub five: FiveNum,
}

impl Summary {
    /// Compute all summary statistics in one pass plus one sort.
    pub fn of(xs: &[f64]) -> Self {
        let five = FiveNum::of(xs);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if xs.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        Self {
            count: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: lo,
            max: hi,
            five,
        }
    }
}

/// Simple linear-regression slope for trend/stationarity probes
/// (Fig 10a: PoT's response time *grows* with job index; PPoT's does not).
pub fn linreg_slope(ys: &[f64]) -> f64 {
    let n = ys.len();
    if n < 2 {
        return 0.0;
    }
    let nx = n as f64;
    let mean_x = (nx - 1.0) / 2.0;
    let mean_y = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in ys.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (y - mean_y);
        den += dx * dx;
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn variance_basic() {
        let v = variance(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((v - 4.571428).abs() < 1e-4);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_median_odd() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        // p50 of [1, 2, 3, 4] = 2.5 under type-7.
        assert_eq!(percentile(&[4.0, 1.0, 3.0, 2.0], 50.0), 2.5);
    }

    #[test]
    fn percentile_extremes() {
        let xs = [5.0, 1.0, 9.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn fivenum_is_monotone() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 * 17.0) % 503.0).collect();
        let f = FiveNum::of(&xs);
        assert!(f.p5 <= f.p25 && f.p25 <= f.p50 && f.p50 <= f.p75 && f.p75 <= f.p95);
    }

    #[test]
    fn summary_of_uniform_grid() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 101);
        assert_eq!(s.mean, 50.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.five.p50, 50.0);
        assert_eq!(s.five.p25, 25.0);
    }

    #[test]
    fn slope_detects_growth() {
        let grow: Vec<f64> = (0..100).map(|i| 2.0 * i as f64 + 1.0).collect();
        assert!((linreg_slope(&grow) - 2.0).abs() < 1e-9);
        let flat = vec![5.0; 100];
        assert!(linreg_slope(&flat).abs() < 1e-9);
    }

    #[test]
    fn slope_degenerate() {
        assert_eq!(linreg_slope(&[]), 0.0);
        assert_eq!(linreg_slope(&[1.0]), 0.0);
    }
}
