//! Worker (backend) state: dual priority queues, reservations, and service
//! accounting.
//!
//! Mirrors the paper's node monitor (§5): each backend keeps one queue for
//! *real* tasks and a second, strictly lower-priority queue for *benchmark*
//! tasks injected by the performance learner, so benchmark jobs "will not be
//! executed if other real jobs are waiting". Late-binding reservations
//! (Sparrow [7]) sit in the real queue as placeholders and are resolved to a
//! concrete task — or discarded — only when they reach the head.

use crate::types::{JobId, Task, TaskKind};
use std::collections::VecDeque;

/// An entry in a worker's real queue.
#[derive(Debug, Clone)]
pub enum QueueEntry {
    /// A concrete task pushed by the scheduler.
    Task(Task),
    /// A late-binding placeholder: "some task of job `job`, to be fetched
    /// when I get to it".
    Reservation { job: JobId },
}

/// The task currently being served.
#[derive(Debug, Clone)]
pub struct InService {
    pub task: Task,
    /// Time service started.
    pub start: f64,
    /// Time the task entered the worker's queue (for queueing-delay stats).
    pub enqueued_at: f64,
    /// Remaining service *demand* (unit-speed seconds) at `last_update`.
    pub remaining_demand: f64,
    /// Sim time of the last demand-accounting update (service start or the
    /// last speed shock).
    pub last_update: f64,
}

/// One backend worker.
#[derive(Debug)]
pub struct Worker {
    /// Current true speed multiplier `s > 0`; a task with demand `d` takes
    /// `d / s` seconds of service.
    speed: f64,
    /// Real-task queue (tasks + reservations), FIFO.
    real: VecDeque<(QueueEntry, f64)>,
    /// Benchmark-task queue, FIFO, strictly lower priority.
    bench: VecDeque<(Task, f64)>,
    /// Task in service, if any.
    in_service: Option<InService>,
    /// Monotonic count of in-service reschedules (speed shocks that re-based
    /// the running task). The DES event queue cancels a superseded
    /// completion at the source when the replacement is pushed; this counter
    /// remains as the worker-local record of reschedules (tests,
    /// diagnostics).
    generation: u64,
    /// Cached count of *real* entries (queued + in service if real) so the
    /// scheduler's probe is O(1).
    real_len: usize,
    /// Total busy time integrated (for utilization reports).
    busy_time: f64,
    busy_since: Option<f64>,
    /// Completion counters.
    completed_real: u64,
    completed_bench: u64,
}

impl Worker {
    /// New idle worker with the given speed.
    pub fn new(speed: f64) -> Self {
        assert!(speed > 0.0 && speed.is_finite(), "invalid worker speed {speed}");
        Self {
            speed,
            real: VecDeque::new(),
            bench: VecDeque::new(),
            in_service: None,
            generation: 0,
            real_len: 0,
            busy_time: 0.0,
            busy_since: None,
            completed_real: 0,
            completed_bench: 0,
        }
    }

    /// Current true speed.
    pub fn speed(&self) -> f64 {
        self.speed
    }

    /// Current completion-event generation.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The queue length the scheduler probes: queued real entries plus the
    /// in-service task if it is real. Benchmark tasks are invisible to the
    /// scheduling policy, matching the paper's separation of queues.
    #[inline]
    pub fn probe_len(&self) -> usize {
        self.real_len
    }

    /// Number of queued (not in-service) benchmark tasks.
    pub fn bench_backlog(&self) -> usize {
        self.bench.len()
    }

    /// Task currently in service.
    pub fn in_service(&self) -> Option<&InService> {
        self.in_service.as_ref()
    }

    /// Completed real-task count.
    pub fn completed_real(&self) -> u64 {
        self.completed_real
    }

    /// Completed benchmark-task count.
    pub fn completed_bench(&self) -> u64 {
        self.completed_bench
    }

    /// Total integrated busy time up to `now`.
    pub fn busy_time(&self, now: f64) -> f64 {
        self.busy_time + self.busy_since.map_or(0.0, |s| now - s)
    }

    /// Enqueue a concrete task (real or benchmark).
    pub fn enqueue(&mut self, task: Task, now: f64) {
        match task.kind {
            TaskKind::Real => {
                self.real.push_back((QueueEntry::Task(task), now));
                self.real_len += 1;
            }
            TaskKind::Benchmark => self.bench.push_back((task, now)),
        }
    }

    /// Enqueue a late-binding reservation for `job`.
    pub fn enqueue_reservation(&mut self, job: JobId, now: f64) {
        self.real.push_back((QueueEntry::Reservation { job }, now));
        self.real_len += 1;
    }

    /// True when the worker can start a new task (nothing in service).
    pub fn is_idle(&self) -> bool {
        self.in_service.is_none()
    }

    /// Pop the next entry to serve, respecting priorities: real entries
    /// first, then benchmark tasks. Returns `None` if both queues are empty.
    ///
    /// The caller resolves `Reservation` entries against the scheduler's
    /// unlaunched-task pool and calls `start` / re-polls as appropriate.
    pub fn next_entry(&mut self) -> Option<(QueueEntry, f64)> {
        debug_assert!(self.in_service.is_none(), "next_entry while busy");
        if let Some((entry, t)) = self.real.pop_front() {
            self.real_len -= 1;
            return Some((entry, t));
        }
        self.bench.pop_front().map(|(t, at)| (QueueEntry::Task(t), at))
    }

    /// Begin serving `task` at time `now`; returns the scheduled completion
    /// time under the current speed.
    pub fn start(&mut self, task: Task, enqueued_at: f64, now: f64) -> f64 {
        debug_assert!(self.in_service.is_none(), "start while busy");
        if task.kind == TaskKind::Real {
            self.real_len += 1; // in-service real task still counts in probes
        }
        let completion = now + task.demand / self.speed;
        self.in_service = Some(InService {
            remaining_demand: task.demand,
            task,
            start: now,
            enqueued_at,
            last_update: now,
        });
        if self.busy_since.is_none() {
            self.busy_since = Some(now);
        }
        completion
    }

    /// Complete the in-service task at `now`; returns it together with its
    /// total service duration (now − start).
    pub fn complete(&mut self, now: f64) -> (Task, f64, f64) {
        let s = self.in_service.take().expect("complete with nothing in service");
        if s.task.kind == TaskKind::Real {
            self.real_len -= 1;
            self.completed_real += 1;
        } else {
            self.completed_bench += 1;
        }
        if self.real.is_empty() && self.bench.is_empty() {
            if let Some(since) = self.busy_since.take() {
                self.busy_time += now - since;
            }
        }
        let wait = s.start - s.enqueued_at;
        (s.task, now - s.start, wait)
    }

    /// Change the worker's speed at time `now` (a shock). If a task is in
    /// service, its remaining demand is re-based and the new completion time
    /// is returned; the caller reschedules the completion event (the event
    /// queue cancels the superseded one) and the generation counter records
    /// the reschedule.
    pub fn set_speed(&mut self, new_speed: f64, now: f64) -> Option<f64> {
        assert!(new_speed > 0.0 && new_speed.is_finite());
        let old_speed = self.speed;
        self.speed = new_speed;
        if let Some(s) = self.in_service.as_mut() {
            let elapsed = now - s.last_update;
            s.remaining_demand = (s.remaining_demand - elapsed * old_speed).max(0.0);
            s.last_update = now;
            self.generation += 1;
            Some(now + s.remaining_demand / new_speed)
        } else {
            None
        }
    }

    /// Drop all queued benchmark tasks (throttling, §5: "implementing
    /// throttling ensures the benchmark jobs will not adversarially affect
    /// the system"). Returns how many were dropped.
    pub fn drop_benchmarks(&mut self) -> usize {
        let n = self.bench.len();
        self.bench.clear();
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::TaskKind;

    fn task(id: u64, kind: TaskKind, demand: f64) -> Task {
        Task { id, job: id, kind, demand, arrival: 0.0 }
    }

    #[test]
    fn probe_counts_real_only() {
        let mut w = Worker::new(1.0);
        w.enqueue(task(1, TaskKind::Real, 0.1), 0.0);
        w.enqueue(task(2, TaskKind::Benchmark, 0.1), 0.0);
        w.enqueue(task(3, TaskKind::Real, 0.1), 0.0);
        assert_eq!(w.probe_len(), 2);
        assert_eq!(w.bench_backlog(), 1);
    }

    #[test]
    fn service_time_scales_with_speed() {
        let mut w = Worker::new(2.0);
        let c = w.start(task(1, TaskKind::Real, 1.0), 0.0, 0.0);
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn real_before_benchmark() {
        let mut w = Worker::new(1.0);
        w.enqueue(task(9, TaskKind::Benchmark, 0.1), 0.0);
        w.enqueue(task(1, TaskKind::Real, 0.1), 0.0);
        match w.next_entry().unwrap().0 {
            QueueEntry::Task(t) => assert_eq!(t.id, 1),
            e => panic!("unexpected {e:?}"),
        }
        match w.next_entry().unwrap().0 {
            QueueEntry::Task(t) => assert_eq!(t.id, 9),
            e => panic!("unexpected {e:?}"),
        }
    }

    #[test]
    fn in_service_real_still_counted_in_probe() {
        let mut w = Worker::new(1.0);
        w.enqueue(task(1, TaskKind::Real, 1.0), 0.0);
        let (entry, at) = w.next_entry().unwrap();
        let t = match entry {
            QueueEntry::Task(t) => t,
            e => panic!("unexpected {e:?}"),
        };
        assert_eq!(w.probe_len(), 0);
        w.start(t, at, 0.0);
        assert_eq!(w.probe_len(), 1);
        let (done, dur, wait) = w.complete(1.0);
        assert_eq!(done.id, 1);
        assert!((dur - 1.0).abs() < 1e-12);
        assert_eq!(wait, 0.0);
        assert_eq!(w.probe_len(), 0);
        assert_eq!(w.completed_real(), 1);
    }

    #[test]
    fn speed_shock_rebases_remaining_demand() {
        let mut w = Worker::new(1.0);
        w.start(task(1, TaskKind::Real, 1.0), 0.0, 0.0);
        // At t=0.5, half the demand is done. Speed doubles: remaining 0.5
        // demand takes 0.25s -> completion at 0.75.
        let new_completion = w.set_speed(2.0, 0.5).unwrap();
        assert!((new_completion - 0.75).abs() < 1e-12);
        assert_eq!(w.generation(), 1);
    }

    #[test]
    fn speed_shock_while_idle_returns_none() {
        let mut w = Worker::new(1.0);
        assert!(w.set_speed(3.0, 1.0).is_none());
        assert_eq!(w.generation(), 0);
        assert_eq!(w.speed(), 3.0);
    }

    #[test]
    fn reservations_count_in_probe() {
        let mut w = Worker::new(1.0);
        w.enqueue_reservation(42, 0.0);
        w.enqueue_reservation(43, 0.0);
        assert_eq!(w.probe_len(), 2);
        match w.next_entry().unwrap().0 {
            QueueEntry::Reservation { job } => assert_eq!(job, 42),
            e => panic!("unexpected {e:?}"),
        }
        assert_eq!(w.probe_len(), 1);
    }

    #[test]
    fn busy_time_integration() {
        let mut w = Worker::new(1.0);
        w.start(task(1, TaskKind::Real, 1.0), 0.0, 0.0);
        w.complete(1.0);
        assert!((w.busy_time(2.0) - 1.0).abs() < 1e-12);
        w.start(task(2, TaskKind::Real, 1.0), 2.0, 2.0);
        assert!((w.busy_time(2.5) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn drop_benchmarks_clears_queue() {
        let mut w = Worker::new(1.0);
        w.enqueue(task(1, TaskKind::Benchmark, 0.1), 0.0);
        w.enqueue(task(2, TaskKind::Benchmark, 0.1), 0.0);
        assert_eq!(w.drop_benchmarks(), 2);
        assert_eq!(w.bench_backlog(), 0);
    }

    #[test]
    fn queueing_delay_reported() {
        let mut w = Worker::new(1.0);
        w.enqueue(task(1, TaskKind::Real, 0.5), 1.0);
        let (entry, at) = w.next_entry().unwrap();
        let t = match entry {
            QueueEntry::Task(t) => t,
            e => panic!("unexpected {e:?}"),
        };
        w.start(t, at, 3.0);
        let (_, dur, wait) = w.complete(3.5);
        assert!((wait - 2.0).abs() < 1e-12);
        assert!((dur - 0.5).abs() < 1e-12);
    }
}
