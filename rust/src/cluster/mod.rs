//! Cluster substrate: worker state, speed profiles, and volatility models.
//!
//! This module replaces the paper's AWS/EC2 testbed (§6.1) with a faithful,
//! controllable model: workers with dual priority queues exactly as the
//! modified Sparrow node monitor (§5), artificial speed multipliers exactly
//! as the paper's slowed-down Spark executors, and the paper's
//! random-permutation shock model.

pub mod speed;
pub mod volatility;
pub mod worker;

pub use speed::{total_speed, SpeedProfile};
pub use volatility::Volatility;
pub use worker::{InService, QueueEntry, Worker};
