//! Environment volatility models.
//!
//! The paper's evaluation perturbs worker speeds with a *random permutation
//! shock*: "we randomly permute the worker speeds every two minutes" (§6.1)
//! / "every minute" (§6.2). Permutation keeps the total throughput constant
//! so the experiments isolate the schedulers' *learning* behaviour from
//! overload behaviour. We additionally provide a multiplicative-drift model
//! (the T-instance / shared-cluster motivation of §1) for extension
//! experiments.

use crate::stats::Rng;

/// A volatility model mutates the speed vector at shock instants.
#[derive(Debug, Clone, PartialEq)]
pub enum Volatility {
    /// Speeds never change (the paper's "static environment").
    Static,
    /// Every `period` seconds, randomly permute the speed vector
    /// (the paper's model; total throughput invariant).
    Permute { period: f64 },
    /// Every `period` seconds, multiply each speed by a log-normal factor
    /// `exp(sigma · N(0,1))`, clamped to `[min_speed, max_speed]`.
    /// Changes total throughput — models volatile cloud instances.
    Drift { period: f64, sigma: f64, min_speed: f64, max_speed: f64 },
}

impl Volatility {
    /// Interval between shocks, if any.
    pub fn period(&self) -> Option<f64> {
        match self {
            Volatility::Static => None,
            Volatility::Permute { period } => Some(*period),
            Volatility::Drift { period, .. } => Some(*period),
        }
    }

    /// Apply one shock in place. Returns `true` if speeds changed.
    pub fn shock(&self, speeds: &mut [f64], rng: &mut Rng) -> bool {
        match self {
            Volatility::Static => false,
            Volatility::Permute { .. } => {
                rng.shuffle(speeds);
                true
            }
            Volatility::Drift { sigma, min_speed, max_speed, .. } => {
                for s in speeds.iter_mut() {
                    *s = (*s * (sigma * rng.next_gaussian()).exp()).clamp(*min_speed, *max_speed);
                }
                true
            }
        }
    }

    /// Parse from CLI: `static`, `permute:<seconds>`, `drift:<seconds>:<sigma>`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let lower = s.to_ascii_lowercase();
        if lower == "static" {
            return Ok(Volatility::Static);
        }
        let parts: Vec<&str> = lower.split(':').collect();
        match parts.as_slice() {
            ["permute", p] => Ok(Volatility::Permute {
                period: p.parse().map_err(|e| format!("bad period: {e}"))?,
            }),
            ["drift", p, sg] => Ok(Volatility::Drift {
                period: p.parse().map_err(|e| format!("bad period: {e}"))?,
                sigma: sg.parse().map_err(|e| format!("bad sigma: {e}"))?,
                min_speed: 0.05,
                max_speed: 8.0,
            }),
            _ => Err(format!("unknown volatility '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_never_shocks() {
        let mut r = Rng::new(1);
        let mut v = vec![1.0, 2.0, 3.0];
        let before = v.clone();
        assert!(!Volatility::Static.shock(&mut v, &mut r));
        assert_eq!(v, before);
        assert_eq!(Volatility::Static.period(), None);
    }

    #[test]
    fn permute_preserves_multiset_and_total() {
        let mut r = Rng::new(2);
        let mut v: Vec<f64> = (1..=15).map(|k| k as f64 / 10.0).collect();
        let total: f64 = v.iter().sum();
        let mut sorted_before = v.clone();
        sorted_before.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(Volatility::Permute { period: 60.0 }.shock(&mut v, &mut r));
        let mut sorted_after = v.clone();
        sorted_after.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted_before, sorted_after);
        assert!((v.iter().sum::<f64>() - total).abs() < 1e-12);
    }

    #[test]
    fn permute_actually_changes_assignment() {
        let mut r = Rng::new(3);
        let mut v: Vec<f64> = (1..=15).map(|k| k as f64).collect();
        let before = v.clone();
        Volatility::Permute { period: 60.0 }.shock(&mut v, &mut r);
        assert_ne!(v, before);
    }

    #[test]
    fn drift_respects_clamps() {
        let mut r = Rng::new(4);
        let model =
            Volatility::Drift { period: 30.0, sigma: 2.0, min_speed: 0.1, max_speed: 4.0 };
        let mut v = vec![1.0; 100];
        for _ in 0..10 {
            model.shock(&mut v, &mut r);
        }
        assert!(v.iter().all(|&s| (0.1..=4.0).contains(&s)));
    }

    #[test]
    fn parse_variants() {
        assert_eq!(Volatility::parse("static").unwrap(), Volatility::Static);
        assert_eq!(
            Volatility::parse("permute:120").unwrap(),
            Volatility::Permute { period: 120.0 }
        );
        match Volatility::parse("drift:30:0.5").unwrap() {
            Volatility::Drift { period, sigma, .. } => {
                assert_eq!(period, 30.0);
                assert_eq!(sigma, 0.5);
            }
            v => panic!("unexpected {v:?}"),
        }
        assert!(Volatility::parse("bogus").is_err());
    }
}
