//! Worker speed profiles used across the paper's experiments.
//!
//! * §6.1 (TPC-H): speeds from `{0.01, 0.04, …, 0.81}` — the squares
//!   `((k+1)/10)²` — "to mimic heterogeneous environments".
//! * §6.2 (synthetic): Zipf-sampled speeds ("a small number of powerful
//!   servers"), plus the two explicit sets
//!   `S1 = {0.2, 0.3, …, 1.6}` and
//!   `S2 = {0.15×5, 0.2, 0.3, 0.4, 0.5, 0.6, 1, 1, 1, 2, 2}`.

use crate::stats::{Rng, Zipf};

/// Named speed profiles from the paper.
#[derive(Debug, Clone, PartialEq)]
pub enum SpeedProfile {
    /// All workers identical (baseline sanity checks).
    Homogeneous { n: usize, speed: f64 },
    /// §6.2 set S1: 0.2, 0.3, …, 1.6 (15 workers).
    S1,
    /// §6.2 set S2: highly heterogeneous 15-worker set.
    S2,
    /// §6.1 TPC-H speeds `((k mod 9 + 1)/10)²` cycled over `n` workers.
    TpchSquares { n: usize },
    /// Zipf-sampled speeds: rank `r ~ Zipf(n_ranks, s)` mapped to speed
    /// `base · ratio^(r − 1)` — rank 1 (most likely) is the slowest; a few
    /// workers are much faster.
    Zipf { n: usize, exponent: f64, ranks: usize, base: f64, ratio: f64 },
    /// The running example of §2.1: nine workers of speed 1, one of 6.
    Example1,
    /// Explicit speeds.
    Explicit(Vec<f64>),
}

impl SpeedProfile {
    /// Materialize the speed vector. Random profiles consume `rng`.
    pub fn speeds(&self, rng: &mut Rng) -> Vec<f64> {
        match self {
            SpeedProfile::Homogeneous { n, speed } => vec![*speed; *n],
            SpeedProfile::S1 => (2..=16).map(|k| k as f64 / 10.0).collect(),
            SpeedProfile::S2 => vec![
                0.15, 0.15, 0.15, 0.15, 0.15, 0.2, 0.3, 0.4, 0.5, 0.6, 1.0, 1.0, 1.0, 2.0, 2.0,
            ],
            SpeedProfile::TpchSquares { n } => (0..*n)
                .map(|k| {
                    let b = (k % 9 + 1) as f64 / 10.0;
                    b * b
                })
                .collect(),
            SpeedProfile::Zipf { n, exponent, ranks, base, ratio } => {
                let z = Zipf::new(*ranks, *exponent);
                (0..*n)
                    .map(|_| {
                        let r = z.sample(rng);
                        base * ratio.powi((r - 1) as i32)
                    })
                    .collect()
            }
            SpeedProfile::Example1 => {
                let mut v = vec![1.0; 9];
                v.push(6.0);
                v
            }
            SpeedProfile::Explicit(v) => v.clone(),
        }
    }

    /// Number of workers the profile defines.
    pub fn n(&self) -> usize {
        match self {
            SpeedProfile::Homogeneous { n, .. } => *n,
            SpeedProfile::S1 | SpeedProfile::S2 => 15,
            SpeedProfile::TpchSquares { n } => *n,
            SpeedProfile::Zipf { n, .. } => *n,
            SpeedProfile::Example1 => 10,
            SpeedProfile::Explicit(v) => v.len(),
        }
    }

    /// Parse a profile from a CLI string: `s1`, `s2`, `example1`,
    /// `homogeneous:<n>:<speed>`, `tpch:<n>`, `zipf:<n>:<exp>`, or a
    /// comma-separated explicit list `0.2,0.4,1.0`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "s1" => return Ok(SpeedProfile::S1),
            "s2" => return Ok(SpeedProfile::S2),
            "example1" => return Ok(SpeedProfile::Example1),
            _ => {}
        }
        let parts: Vec<&str> = lower.split(':').collect();
        match parts.as_slice() {
            ["homogeneous", n, sp] => Ok(SpeedProfile::Homogeneous {
                n: n.parse().map_err(|e| format!("bad n: {e}"))?,
                speed: sp.parse().map_err(|e| format!("bad speed: {e}"))?,
            }),
            ["tpch", n] => Ok(SpeedProfile::TpchSquares {
                n: n.parse().map_err(|e| format!("bad n: {e}"))?,
            }),
            ["zipf", n, exp] => Ok(SpeedProfile::Zipf {
                n: n.parse().map_err(|e| format!("bad n: {e}"))?,
                exponent: exp.parse().map_err(|e| format!("bad exponent: {e}"))?,
                ranks: 5,
                base: 0.25,
                ratio: 2.0,
            }),
            _ if lower.contains(',') => {
                let v: Result<Vec<f64>, _> = lower.split(',').map(|x| x.trim().parse()).collect();
                Ok(SpeedProfile::Explicit(v.map_err(|e| format!("bad speed list: {e}"))?))
            }
            _ => Err(format!("unknown speed profile '{s}'")),
        }
    }
}

/// Total processing power `μ = Σ s_i` of a speed vector.
pub fn total_speed(speeds: &[f64]) -> f64 {
    speeds.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s1_matches_paper() {
        let mut r = Rng::new(1);
        let v = SpeedProfile::S1.speeds(&mut r);
        assert_eq!(v.len(), 15);
        assert!((v[0] - 0.2).abs() < 1e-12);
        assert!((v[14] - 1.6).abs() < 1e-12);
        assert!((total_speed(&v) - 13.5).abs() < 1e-9);
    }

    #[test]
    fn s2_matches_paper() {
        let mut r = Rng::new(1);
        let v = SpeedProfile::S2.speeds(&mut r);
        assert_eq!(v.len(), 15);
        assert_eq!(v.iter().filter(|&&s| s == 0.15).count(), 5);
        assert_eq!(v.iter().filter(|&&s| s == 2.0).count(), 2);
    }

    #[test]
    fn example1_matches_paper() {
        let mut r = Rng::new(1);
        let v = SpeedProfile::Example1.speeds(&mut r);
        assert_eq!(v, vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 6.0]);
        assert_eq!(total_speed(&v), 15.0);
    }

    #[test]
    fn tpch_squares_range() {
        let mut r = Rng::new(1);
        let v = SpeedProfile::TpchSquares { n: 30 }.speeds(&mut r);
        assert_eq!(v.len(), 30);
        assert!((v[0] - 0.01).abs() < 1e-12);
        assert!((v[8] - 0.81).abs() < 1e-12);
        assert!(v.iter().all(|&s| (0.01..=0.81).contains(&s)));
    }

    #[test]
    fn zipf_profile_has_fast_minority() {
        let mut r = Rng::new(7);
        let p = SpeedProfile::Zipf { n: 100, exponent: 1.2, ranks: 5, base: 0.25, ratio: 2.0 };
        let v = p.speeds(&mut r);
        assert_eq!(v.len(), 100);
        let fast = v.iter().filter(|&&s| s >= 2.0).count();
        let slow = v.iter().filter(|&&s| s <= 0.5).count();
        assert!(fast < slow, "fast={fast} slow={slow}");
        assert!(fast > 0);
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(SpeedProfile::parse("s1").unwrap(), SpeedProfile::S1);
        assert_eq!(SpeedProfile::parse("S2").unwrap(), SpeedProfile::S2);
        assert_eq!(
            SpeedProfile::parse("homogeneous:4:2.0").unwrap(),
            SpeedProfile::Homogeneous { n: 4, speed: 2.0 }
        );
        assert_eq!(
            SpeedProfile::parse("0.5, 1.0, 2.0").unwrap(),
            SpeedProfile::Explicit(vec![0.5, 1.0, 2.0])
        );
        assert!(SpeedProfile::parse("nope").is_err());
    }
}
