//! TPC-H-shaped workload (§6.1).
//!
//! The paper runs TPC-H queries q3 and q6 through Shark, which compiles each
//! query into Spark *stages*; each stage is one job of many tasks. We do not
//! need the SQL engine — the scheduler only observes the stage/task
//! structure — so this module generates a trace with the same shape:
//!
//! * q6 is a single-scan query: stages are wide (many short map tasks);
//! * q3 is a 3-way join: a mix of wide scan stages and narrower
//!   join/aggregate stages with more skewed task durations;
//! * a small fraction of tasks are *constrained* to a specific backend
//!   (§6.1: ~2k constrained of >30k total, i.e. ≈6%) — for these, "the
//!   PPoT scheduling policy is disabled";
//! * task demands are exponential around a per-stage mean, giving the
//!   intra-stage variability that makes late binding matter.
//!
//! The substitution is documented in DESIGN.md §2.

use super::Workload;
use crate::stats::{Exponential, Rng};
use crate::types::{JobSpec, TaskSpec};

/// Which TPC-H query shape to emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Query {
    /// Join-heavy: mixed wide/narrow stages, skewed demands.
    Q3,
    /// Scan-heavy: wide uniform stages.
    Q6,
}

/// One stage archetype: (weight, min_tasks, max_tasks, mean_demand_secs).
#[derive(Debug, Clone, Copy)]
struct StageShape {
    weight: f64,
    min_tasks: usize,
    max_tasks: usize,
    mean_demand: f64,
}

/// TPC-H-shaped stage trace generator.
#[derive(Debug, Clone)]
pub struct TpchWorkload {
    query: Query,
    shapes: Vec<StageShape>,
    cum_weights: Vec<f64>,
    gap: Exponential,
    mean_demand: f64,
    mean_tasks: f64,
    lambda_tasks: f64,
    /// Fraction of tasks pinned to a fixed backend.
    constrained_frac: f64,
    /// Number of backends (for constrained placement).
    n_workers: usize,
}

impl TpchWorkload {
    /// Build a trace calibrated to `load` on total cluster speed
    /// `total_speed`. Worker count defaults to 30 (the paper's cluster);
    /// use [`with_workers`](Self::with_workers) to override.
    pub fn new(query: Query, load: f64, total_speed: f64) -> Self {
        Self::with_workers(query, load, total_speed, 30)
    }

    /// Build with an explicit backend count for constrained placement.
    pub fn with_workers(query: Query, load: f64, total_speed: f64, n_workers: usize) -> Self {
        assert!(load > 0.0 && total_speed > 0.0 && n_workers > 0);
        let shapes: Vec<StageShape> = match query {
            // q3: scan lineitem + scan orders/customer + join/agg stages.
            Query::Q3 => vec![
                StageShape { weight: 0.35, min_tasks: 8, max_tasks: 24, mean_demand: 0.12 },
                StageShape { weight: 0.35, min_tasks: 4, max_tasks: 12, mean_demand: 0.08 },
                StageShape { weight: 0.20, min_tasks: 2, max_tasks: 8, mean_demand: 0.20 },
                StageShape { weight: 0.10, min_tasks: 1, max_tasks: 4, mean_demand: 0.05 },
            ],
            // q6: one wide scan stage shape + a tiny aggregate stage.
            Query::Q6 => vec![
                StageShape { weight: 0.80, min_tasks: 8, max_tasks: 32, mean_demand: 0.10 },
                StageShape { weight: 0.20, min_tasks: 1, max_tasks: 4, mean_demand: 0.04 },
            ],
        };
        let total_w: f64 = shapes.iter().map(|s| s.weight).sum();
        let mut cum = 0.0;
        let cum_weights: Vec<f64> = shapes
            .iter()
            .map(|s| {
                cum += s.weight / total_w;
                cum
            })
            .collect();
        // Expected tasks/stage and demand/task for calibration.
        let mean_tasks: f64 = shapes
            .iter()
            .map(|s| s.weight / total_w * (s.min_tasks + s.max_tasks) as f64 / 2.0)
            .sum();
        let mean_demand: f64 = shapes
            .iter()
            .map(|s| {
                s.weight / total_w * (s.min_tasks + s.max_tasks) as f64 / 2.0 * s.mean_demand
            })
            .sum::<f64>()
            / mean_tasks;
        let lambda_tasks = load * total_speed / mean_demand;
        let lambda_jobs = lambda_tasks / mean_tasks;
        Self {
            query,
            shapes,
            cum_weights,
            gap: Exponential::new(lambda_jobs),
            mean_demand,
            mean_tasks,
            lambda_tasks,
            constrained_frac: 2_000.0 / 32_000.0, // §6.1: 2k of >30k tasks
            n_workers,
        }
    }

    /// Mean number of tasks per stage.
    pub fn mean_tasks(&self) -> f64 {
        self.mean_tasks
    }

    fn pick_shape(&self, rng: &mut Rng) -> StageShape {
        let u = rng.next_f64();
        for (i, &c) in self.cum_weights.iter().enumerate() {
            if u <= c {
                return self.shapes[i];
            }
        }
        *self.shapes.last().unwrap()
    }
}

impl Workload for TpchWorkload {
    fn name(&self) -> String {
        match self.query {
            Query::Q3 => "tpch-q3".into(),
            Query::Q6 => "tpch-q6".into(),
        }
    }

    fn next_gap(&mut self, rng: &mut Rng) -> f64 {
        self.gap.sample(rng)
    }

    fn next_job(&mut self, rng: &mut Rng) -> JobSpec {
        let mut spec = JobSpec::default();
        self.next_job_into(rng, &mut spec);
        spec
    }

    fn next_job_into(&mut self, rng: &mut Rng, out: &mut JobSpec) {
        let shape = self.pick_shape(rng);
        let span = shape.max_tasks - shape.min_tasks;
        let m = shape.min_tasks + if span > 0 { rng.gen_index(span + 1) } else { 0 };
        let demand = Exponential::with_mean(shape.mean_demand);
        out.tasks.clear();
        for _ in 0..m {
            let d = demand.sample(rng).max(1e-6);
            out.tasks.push(if rng.gen_bool(self.constrained_frac) {
                TaskSpec::pinned(d, rng.gen_index(self.n_workers))
            } else {
                TaskSpec::new(d)
            });
        }
    }

    fn mean_demand(&self) -> f64 {
        self.mean_demand
    }

    fn benchmark_demand(&mut self, rng: &mut Rng) -> f64 {
        Exponential::with_mean(self.mean_demand).sample(rng).max(1e-6)
    }

    fn lambda_tasks(&self) -> f64 {
        self.lambda_tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_sizes_within_shapes() {
        let mut w = TpchWorkload::new(Query::Q3, 0.8, 10.0);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            let j = w.next_job(&mut rng);
            assert!((1..=24).contains(&j.len()), "q3 stage size {}", j.len());
        }
        let mut w6 = TpchWorkload::new(Query::Q6, 0.8, 10.0);
        for _ in 0..2000 {
            let j = w6.next_job(&mut rng);
            assert!((1..=32).contains(&j.len()), "q6 stage size {}", j.len());
        }
    }

    #[test]
    fn constrained_fraction_close_to_paper() {
        let mut w = TpchWorkload::new(Query::Q3, 0.8, 10.0);
        let mut rng = Rng::new(2);
        let mut total = 0usize;
        let mut constrained = 0usize;
        for _ in 0..5000 {
            let j = w.next_job(&mut rng);
            total += j.len();
            constrained += j.len() - j.unconstrained();
        }
        let frac = constrained as f64 / total as f64;
        assert!((frac - 2_000.0 / 32_000.0).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn constrained_targets_valid_workers() {
        let mut w = TpchWorkload::with_workers(Query::Q3, 0.8, 10.0, 7);
        let mut rng = Rng::new(3);
        for _ in 0..3000 {
            for t in &w.next_job(&mut rng).tasks {
                if let Some(b) = t.constrained_to {
                    assert!(b < 7);
                }
            }
        }
    }

    #[test]
    fn calibration_achieves_target_task_rate() {
        let mut w = TpchWorkload::new(Query::Q6, 0.8, 13.5);
        let mut rng = Rng::new(4);
        let jobs = 20_000;
        let mut time = 0.0;
        let mut tasks = 0usize;
        for _ in 0..jobs {
            time += w.next_gap(&mut rng);
            tasks += w.next_job(&mut rng).len();
        }
        let rate = tasks as f64 / time;
        let target = w.lambda_tasks();
        assert!((rate - target).abs() / target < 0.05, "rate={rate} target={target}");
    }

    #[test]
    fn mean_demand_is_consistent() {
        let mut w = TpchWorkload::new(Query::Q3, 0.8, 10.0);
        let mut rng = Rng::new(5);
        let mut sum = 0.0;
        let mut count = 0usize;
        for _ in 0..20_000 {
            for t in &w.next_job(&mut rng).tasks {
                sum += t.demand;
                count += 1;
            }
        }
        let emp = sum / count as f64;
        assert!((emp - w.mean_demand()).abs() / w.mean_demand() < 0.05, "emp={emp}");
    }

    #[test]
    fn q3_has_more_demand_skew_than_q6() {
        let mut rng = Rng::new(6);
        let collect = |w: &mut TpchWorkload, rng: &mut Rng| -> Vec<f64> {
            let mut v = Vec::new();
            for _ in 0..5000 {
                for t in &w.next_job(rng).tasks {
                    v.push(t.demand);
                }
            }
            v
        };
        let mut q3 = TpchWorkload::new(Query::Q3, 0.8, 10.0);
        let mut q6 = TpchWorkload::new(Query::Q6, 0.8, 10.0);
        let d3 = collect(&mut q3, &mut rng);
        let d6 = collect(&mut q6, &mut rng);
        let cv = |v: &[f64]| crate::stats::stddev(v) / crate::stats::mean(v);
        assert!(cv(&d3) > cv(&d6), "cv3={} cv6={}", cv(&d3), cv(&d6));
    }
}
