//! Workload generation: the paper's synthetic sleep-task workload (§6.2)
//! and a TPC-H-shaped multi-task trace (§6.1).
//!
//! A workload supplies (a) exponential job inter-arrival gaps calibrated to
//! a target load ratio α and (b) job specs (task counts, per-task demands,
//! placement constraints). The target arrival rate is
//! `λ_tasks = α · Σ s_i / τ̄` where `Σ s_i` is the cluster's total speed and
//! τ̄ the mean task demand; a job of `m̄` tasks on average then arrives at
//! rate `λ_tasks / m̄`.

pub mod synthetic;
pub mod tpch;

pub use synthetic::SyntheticWorkload;
pub use tpch::TpchWorkload;

use crate::stats::Rng;
use crate::types::JobSpec;

/// A stream of jobs with Poisson arrivals.
pub trait Workload: Send {
    /// Human-readable name.
    fn name(&self) -> String;
    /// Sample the gap until the next job arrival (seconds).
    fn next_gap(&mut self, rng: &mut Rng) -> f64;
    /// Sample the next job.
    fn next_job(&mut self, rng: &mut Rng) -> JobSpec;
    /// Mean task demand τ̄ (unit-speed seconds) — used by the learner and
    /// the benchmark-job generator ("benchmark jobs shall resemble recent
    /// workloads", §3.2).
    fn mean_demand(&self) -> f64;
    /// Sample a benchmark-task demand resembling the workload.
    fn benchmark_demand(&mut self, rng: &mut Rng) -> f64;
    /// Target task arrival rate λ (tasks/sec) the stream was calibrated to.
    fn lambda_tasks(&self) -> f64;
}

/// Workload selector for configs/CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// §6.2: single-task sleep jobs, demand ~ Exp(mean 100 ms).
    Synthetic,
    /// §6.1: TPC-H-shaped stages with constrained and unconstrained tasks.
    /// `query` selects the stage-shape mix ("q3" or "q6").
    Tpch { query: tpch::Query },
}

impl WorkloadKind {
    /// Build the workload for a cluster of `n_workers` with total speed
    /// `total_speed` at target load `load`.
    pub fn build(&self, load: f64, total_speed: f64, n_workers: usize) -> Box<dyn Workload> {
        match self {
            WorkloadKind::Synthetic => {
                Box::new(SyntheticWorkload::new(load, total_speed, 0.1))
            }
            WorkloadKind::Tpch { query } => {
                Box::new(TpchWorkload::with_workers(*query, load, total_speed, n_workers))
            }
        }
    }

    /// Parse `synthetic`, `tpch:q3`, `tpch:q6`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "synthetic" | "sleep" => Ok(WorkloadKind::Synthetic),
            "tpch:q3" => Ok(WorkloadKind::Tpch { query: tpch::Query::Q3 }),
            "tpch:q6" => Ok(WorkloadKind::Tpch { query: tpch::Query::Q6 }),
            other => Err(format!("unknown workload '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(WorkloadKind::parse("synthetic").unwrap(), WorkloadKind::Synthetic);
        assert_eq!(
            WorkloadKind::parse("tpch:q3").unwrap(),
            WorkloadKind::Tpch { query: tpch::Query::Q3 }
        );
        assert!(WorkloadKind::parse("nope").is_err());
    }

    #[test]
    fn build_calibrates_lambda() {
        let w = WorkloadKind::Synthetic.build(0.8, 13.5, 15);
        // λ_tasks = 0.8 · 13.5 / 0.1 = 108 tasks/s.
        assert!((w.lambda_tasks() - 108.0).abs() < 1e-9);
    }
}
