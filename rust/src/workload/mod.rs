//! Workload generation: the paper's synthetic sleep-task workload (§6.2)
//! and a TPC-H-shaped multi-task trace (§6.1).
//!
//! A workload supplies (a) exponential job inter-arrival gaps calibrated to
//! a target load ratio α and (b) job specs (task counts, per-task demands,
//! placement constraints). The target arrival rate is
//! `λ_tasks = α · Σ s_i / τ̄` where `Σ s_i` is the cluster's total speed and
//! τ̄ the mean task demand; a job of `m̄` tasks on average then arrives at
//! rate `λ_tasks / m̄`.

pub mod synthetic;
pub mod tpch;

pub use synthetic::SyntheticWorkload;
pub use tpch::TpchWorkload;

use crate::stats::Rng;
use crate::types::JobSpec;

/// A stream of jobs with Poisson arrivals.
pub trait Workload: Send {
    /// Human-readable name.
    fn name(&self) -> String;
    /// Sample the gap until the next job arrival (seconds).
    fn next_gap(&mut self, rng: &mut Rng) -> f64;
    /// Sample the next job.
    fn next_job(&mut self, rng: &mut Rng) -> JobSpec;
    /// Sample the next job into a reusable buffer — the allocation-free
    /// arrival path. Implementations must draw from `rng` in exactly the
    /// same order as [`Self::next_job`] so the two paths generate identical
    /// streams from identical seeds; the default delegates (and therefore
    /// still allocates). `out.tasks` keeps its capacity across arrivals, so
    /// steady-state multi-task jobs stop allocating a fresh `Vec` each.
    fn next_job_into(&mut self, rng: &mut Rng, out: &mut JobSpec) {
        *out = self.next_job(rng);
    }
    /// Mean task demand τ̄ (unit-speed seconds) — used by the learner and
    /// the benchmark-job generator ("benchmark jobs shall resemble recent
    /// workloads", §3.2).
    fn mean_demand(&self) -> f64;
    /// Sample a benchmark-task demand resembling the workload.
    fn benchmark_demand(&mut self, rng: &mut Rng) -> f64;
    /// Target task arrival rate λ (tasks/sec) the stream was calibrated to.
    fn lambda_tasks(&self) -> f64;
}

/// Workload selector for configs/CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// §6.2: single-task sleep jobs, demand ~ Exp(mean 100 ms).
    Synthetic,
    /// §6.1: TPC-H-shaped stages with constrained and unconstrained tasks.
    /// `query` selects the stage-shape mix ("q3" or "q6").
    Tpch { query: tpch::Query },
}

impl WorkloadKind {
    /// Build the workload for a cluster of `n_workers` with total speed
    /// `total_speed` at target load `load`.
    pub fn build(&self, load: f64, total_speed: f64, n_workers: usize) -> Box<dyn Workload> {
        match self {
            WorkloadKind::Synthetic => {
                Box::new(SyntheticWorkload::new(load, total_speed, 0.1))
            }
            WorkloadKind::Tpch { query } => {
                Box::new(TpchWorkload::with_workers(*query, load, total_speed, n_workers))
            }
        }
    }

    /// Parse `synthetic`, `tpch:q3`, `tpch:q6`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "synthetic" | "sleep" => Ok(WorkloadKind::Synthetic),
            "tpch:q3" => Ok(WorkloadKind::Tpch { query: tpch::Query::Q3 }),
            "tpch:q6" => Ok(WorkloadKind::Tpch { query: tpch::Query::Q6 }),
            other => Err(format!("unknown workload '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(WorkloadKind::parse("synthetic").unwrap(), WorkloadKind::Synthetic);
        assert_eq!(
            WorkloadKind::parse("tpch:q3").unwrap(),
            WorkloadKind::Tpch { query: tpch::Query::Q3 }
        );
        assert!(WorkloadKind::parse("nope").is_err());
    }

    #[test]
    fn build_calibrates_lambda() {
        let w = WorkloadKind::Synthetic.build(0.8, 13.5, 15);
        // λ_tasks = 0.8 · 13.5 / 0.1 = 108 tasks/s.
        assert!((w.lambda_tasks() - 108.0).abs() < 1e-9);
    }

    /// The allocation-free `next_job_into` path must draw from the RNG in
    /// exactly the same order as `next_job`: the engines switched to the
    /// buffered path and a fixed seed must keep reproducing the seed
    /// engine's stream bit for bit.
    #[test]
    fn next_job_into_matches_next_job_stream() {
        for kind in [
            WorkloadKind::Synthetic,
            WorkloadKind::Tpch { query: tpch::Query::Q3 },
            WorkloadKind::Tpch { query: tpch::Query::Q6 },
        ] {
            let mut a = kind.build(0.8, 10.0, 9);
            let mut b = kind.build(0.8, 10.0, 9);
            let mut rng_a = Rng::new(1234);
            let mut rng_b = Rng::new(1234);
            let mut buf = JobSpec::default();
            for k in 0..2_000 {
                let fresh = a.next_job(&mut rng_a);
                b.next_job_into(&mut rng_b, &mut buf);
                assert_eq!(fresh.len(), buf.len(), "{kind:?} job {k} length diverged");
                for (x, y) in fresh.tasks.iter().zip(buf.tasks.iter()) {
                    assert!(
                        x.demand.to_bits() == y.demand.to_bits()
                            && x.constrained_to == y.constrained_to,
                        "{kind:?} job {k} task diverged: {x:?} vs {y:?}"
                    );
                }
            }
            // The two RNG streams must stay in lockstep afterwards too.
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{kind:?} drained the RNG unevenly");
        }
    }

    /// The buffer's task capacity is recycled: multi-task arrivals stop
    /// allocating once the buffer has grown to the largest stage seen.
    #[test]
    fn next_job_into_reuses_buffer_capacity() {
        let mut w = WorkloadKind::Tpch { query: tpch::Query::Q6 }.build(0.8, 10.0, 9);
        let mut rng = Rng::new(7);
        let mut buf = JobSpec::default();
        let mut max_cap = 0;
        for _ in 0..200 {
            w.next_job_into(&mut rng, &mut buf);
            assert!(!buf.is_empty());
            let cap = buf.tasks.capacity();
            assert!(cap >= max_cap, "capacity shrank: {cap} < {max_cap}");
            max_cap = max_cap.max(cap);
        }
    }
}
