//! Synthetic sleep-task workload (§6.2).
//!
//! Jobs arrive as a Poisson process; each job contains a configurable
//! number of tasks (one in the paper's theoretical model, §4). The
//! processing demand of the i-th task is `τ_i ~ Exp(mean 100 ms)`; worker
//! `j` serves it in `τ_i / μ_j` seconds — exactly the paper's sleep-task
//! setup.

use super::Workload;
use crate::stats::{Exponential, Rng};
use crate::types::{JobSpec, TaskSpec};

/// Exponential-demand, Poisson-arrival workload.
#[derive(Debug, Clone)]
pub struct SyntheticWorkload {
    gap: Exponential,
    demand: Exponential,
    mean_demand: f64,
    lambda_tasks: f64,
    tasks_per_job: usize,
}

impl SyntheticWorkload {
    /// Calibrate to load ratio `load` on a cluster with total speed
    /// `total_speed`; task demands are exponential with mean `mean_demand`
    /// seconds (0.1 in the paper). Single-task jobs.
    pub fn new(load: f64, total_speed: f64, mean_demand: f64) -> Self {
        Self::with_tasks_per_job(load, total_speed, mean_demand, 1)
    }

    /// Multi-task variant: each job has exactly `tasks_per_job` tasks.
    pub fn with_tasks_per_job(
        load: f64,
        total_speed: f64,
        mean_demand: f64,
        tasks_per_job: usize,
    ) -> Self {
        assert!(load > 0.0 && total_speed > 0.0 && mean_demand > 0.0 && tasks_per_job >= 1);
        let lambda_tasks = load * total_speed / mean_demand;
        let lambda_jobs = lambda_tasks / tasks_per_job as f64;
        Self {
            gap: Exponential::new(lambda_jobs),
            demand: Exponential::with_mean(mean_demand),
            mean_demand,
            lambda_tasks,
            tasks_per_job,
        }
    }
}

impl Workload for SyntheticWorkload {
    fn name(&self) -> String {
        if self.tasks_per_job == 1 {
            "synthetic".into()
        } else {
            format!("synthetic-m{}", self.tasks_per_job)
        }
    }

    fn next_gap(&mut self, rng: &mut Rng) -> f64 {
        self.gap.sample(rng)
    }

    fn next_job(&mut self, rng: &mut Rng) -> JobSpec {
        let mut spec = JobSpec::default();
        self.next_job_into(rng, &mut spec);
        spec
    }

    fn next_job_into(&mut self, rng: &mut Rng, out: &mut JobSpec) {
        out.tasks.clear();
        for _ in 0..self.tasks_per_job {
            out.tasks.push(TaskSpec::new(self.demand.sample(rng)));
        }
    }

    fn mean_demand(&self) -> f64 {
        self.mean_demand
    }

    fn benchmark_demand(&mut self, rng: &mut Rng) -> f64 {
        self.demand.sample(rng)
    }

    fn lambda_tasks(&self) -> f64 {
        self.lambda_tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_load() {
        // 15 workers of mean speed 0.9 -> total 13.5; α = 0.9.
        let w = SyntheticWorkload::new(0.9, 13.5, 0.1);
        assert!((w.lambda_tasks() - 121.5).abs() < 1e-9);
    }

    #[test]
    fn empirical_arrival_rate() {
        let mut w = SyntheticWorkload::new(0.5, 10.0, 0.1);
        let mut rng = Rng::new(1);
        let n = 100_000;
        let total: f64 = (0..n).map(|_| w.next_gap(&mut rng)).sum();
        let rate = n as f64 / total;
        assert!((rate - 50.0).abs() < 1.0, "rate={rate}");
    }

    #[test]
    fn demands_have_configured_mean() {
        let mut w = SyntheticWorkload::new(0.5, 10.0, 0.1);
        let mut rng = Rng::new(2);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| w.next_job(&mut rng).tasks[0].demand).sum::<f64>() / n as f64;
        assert!((mean - 0.1).abs() < 0.002, "mean={mean}");
        assert!((w.mean_demand() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn benchmark_demand_resembles_workload() {
        let mut w = SyntheticWorkload::new(0.5, 10.0, 0.1);
        let mut rng = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| w.benchmark_demand(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.1).abs() < 0.003, "mean={mean}");
    }

    #[test]
    fn multi_task_jobs() {
        let mut w = SyntheticWorkload::with_tasks_per_job(0.5, 10.0, 0.1, 4);
        let mut rng = Rng::new(4);
        let j = w.next_job(&mut rng);
        assert_eq!(j.len(), 4);
        assert_eq!(j.unconstrained(), 4);
        // Job rate is a quarter of the task rate.
        let n = 50_000;
        let total: f64 = (0..n).map(|_| w.next_gap(&mut rng)).sum();
        let job_rate = n as f64 / total;
        assert!((job_rate - 12.5).abs() < 0.5, "job_rate={job_rate}");
    }
}
