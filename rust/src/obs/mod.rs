//! Live observability plane: metrics registry, flight recorder, scrape
//! endpoint, and leveled logging — all dependency-free (`std` only).
//!
//! Rosella's premise is a scheduler that *watches* the system (§5:
//! "monitors total system load and uses the information to dynamically
//! determine optimal estimation strategy"), yet the end-of-run
//! `PlaneReport`/`NetReport` JSON can only be inspected post-mortem. This
//! module makes a live run observable without perturbing it:
//!
//! * [`registry`] — a lock-free metrics [`Registry`]: atomic counters,
//!   f64-bits gauges (the same pattern as the plane's seqlock estimate
//!   table), and fixed-bucket log2 histograms. Every shard/frontend thread
//!   writes its own [`ShardSlot`], so the per-decision hot path is O(1),
//!   allocation-free, and uncontended; readers aggregate across slots on
//!   scrape (aggregate-on-read, never aggregate-on-write).
//! * [`flight`] — a bounded per-scheduler ring buffer ([`FlightRecorder`])
//!   capturing each placement (task id, probed workers and the queue
//!   lengths seen, chosen worker, μ̂/λ̂ snapshot, decision ns) and each
//!   consensus event (policy, divergence at trigger, views merged, epoch
//!   lag), dumped as JSONL on drain or on demand from the scrape endpoint.
//! * [`scrape`] — a minimal HTTP/1.1 listener ([`MetricsServer`]) over
//!   `std::net` serving Prometheus text exposition at `/metrics` and the
//!   flight-recorder JSONL at `/flight` (`--metrics-listen ADDR` on
//!   `rosella plane`, both in-process and `--listen` server modes).
//! * [`expo`] — the Prometheus text-exposition encoder (label escaping,
//!   `# TYPE` headers, cumulative `le` histogram buckets).
//! * [`log`] — a tiny leveled logger, env-filtered via `ROSELLA_LOG`
//!   (`error|warn|info|debug`, off by default so benches pay nothing).
//! * [`trace`] — sampled per-task lifecycle tracing ([`Tracer`]): stage
//!   decomposition histograms (`rosella_stage_us{stage=...}`), a bounded
//!   raw-span ring rendered as Perfetto-loadable Chrome trace-event JSON
//!   (`/trace`, `--trace-json`), and the NTP-style [`ClockAlign`]
//!   cross-process clock-offset estimator. Deterministic 1-in-N sampling
//!   by task-id hash keeps unsampled tasks on the allocation-free path.
//!
//! None of this touches an RNG stream or reorders a decision: counters are
//! relaxed atomics, the flight recorder only *reads* decision state, and
//! everything beyond the always-on counters is opt-in — which is what keeps
//! `tests/determinism.rs` bit-exact with instrumentation compiled in, and
//! the `hotpath` overhead gate (instrumented ≤ 1.10× uninstrumented
//! decision ns/op) honest.

pub mod expo;
pub mod flight;
pub mod log;
pub mod registry;
pub mod scrape;
pub mod trace;

pub use expo::{escape_label_value, valid_metric_name, Expo};
pub use flight::{FlightEvent, FlightRecorder, ProbeTrace};
pub use registry::{Counter, Gauge, HistSnapshot, Log2Histogram, Registry, ShardSlot};
pub use scrape::MetricsServer;
pub use trace::{ClockAlign, SpanRecord, Tracer, STAGES};
