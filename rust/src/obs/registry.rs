//! Lock-free metrics registry.
//!
//! Three primitives, all plain atomics (no locks, no allocation after
//! construction):
//!
//! * [`Counter`] — monotone `AtomicU64`;
//! * [`Gauge`] — an `AtomicU64` holding f64 bit patterns (the same
//!   atomic-float idiom as [`crate::plane::EstimateTable`]);
//! * [`Log2Histogram`] — 65 fixed power-of-two buckets over `u64` values.
//!   Bucket `b ≥ 1` holds `2^(b-1) ≤ v < 2^b`; bucket 0 holds `v = 0`.
//!   Recording is two relaxed `fetch_add`s plus a `leading_zeros` — a few
//!   ns, bounded memory, no resizing ever.
//!
//! The [`Registry`] pre-allocates one [`ShardSlot`] per scheduler thread.
//! Each thread only ever writes its own slot, so the hot path is
//! uncontended (one writer per cache line); scrapes and reports aggregate
//! across slots on read. The registry is created per run (testable,
//! no global state) and shared via `Arc`.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone counter. `inc`/`add` are single relaxed atomic RMWs.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bit pattern — never torn).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Self(AtomicU64::new(0f64.to_bits()))
    }
}

impl Gauge {
    /// Gauge initialized to 0.0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Gauge initialized to `v` (e.g. −1.0 sentinels for "not set").
    pub fn with_value(v: f64) -> Self {
        Self(AtomicU64::new(v.to_bits()))
    }

    /// Store a new value.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of buckets: one for zero plus one per possible `floor(log2 v)`.
pub const LOG2_BUCKETS: usize = 65;

/// Power-of-two bucket index of a value.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `b` (the Prometheus `le` boundary).
pub fn bucket_upper(b: usize) -> u64 {
    match b {
        0 => 0,
        1..=63 => (1u64 << b) - 1,
        _ => u64::MAX,
    }
}

/// Fixed-bucket log2 histogram over `u64` samples (latency in ns/µs,
/// queue lengths). Lock-free, bounded, O(1) record.
#[derive(Debug)]
pub struct Log2Histogram {
    counts: [AtomicU64; LOG2_BUCKETS],
    sum: AtomicU64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self { counts: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }
}

impl Log2Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total samples recorded so far.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Sum of recorded values (wrapping on overflow).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Consistent-enough point-in-time copy. Individual bucket loads are
    /// relaxed, so a snapshot taken mid-record can be off by the in-flight
    /// sample — fine for scraping, never for accounting invariants.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// Accumulate this histogram into an aggregate snapshot
    /// (aggregate-on-read across shard slots).
    pub fn merge_into(&self, acc: &mut HistSnapshot) {
        assert_eq!(acc.counts.len(), LOG2_BUCKETS, "snapshot geometry mismatch");
        for (a, c) in acc.counts.iter_mut().zip(self.counts.iter()) {
            *a += c.load(Ordering::Relaxed);
        }
        acc.sum = acc.sum.wrapping_add(self.sum.load(Ordering::Relaxed));
    }
}

/// Plain (non-atomic) copy of a [`Log2Histogram`], used for aggregation
/// and rendering.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket counts ([`LOG2_BUCKETS`] entries).
    pub counts: Vec<u64>,
    /// Sum of recorded values.
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { counts: vec![0; LOG2_BUCKETS], sum: 0 }
    }
}

impl HistSnapshot {
    /// Empty snapshot (all-zero buckets).
    pub fn empty() -> Self {
        Self::default()
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Approximate quantile `q ∈ [0, 1]`: the midpoint of the bucket
    /// holding the target rank (0 for an empty snapshot).
    pub fn quantile(&self, q: f64) -> u64 {
        assert!((0.0..=1.0).contains(&q));
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_mid(b);
            }
        }
        bucket_upper(LOG2_BUCKETS - 1)
    }
}

/// Representative (midpoint) value of bucket `b`.
pub fn bucket_mid(b: usize) -> u64 {
    match b {
        0 => 0,
        1 => 1,
        _ => {
            let lo = 1u64 << (b - 1);
            lo + lo / 2
        }
    }
}

/// Per-scheduler-thread metric slot. One thread writes, any thread reads.
#[derive(Debug)]
pub struct ShardSlot {
    /// Scheduling decisions made.
    pub decisions: Counter,
    /// Real tasks handed to workers.
    pub dispatched: Counter,
    /// Real tasks whose completions this shard has observed.
    pub completed: Counter,
    /// Benchmark (fake) tasks dispatched by this shard's learner.
    pub bench_dispatched: Counter,
    /// Queue length of the chosen worker at each decision.
    pub queue_len: Log2Histogram,
    /// Per-decision latency in nanoseconds (recorded only when the flight
    /// recorder is on — clock reads are not free).
    pub decision_ns: Log2Histogram,
    /// End-to-end task response time in microseconds.
    pub response_us: Log2Histogram,
    /// CPU this shard's thread is pinned to; −1 when unpinned (or when the
    /// pin was requested but denied), so the gauge exists in every config
    /// and dashboards never see a missing series.
    pub shard_cpu: Gauge,
    /// Decisions that spilled past this shard's socket-local worker group
    /// (`--pin sockets` only; stays 0 in every other mode).
    pub cross_socket: Counter,
}

impl Default for ShardSlot {
    fn default() -> Self {
        Self {
            decisions: Counter::new(),
            dispatched: Counter::new(),
            completed: Counter::new(),
            bench_dispatched: Counter::new(),
            queue_len: Log2Histogram::new(),
            decision_ns: Log2Histogram::new(),
            response_us: Log2Histogram::new(),
            shard_cpu: Gauge::with_value(-1.0),
            cross_socket: Counter::new(),
        }
    }
}

/// Per-poll-shard metric slot for the net server's data plane. One poll
/// shard thread writes, any thread reads — the same one-writer-per-slot
/// discipline as [`ShardSlot`].
#[derive(Debug, Default)]
pub struct PollSlot {
    /// Poller wait calls that returned (kernel wakeups or sweep passes).
    pub wakeups: Counter,
    /// Readiness events surfaced per wakeup: the batching the kernel
    /// poller buys — high values mean one wakeup served many sockets.
    pub events_per_wake: Log2Histogram,
}

/// The run-wide registry: per-shard slots plus cluster-level gauges and
/// consensus counters. Constructed once per run, shared via `Arc`.
#[derive(Debug)]
pub struct Registry {
    shards: Box<[ShardSlot]>,
    polls: Box<[PollSlot]>,
    mu_hat: Box<[Gauge]>,
    /// Aggregate arrival-rate estimate λ̂ (tasks/second).
    pub lambda_hat: Gauge,
    /// Estimate-sync check epochs evaluated.
    pub sync_epochs: Counter,
    /// Consensus merge operations performed.
    pub sync_merges: Counter,
    /// Sync payloads exported (shared-memory stores or wire frames).
    pub sync_exports: Counter,
    /// Estimate-table publications.
    pub publishes: Counter,
    /// Arrivals generated by the ingest layer.
    pub arrivals: Counter,
    /// Tasks carried per submit-carrying wire frame (`Submit` records 1,
    /// `SubmitBatch` records its item count): the direct measure of how
    /// well frontend coalescing amortizes headers and write syscalls.
    pub wire_batch: Log2Histogram,
}

impl Registry {
    /// Registry for `shards` scheduler threads over `workers` workers,
    /// with one poll slot (the in-process plane has no poll shards; one
    /// slot keeps the exposition surface uniform).
    pub fn new(shards: usize, workers: usize) -> Self {
        Self::with_poll_shards(shards, workers, 1)
    }

    /// Registry for `shards` scheduler threads over `workers` workers and
    /// `poll_shards` net data-plane poller threads.
    pub fn with_poll_shards(shards: usize, workers: usize, poll_shards: usize) -> Self {
        assert!(shards > 0, "registry needs at least one shard slot");
        assert!(poll_shards > 0, "registry needs at least one poll slot");
        Self {
            shards: (0..shards).map(|_| ShardSlot::default()).collect(),
            polls: (0..poll_shards).map(|_| PollSlot::default()).collect(),
            mu_hat: (0..workers).map(|_| Gauge::new()).collect(),
            lambda_hat: Gauge::new(),
            sync_epochs: Counter::new(),
            sync_merges: Counter::new(),
            sync_exports: Counter::new(),
            publishes: Counter::new(),
            arrivals: Counter::new(),
            wire_batch: Log2Histogram::new(),
        }
    }

    /// Number of shard slots.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Number of poll slots.
    pub fn n_poll_shards(&self) -> usize {
        self.polls.len()
    }

    /// One poll shard's slot. Index must be < `n_poll_shards`.
    #[inline]
    pub fn poll_shard(&self, i: usize) -> &PollSlot {
        &self.polls[i]
    }

    /// All poll slots (rendering/aggregation).
    pub fn poll_shards(&self) -> &[PollSlot] {
        &self.polls
    }

    /// Number of worker gauges.
    pub fn n_workers(&self) -> usize {
        self.mu_hat.len()
    }

    /// This thread's slot. Index must be < `n_shards`.
    #[inline]
    pub fn shard(&self, i: usize) -> &ShardSlot {
        &self.shards[i]
    }

    /// All slots (rendering/aggregation).
    pub fn shards(&self) -> &[ShardSlot] {
        &self.shards
    }

    /// Publish a μ̂ vector into the per-worker gauges (called from the
    /// publish path, never the decision path).
    pub fn set_mu_hat(&self, mu: &[f64]) {
        for (g, &v) in self.mu_hat.iter().zip(mu) {
            g.set(v);
        }
    }

    /// Per-worker μ̂ gauge value.
    pub fn mu_hat(&self, w: usize) -> f64 {
        self.mu_hat[w].get()
    }

    /// Sum of per-shard dispatched counters.
    pub fn dispatched_total(&self) -> u64 {
        self.shards.iter().map(|s| s.dispatched.get()).sum()
    }

    /// Sum of per-shard completed counters.
    pub fn completed_total(&self) -> u64 {
        self.shards.iter().map(|s| s.completed.get()).sum()
    }

    /// Sum of per-shard decision counters.
    pub fn decisions_total(&self) -> u64 {
        self.shards.iter().map(|s| s.decisions.get()).sum()
    }

    /// Aggregate a per-shard histogram across all slots.
    pub fn aggregate<F: Fn(&ShardSlot) -> &Log2Histogram>(&self, f: F) -> HistSnapshot {
        let mut acc = HistSnapshot::empty();
        for s in self.shards.iter() {
            f(s).merge_into(&mut acc);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(-1.25);
        assert_eq!(g.get(), -1.25);
    }

    #[test]
    fn log2_bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 64);
        // Upper bounds partition the axis: bucket_of(upper) == b and
        // bucket_of(upper + 1) == b + 1.
        for b in 0..LOG2_BUCKETS - 1 {
            let hi = bucket_upper(b);
            assert_eq!(bucket_of(hi), b, "upper({b})");
            assert_eq!(bucket_of(hi + 1), b + 1, "upper({b}) + 1");
        }
        assert_eq!(bucket_upper(LOG2_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_count_sum_quantile() {
        let h = Log2Histogram::new();
        for v in [0u64, 1, 1, 7, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1109);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 6);
        // Median rank lands in the bucket of value 1.
        assert_eq!(snap.quantile(0.5), 1);
        // Max quantile lands in value-1000's bucket [512, 1024).
        let q100 = snap.quantile(1.0);
        assert!((512..1024).contains(&q100), "q100={q100}");
        assert_eq!(HistSnapshot::empty().quantile(0.99), 0);
    }

    #[test]
    fn aggregate_on_read_sums_shard_slots() {
        let reg = Registry::new(3, 2);
        for (i, n) in [(0usize, 5u64), (1, 7), (2, 11)] {
            reg.shard(i).dispatched.add(n);
            reg.shard(i).completed.add(n - 1);
            for v in 0..n {
                reg.shard(i).queue_len.record(v);
            }
        }
        assert_eq!(reg.dispatched_total(), 23);
        assert_eq!(reg.completed_total(), 20);
        let agg = reg.aggregate(|s| &s.queue_len);
        assert_eq!(agg.count(), 23);
        reg.set_mu_hat(&[1.5, 0.5]);
        assert_eq!(reg.mu_hat(0), 1.5);
        assert_eq!(reg.mu_hat(1), 0.5);
    }

    #[test]
    fn shard_cpu_gauge_defaults_to_unpinned_sentinel() {
        let reg = Registry::new(2, 1);
        assert_eq!(reg.shard(0).shard_cpu.get(), -1.0);
        assert_eq!(reg.shard(1).cross_socket.get(), 0);
        reg.shard(1).shard_cpu.set(3.0);
        assert_eq!(reg.shard(1).shard_cpu.get(), 3.0);
        assert_eq!(reg.shard(0).shard_cpu.get(), -1.0, "slots are independent");
    }

    #[test]
    fn poll_slots_default_to_one_and_scale_on_request() {
        let reg = Registry::new(2, 1);
        assert_eq!(reg.n_poll_shards(), 1);
        let reg = Registry::with_poll_shards(2, 1, 4);
        assert_eq!(reg.n_poll_shards(), 4);
        reg.poll_shard(3).wakeups.inc();
        reg.poll_shard(3).events_per_wake.record(5);
        assert_eq!(reg.poll_shard(3).wakeups.get(), 1);
        assert_eq!(reg.poll_shard(0).wakeups.get(), 0, "slots are independent");
        let total: u64 = reg.poll_shards().iter().map(|p| p.events_per_wake.count()).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn concurrent_writers_lose_nothing() {
        let reg = Arc::new(Registry::new(4, 1));
        let mut threads = Vec::new();
        for i in 0..4 {
            let reg = reg.clone();
            threads.push(std::thread::spawn(move || {
                let slot = reg.shard(i);
                for v in 0..10_000u64 {
                    slot.decisions.inc();
                    slot.queue_len.record(v % 17);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(reg.decisions_total(), 40_000);
        assert_eq!(reg.aggregate(|s| &s.queue_len).count(), 40_000);
    }
}
