//! Decision flight recorder: a bounded per-scheduler ring buffer of the
//! plane's most recent placements and consensus events.
//!
//! A post-mortem `PlaneReport` says *how fast* the plane ran; the flight
//! recorder says *what it was thinking*: for each placement, the task id,
//! the workers actually probed and the queue lengths seen at those probes,
//! the chosen worker with its μ̂, the λ̂ in force, and the decision latency
//! in nanoseconds; for each consensus event, the sync policy, the
//! divergence at trigger, how many views merged, and the epoch lag since
//! the previous merge. Rings are fixed-capacity and overwrite the oldest
//! entry, so a recorder is O(capacity) memory regardless of run length.
//!
//! Each scheduler thread writes its own lane (one `Mutex` per lane,
//! uncontended except against a dump), and the whole recorder dumps as
//! JSONL — one event per line — on drain or on demand from the scrape
//! endpoint's `/flight` route.
//!
//! [`ProbeTrace`] is the capture half: a `Cell`-based scratchpad handed to
//! the decision view, recording which workers the policy probed without
//! changing the policy trait or any RNG stream.

use crate::config::Json;
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Maximum probes captured per decision (power-of-d-choices uses 2; late
/// binding can touch a few more).
pub const MAX_PROBES: usize = 4;

/// Per-decision probe scratchpad. Lives on one scheduler thread; cleared
/// before each decision, filled by the view's `queue_len` reads.
#[derive(Debug, Default)]
pub struct ProbeTrace {
    len: Cell<usize>,
    workers: [Cell<u32>; MAX_PROBES],
    qlens: [Cell<u32>; MAX_PROBES],
}

impl ProbeTrace {
    /// Fresh, empty trace.
    pub fn new() -> Self {
        Self::default()
    }

    /// Forget the previous decision's probes.
    #[inline]
    pub fn clear(&self) {
        self.len.set(0);
    }

    /// Record one probe (worker, observed queue length). Extra probes
    /// beyond [`MAX_PROBES`] are dropped — the first probes are the ones
    /// the decision logic weighs.
    #[inline]
    pub fn push(&self, worker: usize, qlen: usize) {
        let n = self.len.get();
        if n < MAX_PROBES {
            self.workers[n].set(worker as u32);
            self.qlens[n].set(qlen.min(u32::MAX as usize) as u32);
            self.len.set(n + 1);
        }
    }

    /// Captured probes as `(worker, qlen)` pairs.
    pub fn probes(&self) -> Vec<(u32, u32)> {
        (0..self.len.get()).map(|i| (self.workers[i].get(), self.qlens[i].get())).collect()
    }
}

/// One recorded event.
#[derive(Debug, Clone, PartialEq)]
pub enum FlightEvent {
    /// A placement decision.
    Placement {
        /// Nanoseconds since the run started.
        t_ns: u64,
        /// Deciding scheduler (shard) index.
        shard: u32,
        /// Task id (encoded job id in the plane).
        task: u64,
        /// Workers probed and the queue lengths seen there.
        probed: Vec<(u32, u32)>,
        /// Chosen worker.
        chosen: u32,
        /// μ̂ of the chosen worker at decision time.
        mu_chosen: f64,
        /// λ̂ in force at decision time (tasks/second).
        lambda_hat: f64,
        /// Wall-clock decision latency in nanoseconds.
        decision_ns: u64,
    },
    /// A consensus (estimate-sync) event.
    Consensus {
        /// Nanoseconds since the run started.
        t_ns: u64,
        /// Sync policy name (`periodic`, `adaptive`, `gossip`).
        policy: &'static str,
        /// Check epoch counter at this event.
        epoch: u64,
        /// Divergence measured at the trigger (0 when not applicable).
        divergence: f64,
        /// Number of scheduler views merged (0 for a skipped epoch).
        views: u32,
        /// Check epochs since the last merge (staleness at trigger).
        epoch_lag: u64,
    },
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

impl FlightEvent {
    /// One JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            FlightEvent::Placement {
                t_ns,
                shard,
                task,
                probed,
                chosen,
                mu_chosen,
                lambda_hat,
                decision_ns,
            } => {
                m.insert("type".into(), Json::Str("placement".into()));
                m.insert("t_ns".into(), num(*t_ns as f64));
                m.insert("shard".into(), num(*shard as f64));
                m.insert("task".into(), num(*task as f64));
                m.insert(
                    "probed".into(),
                    Json::Arr(
                        probed
                            .iter()
                            .map(|&(w, q)| {
                                Json::Arr(vec![num(w as f64), num(q as f64)])
                            })
                            .collect(),
                    ),
                );
                m.insert("chosen".into(), num(*chosen as f64));
                m.insert("mu_chosen".into(), num(*mu_chosen));
                m.insert("lambda_hat".into(), num(*lambda_hat));
                m.insert("decision_ns".into(), num(*decision_ns as f64));
            }
            FlightEvent::Consensus { t_ns, policy, epoch, divergence, views, epoch_lag } => {
                m.insert("type".into(), Json::Str("consensus".into()));
                m.insert("t_ns".into(), num(*t_ns as f64));
                m.insert("policy".into(), Json::Str((*policy).into()));
                m.insert("epoch".into(), num(*epoch as f64));
                m.insert("divergence".into(), num(*divergence));
                m.insert("views".into(), num(*views as f64));
                m.insert("epoch_lag".into(), num(*epoch_lag as f64));
            }
        }
        crate::config::to_string(&Json::Obj(m))
    }
}

/// Fixed-capacity overwrite-oldest ring.
#[derive(Debug)]
struct Ring {
    cap: usize,
    buf: Vec<FlightEvent>,
    /// Next write position once the ring is full.
    next: usize,
    /// Total events ever recorded into this ring.
    total: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { cap, buf: Vec::with_capacity(cap.min(1024)), next: 0, total: 0 }
    }

    fn push(&mut self, ev: FlightEvent) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Events oldest-first.
    fn ordered(&self) -> impl Iterator<Item = &FlightEvent> {
        self.buf[self.next..].iter().chain(self.buf[..self.next].iter())
    }
}

/// The recorder: one lane per scheduler thread plus one for consensus
/// events. Lanes are independently locked, so a scheduler only ever
/// contends with a concurrent dump, never with its peers.
#[derive(Debug)]
pub struct FlightRecorder {
    lanes: Vec<Mutex<Ring>>,
}

/// Default per-lane capacity: enough tail to be useful, small enough that
/// a recorder is a few hundred KB at most.
pub const DEFAULT_CAPACITY: usize = 4096;

impl FlightRecorder {
    /// Recorder for `shards` scheduler lanes (+1 internal consensus lane),
    /// each holding the most recent `capacity` events.
    pub fn new(shards: usize, capacity: usize) -> Self {
        assert!(shards > 0 && capacity > 0, "flight recorder needs lanes and capacity");
        Self { lanes: (0..=shards).map(|_| Mutex::new(Ring::new(capacity))).collect() }
    }

    /// Number of scheduler lanes (excluding the consensus lane).
    pub fn n_shards(&self) -> usize {
        self.lanes.len() - 1
    }

    /// Record an event into scheduler lane `shard`.
    pub fn record(&self, shard: usize, ev: FlightEvent) {
        debug_assert!(shard < self.n_shards(), "lane out of range");
        self.lanes[shard].lock().unwrap().push(ev);
    }

    /// Record a consensus event (the shared consensus lane).
    pub fn record_consensus(&self, ev: FlightEvent) {
        let lane = self.lanes.len() - 1;
        self.lanes[lane].lock().unwrap().push(ev);
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.lanes.iter().map(|l| l.lock().unwrap().total).sum()
    }

    /// Events lost to ring overwrites: recorded minus retained, summed
    /// across lanes. Exposed as `rosella_flight_dropped_total` so a scrape
    /// can tell whether the `/flight` tail is the whole story.
    pub fn dropped(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| {
                let ring = l.lock().unwrap();
                ring.total - ring.buf.len() as u64
            })
            .sum()
    }

    /// Dump every lane as JSONL, oldest-first within each lane (lanes are
    /// concatenated; consumers sort on `t_ns` if they need a global
    /// order). Ends with a newline when non-empty.
    pub fn dump_jsonl(&self) -> String {
        let mut out = String::new();
        for lane in &self.lanes {
            let ring = lane.lock().unwrap();
            for ev in ring.ordered() {
                out.push_str(&ev.to_json_line());
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placement(task: u64) -> FlightEvent {
        FlightEvent::Placement {
            t_ns: task * 10,
            shard: 0,
            task,
            probed: vec![(1, 3), (4, 0)],
            chosen: 4,
            mu_chosen: 1.5,
            lambda_hat: 200.0,
            decision_ns: 420,
        }
    }

    #[test]
    fn probe_trace_captures_and_clears() {
        let t = ProbeTrace::new();
        t.push(3, 7);
        t.push(9, 0);
        assert_eq!(t.probes(), vec![(3, 7), (9, 0)]);
        t.clear();
        assert!(t.probes().is_empty());
        // Overflow beyond MAX_PROBES is dropped, not panicked on.
        for i in 0..10 {
            t.push(i, i);
        }
        assert_eq!(t.probes().len(), MAX_PROBES);
    }

    #[test]
    fn events_serialize_to_parseable_json_lines() {
        let line = placement(42).to_json_line();
        let v = crate::config::parse(&line).expect("placement line must be valid JSON");
        assert_eq!(v.get("type").unwrap().as_str(), Some("placement"));
        assert_eq!(v.get("task").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("chosen").unwrap().as_u64(), Some(4));
        assert_eq!(v.get("probed").unwrap().as_arr().unwrap().len(), 2);
        let cons = FlightEvent::Consensus {
            t_ns: 5,
            policy: "adaptive",
            epoch: 9,
            divergence: 0.125,
            views: 4,
            epoch_lag: 3,
        };
        let v = crate::config::parse(&cons.to_json_line()).unwrap();
        assert_eq!(v.get("policy").unwrap().as_str(), Some("adaptive"));
        assert_eq!(v.get("divergence").unwrap().as_f64(), Some(0.125));
    }

    #[test]
    fn ring_overwrites_oldest_and_keeps_order() {
        let rec = FlightRecorder::new(1, 4);
        for task in 0..10 {
            rec.record(0, placement(task));
        }
        assert_eq!(rec.total(), 10);
        assert_eq!(rec.dropped(), 6, "10 recorded into capacity 4 drops 6");
        let dump = rec.dump_jsonl();
        let tasks: Vec<u64> = dump
            .lines()
            .map(|l| crate::config::parse(l).unwrap().get("task").unwrap().as_u64().unwrap())
            .collect();
        // Capacity 4: the last four events, oldest first.
        assert_eq!(tasks, vec![6, 7, 8, 9]);
    }

    #[test]
    fn consensus_lane_is_separate_from_shard_lanes() {
        let rec = FlightRecorder::new(2, 8);
        rec.record(1, placement(1));
        rec.record_consensus(FlightEvent::Consensus {
            t_ns: 1,
            policy: "periodic",
            epoch: 1,
            divergence: 0.0,
            views: 2,
            epoch_lag: 1,
        });
        let dump = rec.dump_jsonl();
        assert_eq!(dump.lines().count(), 2);
        assert!(dump.contains("\"placement\""));
        assert!(dump.contains("\"consensus\""));
    }

    #[test]
    fn empty_recorder_dumps_empty() {
        let rec = FlightRecorder::new(3, 16);
        assert_eq!(rec.dump_jsonl(), "");
        assert_eq!(rec.total(), 0);
        assert_eq!(rec.dropped(), 0);
    }
}
