//! Sampled per-task lifecycle tracing: where each microsecond of a
//! task's response time goes.
//!
//! A traced task carries monotonic timestamps through every stage of its
//! life — frontend decision, coalescing-buffer enqueue, frame send,
//! pool-server frame receive, worker queue, service, and the reply path
//! back — and the decomposition is rendered two ways:
//!
//! * aggregated per-stage [`Log2Histogram`]s, exposed as
//!   `rosella_stage_us{stage=...}` on the `/metrics` scrape surface;
//! * raw sampled spans as Chrome trace-event JSON (loadable in Perfetto
//!   or `chrome://tracing`), served at `/trace` and dumped via
//!   `--trace-json`.
//!
//! Sampling is deterministic by task-id hash ([`sampled`]), so two
//! processes agree on which tasks are traced without negotiation, and an
//! unsampled task touches none of this module on the hot path beyond one
//! branch. Cross-process stamps are aligned by [`ClockAlign`], a
//! four-timestamp NTP-style offset estimator fed by the Hello/HelloAck
//! handshake and refreshed on every Tick/TickReply beat.
//!
//! All stamps are nanoseconds on a process-wide monotonic timeline
//! anchored at the first [`now_ns`] call ([`ns_of`] maps an
//! [`Instant`] captured elsewhere — e.g. a worker's completion stamp —
//! onto the same timeline).

use super::expo::Expo;
use super::registry::{bucket_upper, Gauge, HistSnapshot, Log2Histogram, LOG2_BUCKETS};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Lifecycle stage names, in task order. Indexes into
/// [`SpanRecord::stages_us`].
pub const STAGES: [&str; 6] = ["decide", "coalesce", "wire", "queue", "service", "reply"];

/// Stage index: arrival → placement decision made.
pub const STAGE_DECIDE: usize = 0;
/// Stage index: decision → coalescing-buffer flush (frame send).
pub const STAGE_COALESCE: usize = 1;
/// Stage index: frame send → pool-server frame receive (clock-aligned).
pub const STAGE_WIRE: usize = 2;
/// Stage index: waiting in the worker's queue.
pub const STAGE_QUEUE: usize = 3;
/// Stage index: task service time.
pub const STAGE_SERVICE: usize = 4;
/// Stage index: completion → reply received at the frontend.
pub const STAGE_REPLY: usize = 5;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-wide trace epoch. The epoch is anchored
/// lazily at the first call, so stamps from any thread share one
/// monotonic timeline.
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Map an [`Instant`] captured elsewhere (e.g. a worker completion
/// stamp) onto the trace timeline. Instants predating the epoch clamp
/// to 0.
#[inline]
pub fn ns_of(at: Instant) -> u64 {
    at.saturating_duration_since(*EPOCH.get_or_init(Instant::now)).as_nanos() as u64
}

/// SplitMix64 finalizer: a cheap, well-mixed task-id hash.
#[inline]
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Deterministic 1-in-`n` sampling decision by task-id hash. `n == 0`
/// disables tracing entirely; `n == 1` traces every task. Both sides of
/// the wire evaluate this identically, so sampled stamps never need a
/// per-task negotiation bit.
#[inline]
pub fn sampled(job: u64, n: u32) -> bool {
    n > 0 && splitmix(job) % u64::from(n) == 0
}

/// Parse a `--trace-sample` spec: `1/N` (the canonical form), a bare
/// `N`, or `off`/`0` to disable.
pub fn parse_sample(s: &str) -> Result<u32, String> {
    let s = s.trim();
    if s.eq_ignore_ascii_case("off") {
        return Ok(0);
    }
    let n = match s.split_once('/') {
        Some((num, den)) => {
            if num.trim() != "1" {
                return Err(format!("--trace-sample expects 1/N (got '{s}')"));
            }
            den.trim().parse::<u32>()
        }
        None => s.parse::<u32>(),
    };
    n.map_err(|_| format!("--trace-sample expects 1/N, N, or 'off' (got '{s}')"))
}

/// One accepted clock exchange: estimated remote−local offset and the
/// round-trip delay it rode on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockSample {
    /// Estimated `remote_clock − local_clock`, nanoseconds.
    pub offset_ns: f64,
    /// Round-trip delay minus remote processing time, nanoseconds.
    pub delay_ns: f64,
}

/// Four-timestamp NTP-style clock-offset estimator.
///
/// An exchange stamps `t0` (local send), `t1` (remote receive), `t2`
/// (remote send), `t3` (local receive). The classic estimate is
///
/// ```text
/// offset θ = ((t1 − t0) + (t2 − t3)) / 2
/// delay  δ = (t3 − t0) − (t2 − t1)
/// ```
///
/// With one-way delays `a` (outbound) and `b` (return), the estimator's
/// error is exactly `|a − b| / 2 ≤ δ / 2`, so `δ / 2` is a sound error
/// bound regardless of asymmetry. The estimator keeps the minimum-delay
/// exchange seen so far — the exchange whose bound is tightest — and is
/// refreshed by every Tick/TickReply beat after the handshake seeds it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClockAlign {
    best: Option<ClockSample>,
    exchanges: u64,
}

impl ClockAlign {
    /// Fresh estimator with no exchanges observed.
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one four-timestamp exchange (`t0`/`t3` on the local
    /// timeline, `t1`/`t2` on the remote one). Keeps it iff its delay
    /// beats the best so far.
    pub fn observe(&mut self, t0: u64, t1: u64, t2: u64, t3: u64) {
        self.exchanges += 1;
        let (t0, t1, t2, t3) = (t0 as i128, t1 as i128, t2 as i128, t3 as i128);
        let offset = ((t1 - t0) + (t2 - t3)) as f64 / 2.0;
        let delay = ((t3 - t0) - (t2 - t1)).max(0) as f64;
        let keep = match self.best {
            None => true,
            Some(b) => delay < b.delay_ns,
        };
        if keep {
            self.best = Some(ClockSample { offset_ns: offset, delay_ns: delay });
        }
    }

    /// Best estimate of `remote_clock − local_clock` in nanoseconds
    /// (0.0 before any exchange).
    pub fn offset_ns(&self) -> f64 {
        self.best.map_or(0.0, |b| b.offset_ns)
    }

    /// Error bound on [`Self::offset_ns`] (half the best round-trip
    /// delay; 0.0 before any exchange).
    pub fn error_ns(&self) -> f64 {
        self.best.map_or(0.0, |b| b.delay_ns / 2.0)
    }

    /// Exchanges observed (accepted or not).
    pub fn exchanges(&self) -> u64 {
        self.exchanges
    }

    /// Whether at least one exchange seeded the estimate.
    pub fn aligned(&self) -> bool {
        self.best.is_some()
    }

    /// Map a remote-timeline stamp onto the local timeline.
    pub fn to_local_ns(&self, remote_ns: u64) -> u64 {
        let v = remote_ns as f64 - self.offset_ns();
        if v <= 0.0 { 0 } else { v as u64 }
    }

    /// Map a local-timeline stamp onto the remote timeline.
    pub fn to_remote_ns(&self, local_ns: u64) -> u64 {
        let v = local_ns as f64 + self.offset_ns();
        if v <= 0.0 { 0 } else { v as u64 }
    }
}

/// One completed task span: where its response time went, stage by
/// stage, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// Task id (shard in the high bits, sequence below).
    pub job: u64,
    /// Span start (task arrival) in µs on the recording process's trace
    /// timeline.
    pub origin_us: u64,
    /// Per-stage durations in µs, indexed by `STAGE_*`.
    pub stages_us: [u32; 6],
}

impl SpanRecord {
    /// Sum of all stage durations, µs.
    pub fn total_us(&self) -> u64 {
        self.stages_us.iter().map(|&s| u64::from(s)).sum()
    }
}

/// Bounded overwrite ring of raw spans (the Perfetto export surface).
#[derive(Debug)]
struct SpanRing {
    buf: Vec<SpanRecord>,
    next: usize,
    cap: usize,
}

impl SpanRing {
    fn push(&mut self, rec: SpanRecord) {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.next] = rec;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Spans oldest-first.
    fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() == self.cap {
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
        } else {
            out.extend_from_slice(&self.buf);
        }
        out
    }
}

/// Default bound on retained raw spans.
pub const SPAN_RING_CAP: usize = 4096;

/// Aggregation point for sampled task spans: per-stage histograms (the
/// `/metrics` surface), a bounded raw-span ring (the `/trace` surface),
/// and the current cross-process clock estimate.
#[derive(Debug)]
pub struct Tracer {
    sample_n: u32,
    stages: [Log2Histogram; 6],
    spans: Mutex<SpanRing>,
    recorded: AtomicU64,
    /// Estimated remote−local clock offset, ns (frontend-reported).
    pub clock_offset_ns: Gauge,
    /// Error bound on the offset estimate, ns.
    pub clock_error_ns: Gauge,
}

impl Tracer {
    /// Tracer sampling 1-in-`n` tasks (`n == 0` = off — callers gate on
    /// [`Self::enabled`] and never reach the recording path).
    pub fn new(sample_n: u32) -> Self {
        Self::with_capacity(sample_n, SPAN_RING_CAP)
    }

    /// Tracer with an explicit raw-span ring bound.
    pub fn with_capacity(sample_n: u32, cap: usize) -> Self {
        Self {
            sample_n,
            stages: std::array::from_fn(|_| Log2Histogram::new()),
            spans: Mutex::new(SpanRing { buf: Vec::new(), next: 0, cap: cap.max(1) }),
            recorded: AtomicU64::new(0),
            clock_offset_ns: Gauge::new(),
            clock_error_ns: Gauge::new(),
        }
    }

    /// Advertised sampling modulus N (tasks are traced iff
    /// `sampled(job, n)`).
    pub fn sample_n(&self) -> u32 {
        self.sample_n
    }

    /// Whether tracing is on at all.
    pub fn enabled(&self) -> bool {
        self.sample_n > 0
    }

    /// Whether `job` is in the deterministic sample.
    #[inline]
    pub fn sampled(&self, job: u64) -> bool {
        sampled(job, self.sample_n)
    }

    /// Record one completed span into the stage histograms and the raw
    /// ring.
    pub fn record(&self, rec: SpanRecord) {
        for (h, &us) in self.stages.iter().zip(rec.stages_us.iter()) {
            h.record(u64::from(us));
        }
        self.spans.lock().unwrap().push(rec);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the in-process lifecycle of one completed task, sampled by
    /// task-id hash like the cross-process path. There are no wire legs,
    /// so only the queue/service/reply stages are populated (decide,
    /// coalesce and wire stay zero) and the origin is reconstructed by
    /// rewinding the completion instant by the measured sojourn
    /// (queue wait + service).
    pub fn record_completion(&self, job: u64, queue_wait_s: f64, duration_s: f64, done: Instant) {
        if !self.sampled(job) {
            return;
        }
        let done_ns = ns_of(done);
        let us = |s: f64| (s.max(0.0) * 1e6).min(u32::MAX as f64) as u32;
        let queue_us = us(queue_wait_s);
        let service_us = us(duration_s);
        let reply_us =
            (now_ns().saturating_sub(done_ns) / 1_000).min(u64::from(u32::MAX)) as u32;
        let sojourn_s = (queue_wait_s + duration_s).max(0.0);
        let origin_ns = done_ns.saturating_sub((sojourn_s * 1e9) as u64);
        self.record(SpanRecord {
            job,
            origin_us: origin_ns / 1_000,
            stages_us: [0, 0, 0, queue_us, service_us, reply_us],
        });
    }

    /// Update the exported clock gauges.
    pub fn set_clock(&self, offset_ns: f64, error_ns: f64) {
        self.clock_offset_ns.set(offset_ns);
        self.clock_error_ns.set(error_ns);
    }

    /// Spans recorded over the tracer's lifetime (the ring may hold
    /// fewer).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Snapshot of the retained raw spans, oldest-first.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().snapshot()
    }

    /// Snapshot one stage histogram.
    pub fn stage_snapshot(&self, stage: usize) -> HistSnapshot {
        self.stages[stage].snapshot()
    }

    /// Append the Prometheus exposition for the trace surface:
    /// `rosella_stage_us{stage=...}` histograms (cumulative buckets with
    /// the empty tail collapsed into `+Inf`, like [`Expo::histogram`]),
    /// the span counter, and the clock gauges.
    pub fn render_prometheus(&self, out: &mut String) {
        let mut e = Expo::new();
        e.header("rosella_stage_us", "histogram");
        for (i, name) in STAGES.iter().enumerate() {
            let snap = self.stages[i].snapshot();
            let hi = snap.counts.iter().rposition(|&c| c > 0).unwrap_or(0);
            let mut acc = 0u64;
            for (b, &c) in snap.counts.iter().enumerate().take((hi + 1).min(LOG2_BUCKETS - 1)) {
                acc += c;
                let le = format!("{}", bucket_upper(b));
                e.sample("rosella_stage_us_bucket", &[("stage", name), ("le", &le)], acc as f64);
            }
            e.sample(
                "rosella_stage_us_bucket",
                &[("stage", name), ("le", "+Inf")],
                snap.count() as f64,
            );
            e.sample("rosella_stage_us_sum", &[("stage", name)], snap.sum as f64);
            e.sample("rosella_stage_us_count", &[("stage", name)], snap.count() as f64);
        }
        e.counter("rosella_trace_spans_total", &[(&[], self.recorded())]);
        e.gauge("rosella_clock_offset_ns", &[(&[], self.clock_offset_ns.get())]);
        e.gauge("rosella_clock_error_ns", &[(&[], self.clock_error_ns.get())]);
        out.push_str(&e.finish());
    }

    /// Render the retained spans as Chrome trace-event JSON (complete
    /// `"ph":"X"` events, µs timestamps), loadable in Perfetto. Each
    /// task renders as six stacked stage events on `pid` = shard id,
    /// `tid` = low task-sequence bits.
    pub fn render_chrome_json(&self) -> String {
        let spans = self.spans();
        let mut out = String::with_capacity(64 + spans.len() * 6 * 96);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        for s in &spans {
            let pid = (s.job >> 48) as u32;
            let tid = s.job & 0xFFFF_FFFF;
            let mut ts = s.origin_us;
            for (i, name) in STAGES.iter().enumerate() {
                if !first {
                    out.push(',');
                }
                first = false;
                out.push_str(&format!(
                    "{{\"name\":\"{name}\",\"cat\":\"task\",\"ph\":\"X\",\"pid\":{pid},\
                     \"tid\":{tid},\"ts\":{ts},\"dur\":{dur},\"args\":{{\"job\":{job}}}}}",
                    dur = s.stages_us[i],
                    job = s.job,
                ));
                ts += u64::from(s.stages_us[i]);
            }
        }
        out.push_str("]}");
        out
    }

    /// Dump [`Self::render_chrome_json`] to a file.
    pub fn dump_chrome_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render_chrome_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_is_monotonic_and_shared() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
        let c = ns_of(Instant::now());
        assert!(c >= b);
    }

    #[test]
    fn sampling_is_deterministic_and_roughly_one_in_n() {
        assert!(!sampled(1, 0), "n=0 must disable sampling");
        assert!(sampled(17, 1), "n=1 must trace everything");
        let n = 64u32;
        let hits = (0..64_000u64).filter(|&j| sampled(j, n)).count();
        // Deterministic: same answer twice.
        assert_eq!(hits, (0..64_000u64).filter(|&j| sampled(j, n)).count());
        // Well-mixed: within a loose factor of the expected 1000.
        assert!((400..2500).contains(&hits), "1/64 sampling hit {hits} of 64000");
    }

    #[test]
    fn sample_spec_parses_canonical_and_bare_forms() {
        assert_eq!(parse_sample("1/64"), Ok(64));
        assert_eq!(parse_sample("1024"), Ok(1024));
        assert_eq!(parse_sample("off"), Ok(0));
        assert_eq!(parse_sample("0"), Ok(0));
        assert!(parse_sample("2/64").is_err());
        assert!(parse_sample("1/").is_err());
        assert!(parse_sample("fast").is_err());
    }

    #[test]
    fn clock_align_recovers_exact_offset_under_symmetric_delay() {
        // Remote clock runs 5 ms ahead; both legs take 100 µs.
        let mut c = ClockAlign::new();
        let (skew, leg) = (5_000_000i64, 100_000u64);
        let t0 = 1_000_000u64;
        let t1 = (t0 + leg) as i64 + skew;
        let t2 = t1 + 30_000; // remote processing
        let t3 = (t2 - skew) as u64 + leg;
        c.observe(t0, t1 as u64, t2 as u64, t3);
        assert!(c.aligned());
        assert_eq!(c.offset_ns(), skew as f64);
        assert_eq!(c.error_ns(), leg as f64);
        // Round-trip mapping is consistent.
        assert_eq!(c.to_local_ns(c.to_remote_ns(42_000)), 42_000);
    }

    #[test]
    fn clock_align_error_is_bounded_by_half_delay_under_asymmetry() {
        // Outbound 900 µs, return 100 µs: worst-case asymmetric routing.
        let (skew, a, b) = (2_000_000i64, 900_000u64, 100_000u64);
        let mut c = ClockAlign::new();
        let t0 = 500_000u64;
        let t1 = (t0 + a) as i64 + skew;
        let t2 = t1 + 10_000;
        let t3 = (t2 - skew) as u64 + b;
        c.observe(t0, t1 as u64, t2 as u64, t3);
        let err = (c.offset_ns() - skew as f64).abs();
        // Analytically the error is exactly |a − b| / 2, and always
        // within the advertised δ/2 bound.
        assert_eq!(err, (a as f64 - b as f64).abs() / 2.0);
        assert!(err <= c.error_ns() + 1e-9, "error {err} exceeds bound {}", c.error_ns());
    }

    #[test]
    fn clock_align_keeps_the_minimum_delay_exchange() {
        let mut c = ClockAlign::new();
        // Noisy exchange: huge delay, wildly wrong offset.
        c.observe(0, 10_000_000, 10_000_000, 20_000_000);
        let noisy = c.offset_ns();
        // Clean exchange: tight delay, true offset 1 ms.
        c.observe(100_000, 1_150_000, 1_160_000, 220_000);
        assert_ne!(c.offset_ns(), noisy);
        assert_eq!(c.offset_ns(), 1_000_000.0 - 5_000.0);
        assert_eq!(c.exchanges(), 2);
        // A later, worse exchange does not displace the best one.
        let best = c.offset_ns();
        c.observe(0, 50_000_000, 50_000_000, 30_000_000);
        assert_eq!(c.offset_ns(), best);
    }

    #[test]
    fn tracer_aggregates_spans_and_bounds_the_ring() {
        let t = Tracer::with_capacity(1, 4);
        for j in 0..10u64 {
            t.record(SpanRecord {
                job: j,
                origin_us: j * 100,
                stages_us: [1, 2, 3, 4, 5, 6],
            });
        }
        assert_eq!(t.recorded(), 10);
        let spans = t.spans();
        assert_eq!(spans.len(), 4, "ring must stay bounded");
        // Oldest-first snapshot of the last 4.
        assert_eq!(spans[0].job, 6);
        assert_eq!(spans[3].job, 9);
        assert_eq!(t.stage_snapshot(STAGE_SERVICE).count(), 10);
        assert_eq!(t.stage_snapshot(STAGE_SERVICE).sum, 50);
    }

    #[test]
    fn chrome_export_is_valid_json_with_stacked_complete_events() {
        let t = Tracer::with_capacity(64, 8);
        t.record(SpanRecord {
            job: (3u64 << 48) | 7,
            origin_us: 1000,
            stages_us: [10, 0, 5, 20, 40, 2],
        });
        let json = t.render_chrome_json();
        let v = crate::config::json::parse(&json).expect("chrome export parses as JSON");
        let events = v.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        assert_eq!(events.len(), STAGES.len());
        let mut expect_ts = 1000.0;
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.get("ph").and_then(|p| p.as_str()), Some("X"));
            assert_eq!(
                ev.get("name").and_then(|n| n.as_str()),
                Some(STAGES[i]),
                "stage order preserved"
            );
            assert_eq!(ev.get("pid").and_then(|p| p.as_f64()), Some(3.0));
            assert_eq!(ev.get("ts").and_then(|t| t.as_f64()), Some(expect_ts));
            expect_ts += ev.get("dur").and_then(|d| d.as_f64()).unwrap();
        }
    }

    #[test]
    fn prometheus_surface_exposes_every_stage_with_labels() {
        let t = Tracer::new(64);
        t.record(SpanRecord { job: 1, origin_us: 0, stages_us: [1, 1, 1, 1, 1, 1] });
        t.set_clock(1234.5, 99.0);
        let mut out = String::new();
        t.render_prometheus(&mut out);
        for s in STAGES {
            assert!(
                out.contains(&format!("rosella_stage_us_count{{stage=\"{s}\"}} 1")),
                "missing stage {s} in:\n{out}"
            );
            assert!(out.contains(&format!("rosella_stage_us_bucket{{stage=\"{s}\",le=\"+Inf\"}} 1")));
        }
        assert!(out.contains("rosella_trace_spans_total 1"));
        assert!(out.contains("rosella_clock_offset_ns 1234.5"));
        assert!(out.contains("rosella_clock_error_ns 99"));
        assert!(crate::obs::expo::is_well_formed(&out), "malformed exposition:\n{out}");
    }
}
