//! Tiny leveled logger, env-filtered via `ROSELLA_LOG`.
//!
//! Off by default so the hot paths and benches pay nothing beyond one
//! relaxed atomic load per *potential* log site; formatting only happens
//! when the level is enabled. Set `ROSELLA_LOG=error|warn|info|debug` to
//! turn it on. Output goes to stderr, prefixed with level and module, so
//! stdout stays reserved for reports and JSON.
//!
//! Use through the crate-root macros:
//!
//! ```
//! rosella::log_info!("pool listening on {}", "127.0.0.1:7411");
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Severity levels, ordered: a configured level enables itself and
/// everything more severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Logging disabled (the default).
    Off = 0,
    /// Unrecoverable or dropped-work conditions.
    Error = 1,
    /// Degraded but continuing.
    Warn = 2,
    /// Lifecycle events (listeners up, drains, consensus).
    Info = 3,
    /// Per-connection / per-epoch chatter.
    Debug = 4,
}

/// Parse a `ROSELLA_LOG` value; anything unrecognized is off.
pub fn parse_level(s: &str) -> Level {
    match s.trim().to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" | "warning" => Level::Warn,
        "info" => Level::Info,
        "debug" | "trace" => Level::Debug,
        _ => Level::Off,
    }
}

static CONFIGURED: OnceLock<u8> = OnceLock::new();
/// Test override: `u8::MAX` means "use the environment".
static OVERRIDE: AtomicU8 = AtomicU8::new(u8::MAX);

fn configured() -> u8 {
    *CONFIGURED.get_or_init(|| {
        std::env::var("ROSELLA_LOG").map(|v| parse_level(&v) as u8).unwrap_or(Level::Off as u8)
    })
}

/// Whether `level` is currently enabled.
#[inline]
pub fn enabled(level: Level) -> bool {
    let over = OVERRIDE.load(Ordering::Relaxed);
    let max = if over == u8::MAX { configured() } else { over };
    (level as u8) <= max && level != Level::Off
}

/// Force a level at runtime (tests; `None` restores the env setting).
pub fn set_level(level: Option<Level>) {
    OVERRIDE.store(level.map(|l| l as u8).unwrap_or(u8::MAX), Ordering::Relaxed);
}

/// Write one formatted record to stderr. Called by the macros only after
/// an `enabled` check, so disabled sites never format.
pub fn write(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN",
        Level::Info => "INFO",
        Level::Debug => "DEBUG",
        Level::Off => return,
    };
    eprintln!("[{tag}] {target}: {args}");
}

/// Log at error level (enabled by `ROSELLA_LOG=error` and above).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Error,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Warn,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at info level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Info,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Debug,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("error"), Level::Error);
        assert_eq!(parse_level("WARN"), Level::Warn);
        assert_eq!(parse_level(" info "), Level::Info);
        assert_eq!(parse_level("debug"), Level::Debug);
        assert_eq!(parse_level("trace"), Level::Debug);
        assert_eq!(parse_level(""), Level::Off);
        assert_eq!(parse_level("yes please"), Level::Off);
    }

    #[test]
    fn override_controls_enablement() {
        // Other tests may run concurrently, but only this module touches
        // the override; restore the env-derived setting when done.
        set_level(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Some(Level::Off));
        assert!(!enabled(Level::Error));
        set_level(None);
    }
}
