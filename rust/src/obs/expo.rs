//! Prometheus text-exposition encoding (format version 0.0.4).
//!
//! Hand-rolled because the repo is dependency-free: `# TYPE` headers,
//! `name{label="value"} 123` samples with proper label-value escaping
//! (`\\`, `\"`, `\n`), and cumulative `le`-bucketed histograms derived
//! from [`HistSnapshot`]s. The [`Expo`] builder is append-only; callers
//! compose the standard registry rendering ([`render_into`]) with any
//! extra live gauges (worker queue probes, wire counters) before
//! finishing.

use crate::obs::registry::{bucket_upper, HistSnapshot, Registry, LOG2_BUCKETS};

/// Escape a label value per the exposition format: backslash, double
/// quote, and line feed must be escaped; everything else passes through.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Whether `s` is a valid metric/label name: `[a-zA-Z_:][a-zA-Z0-9_:]*`
/// (label names additionally may not contain `:`, which none of ours do).
pub fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Append-only exposition builder.
#[derive(Debug, Default)]
pub struct Expo {
    buf: String,
}

impl Expo {
    /// Empty document.
    pub fn new() -> Self {
        Self::default()
    }

    /// Emit a `# TYPE` header (`kind` is `counter`, `gauge`, or
    /// `histogram`).
    pub fn header(&mut self, name: &str, kind: &str) {
        debug_assert!(valid_metric_name(name), "bad metric name {name}");
        self.buf.push_str("# TYPE ");
        self.buf.push_str(name);
        self.buf.push(' ');
        self.buf.push_str(kind);
        self.buf.push('\n');
    }

    /// Emit one sample line with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        debug_assert!(valid_metric_name(name), "bad metric name {name}");
        self.buf.push_str(name);
        if !labels.is_empty() {
            self.buf.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                debug_assert!(valid_metric_name(k), "bad label name {k}");
                if i > 0 {
                    self.buf.push(',');
                }
                self.buf.push_str(k);
                self.buf.push_str("=\"");
                self.buf.push_str(&escape_label_value(v));
                self.buf.push('"');
            }
            self.buf.push('}');
        }
        self.buf.push(' ');
        if value == value.trunc() && value.abs() < 1e15 {
            self.buf.push_str(&format!("{}", value as i64));
        } else {
            self.buf.push_str(&format!("{value}"));
        }
        self.buf.push('\n');
    }

    /// Emit a counter: header plus one sample per label set.
    pub fn counter(&mut self, name: &str, series: &[(&[(&str, &str)], u64)]) {
        self.header(name, "counter");
        for (labels, v) in series {
            self.sample(name, labels, *v as f64);
        }
    }

    /// Emit a gauge: header plus one sample per label set.
    pub fn gauge(&mut self, name: &str, series: &[(&[(&str, &str)], f64)]) {
        self.header(name, "gauge");
        for (labels, v) in series {
            self.sample(name, labels, *v);
        }
    }

    /// Emit a [`HistSnapshot`] as a Prometheus histogram. `scale` converts
    /// the recorded integer unit to the exposed unit (e.g. `1e-9` for
    /// ns → seconds); empty trailing buckets collapse into `+Inf`.
    pub fn histogram(&mut self, name: &str, snap: &HistSnapshot, scale: f64) {
        debug_assert!(valid_metric_name(name), "bad metric name {name}");
        self.header(name, "histogram");
        let total = snap.count();
        // Highest non-empty bucket: everything above it is represented by
        // the +Inf bucket alone, keeping scrapes compact.
        let hi = snap.counts.iter().rposition(|&c| c > 0).unwrap_or(0);
        let bucket = format!("{name}_bucket");
        let mut acc = 0u64;
        for (b, &c) in snap.counts.iter().enumerate().take((hi + 1).min(LOG2_BUCKETS - 1)) {
            acc += c;
            let le = format!("{}", bucket_upper(b) as f64 * scale);
            self.sample(&bucket, &[("le", &le)], acc as f64);
        }
        self.sample(&bucket, &[("le", "+Inf")], total as f64);
        self.sample(&format!("{name}_sum"), &[], snap.sum as f64 * scale);
        self.sample(&format!("{name}_count"), &[], total as f64);
    }

    /// Finished document.
    pub fn finish(self) -> String {
        self.buf
    }
}

/// Render the standard registry surface into `out`: per-shard task
/// counters, aggregated queue-length / decision-latency / response-time
/// histograms, per-worker μ̂ gauges, λ̂, and the consensus counters.
/// Callers append anything live (worker queue probes, wire counters)
/// before finishing.
pub fn render_into(reg: &Registry, out: &mut Expo) {
    let shard_labels: Vec<String> = (0..reg.n_shards()).map(|i| i.to_string()).collect();

    out.header("rosella_decisions_total", "counter");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample(
            "rosella_decisions_total",
            &[("shard", &shard_labels[i])],
            s.decisions.get() as f64,
        );
    }
    out.header("rosella_tasks_dispatched_total", "counter");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample(
            "rosella_tasks_dispatched_total",
            &[("shard", &shard_labels[i])],
            s.dispatched.get() as f64,
        );
    }
    out.header("rosella_tasks_completed_total", "counter");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample(
            "rosella_tasks_completed_total",
            &[("shard", &shard_labels[i])],
            s.completed.get() as f64,
        );
    }
    out.header("rosella_bench_tasks_total", "counter");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample(
            "rosella_bench_tasks_total",
            &[("shard", &shard_labels[i])],
            s.bench_dispatched.get() as f64,
        );
    }
    // Topology surface, rendered in every config (−1 = unpinned shard) so
    // dashboards keep their series across `--pin` modes.
    out.header("rosella_shard_cpu", "gauge");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample("rosella_shard_cpu", &[("shard", &shard_labels[i])], s.shard_cpu.get());
    }
    out.header("rosella_cross_socket_decisions_total", "counter");
    for (i, s) in reg.shards().iter().enumerate() {
        out.sample(
            "rosella_cross_socket_decisions_total",
            &[("shard", &shard_labels[i])],
            s.cross_socket.get() as f64,
        );
    }

    out.histogram("rosella_queue_len", &reg.aggregate(|s| &s.queue_len), 1.0);
    out.histogram("rosella_decision_seconds", &reg.aggregate(|s| &s.decision_ns), 1e-9);
    out.histogram("rosella_response_seconds", &reg.aggregate(|s| &s.response_us), 1e-6);
    out.histogram("rosella_wire_tasks_per_frame", &reg.wire_batch.snapshot(), 1.0);

    // Net data-plane poller surface: per-shard wakeup counters plus the
    // aggregated events-per-wake histogram (how many sockets one kernel
    // wakeup served — the sweep fallback reports every socket per pass).
    out.header("rosella_poll_wakeups_total", "counter");
    for (i, p) in reg.poll_shards().iter().enumerate() {
        let label = i.to_string();
        out.sample(
            "rosella_poll_wakeups_total",
            &[("poll_shard", &label)],
            p.wakeups.get() as f64,
        );
    }
    let mut events_per_wake = HistSnapshot::empty();
    for p in reg.poll_shards() {
        p.events_per_wake.merge_into(&mut events_per_wake);
    }
    out.histogram("rosella_poll_events_per_wake", &events_per_wake, 1.0);

    out.header("rosella_mu_hat", "gauge");
    for w in 0..reg.n_workers() {
        let label = w.to_string();
        out.sample("rosella_mu_hat", &[("worker", &label)], reg.mu_hat(w));
    }
    out.gauge("rosella_lambda_hat", &[(&[], reg.lambda_hat.get())]);

    out.counter("rosella_sync_epochs_total", &[(&[], reg.sync_epochs.get())]);
    out.counter("rosella_sync_merges_total", &[(&[], reg.sync_merges.get())]);
    out.counter("rosella_sync_exports_total", &[(&[], reg.sync_exports.get())]);
    out.counter("rosella_estimate_publishes_total", &[(&[], reg.publishes.get())]);
    out.counter("rosella_arrivals_total", &[(&[], reg.arrivals.get())]);
}

/// One-call rendering of the standard surface (tests, simple callers).
pub fn render(reg: &Registry) -> String {
    let mut e = Expo::new();
    render_into(reg, &mut e);
    e.finish()
}

/// Structural well-formedness check used by tests and the CI gate logic:
/// every non-comment, non-blank line must be
/// `name{labels} value` or `name value` with a valid metric name and a
/// parseable float value.
pub fn is_well_formed(doc: &str) -> bool {
    for line in doc.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return false,
        };
        if value.parse::<f64>().is_err() && value != "+Inf" && value != "-Inf" && value != "NaN" {
            return false;
        }
        let name = match head.find('{') {
            Some(i) => {
                if !head.ends_with('}') {
                    return false;
                }
                &head[..i]
            }
            None => head,
        };
        if !valid_metric_name(name) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape_label_value("two\nlines"), "two\\nlines");
        // Composed: every special char at once, round-trip stable length.
        assert_eq!(escape_label_value("\\\"\n"), "\\\\\\\"\\n");
    }

    #[test]
    fn metric_name_validity() {
        assert!(valid_metric_name("rosella_tasks_completed_total"));
        assert!(valid_metric_name("_x"));
        assert!(valid_metric_name("a:b"));
        assert!(!valid_metric_name(""));
        assert!(!valid_metric_name("9lives"));
        assert!(!valid_metric_name("has space"));
        assert!(!valid_metric_name("hyphen-ated"));
    }

    #[test]
    fn sample_lines_render_labels() {
        let mut e = Expo::new();
        e.header("m_total", "counter");
        e.sample("m_total", &[("shard", "0"), ("kind", "a\"b")], 3.0);
        let doc = e.finish();
        assert_eq!(doc, "# TYPE m_total counter\nm_total{shard=\"0\",kind=\"a\\\"b\"} 3\n");
        assert!(is_well_formed(&doc));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let mut snap = crate::obs::registry::HistSnapshot::empty();
        snap.counts[1] = 2; // two samples of value 1
        snap.counts[3] = 1; // one sample in [4, 8)
        snap.sum = 7;
        let mut e = Expo::new();
        e.histogram("lat", &snap, 1.0);
        let doc = e.finish();
        assert!(doc.contains("# TYPE lat histogram"));
        assert!(doc.contains("lat_bucket{le=\"1\"} 2"));
        assert!(doc.contains("lat_bucket{le=\"7\"} 3"));
        assert!(doc.contains("lat_bucket{le=\"+Inf\"} 3"));
        assert!(doc.contains("lat_sum 7"));
        assert!(doc.contains("lat_count 3"));
        assert!(is_well_formed(&doc));
        // Cumulative counts never decrease.
        let mut last = 0.0;
        for line in doc.lines().filter(|l| l.starts_with("lat_bucket")) {
            let v: f64 = line.rsplit_once(' ').unwrap().1.parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {doc}");
            last = v;
        }
    }

    #[test]
    fn registry_rendering_is_well_formed_and_covers_surface() {
        let reg = Registry::with_poll_shards(2, 3, 2);
        reg.shard(0).dispatched.add(10);
        reg.shard(1).dispatched.add(5);
        reg.shard(0).completed.add(9);
        reg.shard(0).queue_len.record(2);
        reg.shard(0).response_us.record(1500);
        reg.set_mu_hat(&[1.0, 2.0, 0.5]);
        reg.lambda_hat.set(123.0);
        reg.sync_merges.add(4);
        reg.wire_batch.record(64);
        reg.poll_shard(1).wakeups.add(7);
        reg.poll_shard(0).events_per_wake.record(3);
        reg.poll_shard(1).events_per_wake.record(1);
        let doc = render(&reg);
        assert!(is_well_formed(&doc), "malformed exposition:\n{doc}");
        for name in [
            "rosella_tasks_dispatched_total",
            "rosella_tasks_completed_total",
            "rosella_decisions_total",
            "rosella_queue_len_bucket",
            "rosella_response_seconds_sum",
            "rosella_wire_tasks_per_frame_count",
            "rosella_mu_hat",
            "rosella_lambda_hat",
            "rosella_sync_merges_total",
            "rosella_shard_cpu",
            "rosella_cross_socket_decisions_total",
            "rosella_poll_wakeups_total",
            "rosella_poll_events_per_wake_count",
        ] {
            assert!(doc.contains(name), "missing {name} in:\n{doc}");
        }
        assert!(doc.contains("rosella_tasks_dispatched_total{shard=\"1\"} 5"));
        assert!(doc.contains("rosella_mu_hat{worker=\"2\"} 0.5"));
        // Poll slots render per shard; the histogram aggregates both.
        assert!(doc.contains("rosella_poll_wakeups_total{poll_shard=\"1\"} 7"));
        assert!(doc.contains("rosella_poll_wakeups_total{poll_shard=\"0\"} 0"));
        assert!(doc.contains("rosella_poll_events_per_wake_count 2"));
        // Topology gauges exist even with pinning disabled: the unpinned
        // sentinel is rendered, not omitted.
        assert!(doc.contains("rosella_shard_cpu{shard=\"0\"} -1"));
        assert!(doc.contains("rosella_cross_socket_decisions_total{shard=\"1\"} 0"));
        reg.shard(1).shard_cpu.set(5.0);
        reg.shard(1).cross_socket.inc();
        let doc = render(&reg);
        assert!(doc.contains("rosella_shard_cpu{shard=\"1\"} 5"));
        assert!(doc.contains("rosella_cross_socket_decisions_total{shard=\"1\"} 1"));
    }

    #[test]
    fn well_formedness_rejects_garbage() {
        assert!(is_well_formed("# just a comment\n"));
        assert!(!is_well_formed("no_value_here\n"));
        assert!(!is_well_formed("bad-name 1\n"));
        assert!(!is_well_formed("name{unclosed 1\n"));
        assert!(!is_well_formed("name not_a_number\n"));
    }
}
