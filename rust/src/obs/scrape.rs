//! `/metrics` scrape endpoint: a minimal HTTP/1.1 listener over
//! `std::net`, serving whatever the registered handler renders.
//!
//! One accept-loop thread, one short-lived request per connection
//! (`Connection: close`) — exactly what a Prometheus scraper or a `curl`
//! in the CI loopback smoke needs, and nothing more. The handler is a
//! closure from request path to `(content type, body)`, so the plane can
//! route `/metrics` to the exposition renderer and `/flight` to the
//! flight-recorder JSONL dump without this module knowing about either.
//! `/healthz` is answered here (200 `ok`) before the handler is consulted,
//! and unknown paths get a proper 404 with `Content-Length` framing.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Route handler: path → `Some((content_type, body))` or `None` for 404.
pub type Handler = dyn Fn(&str) -> Option<(&'static str, String)> + Send + Sync;

/// A running scrape listener. Shut down explicitly with
/// [`MetricsServer::shutdown`] or implicitly on drop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsServer").field("addr", &self.addr).finish()
    }
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve requests on a background
    /// thread until shutdown.
    pub fn spawn(addr: &str, handler: Arc<Handler>) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("rosella-metrics".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop_flag.load(Ordering::Acquire) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            // Serve inline: scrapes are tiny and rare, and a
                            // stuck client is bounded by the read timeout.
                            let _ = serve_one(s, &handler);
                        }
                        Err(e) => {
                            crate::log_debug!("metrics accept error: {e}");
                        }
                    }
                }
            })?;
        crate::log_info!("metrics endpoint listening on http://{local}/metrics");
        Ok(Self { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::Release);
            // Wake the blocking accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

/// Read one request head, dispatch on its path, write one response.
fn serve_one(mut stream: TcpStream, handler: &Arc<Handler>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    // Read until the end of the request head (or a bounded limit — this
    // endpoint takes no bodies).
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.len() > 8192 {
            return respond(&mut stream, 400, "text/plain", "request too large");
        }
    }
    let text = String::from_utf8_lossy(&head);
    let mut parts = text.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    if method != "GET" {
        return respond(&mut stream, 405, "text/plain", "method not allowed");
    }
    // Strip any query string before routing.
    let path = path.split('?').next().unwrap_or(path);
    // Liveness probe, served by every endpoint regardless of handler: a
    // 200 here means the accept loop is alive, nothing more.
    if path == "/healthz" {
        return respond(&mut stream, 200, "text/plain", "ok\n");
    }
    match handler(path) {
        Some((content_type, body)) => respond(&mut stream, 200, content_type, &body),
        None => respond(&mut stream, 404, "text/plain", "not found"),
    }
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Content type for Prometheus text exposition.
pub const EXPOSITION_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

#[cfg(test)]
mod tests {
    use super::*;

    fn get_raw(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let out = get_raw(addr, path);
        let status: u16 =
            out.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
        let body = out.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
        (status, body)
    }

    #[test]
    fn serves_routes_and_404s() {
        let handler: Arc<Handler> = Arc::new(|path: &str| match path {
            "/metrics" => {
                Some((EXPOSITION_CONTENT_TYPE, "rosella_up 1\n".to_string()))
            }
            "/flight" => Some(("application/jsonl", "{\"ev\":\"x\"}\n".to_string())),
            _ => None,
        });
        let server = MetricsServer::spawn("127.0.0.1:0", handler).unwrap();
        let addr = server.addr();
        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert_eq!(body, "rosella_up 1\n");
        let (status, body) = get(addr, "/flight");
        assert_eq!(status, 200);
        assert!(body.starts_with('{'));
        let (status, _) = get(addr, "/nope");
        assert_eq!(status, 404);
        // Query strings are stripped before routing.
        let (status, _) = get(addr, "/metrics?x=1");
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn healthz_is_built_in_and_404_carries_framing_headers() {
        // Even a handler that serves nothing still answers the liveness
        // probe, and its 404s carry a correct Content-Length so keep-alive
        // clients and proxies can frame the response.
        let handler: Arc<Handler> = Arc::new(|_| None);
        let server = MetricsServer::spawn("127.0.0.1:0", handler).unwrap();
        let addr = server.addr();
        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(body, "ok\n");
        let raw = get_raw(addr, "/missing");
        assert!(raw.starts_with("HTTP/1.1 404 Not Found"), "bad status line: {raw}");
        let body = "not found";
        assert!(
            raw.contains(&format!("Content-Length: {}\r\n", body.len())),
            "404 must declare its body length: {raw}"
        );
        assert!(raw.contains("Connection: close\r\n"));
        assert!(raw.ends_with(body), "404 body mismatch: {raw}");
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly_and_frees_the_port() {
        let handler: Arc<Handler> = Arc::new(|_| None);
        let server = MetricsServer::spawn("127.0.0.1:0", handler).unwrap();
        let addr = server.addr();
        server.shutdown();
        // Port is free again: a new bind on the same address succeeds.
        let _rebound = TcpListener::bind(addr).unwrap();
    }
}
