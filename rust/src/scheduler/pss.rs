//! Proportional sampling schedule (PSS, §3.1).
//!
//! Each task is routed to a worker drawn from the multinomial with
//! `p_i = μ̂_i / Σ μ̂`. With accurate estimates every worker behaves like an
//! independent queue loaded at the system ratio α, giving max queue O(log n).
//! The draw is O(1) through the alias table carried in the cluster view.

use super::{per_task, Policy};
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// Proportional sampling without queue probes.
#[derive(Debug, Default)]
pub struct Pss;

impl Pss {
    /// New PSS policy.
    pub fn new() -> Self {
        Self
    }
}

impl Policy for Pss {
    fn name(&self) -> String {
        "pss".into()
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        per_task(job, |_| view.sample(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;

    #[test]
    fn proportional_to_estimates() {
        let mut p = Pss::new();
        let mut rng = Rng::new(7);
        let q = vec![0; 3];
        let mu = vec![1.0, 2.0, 5.0];
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 1.0 };
        let job = JobSpec::single(0.1);
        let mut counts = [0usize; 3];
        let n = 80_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view, &mut rng) {
                counts[w0] += 1;
            }
        }
        let total: f64 = mu.iter().sum();
        for i in 0..3 {
            let frac = counts[i] as f64 / n as f64;
            assert!((frac - mu[i] / total).abs() < 0.01, "i={i} counts={counts:?}");
        }
    }

    #[test]
    fn ignores_queue_lengths() {
        // PSS has no queue information: a fully loaded fast worker still
        // receives proportional traffic.
        let mut p = Pss::new();
        let mut rng = Rng::new(8);
        let q = vec![1000, 0];
        let mu = vec![9.0, 1.0];
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 1.0 };
        let job = JobSpec::single(0.1);
        let mut fast = 0;
        let n = 40_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view, &mut rng) {
                if w0 == 0 {
                    fast += 1;
                }
            }
        }
        assert!((fast as f64 / n as f64 - 0.9).abs() < 0.01);
    }
}
