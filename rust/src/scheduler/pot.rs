//! Power-of-d-choices with uniform probes (§2.1.1).
//!
//! Probes `d` workers uniformly at random (distinct) and assigns the task to
//! the one with the shortest queue. Optimal for homogeneous clusters
//! (max queue O(log log n), [11]); with heterogeneous speeds the slow
//! majority still absorbs most of the load (Example 2: 0.81 probability of
//! picking two slow workers → aggregate 11.34 arrivals vs 9 capacity).

use super::{per_task, Policy};
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// Power-of-`d`-choices with uniform sampling (the classical PoT is d = 2).
#[derive(Debug)]
pub struct PoT {
    d: usize,
}

impl PoT {
    /// New policy with `d >= 1` probes.
    pub fn new(d: usize) -> Self {
        assert!(d >= 1, "pot needs at least one probe");
        Self { d }
    }
}

impl Policy for PoT {
    fn name(&self) -> String {
        if self.d == 2 {
            "pot".into()
        } else {
            format!("pot{}", self.d)
        }
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        let n = view.n();
        let d = self.d.min(n);
        per_task(job, |_| {
            let mut best = rng.gen_index(n);
            for _ in 1..d {
                let cand = rng.gen_index(n);
                if view.queue_len(cand) < view.queue_len(best) {
                    best = cand;
                }
            }
            best
        })
    }

    fn needs_estimates(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;
    use crate::types::TaskSpec;

    fn view<'a>(q: &'a [usize], mu: &'a [f64], t: &'a AliasTable) -> LocalView<'a> {
        LocalView { queue_len: q, mu_hat: mu, sampler: t, lambda_hat: 1.0 }
    }

    #[test]
    fn prefers_shorter_queue() {
        let mut p = PoT::new(2);
        let mut rng = Rng::new(3);
        // Worker 0 empty, workers 1..9 heavily loaded.
        let mut q = vec![100usize; 10];
        q[0] = 0;
        let mu = vec![1.0; 10];
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut zero = 0;
        let n = 20_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
                if w0 == 0 {
                    zero += 1;
                }
            }
        }
        // P(worker 0 among 2 uniform probes) = 1 - (9/10)^2 = 0.19.
        let frac = zero as f64 / n as f64;
        assert!((frac - 0.19).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn example2_slow_worker_mass() {
        // Paper Example 2: 9 slow + 1 fast; with prob 0.81 both probes land
        // on slow workers. With equal queue lengths the chosen worker is
        // slow at least 81% of the time.
        let mut p = PoT::new(2);
        let mut rng = Rng::new(4);
        let q = vec![5usize; 10];
        let mu = vec![1.0; 10];
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut slow = 0;
        let n = 50_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
                if w0 != 9 {
                    slow += 1;
                }
            }
        }
        let frac = slow as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.01, "slow frac {frac}"); // ties keep first probe
    }

    #[test]
    fn d1_degenerates_to_uniform() {
        let mut p = PoT::new(1);
        let mut rng = Rng::new(5);
        let q = vec![0, 100];
        let mu = vec![1.0, 1.0];
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut one = 0;
        for _ in 0..10_000 {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
                one += w0;
            }
        }
        // d=1 ignores queue lengths entirely.
        assert!((one as f64 / 10_000.0 - 0.5).abs() < 0.03);
    }

    #[test]
    fn multi_task_jobs_get_independent_choices() {
        let mut p = PoT::new(2);
        let mut rng = Rng::new(6);
        let q = vec![0; 16];
        let mu = vec![1.0; 16];
        let t = AliasTable::new(&mu);
        let job = JobSpec::new(vec![TaskSpec::new(0.1); 8]);
        if let JobPlacement::PerTask(ws) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
            assert_eq!(ws.len(), 8);
            let distinct: std::collections::HashSet<_> = ws.iter().collect();
            assert!(distinct.len() > 1, "all tasks on one worker: {ws:?}");
        } else {
            panic!("multi-task job must use PerTask");
        }
    }
}
