//! Uniform random placement (§2.1.1).
//!
//! The classical baseline: every task goes to a uniformly random worker.
//! With homogeneous speeds each queue is an independent M/M/1 and the
//! maximum queue length is O(log n); with heterogeneous speeds slow workers
//! receive more than they can process and their queues grow without bound
//! (Example 1: λ₁ = 1.4 > μ₁ = 1).

use super::{per_task, Policy};
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// Uniform random scheduler.
#[derive(Debug, Default)]
pub struct Uniform;

impl Uniform {
    /// New uniform policy.
    pub fn new() -> Self {
        Self
    }
}

impl Policy for Uniform {
    fn name(&self) -> String {
        "uniform".into()
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        let n = view.n();
        per_task(job, |_| rng.gen_index(n))
    }

    fn needs_estimates(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;

    fn view<'a>(q: &'a [usize], mu: &'a [f64], t: &'a AliasTable) -> LocalView<'a> {
        LocalView { queue_len: q, mu_hat: mu, sampler: t, lambda_hat: 1.0 }
    }

    #[test]
    fn places_every_unconstrained_task() {
        let mut p = Uniform::new();
        let mut rng = Rng::new(1);
        let q = vec![0; 8];
        let mu = vec![1.0; 8];
        let t = AliasTable::new(&mu);
        let job = JobSpec::new(vec![crate::types::TaskSpec::new(0.1); 5]);
        match p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
            JobPlacement::PerTask(ws) => {
                assert_eq!(ws.len(), 5);
                assert!(ws.iter().all(|&w| w < 8));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn is_uniform_over_workers() {
        let mut p = Uniform::new();
        let mut rng = Rng::new(2);
        let q = vec![0; 4];
        let mu = vec![1.0, 10.0, 100.0, 1000.0]; // must be ignored
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
                counts[w0] += 1;
            }
        }
        for &c in &counts {
            assert!((c as f64 / n as f64 - 0.25).abs() < 0.02, "{counts:?}");
        }
    }
}
