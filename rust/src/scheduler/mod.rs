//! Scheduling policies: Rosella's PPoT and every baseline the paper
//! evaluates (§6).
//!
//! | Policy | Paper reference | Probe info | Needs learning |
//! |---|---|---|---|
//! | [`Uniform`] | §2.1.1 "uniform algorithm" | none | no |
//! | [`PoT`] | §2.1.1 power-of-two-choices | 2 queue lengths | no |
//! | [`Pss`] | §3.1 proportional sampling | none | yes |
//! | [`PPoT`] | §3.1 Rosella's policy (SQ(2)/LL(2)) | 2 queue lengths | yes |
//! | [`Sparrow`] | [7] batch sampling + late binding | reservations | no |
//! | [`Bandit`] | §6 baseline (v): ε-greedy explore | mixed | yes |
//! | [`Halo`] | [10] oracle water-filling routing | none | oracle |
//!
//! All policies implement [`Policy`]; an experiment instantiates one via
//! [`PolicyKind::build`].

pub mod bandit;
pub mod halo;
pub mod pot;
pub mod ppot;
pub mod pss;
pub mod sparrow;
pub mod uniform;

pub use bandit::Bandit;
pub use halo::Halo;
pub use pot::PoT;
pub use ppot::{PPoT, TieRule};
pub use pss::Pss;
pub use sparrow::Sparrow;
pub use uniform::Uniform;

use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// A task-scheduling policy. One instance serves one scheduler (frontend);
/// the sharded plane builds one instance per frontend thread.
pub trait Policy: Send {
    /// Human-readable name used in reports.
    fn name(&self) -> String;

    /// Place the *unconstrained* tasks of `job`. Constrained tasks are
    /// routed by the engine directly and never reach the policy.
    ///
    /// `view` is any [`ClusterView`] backing: borrowed slices in the
    /// single-frontend drivers, or the lock-free shared view of the plane.
    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement;

    /// Notification that the learner published fresh estimates. Policies
    /// that precompute routing tables (Halo) react here. `lambda_hat` is
    /// expressed in the same service-rate units as `mu_hat` (task arrivals
    /// per second × mean task demand), so `lambda_hat / sum(mu_hat)` is the
    /// load ratio.
    fn on_estimates(&mut self, _mu_hat: &[f64], _lambda_hat: f64) {}

    /// Whether the policy's decisions depend on speed estimates at all.
    /// Policies that return `false` (uniform, PoT, Sparrow) are insensitive
    /// to learner state — the property behind Figure 8b's observation that
    /// Sparrow "does not degrade" under volatility.
    fn needs_estimates(&self) -> bool {
        true
    }
}

/// Helper: per-task placement via a closure, shared by the simple policies.
pub(crate) fn per_task<F>(job: &JobSpec, mut pick: F) -> JobPlacement
where
    F: FnMut(usize) -> usize,
{
    let m = job.unconstrained();
    if m == 1 {
        // Allocation-free fast path: single-task jobs dominate serving
        // workloads and the §4 theoretical model.
        JobPlacement::Single(pick(0))
    } else {
        JobPlacement::PerTask((0..m).map(&mut pick).collect())
    }
}

/// Configuration-level policy selector (CLI strings, experiment configs).
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    Uniform,
    /// Power-of-`d`-choices with uniform probes.
    PoT { d: usize },
    Pss,
    /// Rosella's policy. `late_binding` enables Sparrow-style reservations
    /// on top of proportional PoT (§6.1 "Integration with late-binding").
    PPoT { tie: TieRule, late_binding: bool },
    /// Sparrow with batch sampling and late binding; `probes_per_task` = 2
    /// in the paper.
    Sparrow { probes_per_task: usize },
    /// ε-greedy multi-armed bandit, η ∈ {0.2, 0.3} in §6.
    Bandit { eta: f64 },
    Halo,
}

impl PolicyKind {
    /// Instantiate the policy for a cluster of `n` workers.
    pub fn build(&self, n: usize) -> Box<dyn Policy> {
        match *self {
            PolicyKind::Uniform => Box::new(Uniform::new()),
            PolicyKind::PoT { d } => Box::new(PoT::new(d)),
            PolicyKind::Pss => Box::new(Pss::new()),
            PolicyKind::PPoT { tie, late_binding } => Box::new(PPoT::new(tie, late_binding)),
            PolicyKind::Sparrow { probes_per_task } => Box::new(Sparrow::new(probes_per_task)),
            PolicyKind::Bandit { eta } => Box::new(Bandit::new(eta)),
            PolicyKind::Halo => Box::new(Halo::new(n)),
        }
    }

    /// Whether this policy requires the learner to be useful (PSS-family)
    /// as opposed to ignoring estimates entirely.
    pub fn needs_estimates(&self) -> bool {
        !matches!(
            self,
            PolicyKind::Uniform | PolicyKind::PoT { .. } | PolicyKind::Sparrow { .. }
        )
    }

    /// Parse CLI names: `uniform`, `pot`, `pot:<d>`, `pss`, `ppot`,
    /// `ppot-ll2`, `rosella` (= ppot + late binding), `sparrow`,
    /// `bandit:<eta>`, `halo`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "uniform" | "random" => return Ok(PolicyKind::Uniform),
            "pot" => return Ok(PolicyKind::PoT { d: 2 }),
            "pss" => return Ok(PolicyKind::Pss),
            "ppot" | "ppot-sq2" => {
                return Ok(PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false })
            }
            "ppot-ll2" => return Ok(PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false }),
            "rosella" => return Ok(PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true }),
            "sparrow" => return Ok(PolicyKind::Sparrow { probes_per_task: 2 }),
            "halo" => return Ok(PolicyKind::Halo),
            _ => {}
        }
        let parts: Vec<&str> = lower.split(':').collect();
        match parts.as_slice() {
            ["pot", d] => Ok(PolicyKind::PoT { d: d.parse().map_err(|e| format!("bad d: {e}"))? }),
            ["bandit", eta] => Ok(PolicyKind::Bandit {
                eta: eta.parse().map_err(|e| format!("bad eta: {e}"))?,
            }),
            _ => Err(format!("unknown policy '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        assert_eq!(PolicyKind::parse("uniform").unwrap(), PolicyKind::Uniform);
        assert_eq!(PolicyKind::parse("pot").unwrap(), PolicyKind::PoT { d: 2 });
        assert_eq!(PolicyKind::parse("pot:3").unwrap(), PolicyKind::PoT { d: 3 });
        assert_eq!(PolicyKind::parse("pss").unwrap(), PolicyKind::Pss);
        assert_eq!(
            PolicyKind::parse("ppot").unwrap(),
            PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }
        );
        assert_eq!(
            PolicyKind::parse("ppot-ll2").unwrap(),
            PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false }
        );
        assert_eq!(
            PolicyKind::parse("rosella").unwrap(),
            PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: true }
        );
        assert_eq!(
            PolicyKind::parse("sparrow").unwrap(),
            PolicyKind::Sparrow { probes_per_task: 2 }
        );
        assert_eq!(PolicyKind::parse("bandit:0.2").unwrap(), PolicyKind::Bandit { eta: 0.2 });
        assert_eq!(PolicyKind::parse("halo").unwrap(), PolicyKind::Halo);
        assert!(PolicyKind::parse("wat").is_err());
    }

    #[test]
    fn needs_estimates_classification() {
        assert!(!PolicyKind::Uniform.needs_estimates());
        assert!(!PolicyKind::PoT { d: 2 }.needs_estimates());
        assert!(!PolicyKind::Sparrow { probes_per_task: 2 }.needs_estimates());
        assert!(PolicyKind::Pss.needs_estimates());
        assert!(PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false }.needs_estimates());
        assert!(PolicyKind::Halo.needs_estimates());
    }

    #[test]
    fn build_produces_named_policies() {
        let names: Vec<String> = [
            PolicyKind::Uniform,
            PolicyKind::PoT { d: 2 },
            PolicyKind::Pss,
            PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            PolicyKind::Sparrow { probes_per_task: 2 },
            PolicyKind::Bandit { eta: 0.2 },
            PolicyKind::Halo,
        ]
        .iter()
        .map(|k| k.build(10).name())
        .collect();
        assert!(names.iter().all(|n| !n.is_empty()));
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate names: {names:?}");
    }
}
