//! Rosella's scheduling policy: proportional sampling + power-of-two-choices
//! (PPoT, §3.1, pseudocode Fig. 5).
//!
//! Two candidate workers are drawn from the proportional-sampling
//! multinomial (with replacement — the paper runs "the proportional sampling
//! algorithm twice"), then the job is placed using one of two tie rules:
//!
//! * **SQ(2)** — join the *shortest queue* (Rosella's choice). Slower
//!   workers are utilized before fast workers become too full, which is
//!   what reduces the max queue to O(log log n).
//! * **LL(2)** — join the *least loaded* queue, i.e. smallest expected wait
//!   `(q+1)/μ̂`. Provided for the Figure 13 comparison: LL(2) keeps piling
//!   onto fast workers until everybody is as slow as the slowest server
//!   (Example 3).
//!
//! With `late_binding = true` the policy emits two reservations per task
//! instead of a direct placement (§6.1 "Integration with late-binding").

use super::{per_task, Policy};
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec, WorkerId};

/// Rule for choosing between the two probed candidates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieRule {
    /// Join the shortest queue (Rosella, §3.1).
    Sq2,
    /// Join the least-loaded queue (shortest expected wait).
    Ll2,
}

/// Proportional-sampling power-of-two-choices.
#[derive(Debug)]
pub struct PPoT {
    tie: TieRule,
    late_binding: bool,
}

impl PPoT {
    /// New PPoT policy with the given tie rule.
    pub fn new(tie: TieRule, late_binding: bool) -> Self {
        Self { tie, late_binding }
    }

    /// Pick between two candidates using the configured rule.
    #[inline]
    fn choose(&self, a: WorkerId, b: WorkerId, view: &dyn ClusterView) -> WorkerId {
        match self.tie {
            TieRule::Sq2 => {
                if view.queue_len(b) < view.queue_len(a) {
                    b
                } else {
                    a
                }
            }
            TieRule::Ll2 => {
                if view.expected_wait(b) < view.expected_wait(a) {
                    b
                } else {
                    a
                }
            }
        }
    }
}

impl Policy for PPoT {
    fn name(&self) -> String {
        let base = match self.tie {
            TieRule::Sq2 => "ppot-sq2",
            TieRule::Ll2 => "ppot-ll2",
        };
        if self.late_binding {
            format!("{base}+lb")
        } else {
            base.into()
        }
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        if self.late_binding {
            // Two proportionally-sampled reservations per task; the first
            // worker to reach a reservation pulls the task (late binding).
            let m = job.unconstrained();
            let mut ws = Vec::with_capacity(2 * m);
            for _ in 0..m {
                let (a, b) = view.sample_pair(rng);
                ws.push(a);
                ws.push(b);
            }
            JobPlacement::Reservations(ws)
        } else {
            per_task(job, |_| {
                let (a, b) = view.sample_pair(rng);
                self.choose(a, b, view)
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;

    fn view<'a>(q: &'a [usize], mu: &'a [f64], t: &'a AliasTable) -> LocalView<'a> {
        LocalView { queue_len: q, mu_hat: mu, sampler: t, lambda_hat: 1.0 }
    }

    #[test]
    fn sq2_takes_shorter_queue_of_probed_pair() {
        let mut p = PPoT::new(TieRule::Sq2, false);
        let mut rng = Rng::new(11);
        // Two workers only, so both probes hit {0,1}; worker 1 shorter.
        let q = vec![10, 2];
        let mu = vec![1.0, 1.0];
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        for _ in 0..200 {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng)
            {
                // Either both probes hit 0 (prob 1/4) or worker 1 wins.
                assert!(w0 == 1 || w0 == 0);
            }
        }
        // Statistically worker 1 must dominate: P(choose 1) = 3/4.
        let mut one = 0;
        let n = 40_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng)
            {
                one += w0;
            }
        }
        assert!((one as f64 / n as f64 - 0.75).abs() < 0.01);
    }

    #[test]
    fn sq2_vs_ll2_on_figure4_example() {
        // Paper Figure 4: left worker has the shorter queue but is slower
        // (longer expected wait). SQ(2) picks left; LL(2) picks right.
        let q = vec![2usize, 4];
        let mu = vec![0.5, 4.0]; // waits: 3/0.5 = 6 vs 5/4 = 1.25
        let t = AliasTable::new(&[1.0, 1.0]); // force both candidates probed
        let v = view(&q, &mu, &t);
        let sq = PPoT::new(TieRule::Sq2, false);
        let ll = PPoT::new(TieRule::Ll2, false);
        assert_eq!(sq.choose(0, 1, &v), 0, "SQ(2) chooses the shorter queue");
        assert_eq!(ll.choose(0, 1, &v), 1, "LL(2) chooses the shorter wait");
    }

    #[test]
    fn probes_are_proportional() {
        let mut p = PPoT::new(TieRule::Sq2, false);
        let mut rng = Rng::new(12);
        // Equal queues -> choice decided by probes alone. Worker 1 has 4x
        // the estimate, so P(worker 1 involved) = 1 - (0.2)^2 = 0.96.
        let q = vec![3, 3];
        let mu = vec![1.0, 4.0];
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut one = 0;
        let n = 60_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view(&q, &mu, &t), &mut rng)
            {
                one += w0;
            }
        }
        // Equal queue lengths: SQ2 keeps the first probe unless the second
        // is strictly shorter, so P(place at 1) = P(first probe = 1) = 0.8.
        assert!((one as f64 / n as f64 - 0.8).abs() < 0.01, "frac={}", one as f64 / n as f64);
    }

    #[test]
    fn late_binding_emits_two_reservations_per_task() {
        let mut p = PPoT::new(TieRule::Sq2, true);
        let mut rng = Rng::new(13);
        let q = vec![0; 6];
        let mu = vec![1.0; 6];
        let t = AliasTable::new(&mu);
        let job = JobSpec::new(vec![crate::types::TaskSpec::new(0.1); 4]);
        match p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
            JobPlacement::Reservations(ws) => {
                assert_eq!(ws.len(), 8);
                assert!(ws.iter().all(|&w| w < 6));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn ll2_treats_zero_estimate_as_infinitely_slow() {
        let q = vec![0usize, 50];
        let mu = vec![0.0, 2.0];
        let t = AliasTable::new(&[1.0, 1.0]);
        let v = view(&q, &mu, &t);
        let ll = PPoT::new(TieRule::Ll2, false);
        assert_eq!(ll.choose(0, 1, &v), 1, "zero-estimate worker must lose");
    }
}
