//! Multi-armed-bandit baseline (§6, baseline (v)).
//!
//! ε-greedy exploration: with probability η the task goes to a uniformly
//! random worker (explore); with probability 1−η it is placed with PPoT
//! (exploit). The paper tests η ∈ {0.2, 0.3} and finds this the *worst*
//! baseline — the uniform exploration stream keeps overloading slow workers
//! and, unlike Rosella's benchmark jobs, the exploration jobs are real jobs
//! whose response time counts.

use super::{per_task, Policy, TieRule};
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// ε-greedy bandit over PPoT.
#[derive(Debug)]
pub struct Bandit {
    eta: f64,
    tie: TieRule,
}

impl Bandit {
    /// New bandit policy with exploration probability `eta ∈ [0, 1]`.
    pub fn new(eta: f64) -> Self {
        assert!((0.0..=1.0).contains(&eta), "bad eta {eta}");
        Self { eta, tie: TieRule::Sq2 }
    }
}

impl Policy for Bandit {
    fn name(&self) -> String {
        format!("bandit{:.1}", self.eta)
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        let n = view.n();
        per_task(job, |_| {
            if rng.gen_bool(self.eta) {
                rng.gen_index(n)
            } else {
                let (a, b) = view.sample_pair(rng);
                match self.tie {
                    TieRule::Sq2 => {
                        if view.queue_len(b) < view.queue_len(a) {
                            b
                        } else {
                            a
                        }
                    }
                    TieRule::Ll2 => {
                        if view.expected_wait(b) < view.expected_wait(a) {
                            b
                        } else {
                            a
                        }
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;

    #[test]
    fn explores_at_rate_eta() {
        let mut p = Bandit::new(0.3);
        let mut rng = Rng::new(31);
        // Worker 0 has zero estimate: PPoT never probes it, so any placement
        // on worker 0 must come from the uniform exploration branch.
        let q = vec![5usize; 10];
        let mu = {
            let mut v = vec![1.0; 10];
            v[0] = 0.0;
            v
        };
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 1.0 };
        let job = JobSpec::single(0.1);
        let mut zero = 0;
        let n = 60_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view, &mut rng) {
                zero += (w0 == 0) as usize;
            }
        }
        // P(place at 0) = eta / n = 0.03.
        let frac = zero as f64 / n as f64;
        assert!((frac - 0.03).abs() < 0.005, "frac={frac}");
    }

    #[test]
    fn eta_zero_is_pure_ppot() {
        let mut p = Bandit::new(0.0);
        let mut rng = Rng::new(32);
        let q = vec![5usize, 5];
        let mu = vec![0.0, 1.0];
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 1.0 };
        let job = JobSpec::single(0.1);
        for _ in 0..5_000 {
            if let JobPlacement::Single(w0) = p.schedule_job(&job, &view, &mut rng) {
                assert_eq!(w0, 1, "zero-estimate worker must never be chosen");
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_bad_eta() {
        Bandit::new(1.5);
    }
}
