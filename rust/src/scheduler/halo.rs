//! Halo baseline (Gandhi, Zhang, Mittal — MASCOTS'15, reference [10]):
//! heterogeneity-aware load balancing with *known* worker speeds and a
//! single probe.
//!
//! Halo routes probabilistically with a routing vector optimized for mean
//! response time when each worker is an M/M/1 queue: minimize
//! `Σ_i λ_i / (μ_i − λ_i)` subject to `Σ λ_i = λ`, `0 ≤ λ_i < μ_i`.
//! The KKT conditions give the classical square-root water-filling rule
//!
//! `λ_i = max(0, μ_i − √(μ_i / ν))`
//!
//! with `ν > 0` chosen so the rates sum to λ — faster servers absorb
//! super-proportional load, and sufficiently slow servers are switched off
//! entirely. The paper evaluates Halo only under known speeds (Fig. 10b)
//! and observes a limited gain over plain PSS.

use super::{per_task, Policy};
use crate::stats::{AliasTable, Rng};
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// Halo oracle router.
#[derive(Debug)]
pub struct Halo {
    /// Optimized routing probabilities (rebuilt on estimate publish).
    routing: Vec<f64>,
    table: Option<AliasTable>,
}

impl Halo {
    /// New Halo policy for `n` workers (uniform routing until estimates
    /// arrive).
    pub fn new(n: usize) -> Self {
        Self { routing: vec![1.0 / n as f64; n], table: None }
    }

    /// Water-filling solution: per-worker arrival rates `λ_i` for total
    /// arrival `lambda` and service rates `mu`. Exposed for tests.
    pub fn water_fill(mu: &[f64], lambda: f64) -> Vec<f64> {
        let mut out = Vec::new();
        Self::water_fill_into(mu, lambda, &mut out);
        out
    }

    /// In-place [`Self::water_fill`]: writes the rates into `out`, reusing
    /// its capacity — the estimate-publish path allocates nothing after the
    /// first build.
    pub fn water_fill_into(mu: &[f64], lambda: f64, out: &mut Vec<f64>) {
        let total: f64 = mu.iter().sum();
        assert!(lambda >= 0.0);
        out.clear();
        if lambda >= total || total <= 0.0 {
            // Overloaded or degenerate: fall back to proportional split.
            out.extend(mu.iter().map(|&m| if total > 0.0 { lambda * m / total } else { 0.0 }));
            return;
        }
        // Find ν by bisection on the monotone residual
        // f(ν) = Σ max(0, μ_i − √(μ_i/ν)) − λ  (increasing in ν).
        let assigned = |nu: f64| -> f64 {
            mu.iter().map(|&m| (m - (m / nu).sqrt()).max(0.0)).sum::<f64>()
        };
        let (mut lo, mut hi): (f64, f64) = (1e-12, 1e12);
        for _ in 0..200 {
            let mid = (lo * hi).sqrt(); // geometric bisection for scale-freeness
            if assigned(mid) < lambda {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let nu = (lo * hi).sqrt();
        out.extend(mu.iter().map(|&m| (m - (m / nu).sqrt()).max(0.0)));
    }

    fn rebuild(&mut self, mu_hat: &[f64], lambda_hat: f64) {
        Self::water_fill_into(mu_hat, lambda_hat.max(0.0), &mut self.routing);
        let total: f64 = self.routing.iter().sum();
        if total > 0.0 {
            for r in &mut self.routing {
                *r /= total;
            }
        } else {
            self.routing.clear();
            self.routing.resize(mu_hat.len(), 1.0 / mu_hat.len() as f64);
        }
        // Recycle the sampler's buffers across publishes.
        match self.table.as_mut() {
            Some(t) => t.rebuild(&self.routing),
            None => self.table = Some(AliasTable::new(&self.routing)),
        }
    }

    /// Current routing probabilities (diagnostics/tests).
    pub fn routing(&self) -> &[f64] {
        &self.routing
    }
}

impl Policy for Halo {
    fn name(&self) -> String {
        "halo".into()
    }

    fn on_estimates(&mut self, mu_hat: &[f64], lambda_hat: f64) {
        self.rebuild(mu_hat, lambda_hat);
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        // Halo probes a single machine: one sample from the optimized
        // routing distribution, no queue information.
        if self.table.is_none() {
            let mu: Vec<f64> = (0..view.n()).map(|w| view.mu_hat(w)).collect();
            self.rebuild(&mu, view.lambda_hat());
        }
        let table = self.table.as_ref().unwrap();
        per_task(job, |_| table.sample(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::LocalView;

    #[test]
    fn water_fill_conserves_total_rate() {
        let mu = [1.0, 2.0, 4.0];
        let lambda = 3.5;
        let rates = Halo::water_fill(&mu, lambda);
        let total: f64 = rates.iter().sum();
        assert!((total - lambda).abs() < 1e-6, "rates={rates:?}");
        for (r, m) in rates.iter().zip(mu.iter()) {
            assert!(*r >= 0.0 && *r < *m, "rates={rates:?}");
        }
    }

    #[test]
    fn water_fill_switches_off_slow_servers_at_low_load() {
        // With very low load, only the fastest servers carry traffic.
        let mu = [0.1, 0.1, 10.0];
        let rates = Halo::water_fill(&mu, 0.5);
        assert!(rates[2] > 0.4, "{rates:?}");
        assert!(rates[0] < 0.05 && rates[1] < 0.05, "{rates:?}");
    }

    #[test]
    fn water_fill_homogeneous_is_even() {
        let mu = [1.0; 4];
        let rates = Halo::water_fill(&mu, 2.0);
        for r in &rates {
            assert!((r - 0.5).abs() < 1e-6, "{rates:?}");
        }
    }

    #[test]
    fn water_fill_faster_gets_superproportional_share() {
        let mu = [1.0, 4.0];
        let rates = Halo::water_fill(&mu, 3.0);
        // Proportional would be 0.6 / 2.4; water-filling shifts even more
        // to the fast server.
        assert!(rates[1] / rates[0] > 4.0, "{rates:?}");
    }

    #[test]
    fn overload_falls_back_to_proportional() {
        let mu = [1.0, 3.0];
        let rates = Halo::water_fill(&mu, 8.0);
        assert!((rates[0] - 2.0).abs() < 1e-9 && (rates[1] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn routing_reacts_to_estimates() {
        let mut h = Halo::new(2);
        h.on_estimates(&[1.0, 9.0], 5.0);
        let r = h.routing().to_vec();
        assert!(r[1] > 0.8, "routing={r:?}");
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn schedules_from_routing_distribution() {
        let mut h = Halo::new(2);
        h.on_estimates(&[1.0, 9.0], 5.0);
        let expect = h.routing()[1];
        let mut rng = Rng::new(41);
        let q = vec![0, 0];
        let mu = vec![1.0, 9.0];
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 5.0 };
        let job = JobSpec::single(0.1);
        let mut fast = 0;
        let n = 60_000;
        for _ in 0..n {
            if let JobPlacement::Single(w0) = h.schedule_job(&job, &view, &mut rng) {
                fast += (w0 == 1) as usize;
            }
        }
        assert!((fast as f64 / n as f64 - expect).abs() < 0.01);
    }
}
