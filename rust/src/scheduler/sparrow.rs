//! Sparrow baseline: batch sampling + late binding (Ousterhout et al.,
//! SOSP'13 — reference [7] of the paper).
//!
//! For a job of `m` tasks, Sparrow probes `d·m` *distinct* workers chosen
//! uniformly at random and places a lightweight reservation at each. Workers
//! serve their queues FIFO; when a reservation reaches the head, the worker
//! asks the scheduler for the next unlaunched task of the job (late
//! binding). Once all `m` tasks have launched the remaining reservations
//! are discarded. Sparrow ignores worker speeds entirely — which is why its
//! performance does not degrade under volatility (§6.1, Fig. 8b) but is far
//! from Rosella's on heterogeneous clusters.

use super::Policy;
use crate::stats::Rng;
use crate::types::{ClusterView, JobPlacement, JobSpec};

/// Sparrow scheduler (batch sampling + late binding).
#[derive(Debug)]
pub struct Sparrow {
    probes_per_task: usize,
}

impl Sparrow {
    /// New Sparrow policy; the paper (and the original system) use
    /// `probes_per_task = 2`.
    pub fn new(probes_per_task: usize) -> Self {
        assert!(probes_per_task >= 1);
        Self { probes_per_task }
    }
}

impl Policy for Sparrow {
    fn name(&self) -> String {
        "sparrow".into()
    }

    fn schedule_job(
        &mut self,
        job: &JobSpec,
        view: &dyn ClusterView,
        rng: &mut Rng,
    ) -> JobPlacement {
        let n = view.n();
        let m = job.unconstrained();
        let want = self.probes_per_task * m;
        if want <= n {
            JobPlacement::Reservations(rng.sample_distinct(n, want))
        } else {
            // Tiny cluster relative to the job: distinct probes are
            // impossible, fall back to sampling with replacement so every
            // task still gets `probes_per_task` reservations.
            JobPlacement::Reservations((0..want).map(|_| rng.gen_index(n)).collect())
        }
    }

    fn needs_estimates(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;
    use crate::types::LocalView;
    use crate::types::TaskSpec;

    fn view<'a>(q: &'a [usize], mu: &'a [f64], t: &'a AliasTable) -> LocalView<'a> {
        LocalView { queue_len: q, mu_hat: mu, sampler: t, lambda_hat: 1.0 }
    }

    #[test]
    fn probes_two_m_distinct_workers() {
        let mut p = Sparrow::new(2);
        let mut rng = Rng::new(21);
        let q = vec![0; 30];
        let mu = vec![1.0; 30];
        let t = AliasTable::new(&mu);
        let job = JobSpec::new(vec![TaskSpec::new(0.1); 5]);
        match p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
            JobPlacement::Reservations(ws) => {
                assert_eq!(ws.len(), 10);
                let mut d = ws.clone();
                d.sort_unstable();
                d.dedup();
                assert_eq!(d.len(), 10, "probes must be distinct: {ws:?}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn small_cluster_falls_back_to_replacement() {
        let mut p = Sparrow::new(2);
        let mut rng = Rng::new(22);
        let q = vec![0; 4];
        let mu = vec![1.0; 4];
        let t = AliasTable::new(&mu);
        let job = JobSpec::new(vec![TaskSpec::new(0.1); 10]); // 2m = 20 > n = 4
        match p.schedule_job(&job, &view(&q, &mu, &t), &mut rng) {
            JobPlacement::Reservations(ws) => {
                assert_eq!(ws.len(), 20, "every task keeps 2 reservations");
                assert!(ws.iter().all(|&w| w < 4));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn probes_are_uniform_not_proportional() {
        let mut p = Sparrow::new(1);
        let mut rng = Rng::new(23);
        let q = vec![0; 2];
        let mu = vec![100.0, 1.0]; // estimates must be ignored
        let t = AliasTable::new(&mu);
        let job = JobSpec::single(0.1);
        let mut first = 0;
        let n = 40_000;
        for _ in 0..n {
            if let JobPlacement::Reservations(ws) =
                p.schedule_job(&job, &view(&q, &mu, &t), &mut rng)
            {
                first += (ws[0] == 0) as usize;
            }
        }
        assert!((first as f64 / n as f64 - 0.5).abs() < 0.02);
    }
}
