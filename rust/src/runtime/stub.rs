//! Offline stand-ins for the PJRT runtime (built when the `pjrt` feature
//! is off, which is the default in environments without the `xla` crate).
//!
//! Every type mirrors the real module's API; the loaders return an error,
//! so call sites that probe for artifacts — the live coordinator's payload
//! and learner paths, `bench_runtime` — fall back to their native
//! implementations exactly as they do when `make artifacts` has not run.

use crate::learner::{LearnerParams, PerfLearner};

/// Batch size baked into the (absent) payload artifact.
pub const BATCH: usize = 8;
/// Input feature width.
pub const D_IN: usize = 128;
/// Output width.
pub const D_OUT: usize = 128;

const UNAVAILABLE: &str = "built without the `pjrt` feature (xla crate not vendored)";

/// Constants of the learner artifact, mirrored from `learner_exec`.
pub mod learner_exec {
    /// Worker count baked into the artifact (pad smaller clusters).
    pub const N_WORKERS: usize = 16;
    /// Ring-buffer depth baked into the artifact.
    pub const K_SAMPLES: usize = 64;
}

/// Stub payload runner; loading always fails.
pub struct PayloadRunner {
    _private: (),
}

impl PayloadRunner {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn load(_dir: &str, _seed: u64) -> Result<Self, String> {
        Err(UNAVAILABLE.into())
    }

    /// Unreachable (no instance can exist), kept for API parity.
    pub fn infer(&self, _x: &[f32]) -> Result<Vec<f32>, String> {
        Err(UNAVAILABLE.into())
    }

    /// Native reference of the MLP; the stub has no weights, so this
    /// returns zeros (unreachable in practice — `load` never succeeds).
    pub fn infer_native(&self, _x: &[f32]) -> Vec<f32> {
        vec![0.0; BATCH * D_OUT]
    }
}

/// Stub learner kernel; loading always fails.
pub struct LearnerKernel {
    _private: (),
}

impl LearnerKernel {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn load(_dir: &str) -> Result<Self, String> {
        Err(UNAVAILABLE.into())
    }

    /// Unreachable (no instance can exist), kept for API parity.
    pub fn publish(
        &self,
        _learner: &PerfLearner,
        _now: f64,
        _params: &LearnerParams,
        _cold_start: bool,
    ) -> Result<Vec<f32>, String> {
        Err(UNAVAILABLE.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loaders_report_unavailable() {
        assert!(PayloadRunner::load("artifacts", 1).is_err());
        assert!(LearnerKernel::load("artifacts").is_err());
    }

    #[test]
    fn constants_match_artifact_shapes() {
        assert_eq!(BATCH, 8);
        assert_eq!(D_IN, 128);
        assert_eq!(learner_exec::N_WORKERS, 16);
        assert_eq!(learner_exec::K_SAMPLES, 64);
    }
}
