//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO *text*: jax ≥ 0.5 serializes HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and aot.py).

/// String-error result: keeps the `pjrt` feature free of the
/// `anyhow` dependency (unavailable offline).
pub type Result<T> = std::result::Result<T, String>;

/// A PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("create PJRT CPU client: {e}"))?;
        Ok(Self { client })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| format!("parse HLO text {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| format!("compile {path}: {e}"))?;
        Ok(Executable { exe })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given input literals; the artifact was lowered with
    /// `return_tuple=True`, so the single output is a 1-tuple whose element
    /// is returned.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| format!("execute artifact: {e}"))?;
        let out = result[0][0].to_literal_sync().map_err(|e| format!("fetch result: {e}"))?;
        out.to_tuple1().map_err(|e| format!("unwrap 1-tuple output: {e}"))
    }

    /// Execute and decode the output as a flat f32 vector.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        self.run(inputs)?.to_vec::<f32>().map_err(|e| format!("decode f32 output: {e}"))
    }
}

/// Build an f32 literal of the given shape from row-major data.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    if numel as usize != data.len() {
        return Err(format!("shape {dims:?} vs {} elems", data.len()));
    }
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(|e| format!("reshape literal: {e}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    if numel as usize != data.len() {
        return Err(format!("shape {dims:?} vs {} elems", data.len()));
    }
    if dims.len() == 1 {
        return Ok(xla::Literal::vec1(data));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(|e| format!("reshape literal: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT tests only run when `make artifacts` has produced the files
    // (they are gitignored build outputs).
    fn artifacts() -> Option<String> {
        let dir = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        crate::runtime::artifacts_present(&dir).then_some(dir)
    }

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[3]).is_ok());
    }

    #[test]
    fn load_and_execute_payload_artifact() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load(&crate::runtime::payload_artifact(&dir)).unwrap();
        // Zero weights -> zero output regardless of x.
        let x = literal_f32(&vec![1.0; 8 * 128], &[8, 128]).unwrap();
        let w1 = literal_f32(&vec![0.0; 128 * 256], &[128, 256]).unwrap();
        let b1 = literal_f32(&vec![0.0; 256], &[256]).unwrap();
        let w2 = literal_f32(&vec![0.0; 256 * 128], &[256, 128]).unwrap();
        let b2 = literal_f32(&vec![0.5; 128], &[128]).unwrap();
        let out = exe.run_f32(&[x, w1, b1, w2, b2]).unwrap();
        assert_eq!(out.len(), 8 * 128);
        assert!(out.iter().all(|&v| (v - 0.5).abs() < 1e-6), "out[0..4]={:?}", &out[..4]);
    }
}
