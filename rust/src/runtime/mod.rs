//! PJRT runtime: load AOT artifacts (HLO text produced by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Python never runs at serve time — `make artifacts` lowers the JAX/Pallas
//! model once; this module compiles the HLO on the PJRT CPU client and the
//! live coordinator executes the resulting binaries per request.

pub mod client;
pub mod learner_exec;
pub mod payload;

pub use client::{Executable, Runtime};
pub use learner_exec::LearnerKernel;
pub use payload::{PayloadRunner, BATCH, D_IN, D_OUT};

/// Default artifact paths relative to an artifacts directory.
pub fn learner_artifact(dir: &str) -> String {
    format!("{dir}/learner.hlo.txt")
}

/// Payload artifact path.
pub fn payload_artifact(dir: &str) -> String {
    format!("{dir}/payload.hlo.txt")
}

/// True when both artifacts exist (used to skip PJRT tests when
/// `make artifacts` has not been run).
pub fn artifacts_present(dir: &str) -> bool {
    std::path::Path::new(&learner_artifact(dir)).exists()
        && std::path::Path::new(&payload_artifact(dir)).exists()
}
