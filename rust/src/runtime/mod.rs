//! PJRT runtime: load AOT artifacts (HLO text produced by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! Python never runs at serve time — `make artifacts` lowers the JAX/Pallas
//! model once; this module compiles the HLO on the PJRT CPU client and the
//! live coordinator executes the resulting binaries per request.
//!
//! The PJRT client needs the `xla` crate, which the offline build
//! environment cannot fetch, so everything touching it is gated behind the
//! `pjrt` cargo feature (enable it after vendoring `xla` as a path
//! dependency). The default build compiles [`stub`] instead: the same API
//! surface whose loaders report the artifacts as unavailable, so the live
//! coordinator and benches degrade to sleep payloads and the native
//! learner without any call-site changes.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod learner_exec;
#[cfg(feature = "pjrt")]
pub mod payload;

#[cfg(feature = "pjrt")]
pub use client::{Executable, Runtime};
#[cfg(feature = "pjrt")]
pub use learner_exec::LearnerKernel;
#[cfg(feature = "pjrt")]
pub use payload::{PayloadRunner, BATCH, D_IN, D_OUT};

#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(not(feature = "pjrt"))]
pub use stub::{learner_exec, LearnerKernel, PayloadRunner, BATCH, D_IN, D_OUT};

/// Default artifact paths relative to an artifacts directory.
pub fn learner_artifact(dir: &str) -> String {
    format!("{dir}/learner.hlo.txt")
}

/// Payload artifact path.
pub fn payload_artifact(dir: &str) -> String {
    format!("{dir}/payload.hlo.txt")
}

/// True when both artifacts exist (used to skip PJRT tests when
/// `make artifacts` has not been run).
pub fn artifacts_present(dir: &str) -> bool {
    std::path::Path::new(&learner_artifact(dir)).exists()
        && std::path::Path::new(&payload_artifact(dir)).exists()
}
