//! The benchmark-job MLP payload, executed through PJRT by live workers.
//!
//! Shapes are fixed at AOT time (python/compile/kernels/payload.py):
//! x f32[8,128] → f32[8,128] through a 128→256→128 MLP.

use super::client::{literal_f32, Executable, Result, Runtime};

/// Batch size baked into the artifact.
pub const BATCH: usize = 8;
/// Input feature width.
pub const D_IN: usize = 128;
/// Hidden width.
pub const D_H: usize = 256;
/// Output width.
pub const D_OUT: usize = 128;

/// A loaded payload executable with resident weights.
pub struct PayloadRunner {
    exe: Executable,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

impl PayloadRunner {
    /// Load the payload artifact and initialize deterministic weights.
    pub fn load(dir: &str, seed: u64) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let exe = rt.load(&super::payload_artifact(dir))?;
        let mut rng = crate::stats::Rng::new(seed);
        let mut gen = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| (rng.next_f64() as f32 * 2.0 - 1.0) * scale).collect()
        };
        Ok(Self {
            exe,
            w1: gen(D_IN * D_H, 0.05),
            b1: gen(D_H, 0.01),
            w2: gen(D_H * D_OUT, 0.05),
            b2: gen(D_OUT, 0.01),
        })
    }

    /// Run one inference batch; returns the flat f32[BATCH, D_OUT] output.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != BATCH * D_IN {
            return Err(format!("bad input length {}", x.len()));
        }
        let inputs = [
            literal_f32(x, &[BATCH as i64, D_IN as i64])?,
            literal_f32(&self.w1, &[D_IN as i64, D_H as i64])?,
            literal_f32(&self.b1, &[D_H as i64])?,
            literal_f32(&self.w2, &[D_H as i64, D_OUT as i64])?,
            literal_f32(&self.b2, &[D_OUT as i64])?,
        ];
        self.exe.run_f32(&inputs)
    }

    /// Native (pure-rust) reference of the same MLP — used to verify the
    /// whole python→HLO→PJRT path numerically.
    pub fn infer_native(&self, x: &[f32]) -> Vec<f32> {
        let mut h = vec![0.0f32; BATCH * D_H];
        for b in 0..BATCH {
            for j in 0..D_H {
                let mut acc = self.b1[j];
                for i in 0..D_IN {
                    acc += x[b * D_IN + i] * self.w1[i * D_H + j];
                }
                h[b * D_H + j] = acc.max(0.0);
            }
        }
        let mut y = vec![0.0f32; BATCH * D_OUT];
        for b in 0..BATCH {
            for j in 0..D_OUT {
                let mut acc = self.b2[j];
                for i in 0..D_H {
                    acc += h[b * D_H + i] * self.w2[i * D_OUT + j];
                }
                y[b * D_OUT + j] = acc;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<String> {
        let dir = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        crate::runtime::artifacts_present(&dir).then_some(dir)
    }

    #[test]
    fn artifact_matches_native_reference() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let runner = PayloadRunner::load(&dir, 7).unwrap();
        let mut rng = crate::stats::Rng::new(99);
        let x: Vec<f32> =
            (0..BATCH * D_IN).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
        let got = runner.infer(&x).unwrap();
        let want = runner.infer_native(&x);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-3, "pjrt {g} vs native {w}");
        }
    }

    #[test]
    fn infer_rejects_bad_input_length() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let runner = PayloadRunner::load(&dir, 7).unwrap();
        assert!(runner.infer(&[0.0; 3]).is_err());
    }
}
