//! PJRT-backed LEARNER-AGGREGATE: executes the Pallas learner kernel
//! artifact over the dense export of the rust learner's ring buffers.
//!
//! The live coordinator can publish estimates either through the native
//! rust implementation (`learner::PerfLearner::publish`) or through this
//! artifact; both implement the identical Fig. 6 rule and a test checks
//! they agree numerically.

use super::client::{literal_f32, literal_i32, Executable, Result, Runtime};
use crate::learner::{LearnerParams, PerfLearner};

/// Worker count baked into the artifact (pad smaller clusters).
pub const N_WORKERS: usize = 16;
/// Ring-buffer depth baked into the artifact.
pub const K_SAMPLES: usize = 64;

/// Loaded learner executable.
pub struct LearnerKernel {
    exe: Executable,
}

impl LearnerKernel {
    /// Load and compile the learner artifact.
    pub fn load(dir: &str) -> Result<Self> {
        let rt = Runtime::cpu()?;
        Ok(Self { exe: rt.load(&super::learner_artifact(dir))? })
    }

    /// Execute the aggregation for raw dense inputs.
    pub fn run_raw(
        &self,
        durations: &[f32],
        demands: &[f32],
        ages: &[f32],
        counts: &[i32],
        window: f32,
        epsilon: f32,
        horizon: f32,
        cold_start: bool,
    ) -> Result<Vec<f32>> {
        let n = N_WORKERS as i64;
        let k = K_SAMPLES as i64;
        let inputs = [
            literal_f32(durations, &[n, k])?,
            literal_f32(demands, &[n, k])?,
            literal_f32(ages, &[n, k])?,
            literal_i32(counts, &[n])?,
            literal_f32(&[window, epsilon, horizon, if cold_start { 1.0 } else { 0.0 }], &[4])?,
        ];
        self.exe.run_f32(&inputs)
    }

    /// Publish estimates for a [`PerfLearner`] through the artifact:
    /// exports the learner's ring buffers densely (padded to the artifact
    /// shape) and returns μ̂ for the first `learner.n()` workers.
    pub fn publish(
        &self,
        learner: &PerfLearner,
        now: f64,
        params: &LearnerParams,
        cold_start: bool,
    ) -> Result<Vec<f32>> {
        let n = learner.n();
        if n > N_WORKERS {
            return Err(format!("cluster of {n} exceeds artifact capacity {N_WORKERS}"));
        }
        let (dur, dem, age, cnt) = learner.export_dense(now, K_SAMPLES);
        // Pad to the artifact's worker count.
        let mut pdur = vec![0.0f32; N_WORKERS * K_SAMPLES];
        let mut pdem = vec![0.0f32; N_WORKERS * K_SAMPLES];
        let mut page = vec![f32::MAX; N_WORKERS * K_SAMPLES];
        let mut pcnt = vec![0i32; N_WORKERS];
        pdur[..n * K_SAMPLES].copy_from_slice(&dur);
        pdem[..n * K_SAMPLES].copy_from_slice(&dem);
        page[..n * K_SAMPLES].copy_from_slice(&age);
        pcnt[..n].copy_from_slice(&cnt);
        let out = self.run_raw(
            &pdur,
            &pdem,
            &page,
            &pcnt,
            params.window as f32,
            params.epsilon as f32,
            params.horizon as f32,
            cold_start,
        )?;
        Ok(out[..n].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::PerfLearner;

    fn artifacts() -> Option<String> {
        let dir = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        crate::runtime::artifacts_present(&dir).then_some(dir)
    }

    #[test]
    fn artifact_agrees_with_native_learner() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let kernel = LearnerKernel::load(&dir).unwrap();
        // Build a learner with three regimes: fast sampled worker, slow
        // sampled worker, silent worker.
        let mut l = PerfLearner::new(8, 10.0, 0.1, 80.0, 1.0, 0.0);
        let mut t = 0.0;
        for _ in 0..100 {
            t += 0.05;
            l.on_completion(0, t, 0.05, 0.1); // speed 2.0
            l.on_completion(1, t, 0.4, 0.1); // speed 0.25
        }
        let params = l.publish(t, 40.0);
        let native = l.mu_hat().to_vec();
        let cold = t < params.horizon;
        let pjrt = kernel.publish(&l, t, &params, cold).unwrap();
        assert_eq!(pjrt.len(), 8);
        for (i, (p, nv)) in pjrt.iter().zip(native.iter()).enumerate() {
            // Silent workers keep the prior natively during cold start but
            // the kernel reports 0 for empty rows (the prior is a host-side
            // bootstrap); skip those.
            if native[i] == 1.0 && *p == 0.0 {
                continue;
            }
            assert!((*p as f64 - nv).abs() < 1e-3, "worker {i}: pjrt {p} native {nv}");
        }
        // The two sampled workers must match closely.
        assert!((pjrt[0] as f64 - native[0]).abs() < 1e-4);
        assert!((pjrt[1] as f64 - native[1]).abs() < 1e-4);
    }

    #[test]
    fn rejects_oversized_cluster() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let kernel = LearnerKernel::load(&dir).unwrap();
        let l = PerfLearner::new(40, 10.0, 0.1, 400.0, 1.0, 0.0);
        let params = crate::learner::LearnerParams::derive(100.0, 400.0, 10.0, 0.1);
        assert!(kernel.publish(&l, 1.0, &params, true).is_err());
    }
}
