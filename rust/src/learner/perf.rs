//! Performance learner (§3.2, pseudocode LEARNER-AGGREGATE in Fig. 6).
//!
//! Maintains, per worker, a ring buffer of recent *service-time samples*
//! (duration and demand of each completed task, real or benchmark) and
//! computes speed estimates μ̂ on publish:
//!
//! * window length `L = ceil(c / (1 − α̂))` — the paper's *practical* window
//!   (§6.2 "setting it to c/(1−α) achieves the best performance"; the
//!   asymptotic bound of §4.3 is c/(1−α)², which is "too conservative");
//! * `ε = (3/10)(1 − α̂)` and the relative speed floor `μ* = (1 − α̂)/10`;
//! * the *timeout rule*: a worker that did not produce `L` samples within
//!   `(1+ε)·L·τ̄/μ*` seconds is too slow to matter and its estimate is set
//!   to 0 — effectively treating it as dead (Lemma 5(i)). During the cold
//!   start (before one full horizon has elapsed) partial windows are used
//!   instead, since "cannot measure in time" has not yet been observed;
//! * the kept estimate is the deliberate underestimate
//!   `μ̂ = (1 − ε) · Σ demand / Σ duration` (ratio estimator over the last
//!   `L` samples; for unit demands this is exactly the paper's
//!   `(1 − ε)/q̂`).
//!
//! The same aggregation is implemented as a Pallas kernel
//! (`python/compile/kernels/learner.py`) and AOT-compiled; the live
//! coordinator can execute either the native path or the PJRT artifact
//! (they are verified equivalent in tests).

/// One completed-task observation.
#[derive(Debug, Clone, Copy)]
struct Sample {
    /// Completion time (sim or wall clock).
    at: f64,
    /// Observed service duration in seconds.
    duration: f64,
    /// Task demand in unit-speed seconds.
    demand: f64,
}

/// Ring buffer of the most recent `cap` samples for one worker.
#[derive(Debug, Clone)]
struct History {
    buf: Vec<Sample>,
    head: usize,
    len: usize,
}

impl History {
    fn new(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap), head: 0, len: 0 }
    }

    fn push(&mut self, s: Sample) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(s);
            self.len += 1;
        } else {
            self.buf[self.head] = s;
            self.head = (self.head + 1) % self.buf.len();
            self.len = self.buf.len();
        }
    }
}

/// Per-cluster performance learner.
///
/// In a distributed plane (§5) every scheduler owns one of these: it learns
/// from only the completions *it* routed, exports a cheap
/// [`EstimateView`](crate::learner::EstimateView) snapshot at sync epochs
/// ([`Self::export_views_into`]), and adopts the merged consensus back
/// ([`Self::adopt`]).
#[derive(Debug)]
pub struct PerfLearner {
    hist: Vec<History>,
    /// Fraction `c` of the practical window `L = c/(1−α̂)`.
    window_c: f64,
    /// Mean task demand τ̄ used to convert counts to times.
    mean_demand: f64,
    /// Minimum guaranteed total service throughput μ̄ (tasks/sec).
    mu_bar: f64,
    /// Time the learner started (for the cold-start exception).
    start: f64,
    /// Per-worker estimate used while a worker has no usable samples during
    /// cold start: the scalar prior at birth, overwritten by the adopted
    /// consensus in distributed mode (§5) so an unsampled worker inherits
    /// what the *other* schedulers learned about it.
    fallback: Vec<f64>,
    /// Published estimates.
    mu_hat: Vec<f64>,
    /// In-window sample count behind each published estimate (the merge
    /// weight exported to estimate-sync consensus).
    samples: Vec<u64>,
    /// How many distributed schedulers split the completion stream (k).
    /// This learner sees only ~1/k of each worker's completions, so its
    /// full-window requirement drops to ⌈L/k⌉ while the timeout horizon
    /// keeps the full-L value: the aggregate evidence behind a consensus —
    /// k schedulers × L/k samples in the same horizon — matches the
    /// centralized learner's L, and the discard floor stays ≈ μ* instead
    /// of multiplying by k.
    schedulers: usize,
}

/// Parameters derived from the current load estimate; shared with the
/// Pallas kernel so both implementations agree bit-for-bit on the rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LearnerParams {
    /// Estimated load ratio α̂ = λ̂/μ̄, clamped to [0, 0.99].
    pub alpha: f64,
    /// ε = 0.3(1 − α̂).
    pub epsilon: f64,
    /// Window length L = ceil(c / (1 − α̂)).
    pub window: usize,
    /// Relative speed floor μ* = (1 − α̂)/10.
    pub mu_star: f64,
    /// Timeout horizon (1+ε)·L·τ̄/μ* in seconds.
    pub horizon: f64,
}

impl LearnerParams {
    /// Derive parameters from the load estimate.
    pub fn derive(lambda_hat: f64, mu_bar: f64, window_c: f64, mean_demand: f64) -> Self {
        let alpha = (lambda_hat / mu_bar).clamp(0.0, 0.99);
        let epsilon = 0.3 * (1.0 - alpha);
        // Round (rather than ceil) to dodge f64 artifacts like
        // 10/0.2 = 50.000000000000007.
        let window = (window_c / (1.0 - alpha)).round().max(1.0) as usize;
        let mu_star = (1.0 - alpha) / 10.0;
        let horizon = (1.0 + epsilon) * window as f64 * mean_demand / mu_star;
        Self { alpha, epsilon, window, mu_star, horizon }
    }
}

impl PerfLearner {
    /// New learner.
    ///
    /// * `n` — number of workers;
    /// * `window_c` — the practical window constant `c` (§6.2 sweeps
    ///   {10, 20, 30, 40}; Rosella's default is 10);
    /// * `mean_demand` — τ̄, mean task demand in seconds (0.1 in §6.2);
    /// * `mu_bar` — minimum guaranteed total throughput in tasks/sec;
    /// * `prior` — estimate used for a worker before any samples arrive
    ///   (the mean relative speed, so cold-start ≈ uniform sampling);
    /// * `start` — clock value at learner birth.
    pub fn new(
        n: usize,
        window_c: f64,
        mean_demand: f64,
        mu_bar: f64,
        prior: f64,
        start: f64,
    ) -> Self {
        assert!(n > 0 && window_c > 0.0 && mean_demand > 0.0 && mu_bar > 0.0);
        // Capacity for the largest window we will ever need (α̂ ≤ 0.99).
        let max_window = (window_c / 0.01).ceil() as usize;
        Self {
            hist: (0..n).map(|_| History::new(max_window.min(4096))).collect(),
            window_c,
            mean_demand,
            mu_bar,
            start,
            fallback: vec![prior; n],
            mu_hat: vec![prior; n],
            samples: vec![0; n],
            schedulers: 1,
        }
    }

    /// Mark this learner as one of `schedulers` distributed learners
    /// splitting the completion stream (§5): scales the per-scheduler
    /// window requirement to ⌈L/k⌉ (see the `schedulers` field docs).
    /// `shared_among(1)` is the identity.
    pub fn shared_among(mut self, schedulers: usize) -> Self {
        assert!(schedulers >= 1);
        self.schedulers = schedulers;
        self
    }

    /// Number of workers tracked.
    pub fn n(&self) -> usize {
        self.hist.len()
    }

    /// Record a completed task on `worker`.
    pub fn on_completion(&mut self, worker: usize, now: f64, duration: f64, demand: f64) {
        debug_assert!(duration > 0.0 && demand > 0.0);
        self.hist[worker].push(Sample { at: now, duration, demand });
    }

    /// Recompute and publish estimates for all workers given the current
    /// arrival estimate. Returns the derived parameters (for logging).
    pub fn publish(&mut self, now: f64, lambda_hat: f64) -> LearnerParams {
        let mut p = LearnerParams::derive(lambda_hat, self.mu_bar, self.window_c, self.mean_demand);
        // k-aware window: this learner samples ~1/k of the completion
        // stream, so it needs only its share of L — within the *full-L*
        // horizon, which `derive` already set and we keep.
        p.window = p.window.div_ceil(self.schedulers).max(1);
        let cold_start = now - self.start < p.horizon;
        for (w, h) in self.hist.iter().enumerate() {
            let (est, weight) = Self::estimate_one(h, now, &p, cold_start, self.fallback[w]);
            self.mu_hat[w] = est;
            self.samples[w] = weight;
        }
        p
    }

    /// LEARNER-AGGREGATE for a single worker. Returns the estimate plus its
    /// merge weight: the in-window sample count, except that a timeout
    /// discard with no in-window samples still weighs 1 — a full silent
    /// horizon *is* an observation, so a unanimous discard survives
    /// consensus instead of degrading to "nobody knows" (prior).
    fn estimate_one(
        h: &History,
        now: f64,
        p: &LearnerParams,
        cold_start: bool,
        fallback: f64,
    ) -> (f64, u64) {
        // Walk the most recent samples (newest first), keeping those within
        // the timeout horizon, up to L of them.
        let cutoff = now - p.horizon;
        let mut used = 0usize;
        let mut sum_dur = 0.0;
        let mut sum_dem = 0.0;
        let cap = h.buf.len();
        if cap > 0 {
            let newest = (h.head + h.len - 1) % cap;
            for i in 0..h.len.min(p.window) {
                let s = &h.buf[(newest + cap - i) % cap];
                if s.at < cutoff {
                    break;
                }
                used += 1;
                sum_dur += s.duration;
                sum_dem += s.demand;
            }
        }
        if used >= p.window {
            // Full window observed in time: the paper's estimate
            // μ̂ = (1 − ε) / q̂ generalized to heterogeneous demands.
            ((1.0 - p.epsilon) * sum_dem / sum_dur, used as u64)
        } else if cold_start {
            // Haven't had a full horizon to fail yet: use what we have.
            if used > 0 {
                ((1.0 - p.epsilon) * sum_dem / sum_dur, used as u64)
            } else {
                (fallback, 0)
            }
        } else {
            // "Cannot measure q̂ in (1+ε)L/μ* time" → worker is slower than
            // the floor; discard it (Fig. 6, line 11).
            (0.0, (used as u64).max(1))
        }
    }

    /// Latest published estimates (relative speed units; `mu_hat[i] = 0`
    /// means "treat worker i as dead").
    pub fn mu_hat(&self) -> &[f64] {
        &self.mu_hat
    }

    /// In-window sample count behind each published estimate (the weight
    /// each worker carries into estimate-sync consensus).
    pub fn samples_in_window(&self) -> &[u64] {
        &self.samples
    }

    /// Snapshot this scheduler's view for estimate-sync consensus (§5):
    /// per worker, the published μ̂ and the in-window sample count behind
    /// it. O(n) copies into the reused buffer — cheap enough to run at
    /// every local publish.
    pub fn export_views_into(&self, out: &mut Vec<crate::learner::EstimateView>) {
        out.clear();
        out.extend(
            self.mu_hat
                .iter()
                .zip(self.samples.iter())
                .map(|(&mu_hat, &samples)| crate::learner::EstimateView { mu_hat, samples }),
        );
    }

    /// Allocating convenience form of [`Self::export_views_into`].
    pub fn export_views(&self) -> Vec<crate::learner::EstimateView> {
        let mut out = Vec::with_capacity(self.mu_hat.len());
        self.export_views_into(&mut out);
        out
    }

    /// Adopt a synchronized consensus vector (§5: schedulers "need only
    /// synchronize the estimates of worker speeds regularly"). The
    /// consensus becomes both the published estimate and the cold-start
    /// fallback, so a worker this scheduler never sampled is scheduled with
    /// what the other schedulers learned about it. Local sample histories
    /// are untouched: the next [`Self::publish`] re-derives local estimates
    /// from local observations.
    pub fn adopt(&mut self, consensus: &[f64]) {
        assert_eq!(consensus.len(), self.mu_hat.len(), "consensus length mismatch");
        self.mu_hat.copy_from_slice(consensus);
        self.fallback.copy_from_slice(consensus);
    }

    /// Mean relative estimation error vs true speeds (diagnostics; only the
    /// engine knows the ground truth). Workers estimated 0 count as full
    /// error unless they are truly below the floor.
    pub fn relative_error(&self, true_speeds: &[f64], mu_star_abs: f64) -> f64 {
        relative_error_of(&self.mu_hat, true_speeds, mu_star_abs)
    }

    /// Relative divergence of this learner's current local estimates from
    /// the last adopted consensus — the adaptive sync policy's merge
    /// trigger ([`crate::learner::SyncKind::Adaptive`]): a scheduler
    /// requests a merge only when this crosses the configured threshold.
    pub fn divergence_from(&self, consensus: &[f64]) -> f64 {
        crate::learner::sync::divergence_of(&self.mu_hat, consensus)
    }

    /// Export the raw ring buffers as dense matrices for the PJRT learner
    /// kernel: `(durations, demands, ages, valid_counts)`, each row one
    /// worker, columns newest-first, padded with zeros. `k` columns.
    pub fn export_dense(&self, now: f64, k: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<i32>) {
        let n = self.hist.len();
        let mut dur = vec![0.0f32; n * k];
        let mut dem = vec![0.0f32; n * k];
        let mut age = vec![f32::MAX; n * k];
        let mut cnt = vec![0i32; n];
        for (w, h) in self.hist.iter().enumerate() {
            let cap = h.buf.len();
            if cap == 0 {
                continue;
            }
            let newest = (h.head + h.len - 1) % cap;
            let take = h.len.min(k);
            for i in 0..take {
                let s = &h.buf[(newest + cap - i) % cap];
                dur[w * k + i] = s.duration as f32;
                dem[w * k + i] = s.demand as f32;
                age[w * k + i] = (now - s.at) as f32;
            }
            cnt[w] = take as i32;
        }
        (dur, dem, age, cnt)
    }
}

/// Mean relative error of an estimate vector vs true speeds — the same
/// metric as [`PerfLearner::relative_error`], usable on a merged consensus
/// vector that no single learner owns.
pub fn relative_error_of(mu_hat: &[f64], true_speeds: &[f64], mu_star_abs: f64) -> f64 {
    let mut total = 0.0;
    let mut count = 0usize;
    for (est, &truth) in mu_hat.iter().zip(true_speeds) {
        if truth <= mu_star_abs {
            continue; // legitimately discardable
        }
        total += (est - truth).abs() / truth;
        count += 1;
    }
    if count == 0 {
        0.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn learner(n: usize) -> PerfLearner {
        // τ̄ = 0.1s, μ̄ = n workers × 10 tasks/s.
        PerfLearner::new(n, 10.0, 0.1, n as f64 * 10.0, 1.0, 0.0)
    }

    #[test]
    fn params_match_paper_formulas() {
        let p = LearnerParams::derive(80.0, 100.0, 10.0, 0.1);
        assert!((p.alpha - 0.8).abs() < 1e-12);
        assert!((p.epsilon - 0.06).abs() < 1e-12);
        assert_eq!(p.window, 50); // 10 / 0.2
        assert!((p.mu_star - 0.02).abs() < 1e-12);
        assert!((p.horizon - 1.06 * 50.0 * 0.1 / 0.02).abs() < 1e-9);
    }

    #[test]
    fn params_clamp_overload() {
        let p = LearnerParams::derive(500.0, 100.0, 10.0, 0.1);
        assert!(p.alpha <= 0.99);
        assert!(p.window >= 1);
    }

    #[test]
    fn estimates_speed_of_sampled_worker() {
        let mut l = learner(2);
        // Worker 0 has speed 2.0: tasks with demand 0.1 take 0.05 s.
        let mut t = 0.0;
        for _ in 0..200 {
            t += 0.05;
            l.on_completion(0, t, 0.05, 0.1);
        }
        let p = l.publish(t, 10.0);
        let est = l.mu_hat()[0];
        assert!((est - (1.0 - p.epsilon) * 2.0).abs() < 1e-9, "est={est}");
        // Deliberate underestimate: (1-ε)·μ ≤ μ̂ ≤ μ (Lemma 5(ii)).
        assert!(est <= 2.0 && est >= (1.0 - p.epsilon) * 2.0 - 1e-9);
    }

    #[test]
    fn unsampled_worker_keeps_prior_during_cold_start() {
        let mut l = learner(2);
        l.publish(0.5, 10.0);
        assert_eq!(l.mu_hat()[1], 1.0);
    }

    #[test]
    fn silent_worker_zeroed_after_horizon() {
        let mut l = learner(2);
        // Keep worker 0 lively the whole time; worker 1 never completes.
        let p0 = LearnerParams::derive(10.0, 20.0, 10.0, 0.1);
        let end = p0.horizon * 2.0;
        let mut t = 0.0;
        while t < end {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        l.publish(end, 10.0);
        assert!(l.mu_hat()[0] > 0.0);
        assert_eq!(l.mu_hat()[1], 0.0, "silent worker must be discarded");
    }

    #[test]
    fn stale_samples_beyond_horizon_do_not_count() {
        let mut l = learner(1);
        let p = LearnerParams::derive(10.0, 10.0, 10.0, 0.1);
        // Fill a full window early...
        let mut t = 0.0;
        for _ in 0..p.window + 5 {
            t += 0.01;
            l.on_completion(0, t, 0.1, 0.1);
        }
        // ...then go silent for two horizons.
        let later = t + 2.0 * p.horizon;
        l.publish(later, 10.0);
        assert_eq!(l.mu_hat()[0], 0.0, "stale window must not survive");
    }

    #[test]
    fn window_uses_most_recent_samples_after_speed_change() {
        let mut l = learner(1);
        let mut t = 0.0;
        // Old slow phase: duration 0.2 (speed 0.5).
        for _ in 0..500 {
            t += 0.2;
            l.on_completion(0, t, 0.2, 0.1);
        }
        // New fast phase: duration 0.025 (speed 4.0) — more than L samples.
        let p = LearnerParams::derive(8.0, 10.0, 10.0, 0.1);
        for _ in 0..p.window + 10 {
            t += 0.025;
            l.on_completion(0, t, 0.025, 0.1);
        }
        l.publish(t, 8.0);
        let est = l.mu_hat()[0];
        assert!((est - (1.0 - p.epsilon) * 4.0).abs() < 0.05, "est={est}");
    }

    #[test]
    fn relative_error_ignores_sub_floor_workers() {
        let mut l = learner(2);
        let mut t = 0.0;
        for _ in 0..200 {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        l.publish(t, 10.0);
        // Worker 1 (speed 0.001, below floor) is excluded from the metric.
        // Worker 0 carries the deliberate (1-eps) underestimate bias.
        let err = l.relative_error(&[1.0, 0.001], 0.01);
        assert!(err < 0.2, "err={err}");
    }

    #[test]
    fn exported_views_carry_estimates_and_window_weights() {
        let mut l = learner(2);
        let mut t = 0.0;
        for _ in 0..200 {
            t += 0.05;
            l.on_completion(0, t, 0.05, 0.1);
        }
        let p = l.publish(t, 10.0);
        let views = l.export_views();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].mu_hat, l.mu_hat()[0]);
        // Worker 0's weight is exactly the in-window sample count L.
        assert_eq!(views[0].samples as usize, p.window);
        // Worker 1 has no samples during cold start: prior, weight 0.
        assert_eq!(views[1].mu_hat, 1.0);
        assert_eq!(views[1].samples, 0);
        assert_eq!(l.samples_in_window(), &[p.window as u64, 0]);
    }

    #[test]
    fn discarded_worker_exports_nonzero_weight() {
        // A silent worker past the horizon is discarded — and that discard
        // must carry weight into consensus, not read as "no knowledge".
        let mut l = learner(2);
        let p0 = LearnerParams::derive(10.0, 20.0, 10.0, 0.1);
        let end = p0.horizon * 2.0;
        let mut t = 0.0;
        while t < end {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        l.publish(end, 10.0);
        let views = l.export_views();
        assert_eq!(views[1].mu_hat, 0.0);
        assert!(views[1].samples >= 1, "discard must weigh at least one observation");
    }

    #[test]
    fn adopt_installs_consensus_and_cold_start_fallback() {
        let mut l = learner(2);
        l.adopt(&[2.5, 0.125]);
        assert_eq!(l.mu_hat(), &[2.5, 0.125]);
        // A publish with no samples (cold start) falls back to the adopted
        // consensus, not the birth prior.
        l.publish(0.01, 10.0);
        assert_eq!(l.mu_hat(), &[2.5, 0.125]);
        // But local samples always win over the adopted value.
        let mut t = 0.01;
        for _ in 0..200 {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        let p = l.publish(t, 10.0);
        assert!((l.mu_hat()[0] - (1.0 - p.epsilon)).abs() < 1e-9, "{}", l.mu_hat()[0]);
        assert_eq!(l.mu_hat()[1], 0.125);
    }

    #[test]
    fn sharded_learner_needs_only_its_share_of_the_window() {
        // k = 4 schedulers: each sees ~1/4 of a worker's completions, so
        // the per-scheduler full-window requirement drops to ⌈L/4⌉ while
        // the timeout horizon keeps the full-L value — the discard floor
        // does not multiply with k.
        let p = LearnerParams::derive(5.0, 10.0, 10.0, 0.1);
        assert_eq!(p.window, 20);
        let mk = |k: usize| PerfLearner::new(1, 10.0, 0.1, 10.0, 1.0, 0.0).shared_among(k);
        let mut solo = mk(1);
        let mut quarter = mk(4);
        // Both see the same 5 fresh samples, well past the cold start.
        let t_end = p.horizon * 3.0;
        for i in 0..5 {
            let t = t_end - (4 - i) as f64 * 0.1;
            solo.on_completion(0, t, 0.1, 0.1);
            quarter.on_completion(0, t, 0.1, 0.1);
        }
        solo.publish(t_end, 5.0);
        let pq = quarter.publish(t_end, 5.0);
        assert_eq!(pq.window, 5, "per-scheduler window is L/k");
        assert_eq!(solo.mu_hat()[0], 0.0, "5 of 20 samples: centralized learner discards");
        let eps = 0.3 * 0.5;
        assert!(
            (quarter.mu_hat()[0] - (1.0 - eps)).abs() < 1e-9,
            "5 >= 20/4: the sharded learner keeps the estimate ({})",
            quarter.mu_hat()[0]
        );
        assert_eq!(quarter.samples_in_window(), &[5]);
    }

    #[test]
    #[should_panic]
    fn adopt_rejects_wrong_length() {
        let mut l = learner(2);
        l.adopt(&[1.0]);
    }

    #[test]
    fn divergence_from_tracks_drift_off_the_adopted_consensus() {
        let mut l = learner(2);
        l.adopt(&[2.0, 1.0]);
        // Freshly adopted: zero divergence by construction.
        assert_eq!(l.divergence_from(&[2.0, 1.0]), 0.0);
        // Local samples re-derive worker 0 at ≈ (1−ε)·1.0 ≈ 0.94, a ~53%
        // relative drift off the adopted 2.0; worker 1 stays put.
        let mut t = 0.0;
        for _ in 0..200 {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        l.publish(t, 10.0);
        let d = l.divergence_from(&[2.0, 1.0]);
        assert!(d > 0.2, "drifted estimate must register divergence: {d}");
    }

    #[test]
    fn relative_error_of_matches_method() {
        let mut l = learner(2);
        let mut t = 0.0;
        for _ in 0..100 {
            t += 0.1;
            l.on_completion(0, t, 0.1, 0.1);
        }
        l.publish(t, 10.0);
        let a = l.relative_error(&[1.0, 0.5], 0.01);
        let b = relative_error_of(l.mu_hat(), &[1.0, 0.5], 0.01);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn export_dense_shapes_and_padding() {
        let mut l = learner(3);
        l.on_completion(1, 1.0, 0.05, 0.1);
        l.on_completion(1, 2.0, 0.07, 0.1);
        let (dur, dem, age, cnt) = l.export_dense(3.0, 4);
        assert_eq!(dur.len(), 12);
        assert_eq!(cnt, vec![0, 2, 0]);
        // Newest first for worker 1.
        assert!((dur[4] - 0.07).abs() < 1e-6);
        assert!((dur[5] - 0.05).abs() < 1e-6);
        assert!((age[4] - 1.0).abs() < 1e-6);
        assert!((age[5] - 2.0).abs() < 1e-6);
        assert_eq!(dem[0], 0.0);
        assert_eq!(dur[8], 0.0);
    }
}
