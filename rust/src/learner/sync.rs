//! Multi-scheduler estimate synchronization (paper §5, "Distributed
//! scheduler").
//!
//! "When there are multiple schedulers, they need only synchronize the
//! estimates of worker speeds regularly." Each scheduler observes only the
//! completions of tasks *it* routed (plus its own benchmark jobs), so its
//! per-worker sample counts differ; the merge rule below combines the
//! schedulers' views into one vector that every scheduler adopts:
//!
//! * per worker, estimates are averaged weighted by each scheduler's
//!   in-window sample count (a scheduler that saw 40 fresh samples should
//!   dominate one that saw 2);
//! * a worker all schedulers discarded (μ̂ = 0 everywhere with samples
//!   present) stays discarded;
//! * a worker *no* scheduler has samples for keeps the supplied prior.
//!
//! The same rule throttles benchmark traffic: with `k` schedulers each
//! dispatcher runs at `c0(μ̄ − λ̂)/k` so the aggregate probing rate matches
//! the single-scheduler design (§5: "excessive amount of benchmark jobs
//! ... could be sent"; "implementing throttling ensures the benchmark jobs
//! will not adversarially affect the system").

/// One scheduler's view of one worker at sync time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimateView {
    /// Published estimate μ̂ (0 = discarded).
    pub mu_hat: f64,
    /// Number of in-window samples behind the estimate.
    pub samples: u64,
}

/// Merge `k` schedulers' estimate vectors into the consensus vector.
///
/// `views[s][w]` is scheduler `s`'s view of worker `w`; `prior` fills
/// workers nobody has sampled. Panics if the views disagree on the worker
/// count or are empty.
pub fn merge_estimates(views: &[Vec<EstimateView>], prior: f64) -> Vec<f64> {
    let mut out = vec![0.0; views.first().map_or(0, |v| v.len())];
    merge_estimates_into(views, prior, &mut out);
    out
}

/// [`merge_estimates`] into a caller-owned buffer — the allocation-free
/// form used on the recurring sync paths (the plane's sync thread and the
/// DES engine's sync event), where consensus runs at every epoch.
pub fn merge_estimates_into(views: &[Vec<EstimateView>], prior: f64, out: &mut [f64]) {
    assert!(!views.is_empty(), "no schedulers to merge");
    let n = views[0].len();
    assert!(views.iter().all(|v| v.len() == n), "worker-count mismatch across schedulers");
    assert_eq!(out.len(), n, "consensus buffer length mismatch");
    for (w, slot) in out.iter_mut().enumerate() {
        let mut weighted = 0.0;
        let mut weight = 0u64;
        for view in views {
            let v = view[w];
            if v.samples > 0 {
                weighted += v.mu_hat * v.samples as f64;
                weight += v.samples;
            }
        }
        *slot = if weight == 0 { prior } else { weighted / weight as f64 };
    }
}

/// Per-scheduler benchmark dispatch rate under `k` schedulers: the
/// aggregate probing budget `c0(μ̄ − λ̂)` is split evenly (throttling).
pub fn throttled_rate(c0: f64, mu_bar: f64, lambda_hat: f64, schedulers: usize) -> f64 {
    assert!(schedulers >= 1);
    (c0 * (mu_bar - lambda_hat)).max(0.0) / schedulers as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(mu: f64, s: u64) -> EstimateView {
        EstimateView { mu_hat: mu, samples: s }
    }

    #[test]
    fn weighted_by_sample_counts() {
        // Scheduler A saw 40 samples of worker 0 (est 2.0); B saw 10 (1.0).
        let merged = merge_estimates(&[vec![v(2.0, 40)], vec![v(1.0, 10)]], 1.0);
        assert!((merged[0] - 1.8).abs() < 1e-12, "{merged:?}");
    }

    #[test]
    fn unsampled_worker_keeps_prior() {
        let merged = merge_estimates(&[vec![v(0.0, 0)], vec![v(0.0, 0)]], 0.7);
        assert_eq!(merged[0], 0.7);
    }

    #[test]
    fn unanimous_discard_stays_discarded() {
        // Both schedulers have samples and both zeroed the worker.
        let merged = merge_estimates(&[vec![v(0.0, 20)], vec![v(0.0, 30)]], 1.0);
        assert_eq!(merged[0], 0.0);
    }

    #[test]
    fn one_sided_knowledge_wins() {
        // Only scheduler B has any samples.
        let merged = merge_estimates(&[vec![v(0.0, 0)], vec![v(1.3, 25)]], 1.0);
        assert!((merged[0] - 1.3).abs() < 1e-12);
    }

    #[test]
    fn per_worker_independence() {
        let a = vec![v(2.0, 10), v(0.0, 0)];
        let b = vec![v(2.0, 10), v(0.5, 10)];
        let merged = merge_estimates(&[a, b], 1.0);
        assert!((merged[0] - 2.0).abs() < 1e-12);
        assert!((merged[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_worker_counts_rejected() {
        merge_estimates(&[vec![v(1.0, 1)], vec![v(1.0, 1), v(1.0, 1)]], 1.0);
    }

    #[test]
    fn heavy_sampler_dominates_merge() {
        // 40 in-window samples must dominate 2: the consensus lands next to
        // the well-informed scheduler's estimate.
        let merged = merge_estimates(&[vec![v(3.0, 40)], vec![v(1.0, 2)]], 1.0);
        assert!((merged[0] - 122.0 / 42.0).abs() < 1e-12, "{merged:?}");
        assert!(merged[0] > 2.8, "2 samples dragged the consensus: {merged:?}");
    }

    #[test]
    fn merge_into_matches_allocating_form() {
        let views = vec![vec![v(2.0, 7), v(0.0, 3)], vec![v(1.0, 1), v(0.0, 0)]];
        let alloc = merge_estimates(&views, 0.9);
        let mut buf = vec![f64::NAN; 2];
        merge_estimates_into(&views, 0.9, &mut buf);
        assert_eq!(alloc, buf);
    }

    #[test]
    #[should_panic]
    fn merge_into_rejects_wrong_buffer_length() {
        let mut buf = vec![0.0; 3];
        merge_estimates_into(&[vec![v(1.0, 1)]], 1.0, &mut buf);
    }

    #[test]
    fn throttled_rate_monotone_in_scheduler_count() {
        // Per-scheduler rate shrinks as k grows while the aggregate budget
        // k · c0(μ̄ − λ̂)/k stays pinned to the single-scheduler budget.
        let single = throttled_rate(0.1, 150.0, 100.0, 1);
        let mut prev = f64::INFINITY;
        for k in 1..=16 {
            let r = throttled_rate(0.1, 150.0, 100.0, k);
            assert!(r <= prev, "rate must not grow with k: {r} at k={k}");
            assert!((r * k as f64 - single).abs() < 1e-9, "aggregate budget drifted at k={k}");
            prev = r;
        }
    }

    #[test]
    fn throttling_splits_budget() {
        let single = throttled_rate(0.1, 150.0, 120.0, 1);
        let per_of_three = throttled_rate(0.1, 150.0, 120.0, 3);
        assert!((single - 3.0).abs() < 1e-12);
        assert!((per_of_three - 1.0).abs() < 1e-12);
        // Overload clamps to zero rather than going negative.
        assert_eq!(throttled_rate(0.1, 100.0, 200.0, 2), 0.0);
    }
}
