//! Multi-scheduler estimate synchronization (paper §5, "Distributed
//! scheduler").
//!
//! "When there are multiple schedulers, they need only synchronize the
//! estimates of worker speeds regularly." Each scheduler observes only the
//! completions of tasks *it* routed (plus its own benchmark jobs), so its
//! per-worker sample counts differ; the merge rule below combines the
//! schedulers' views into one vector that every scheduler adopts:
//!
//! * per worker, estimates are averaged weighted by each scheduler's
//!   in-window sample count (a scheduler that saw 40 fresh samples should
//!   dominate one that saw 2);
//! * a worker all schedulers discarded (μ̂ = 0 everywhere with samples
//!   present) stays discarded;
//! * a worker *no* scheduler has samples for keeps the supplied prior.
//!
//! The same rule throttles benchmark traffic: with `k` schedulers each
//! dispatcher runs at `c0(μ̄ − λ̂)/k` so the aggregate probing rate matches
//! the single-scheduler design (§5: "excessive amount of benchmark jobs
//! ... could be sent"; "implementing throttling ensures the benchmark jobs
//! will not adversarially affect the system").
//!
//! ## Sync policies
//!
//! The paper fixes *what* is exchanged but leaves *when* and *with whom*
//! open ("regularly"). [`SyncPolicy`] makes that axis pluggable; the same
//! policy object drives both the threaded plane's sync thread and the
//! deterministic DES engine:
//!
//! * [`SyncKind::Periodic`] — a fixed-timer all-to-all epoch (the original
//!   behavior, bit-compatible);
//! * [`SyncKind::Adaptive`] — state is exchanged only when it buys
//!   scheduling quality: a scheduler requests a merge when its local
//!   estimates diverge from the last adopted consensus beyond a
//!   relative-error threshold ([`divergence_of`]), bounded below by a
//!   minimum merge spacing and above by a staleness deadline that forces a
//!   merge;
//! * [`SyncKind::Gossip`] — each round a deterministic-RNG pairing merges
//!   view *pairs* instead of running an all-to-all epoch; information
//!   spreads epidemically, reaching every scheduler in O(log k) rounds
//!   (the round counter [`SyncPolicy::round`] is the proof handle the
//!   convergence test below pins).
//!
//! The exchanged payload is a [`SyncPayload`]: the per-worker μ̂ views plus
//! the scheduler's *local* arrival-rate estimate λ̂ₛ. Summing the exchanged
//! shares gives λ̂_global, so each dispatcher throttles to
//! `c0(μ̄ − λ̂_global)/k` even when arrival routing is skewed — a scheduler
//! receiving 3× its fair share no longer assumes everyone else sees the
//! same load ([`LambdaShares`] carries the shares under gossip, where no
//! single epoch sees all of them).

use crate::stats::Rng;

/// One scheduler's view of one worker at sync time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EstimateView {
    /// Published estimate μ̂ (0 = discarded).
    pub mu_hat: f64,
    /// Number of in-window samples behind the estimate.
    pub samples: u64,
}

/// One scheduler's full sync payload: its per-worker μ̂ views plus its
/// local arrival-rate estimate λ̂ₛ (tasks/second). Summing the exchanged
/// `lambda_hat` shares over schedulers yields λ̂_global, the §5 throttle's
/// input — computed from *exchanged* estimates, not an assumed even split.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SyncPayload {
    /// Per-worker estimate views.
    pub views: Vec<EstimateView>,
    /// This scheduler's local arrival-rate estimate λ̂ₛ (tasks/second).
    pub lambda_hat: f64,
}

impl AsRef<[EstimateView]> for SyncPayload {
    fn as_ref(&self) -> &[EstimateView] {
        &self.views
    }
}

/// Merge `k` schedulers' estimate vectors into the consensus vector.
///
/// `views[s][w]` is scheduler `s`'s view of worker `w`; `prior` fills
/// workers nobody has sampled. Panics if the views disagree on the worker
/// count or are empty.
pub fn merge_estimates<V: AsRef<[EstimateView]>>(views: &[V], prior: f64) -> Vec<f64> {
    let mut out = vec![0.0; views.first().map_or(0, |v| v.as_ref().len())];
    merge_estimates_into(views, prior, &mut out);
    out
}

/// [`merge_estimates`] into a caller-owned buffer — the allocation-free
/// form used on the recurring sync paths (the plane's sync thread and the
/// DES engine's sync event), where consensus runs at every epoch.
///
/// A single view is its own consensus and is copied bit-exactly: the
/// weighted form would compute `(μ·s)/s`, which can drift one ulp off `μ`.
pub fn merge_estimates_into<V: AsRef<[EstimateView]>>(views: &[V], prior: f64, out: &mut [f64]) {
    assert!(!views.is_empty(), "no schedulers to merge");
    let n = views[0].as_ref().len();
    assert!(
        views.iter().all(|v| v.as_ref().len() == n),
        "worker-count mismatch across schedulers"
    );
    assert_eq!(out.len(), n, "consensus buffer length mismatch");
    if views.len() == 1 {
        // Trivial partition fast path: ulp-identity with the lone view.
        for (slot, v) in out.iter_mut().zip(views[0].as_ref()) {
            *slot = if v.samples == 0 { prior } else { v.mu_hat };
        }
        return;
    }
    for (w, slot) in out.iter_mut().enumerate() {
        let mut weighted = 0.0;
        let mut weight = 0u64;
        for view in views {
            let v = view.as_ref()[w];
            if v.samples > 0 {
                weighted += v.mu_hat * v.samples as f64;
                weight += v.samples;
            }
        }
        *slot = if weight == 0 { prior } else { weighted / weight as f64 };
    }
}

/// Merge full [`SyncPayload`]s: the μ̂ views go through
/// [`merge_estimates_into`]; the returned value is λ̂_global — the sum of
/// the exchanged per-scheduler arrival shares.
pub fn merge_payloads_into(payloads: &[SyncPayload], prior: f64, out: &mut [f64]) -> f64 {
    merge_estimates_into(payloads, prior, out);
    payloads.iter().map(|p| p.lambda_hat).sum()
}

/// Per-scheduler benchmark dispatch rate under `k` schedulers: the
/// aggregate probing budget `c0(μ̄ − λ̂)` is split evenly (throttling).
/// `lambda_hat` must be the *global* arrival estimate — under skewed
/// arrival routing that is the sum of exchanged shares, not `k` times any
/// one scheduler's local estimate.
pub fn throttled_rate(c0: f64, mu_bar: f64, lambda_hat: f64, schedulers: usize) -> f64 {
    assert!(schedulers >= 1);
    (c0 * (mu_bar - lambda_hat)).max(0.0) / schedulers as f64
}

/// Relative divergence of a scheduler's local estimates from the last
/// adopted consensus — the adaptive policy's merge trigger. Treats the
/// consensus as truth ([`crate::learner::relative_error_of`]); workers the
/// consensus discarded (μ̂ = 0) are excluded.
pub fn divergence_of(local_mu: &[f64], consensus: &[f64]) -> f64 {
    crate::learner::perf::relative_error_of(local_mu, consensus, 0.0)
}

/// Which strategy schedules and shapes estimate-sync consensus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncKind {
    /// Fixed-timer all-to-all epochs (the original behavior).
    Periodic,
    /// Divergence-triggered all-to-all merges, bounded by min/max spacing.
    Adaptive,
    /// Deterministic-RNG pairwise merges, one pairing per round.
    Gossip,
}

impl SyncKind {
    /// CLI / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            SyncKind::Periodic => "periodic",
            SyncKind::Adaptive => "adaptive",
            SyncKind::Gossip => "gossip",
        }
    }

    /// Parse the CLI / JSON spelling.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "periodic" => Ok(SyncKind::Periodic),
            "adaptive" => Ok(SyncKind::Adaptive),
            "gossip" => Ok(SyncKind::Gossip),
            other => Err(format!("unknown sync policy '{other}' (periodic | adaptive | gossip)")),
        }
    }
}

/// Configuration of a [`SyncPolicy`]. The epoch interval itself stays where
/// the host keeps it (`LearnerConfig::sync_interval` /
/// `PlaneConfig::sync_interval`); this struct carries the strategy and its
/// knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SyncPolicyConfig {
    /// Strategy.
    pub kind: SyncKind,
    /// Adaptive: relative-error divergence beyond which a scheduler
    /// requests a merge.
    pub threshold: f64,
    /// Adaptive: merges never happen closer together than this; it is also
    /// the divergence-check cadence (0 = use the sync interval).
    pub min_interval: f64,
    /// Adaptive: a merge is forced once this much time passed since the
    /// last one, diverged or not (0 = 10 × the sync interval).
    pub max_interval: f64,
}

impl Default for SyncPolicyConfig {
    fn default() -> Self {
        Self::periodic()
    }
}

impl SyncPolicyConfig {
    /// The original fixed-timer all-to-all behavior.
    pub fn periodic() -> Self {
        Self { kind: SyncKind::Periodic, threshold: 0.1, min_interval: 0.0, max_interval: 0.0 }
    }

    /// Divergence-triggered sync with the given relative-error threshold.
    pub fn adaptive(threshold: f64) -> Self {
        Self { kind: SyncKind::Adaptive, threshold, ..Self::periodic() }
    }

    /// Deterministic pairwise gossip rounds.
    pub fn gossip() -> Self {
        Self { kind: SyncKind::Gossip, ..Self::periodic() }
    }

    /// Shard-count-aware adaptive threshold: the divergence trigger
    /// resolved for a `schedulers`-scheduler topology.
    ///
    /// With `k` schedulers each learner sees only ~1/k of the completion
    /// stream (its window is ⌈L/k⌉), so its local estimates carry ≈√k
    /// times the sampling noise of the centralized learner — and so does
    /// the divergence statistic measured against the adopted consensus.
    /// Comparing that noisier statistic to a *fixed* threshold makes
    /// noise-triggered merges increasingly likely as k grows: exactly the
    /// over-merging §2's minimum-coordination goal forbids. Normalizing
    /// the statistic by 1/√k — applied here as the equivalent √k scale-up
    /// of the configured threshold — keeps the noise-trigger probability
    /// roughly k-independent, so adding schedulers does not silently buy
    /// more coordination. `scaled_threshold(1)` is the identity.
    pub fn scaled_threshold(&self, schedulers: usize) -> f64 {
        assert!(schedulers >= 1);
        if schedulers == 1 {
            // Bit-exact identity for the centralized topology.
            self.threshold
        } else {
            self.threshold * (schedulers as f64).sqrt()
        }
    }

    /// Resolved minimum merge spacing / adaptive check cadence.
    pub fn resolved_min(&self, sync_interval: f64) -> f64 {
        if self.min_interval > 0.0 {
            self.min_interval
        } else {
            sync_interval
        }
    }

    /// Resolved staleness deadline forcing an adaptive merge.
    pub fn resolved_max(&self, sync_interval: f64) -> f64 {
        if self.max_interval > 0.0 {
            self.max_interval
        } else {
            sync_interval * 10.0
        }
    }

    /// Validate against the host's sync interval (cross-field constraints).
    pub fn validate(&self, sync_interval: f64) -> Result<(), String> {
        if !(self.threshold > 0.0 && self.threshold.is_finite()) {
            return Err(format!(
                "sync threshold must be positive and finite (got {}): a NaN or negative \
                 threshold silently yields a policy that never or always merges",
                self.threshold
            ));
        }
        if !(self.min_interval >= 0.0 && self.min_interval.is_finite()) {
            return Err("sync min_interval must be finite and non-negative".into());
        }
        if !(self.max_interval >= 0.0 && self.max_interval.is_finite()) {
            return Err("sync max_interval must be finite and non-negative".into());
        }
        if self.kind != SyncKind::Periodic && !(sync_interval > 0.0 && sync_interval.is_finite())
        {
            return Err(format!(
                "{} sync needs a positive finite sync interval (periodic alone may fuse \
                 consensus into every publish with interval 0)",
                self.kind.name()
            ));
        }
        if self.kind == SyncKind::Adaptive
            && self.resolved_min(sync_interval) > self.resolved_max(sync_interval)
        {
            return Err("adaptive sync min_interval exceeds max_interval".into());
        }
        Ok(())
    }
}

/// What a sync epoch should do, as decided by a [`SyncPolicy`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SyncDecision {
    /// Exchange nothing this epoch.
    Skip,
    /// All-to-all: merge every scheduler's view into one consensus.
    MergeAll,
    /// Gossip: merge exactly these disjoint scheduler pairs.
    MergePairs(Vec<(usize, usize)>),
}

/// The pluggable sync strategy: one state machine shared by the threaded
/// plane's sync thread and the deterministic DES engine. The host fires a
/// *check epoch* every [`SyncPolicy::check_interval`] seconds and asks
/// [`SyncPolicy::on_epoch`] what (if anything) to exchange.
#[derive(Debug)]
pub struct SyncPolicy {
    kind: SyncKind,
    threshold: f64,
    min_interval: f64,
    max_interval: f64,
    check_interval: f64,
    /// Deterministic pairing stream (gossip only; seeded by the host so
    /// simulator runs stay bit-reproducible).
    rng: Rng,
    perm: Vec<usize>,
    last_merge: f64,
    round: u64,
    epochs: u64,
    merges: u64,
}

impl SyncPolicy {
    /// Build a policy for `schedulers` schedulers syncing on
    /// `sync_interval`. Panics on an invalid configuration (hosts with a
    /// fallible surface run [`SyncPolicyConfig::validate`] first).
    pub fn new(cfg: &SyncPolicyConfig, sync_interval: f64, schedulers: usize, seed: u64) -> Self {
        if let Err(e) = cfg.validate(sync_interval) {
            panic!("invalid sync policy: {e}");
        }
        assert!(schedulers >= 1);
        let min_interval = cfg.resolved_min(sync_interval);
        let max_interval = cfg.resolved_max(sync_interval);
        Self {
            kind: cfg.kind,
            // Shard-count-aware trigger: see `scaled_threshold`'s rationale.
            threshold: cfg.scaled_threshold(schedulers),
            min_interval,
            max_interval,
            check_interval: match cfg.kind {
                SyncKind::Adaptive => min_interval,
                _ => sync_interval,
            },
            rng: Rng::new(seed),
            perm: (0..schedulers).collect(),
            last_merge: 0.0,
            round: 0,
            epochs: 0,
            merges: 0,
        }
    }

    /// Strategy.
    pub fn kind(&self) -> SyncKind {
        self.kind
    }

    /// Adaptive divergence threshold, already √k-scaled for the scheduler
    /// count this policy was built for
    /// ([`SyncPolicyConfig::scaled_threshold`]).
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Cadence at which the host should fire check epochs (seconds). The
    /// sync interval for periodic/gossip, the resolved minimum spacing for
    /// adaptive.
    pub fn check_interval(&self) -> f64 {
        self.check_interval
    }

    /// Gossip rounds completed so far.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Check epochs evaluated so far.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Merge operations performed so far (an all-to-all epoch counts one,
    /// every gossip pair counts one) — the coordination-cost counter the
    /// multisched frontier reports.
    pub fn merges(&self) -> u64 {
        self.merges
    }

    /// One check epoch at time `now`. `diverged` reports whether any
    /// scheduler's local view drifted beyond [`Self::threshold`] from the
    /// last adopted consensus (only consulted by the adaptive strategy;
    /// hosts compute it with [`divergence_of`] or collect shard-side merge
    /// requests).
    pub fn on_epoch(&mut self, now: f64, diverged: bool) -> SyncDecision {
        self.epochs += 1;
        match self.kind {
            SyncKind::Periodic => {
                self.last_merge = now;
                self.merges += 1;
                SyncDecision::MergeAll
            }
            SyncKind::Adaptive => {
                let since = now - self.last_merge;
                if (diverged && since >= self.min_interval - 1e-12) || since >= self.max_interval {
                    self.last_merge = now;
                    self.merges += 1;
                    SyncDecision::MergeAll
                } else {
                    SyncDecision::Skip
                }
            }
            SyncKind::Gossip => {
                let pairs = self.draw_pairing();
                self.last_merge = now;
                self.round += 1;
                if pairs.is_empty() {
                    // A lone scheduler has nobody to pair with: its own
                    // view *is* the consensus. Degrade to an all-to-all
                    // epoch so a k=1 run still publishes instead of
                    // silently exchanging nothing.
                    self.merges += 1;
                    return SyncDecision::MergeAll;
                }
                self.merges += pairs.len() as u64;
                SyncDecision::MergePairs(pairs)
            }
        }
    }

    /// One synchronous gossip pairing: a uniform random perfect matching of
    /// the schedulers (⌊k/2⌋ disjoint pairs; with odd `k` one scheduler
    /// sits the round out).
    fn draw_pairing(&mut self) -> Vec<(usize, usize)> {
        self.rng.shuffle(&mut self.perm);
        self.perm.chunks_exact(2).map(|c| (c[0], c[1])).collect()
    }
}

/// One scheduler's knowledge of every scheduler's λ̂ share, aged by when
/// each entry was last heard — the arrival half of the gossip payload.
/// All-to-all merges refresh every entry at once; a pairwise merge
/// exchanges the fresher entry per scheduler, so λ̂_global estimates
/// converge epidemically alongside the μ̂ views.
#[derive(Debug, Clone)]
pub struct LambdaShares {
    vals: Vec<f64>,
    heard: Vec<f64>,
}

impl LambdaShares {
    /// No knowledge yet: every share 0 (λ̂_global starts at the cold-start
    /// value the dispatcher already tolerates).
    pub fn new(schedulers: usize) -> Self {
        assert!(schedulers >= 1);
        Self { vals: vec![0.0; schedulers], heard: vec![f64::NEG_INFINITY; schedulers] }
    }

    /// Number of schedulers tracked.
    pub fn k(&self) -> usize {
        self.vals.len()
    }

    /// Record scheduler `who`'s share as observed at time `now`.
    pub fn learn(&mut self, who: usize, lambda_hat: f64, now: f64) {
        self.vals[who] = lambda_hat;
        self.heard[who] = now;
    }

    /// When scheduler `who`'s share was last heard (−∞ = never).
    pub fn heard_at(&self, who: usize) -> f64 {
        self.heard[who]
    }

    /// Pairwise exchange: each side keeps the fresher entry per scheduler.
    pub fn exchange(a: &mut LambdaShares, b: &mut LambdaShares) {
        assert_eq!(a.vals.len(), b.vals.len(), "scheduler-count mismatch");
        for i in 0..a.vals.len() {
            if a.heard[i] < b.heard[i] {
                a.vals[i] = b.vals[i];
                a.heard[i] = b.heard[i];
            } else if b.heard[i] < a.heard[i] {
                b.vals[i] = a.vals[i];
                b.heard[i] = a.heard[i];
            }
        }
    }

    /// This scheduler's current estimate of λ̂_global: the sum of the
    /// freshest shares it knows.
    pub fn total(&self) -> f64 {
        self.vals.iter().sum()
    }

    /// λ̂_global extrapolated over coverage: the known-share sum scaled by
    /// `k / heard`, so early gossip rounds (2 of k shares heard) estimate
    /// the full load instead of a badly incomplete partial sum. `None`
    /// when no share has been heard yet (callers fall back to their
    /// bootstrap). Converges to [`Self::total`] as coverage completes.
    pub fn extrapolated_total(&self) -> Option<f64> {
        let heard = self.heard.iter().filter(|&&h| h > f64::NEG_INFINITY).count();
        if heard == 0 {
            return None;
        }
        Some(self.total() * self.vals.len() as f64 / heard as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(mu: f64, s: u64) -> EstimateView {
        EstimateView { mu_hat: mu, samples: s }
    }

    #[test]
    fn weighted_by_sample_counts() {
        // Scheduler A saw 40 samples of worker 0 (est 2.0); B saw 10 (1.0).
        let merged = merge_estimates(&[vec![v(2.0, 40)], vec![v(1.0, 10)]], 1.0);
        assert!((merged[0] - 1.8).abs() < 1e-12, "{merged:?}");
    }

    #[test]
    fn unsampled_worker_keeps_prior() {
        let merged = merge_estimates(&[vec![v(0.0, 0)], vec![v(0.0, 0)]], 0.7);
        assert_eq!(merged[0], 0.7);
    }

    #[test]
    fn unanimous_discard_stays_discarded() {
        // Both schedulers have samples and both zeroed the worker.
        let merged = merge_estimates(&[vec![v(0.0, 20)], vec![v(0.0, 30)]], 1.0);
        assert_eq!(merged[0], 0.0);
    }

    #[test]
    fn one_sided_knowledge_wins() {
        // Only scheduler B has any samples.
        let merged = merge_estimates(&[vec![v(0.0, 0)], vec![v(1.3, 25)]], 1.0);
        assert!((merged[0] - 1.3).abs() < 1e-12);
    }

    #[test]
    fn per_worker_independence() {
        let a = vec![v(2.0, 10), v(0.0, 0)];
        let b = vec![v(2.0, 10), v(0.5, 10)];
        let merged = merge_estimates(&[a, b], 1.0);
        assert!((merged[0] - 2.0).abs() < 1e-12);
        assert!((merged[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_worker_counts_rejected() {
        merge_estimates(&[vec![v(1.0, 1)], vec![v(1.0, 1), v(1.0, 1)]], 1.0);
    }

    #[test]
    #[should_panic]
    fn empty_view_set_rejected() {
        // Zero schedulers is a wiring bug, not a degenerate consensus.
        let views: Vec<Vec<EstimateView>> = Vec::new();
        merge_estimates(&views, 1.0);
    }

    #[test]
    fn all_zero_sample_weights_merge_to_the_prior_everywhere() {
        // Every scheduler knows nothing about any worker: the consensus is
        // the prior for the whole cluster, not NaN from a 0/0 division.
        let views = vec![vec![v(3.0, 0), v(0.2, 0), v(9.9, 0)]; 4];
        let merged = merge_estimates(&views, 0.55);
        assert_eq!(merged, vec![0.55; 3]);
    }

    #[test]
    fn single_view_fast_path_is_ulp_identical() {
        // A lone scheduler's consensus is its own view, bit-for-bit: the
        // weighted form would compute (μ·s)/s, which can drift one ulp.
        let mu = 0.1 + 0.2; // 0.30000000000000004 — a classic ulp trap
        let merged = merge_estimates(&[vec![v(mu, 7), v(0.0, 0)]], 1.25);
        assert_eq!(merged[0].to_bits(), mu.to_bits(), "single view must copy exactly");
        assert_eq!(merged[1], 1.25, "unsampled worker still takes the prior");
    }

    #[test]
    fn heavy_sampler_dominates_merge() {
        // 40 in-window samples must dominate 2: the consensus lands next to
        // the well-informed scheduler's estimate.
        let merged = merge_estimates(&[vec![v(3.0, 40)], vec![v(1.0, 2)]], 1.0);
        assert!((merged[0] - 122.0 / 42.0).abs() < 1e-12, "{merged:?}");
        assert!(merged[0] > 2.8, "2 samples dragged the consensus: {merged:?}");
    }

    #[test]
    fn merge_into_matches_allocating_form() {
        let views = vec![vec![v(2.0, 7), v(0.0, 3)], vec![v(1.0, 1), v(0.0, 0)]];
        let alloc = merge_estimates(&views, 0.9);
        let mut buf = vec![f64::NAN; 2];
        merge_estimates_into(&views, 0.9, &mut buf);
        assert_eq!(alloc, buf);
    }

    #[test]
    #[should_panic]
    fn merge_into_rejects_wrong_buffer_length() {
        let mut buf = vec![0.0; 3];
        merge_estimates_into(&[vec![v(1.0, 1)]], 1.0, &mut buf);
    }

    #[test]
    fn payload_merge_sums_exchanged_lambda_shares() {
        let payloads = vec![
            SyncPayload { views: vec![v(2.0, 40)], lambda_hat: 9.0 },
            SyncPayload { views: vec![v(1.0, 10)], lambda_hat: 1.0 },
            SyncPayload { views: vec![v(0.0, 0)], lambda_hat: 2.0 },
        ];
        let mut out = vec![0.0; 1];
        let lambda = merge_payloads_into(&payloads, 1.0, &mut out);
        assert!((out[0] - 1.8).abs() < 1e-12, "{out:?}");
        // λ̂_global is the *sum of shares*: under the 9/1/2 skew the even-
        // split assumption (k × any local share) would be wildly wrong.
        assert_eq!(lambda, 12.0);
    }

    #[test]
    fn throttled_rate_monotone_in_scheduler_count() {
        // Per-scheduler rate shrinks as k grows while the aggregate budget
        // k · c0(μ̄ − λ̂)/k stays pinned to the single-scheduler budget.
        let single = throttled_rate(0.1, 150.0, 100.0, 1);
        let mut prev = f64::INFINITY;
        for k in 1..=16 {
            let r = throttled_rate(0.1, 150.0, 100.0, k);
            assert!(r <= prev, "rate must not grow with k: {r} at k={k}");
            assert!((r * k as f64 - single).abs() < 1e-9, "aggregate budget drifted at k={k}");
            prev = r;
        }
    }

    #[test]
    fn throttling_splits_budget() {
        let single = throttled_rate(0.1, 150.0, 120.0, 1);
        let per_of_three = throttled_rate(0.1, 150.0, 120.0, 3);
        assert!((single - 3.0).abs() < 1e-12);
        assert!((per_of_three - 1.0).abs() < 1e-12);
        // Overload clamps to zero rather than going negative.
        assert_eq!(throttled_rate(0.1, 100.0, 200.0, 2), 0.0);
    }

    #[test]
    fn sync_kind_parse_round_trips() {
        for kind in [SyncKind::Periodic, SyncKind::Adaptive, SyncKind::Gossip] {
            assert_eq!(SyncKind::parse(kind.name()), Ok(kind));
        }
        assert!(SyncKind::parse("nonsense").is_err());
    }

    #[test]
    fn policy_config_validation() {
        // Periodic tolerates interval 0 (consensus fused into publish).
        assert!(SyncPolicyConfig::periodic().validate(0.0).is_ok());
        assert!(SyncPolicyConfig::periodic().validate(0.5).is_ok());
        // Adaptive/gossip need a real epoch cadence.
        assert!(SyncPolicyConfig::adaptive(0.1).validate(0.0).is_err());
        assert!(SyncPolicyConfig::gossip().validate(0.0).is_err());
        assert!(SyncPolicyConfig::adaptive(0.1).validate(f64::INFINITY).is_err());
        assert!(SyncPolicyConfig::adaptive(0.1).validate(1.0).is_ok());
        assert!(SyncPolicyConfig::gossip().validate(1.0).is_ok());
        // Bad thresholds and inverted bounds are rejected.
        assert!(SyncPolicyConfig::adaptive(0.0).validate(1.0).is_err());
        assert!(SyncPolicyConfig::adaptive(f64::NAN).validate(1.0).is_err());
        let inverted = SyncPolicyConfig {
            min_interval: 5.0,
            max_interval: 1.0,
            ..SyncPolicyConfig::adaptive(0.1)
        };
        assert!(inverted.validate(1.0).is_err());
    }

    #[test]
    fn threshold_validation_names_the_rejected_value() {
        // The satellite contract: NaN and negative thresholds are config
        // errors with a clear message, not policies that never (NaN
        // comparisons are all false) or always (negative) merge.
        for bad in [f64::NAN, -0.1, 0.0, f64::INFINITY] {
            let err = SyncPolicyConfig::adaptive(bad).validate(1.0).unwrap_err();
            assert!(err.contains("positive and finite"), "{bad}: {err}");
        }
        // The threshold is validated whatever the strategy: a periodic or
        // gossip config with a poisoned threshold field is still rejected
        // (the field would silently activate on a later policy switch).
        let mks: [fn() -> SyncPolicyConfig; 2] =
            [SyncPolicyConfig::periodic, SyncPolicyConfig::gossip];
        for mk in mks {
            let cfg = SyncPolicyConfig { threshold: f64::NAN, ..mk() };
            assert!(cfg.validate(1.0).is_err(), "{:?} accepted NaN", cfg.kind);
        }
    }

    #[test]
    fn adaptive_threshold_scales_with_the_scheduler_count() {
        let cfg = SyncPolicyConfig::adaptive(0.1);
        // k = 1 is the bit-exact identity; k = 4 doubles the bar (√4).
        assert_eq!(cfg.scaled_threshold(1).to_bits(), 0.1f64.to_bits());
        assert!((cfg.scaled_threshold(4) - 0.2).abs() < 1e-12);
        assert!((cfg.scaled_threshold(16) - 0.4).abs() < 1e-12);
        // The built policy carries the scaled trigger.
        assert_eq!(SyncPolicy::new(&cfg, 1.0, 1, 7).threshold().to_bits(), 0.1f64.to_bits());
        let p4 = SyncPolicy::new(&cfg, 1.0, 4, 7);
        assert!((p4.threshold() - 0.2).abs() < 1e-12);
        // Behavior pin: a 0.15 relative drift is over the k=1 bar but
        // under the k=4 bar — the same noise level that would trigger a
        // lone scheduler must not over-merge a 4-scheduler topology.
        let quiet = SyncPolicyConfig { max_interval: 1e9, ..cfg };
        let mut p1 = SyncPolicy::new(&quiet, 1.0, 1, 7);
        let d = 0.15;
        assert_eq!(p1.on_epoch(1.0, d > p1.threshold()), SyncDecision::MergeAll);
        let mut p4 = SyncPolicy::new(&quiet, 1.0, 4, 7);
        assert_eq!(p4.on_epoch(1.0, d > p4.threshold()), SyncDecision::Skip);
        assert_eq!(p4.merges(), 0);
    }

    #[test]
    fn periodic_policy_merges_every_epoch() {
        let mut p = SyncPolicy::new(&SyncPolicyConfig::periodic(), 0.5, 4, 1);
        assert_eq!(p.check_interval(), 0.5);
        for i in 1..=10 {
            assert_eq!(p.on_epoch(i as f64 * 0.5, false), SyncDecision::MergeAll);
        }
        assert_eq!(p.epochs(), 10);
        assert_eq!(p.merges(), 10);
    }

    #[test]
    fn adaptive_skips_until_diverged_then_merges() {
        let cfg = SyncPolicyConfig { max_interval: 100.0, ..SyncPolicyConfig::adaptive(0.1) };
        let mut p = SyncPolicy::new(&cfg, 1.0, 4, 1);
        for i in 1..=5 {
            assert_eq!(p.on_epoch(i as f64, false), SyncDecision::Skip);
        }
        assert_eq!(p.on_epoch(6.0, true), SyncDecision::MergeAll);
        assert_eq!(p.merges(), 1);
        // Freshly merged: even a diverged report within min_interval skips.
        assert_eq!(p.on_epoch(6.5, true), SyncDecision::Skip);
        assert_eq!(p.on_epoch(7.5, true), SyncDecision::MergeAll);
        assert_eq!(p.merges(), 2);
    }

    #[test]
    fn adaptive_staleness_deadline_forces_a_merge() {
        let cfg = SyncPolicyConfig { max_interval: 3.0, ..SyncPolicyConfig::adaptive(0.1) };
        let mut p = SyncPolicy::new(&cfg, 1.0, 4, 1);
        assert_eq!(p.on_epoch(1.0, false), SyncDecision::Skip);
        assert_eq!(p.on_epoch(2.0, false), SyncDecision::Skip);
        // 3 s since the last merge: forced, divergence or not.
        assert_eq!(p.on_epoch(3.0, false), SyncDecision::MergeAll);
        assert_eq!(p.merges(), 1);
    }

    #[test]
    fn adaptive_property_no_merge_below_threshold() {
        // Property: as long as every scheduler's view stays within the
        // relative-error threshold of the consensus, divergence_of stays
        // below the threshold and the policy never merges before the
        // staleness deadline — across many perturbation patterns.
        let threshold = 0.1;
        let consensus = vec![2.0, 0.5, 1.0, 0.0, 3.5]; // one discarded worker
        let mut rng = Rng::new(20200417);
        for trial in 0..200 {
            let local: Vec<f64> = consensus
                .iter()
                .map(|&c| {
                    // Relative perturbation strictly inside ±threshold;
                    // discarded workers may report anything (excluded).
                    let r = (rng.next_f64() * 2.0 - 1.0) * (threshold * 0.99);
                    if c == 0.0 {
                        rng.next_f64() * 5.0
                    } else {
                        c * (1.0 + r)
                    }
                })
                .collect();
            let d = divergence_of(&local, &consensus);
            assert!(d < threshold, "trial {trial}: divergence {d} crossed the threshold");
            let cfg =
                SyncPolicyConfig { max_interval: 1e9, ..SyncPolicyConfig::adaptive(threshold) };
            let mut p = SyncPolicy::new(&cfg, 1.0, 4, trial);
            for i in 1..=20 {
                assert_eq!(
                    p.on_epoch(i as f64, d > p.threshold()),
                    SyncDecision::Skip,
                    "trial {trial}: merged below threshold"
                );
            }
            assert_eq!(p.merges(), 0);
        }
    }

    #[test]
    fn divergence_crossing_threshold_triggers() {
        let consensus = vec![2.0, 1.0];
        let local = vec![2.0 * 1.4, 1.0]; // worker 0 drifted 40%
        let d = divergence_of(&local, &consensus);
        assert!((d - 0.2).abs() < 1e-12, "mean relative drift: {d}");
        let mut p = SyncPolicy::new(&SyncPolicyConfig::adaptive(0.1), 1.0, 2, 7);
        assert_eq!(p.on_epoch(1.0, d > p.threshold()), SyncDecision::MergeAll);
    }

    #[test]
    fn gossip_pairings_are_disjoint_and_deterministic() {
        let draw = |seed: u64, rounds: usize| -> Vec<Vec<(usize, usize)>> {
            let mut p = SyncPolicy::new(&SyncPolicyConfig::gossip(), 1.0, 8, seed);
            (0..rounds)
                .map(|i| match p.on_epoch(i as f64 + 1.0, false) {
                    SyncDecision::MergePairs(pairs) => pairs,
                    other => panic!("gossip produced {other:?}"),
                })
                .collect()
        };
        let a = draw(42, 10);
        let b = draw(42, 10);
        assert_eq!(a, b, "same seed must draw the same pairing schedule");
        assert_ne!(a, draw(43, 10), "different seeds must differ");
        for pairs in &a {
            assert_eq!(pairs.len(), 4, "8 schedulers form 4 disjoint pairs");
            let mut seen = std::collections::BTreeSet::new();
            for &(x, y) in pairs {
                assert!(x != y && x < 8 && y < 8);
                assert!(seen.insert(x) && seen.insert(y), "pairing reused a scheduler");
            }
        }
    }

    #[test]
    fn gossip_with_one_scheduler_degrades_to_merge_all() {
        // Nobody to pair with must not mean "never publish": a lone
        // scheduler's round is an all-to-all epoch over its own view.
        let mut p = SyncPolicy::new(&SyncPolicyConfig::gossip(), 1.0, 1, 9);
        for i in 1..=3 {
            assert_eq!(p.on_epoch(i as f64, false), SyncDecision::MergeAll);
        }
        assert_eq!(p.merges(), 3);
    }

    #[test]
    fn gossip_odd_scheduler_sits_out() {
        let mut p = SyncPolicy::new(&SyncPolicyConfig::gossip(), 1.0, 5, 3);
        match p.on_epoch(1.0, false) {
            SyncDecision::MergePairs(pairs) => assert_eq!(pairs.len(), 2),
            other => panic!("{other:?}"),
        }
        assert_eq!(p.round(), 1);
        assert_eq!(p.merges(), 2);
    }

    #[test]
    fn gossip_spreads_knowledge_in_logarithmic_rounds() {
        // Epidemic-convergence pin: model each scheduler's knowledge as a
        // bitmask; a pair merge unions the two masks. Starting from "every
        // scheduler knows only itself", full convergence needs at least
        // ⌈log2(k)⌉ rounds (one merge at most doubles a mask's population)
        // and randomized pairings reach it in O(log k) — the round counter
        // is the proof handle.
        let k = 16usize;
        let full = (1u32 << k) - 1;
        let mut know: Vec<u32> = (0..k).map(|s| 1 << s).collect();
        let mut p = SyncPolicy::new(&SyncPolicyConfig::gossip(), 1.0, k, 20200417);
        let mut rounds = 0u64;
        while know.iter().any(|&m| m != full) {
            match p.on_epoch(rounds as f64 + 1.0, false) {
                SyncDecision::MergePairs(pairs) => {
                    for (a, b) in pairs {
                        let u = know[a] | know[b];
                        know[a] = u;
                        know[b] = u;
                    }
                }
                other => panic!("{other:?}"),
            }
            rounds = p.round();
            assert!(rounds < 64, "gossip failed to converge");
        }
        let log2k = (k as f64).log2().ceil() as u64;
        // A pair merge at most doubles a mask's population, so ⌈log2 k⌉ is
        // an information-theoretic floor; randomized matchings land within
        // a small constant factor of it.
        assert!(rounds >= log2k, "converged faster than information can spread: {rounds}");
        assert!(
            rounds <= 4 * log2k + 4,
            "took {rounds} rounds for k={k}; epidemic spread should be O(log k)"
        );
    }

    #[test]
    fn lambda_shares_exchange_keeps_the_fresher_entry() {
        let mut a = LambdaShares::new(3);
        let mut b = LambdaShares::new(3);
        a.learn(0, 9.0, 1.0);
        b.learn(1, 2.0, 2.0);
        a.learn(1, 1.0, 0.5); // stale knowledge of scheduler 1
        LambdaShares::exchange(&mut a, &mut b);
        // a learned b's fresher view of scheduler 1; b learned a's share.
        assert_eq!(a.total(), 11.0);
        assert_eq!(b.total(), 11.0);
        assert_eq!(a.heard_at(1), 2.0);
        assert_eq!(b.heard_at(0), 1.0);
        // Scheduler 2 is still unheard everywhere.
        assert_eq!(a.heard_at(2), f64::NEG_INFINITY);
    }

    #[test]
    fn extrapolated_total_scales_partial_coverage() {
        let mut s = LambdaShares::new(8);
        assert_eq!(s.extrapolated_total(), None, "no shares heard yet");
        // Two of eight shares heard (one gossip pair), 1.5 each: the
        // extrapolation estimates the full load, not the partial sum.
        s.learn(0, 1.5, 1.0);
        s.learn(3, 1.5, 1.0);
        assert_eq!(s.total(), 3.0);
        assert_eq!(s.extrapolated_total(), Some(12.0));
        // Full coverage: extrapolation degrades to the exact sum.
        for i in 0..8 {
            s.learn(i, 1.0, 2.0);
        }
        assert_eq!(s.extrapolated_total(), Some(8.0));
        assert_eq!(s.extrapolated_total(), Some(s.total()));
    }

    #[test]
    fn exchanged_shares_correct_the_even_split_under_skew() {
        // Skewed routing: scheduler 0 sees 9 tasks/s, the other three 1.
        // λ̂_global from exchanged shares is 12; the even-split assumption
        // from scheduler 0's local estimate (k·λ̂₀ = 36) would over-throttle
        // probing by 3×, and from scheduler 3's (k·λ̂₃ = 4) under-throttle.
        let shares = [9.0, 1.0, 1.0, 1.0];
        let mut s = LambdaShares::new(4);
        for (i, &l) in shares.iter().enumerate() {
            s.learn(i, l, 1.0);
        }
        assert_eq!(s.total(), 12.0);
        let correct = throttled_rate(0.1, 150.0, s.total(), 4);
        let naive0 = throttled_rate(0.1, 150.0, 4.0 * shares[0], 4);
        let naive3 = throttled_rate(0.1, 150.0, 4.0 * shares[3], 4);
        assert!((correct - 0.1 * 138.0 / 4.0).abs() < 1e-12);
        assert!(naive0 < correct && correct < naive3);
    }
}
