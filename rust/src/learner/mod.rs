//! The self-driving learning stack (§3.2–§3.3): arrival estimator,
//! performance learner, and the benchmark-job dispatcher.
//!
//! The three pieces interact exactly as the paper's Figure 1: arrivals feed
//! λ̂; λ̂ sets both the dispatcher's probing rate `c0(μ̄ − λ̂)` and the
//! learner's dynamic window `L = c/(1 − α̂)`; completions (real and
//! benchmark) feed the per-worker service histories from which μ̂ is
//! published to the scheduling policy.

pub mod arrival;
pub mod dispatcher;
pub mod perf;
pub mod sync;

pub use arrival::ArrivalEstimator;
pub use dispatcher::FakeJobDispatcher;
pub use perf::{relative_error_of, LearnerParams, PerfLearner};
pub use sync::{
    divergence_of, merge_estimates, merge_estimates_into, merge_payloads_into, throttled_rate,
    EstimateView, LambdaShares, SyncDecision, SyncKind, SyncPayload, SyncPolicy,
    SyncPolicyConfig,
};

/// Bundled learner configuration used by the engine and the live
/// coordinator.
#[derive(Debug, Clone, PartialEq)]
pub struct LearnerConfig {
    /// Enable the learning stack at all. When disabled, the scheduler sees
    /// the configured prior (or oracle speeds if `oracle` is set).
    pub enabled: bool,
    /// Publish true speeds instead of learned estimates (the "worker speeds
    /// are known" settings of §6.2).
    pub oracle: bool,
    /// Enable the benchmark-job dispatcher (Fig. 12 ablates this).
    pub fake_jobs: bool,
    /// Dispatcher constant c0 (paper: 0.1).
    pub c0: f64,
    /// Practical window constant `c` in `L = c/(1 − α̂)` (paper sweeps
    /// {10, 20, 30, 40}; default 10).
    pub window_c: f64,
    /// Arrival-estimator window `S` in samples.
    pub arrival_window: usize,
    /// How often estimates are published / the alias table rebuilt (s).
    pub publish_interval: f64,
    /// Number of logical schedulers (§5 distributed learning): the
    /// completion stream is split across `k` private [`PerfLearner`]s and
    /// the policy only ever sees their [`merge_estimates`] consensus.
    /// 1 = the centralized shared-learner baseline.
    pub schedulers: usize,
    /// Estimate-sync interval in seconds. 0 = consensus at every publish
    /// (the tightest coupling); > 0 = consensus on its own cadence, so the
    /// policy sees estimates up to `sync_interval` stale — the knob the
    /// `multisched` experiment sweeps.
    pub sync_interval: f64,
    /// *How* consensus epochs are scheduled and shaped on that interval:
    /// fixed-timer all-to-all ([`SyncKind::Periodic`], the default),
    /// divergence-triggered ([`SyncKind::Adaptive`]), or pairwise gossip
    /// ([`SyncKind::Gossip`]).
    pub sync: SyncPolicyConfig,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            oracle: false,
            fake_jobs: true,
            c0: 0.1,
            window_c: 10.0,
            arrival_window: 200,
            publish_interval: 0.1,
            schedulers: 1,
            sync_interval: 0.0,
            sync: SyncPolicyConfig::periodic(),
        }
    }
}

impl LearnerConfig {
    /// Oracle configuration: speeds known, no learning, no fake jobs.
    pub fn oracle() -> Self {
        Self { enabled: false, oracle: true, fake_jobs: false, ..Self::default() }
    }

    /// Learning without benchmark jobs, fixed window constant `c`
    /// (the Fig. 12 "w10..w40" baselines).
    pub fn no_fake_jobs(window_c: f64) -> Self {
        Self { fake_jobs: false, window_c, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_constants() {
        let c = LearnerConfig::default();
        assert!(c.enabled && c.fake_jobs && !c.oracle);
        assert_eq!(c.c0, 0.1);
        assert_eq!(c.window_c, 10.0);
        // Centralized single-learner topology by default, periodic sync —
        // the bit-compatible pre-policy behavior.
        assert_eq!(c.schedulers, 1);
        assert_eq!(c.sync_interval, 0.0);
        assert_eq!(c.sync.kind, SyncKind::Periodic);
    }

    #[test]
    fn oracle_preset() {
        let c = LearnerConfig::oracle();
        assert!(c.oracle && !c.enabled && !c.fake_jobs);
    }

    #[test]
    fn ablation_preset() {
        let c = LearnerConfig::no_fake_jobs(30.0);
        assert!(c.enabled && !c.fake_jobs);
        assert_eq!(c.window_c, 30.0);
    }
}
