//! Arrival estimator (§3.3).
//!
//! Estimates the task arrival rate λ as the reciprocal of the mean
//! inter-arrival time over the last `S` task arrivals. `S` is the
//! responsiveness/accuracy knob: large `S` → accurate but slow to react,
//! small `S` → noisy but fast (the paper discusses exactly this tradeoff).
//!
//! In a distributed plane (§5) each scheduler runs its own estimator over
//! only the arrivals *it* routed, so its λ̂ is a per-scheduler *share* of
//! the load. Shares are exchanged through estimate-sync consensus
//! ([`crate::learner::SyncPayload`] carries one per scheduler;
//! [`crate::learner::LambdaShares`] tracks them under gossip) and summed to
//! the λ̂_global that drives the learner window and the §5 benchmark
//! throttle — correct even when arrival routing is skewed.

use crate::stats::SlidingMean;

/// Sliding-window arrival-rate estimator.
#[derive(Debug, Clone)]
pub struct ArrivalEstimator {
    window: SlidingMean,
    last_arrival: Option<f64>,
}

impl ArrivalEstimator {
    /// Estimator over the inter-arrival times of the last `s` arrivals.
    pub fn new(s: usize) -> Self {
        Self { window: SlidingMean::new(s.max(1)), last_arrival: None }
    }

    /// Record `count` task arrivals at time `now` (a job of m tasks counts
    /// as m simultaneous task arrivals; the m−1 extra arrivals contribute
    /// zero inter-arrival gaps, correctly inflating the rate estimate).
    pub fn on_arrival(&mut self, now: f64, count: usize) {
        if count == 0 {
            return;
        }
        if let Some(prev) = self.last_arrival {
            let gap = (now - prev).max(0.0);
            self.window.push(gap);
            for _ in 1..count {
                self.window.push(0.0);
            }
        } else if count > 1 {
            for _ in 1..count {
                self.window.push(0.0);
            }
        }
        self.last_arrival = Some(now);
    }

    /// Current estimate λ̂ in tasks/second, or `None` before two arrivals.
    pub fn lambda_hat(&self) -> Option<f64> {
        match self.window.mean() {
            Some(m) if m > 0.0 => Some(1.0 / m),
            Some(_) => None, // all-zero gaps: burst with no measurable rate yet
            None => None,
        }
    }

    /// Estimate with a fallback default.
    pub fn lambda_or(&self, default: f64) -> f64 {
        self.lambda_hat().unwrap_or(default)
    }

    /// Number of samples currently held.
    pub fn samples(&self) -> usize {
        self.window.len()
    }

    /// Forget all history (e.g. after a reconfiguration).
    pub fn reset(&mut self) {
        self.window.clear();
        self.last_arrival = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_constant_rate() {
        let mut e = ArrivalEstimator::new(50);
        for k in 0..200 {
            e.on_arrival(k as f64 * 0.1, 1); // 10 tasks/s
        }
        let l = e.lambda_hat().unwrap();
        assert!((l - 10.0).abs() < 1e-9, "lambda={l}");
    }

    #[test]
    fn no_estimate_before_two_arrivals() {
        let mut e = ArrivalEstimator::new(10);
        assert!(e.lambda_hat().is_none());
        e.on_arrival(1.0, 1);
        assert!(e.lambda_hat().is_none());
        assert_eq!(e.lambda_or(42.0), 42.0);
        e.on_arrival(1.5, 1);
        assert!((e.lambda_hat().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn multi_task_jobs_inflate_rate() {
        let mut e = ArrivalEstimator::new(100);
        // One 5-task job per second = 5 tasks/s.
        for k in 0..100 {
            e.on_arrival(k as f64, 5);
        }
        let l = e.lambda_hat().unwrap();
        assert!((l - 5.0).abs() < 0.3, "lambda={l}");
    }

    #[test]
    fn tracks_rate_change_within_window() {
        let mut e = ArrivalEstimator::new(20);
        for k in 0..100 {
            e.on_arrival(k as f64, 1); // 1 task/s
        }
        // Rate jumps to 20 tasks/s; after 20+ arrivals the window has
        // flushed the old gaps.
        let mut t = 100.0;
        for _ in 0..40 {
            t += 0.05;
            e.on_arrival(t, 1);
        }
        let l = e.lambda_hat().unwrap();
        assert!((l - 20.0).abs() < 1.0, "lambda={l}");
    }

    #[test]
    fn small_window_reacts_faster_than_large() {
        let mut small = ArrivalEstimator::new(5);
        let mut large = ArrivalEstimator::new(200);
        for k in 0..300 {
            let t = k as f64;
            small.on_arrival(t, 1);
            large.on_arrival(t, 1);
        }
        let mut t = 300.0;
        for _ in 0..10 {
            t += 0.1;
            small.on_arrival(t, 1);
            large.on_arrival(t, 1);
        }
        let ls = small.lambda_hat().unwrap();
        let ll = large.lambda_hat().unwrap();
        assert!(ls > ll * 2.0, "small={ls} large={ll}");
    }

    #[test]
    fn reset_clears_state() {
        let mut e = ArrivalEstimator::new(10);
        e.on_arrival(0.0, 1);
        e.on_arrival(1.0, 1);
        e.reset();
        assert!(e.lambda_hat().is_none());
        assert_eq!(e.samples(), 0);
    }
}
