//! Benchmark-job ("fake job") dispatcher — LEARNER-DISPATCHER (Fig. 6).
//!
//! The learner actively explores the cluster by generating low-priority
//! benchmark jobs as a Poisson process with rate `c0 · (μ̄ − λ̂)`: a fixed
//! fraction (c0 = 0.1 in the paper) of the cluster's *residual* throughput.
//! Each benchmark job goes to a uniformly random worker and resembles the
//! recent workload (its demand is drawn from the same distribution). This
//! keeps every worker supplied with ~L fresh service samples per learner
//! horizon — exactly the rate at which workers faster than μ* can keep up,
//! so slower-than-μ* workers fall behind and get discarded (§4.3).

use crate::learner::sync::throttled_rate;
use crate::stats::{Exponential, Rng};

/// Poisson dispatcher of benchmark jobs.
#[derive(Debug, Clone)]
pub struct FakeJobDispatcher {
    /// The constant c0 (0.1 in the paper).
    c0: f64,
    /// Minimum guaranteed total service throughput μ̄ (tasks/sec).
    mu_bar: f64,
    /// Floor on the dispatch rate so learning never fully stalls even when
    /// λ̂ ≈ μ̄ (residual throughput ≈ 0). Split across schedulers like the
    /// main budget.
    min_rate: f64,
    /// Whether dispatch is enabled at all (Fig. 12 ablates this).
    enabled: bool,
    /// Scheduler count `k`: with multiple distributed schedulers each
    /// running its own dispatcher, every one runs at the §5 throttled
    /// per-scheduler rate `c0(μ̄ − λ̂)/k` so the aggregate probing budget
    /// never multiplies with the scheduler count.
    schedulers: usize,
}

impl FakeJobDispatcher {
    /// Single-scheduler dispatcher. `mu_bar` is the guaranteed aggregate
    /// throughput.
    pub fn new(c0: f64, mu_bar: f64, enabled: bool) -> Self {
        Self::new_sharded(c0, mu_bar, enabled, 1)
    }

    /// One of `schedulers` distributed dispatchers sharing the probing
    /// budget (§5 throttling).
    pub fn new_sharded(c0: f64, mu_bar: f64, enabled: bool, schedulers: usize) -> Self {
        assert!(c0 > 0.0 && mu_bar > 0.0 && schedulers >= 1);
        Self { c0, mu_bar, min_rate: 1e-3 * mu_bar / schedulers as f64, enabled, schedulers }
    }

    /// Whether benchmark jobs are being produced.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// How many schedulers share the probing budget.
    pub fn schedulers(&self) -> usize {
        self.schedulers
    }

    /// Current dispatch rate `c0 · (μ̄ − λ̂) / k` in benchmark tasks/sec.
    ///
    /// `lambda_hat` must be the *global* arrival estimate. In a distributed
    /// plane (§5) that is the sum of the per-scheduler λ̂ shares exchanged
    /// through estimate-sync consensus
    /// ([`crate::learner::SyncPayload::lambda_hat`] /
    /// [`crate::learner::LambdaShares`]) — not `k` times the caller's local
    /// estimate, which is only correct when arrivals split evenly.
    pub fn rate(&self, lambda_hat: f64) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        throttled_rate(self.c0, self.mu_bar, lambda_hat, self.schedulers).max(self.min_rate)
    }

    /// Sample the gap until the next benchmark dispatch, given the current
    /// arrival estimate. Returns `None` when dispatch is disabled.
    pub fn next_gap(&self, lambda_hat: f64, rng: &mut Rng) -> Option<f64> {
        if !self.enabled {
            return None;
        }
        Some(Exponential::new(self.rate(lambda_hat)).sample(rng))
    }

    /// Choose the target worker: uniform over the cluster (Fig. 6 line 4).
    pub fn pick_worker(&self, n: usize, rng: &mut Rng) -> usize {
        rng.gen_index(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_tracks_residual_throughput() {
        let d = FakeJobDispatcher::new(0.1, 150.0, true);
        // α = 0.8 → residual 30 tasks/s → rate 3/s.
        assert!((d.rate(120.0) - 3.0).abs() < 1e-12);
        // α = 0.2 → residual 120 → rate 12/s: lighter load, more probing.
        assert!((d.rate(30.0) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn rate_floor_under_overload() {
        let d = FakeJobDispatcher::new(0.1, 100.0, true);
        assert!(d.rate(99.9) > 0.0);
        assert!(d.rate(200.0) > 0.0); // λ̂ > μ̄: estimate noise must not kill learning
    }

    #[test]
    fn sharded_dispatchers_split_the_probing_budget() {
        // Regression for multi-frontend planes: k per-shard dispatchers must
        // aggregate to the single-scheduler budget, not k times it.
        let single = FakeJobDispatcher::new(0.1, 150.0, true);
        for k in [1usize, 2, 4, 8] {
            let per = FakeJobDispatcher::new_sharded(0.1, 150.0, true, k);
            assert_eq!(per.schedulers(), k);
            let aggregate = per.rate(120.0) * k as f64;
            assert!(
                (aggregate - single.rate(120.0)).abs() < 1e-9,
                "k={k}: aggregate {aggregate} vs budget {}",
                single.rate(120.0)
            );
        }
        // The overload floor splits the same way: aggregate floor is fixed.
        let per4 = FakeJobDispatcher::new_sharded(0.1, 100.0, true, 4);
        let floor = FakeJobDispatcher::new(0.1, 100.0, true).rate(200.0);
        assert!((per4.rate(200.0) * 4.0 - floor).abs() < 1e-12);
    }

    #[test]
    fn exchanged_lambda_shares_fix_the_probing_budget_under_skew() {
        use crate::learner::LambdaShares;
        // Skewed arrival routing: scheduler 0 receives 9 of the 12 tasks/s.
        // Every dispatcher must throttle against the exchanged λ̂_global,
        // not extrapolate its own share to an assumed even split.
        let mut shares = LambdaShares::new(4);
        for (i, l) in [9.0, 1.0, 1.0, 1.0].into_iter().enumerate() {
            shares.learn(i, l, 0.0);
        }
        let d = FakeJobDispatcher::new_sharded(0.1, 150.0, true, 4);
        let correct = d.rate(shares.total());
        assert!((correct - 0.1 * (150.0 - 12.0) / 4.0).abs() < 1e-12);
        // The even-split extrapolations bracket (and miss) the budget.
        assert!(d.rate(4.0 * 9.0) < correct, "hot scheduler would under-probe");
        assert!(d.rate(4.0 * 1.0) > correct, "cold schedulers would over-probe");
    }

    #[test]
    fn disabled_dispatcher_produces_nothing() {
        let d = FakeJobDispatcher::new(0.1, 100.0, false);
        let mut r = Rng::new(1);
        assert_eq!(d.rate(50.0), 0.0);
        assert!(d.next_gap(50.0, &mut r).is_none());
        assert!(!d.enabled());
    }

    #[test]
    fn gaps_are_exponential_with_matching_mean() {
        let d = FakeJobDispatcher::new(0.1, 150.0, true);
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| d.next_gap(120.0, &mut r).unwrap()).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / 3.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn worker_choice_is_uniform() {
        let d = FakeJobDispatcher::new(0.1, 100.0, true);
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[d.pick_worker(5, &mut r)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / n as f64 - 0.2).abs() < 0.02, "{counts:?}");
        }
    }
}
