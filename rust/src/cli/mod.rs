//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated help text.

use std::collections::BTreeMap;

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Flags take no value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Specification of a (sub)command.
#[derive(Debug, Clone, Default)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub positional: Vec<(&'static str, &'static str)>,
    pub options: Vec<OptSpec>,
}

impl CmdSpec {
    /// New command spec.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, positional: Vec::new(), options: Vec::new() }
    }

    /// Add a positional argument.
    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    /// Add a valued option.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.options.push(OptSpec { name, help, is_flag: false, default });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nusage: rosella {}", self.name, self.about, self.name);
        for (p, _) in &self.positional {
            out.push_str(&format!(" <{p}>"));
        }
        if !self.options.is_empty() {
            out.push_str(" [options]");
        }
        out.push('\n');
        if !self.positional.is_empty() {
            out.push_str("\narguments:\n");
            for (p, h) in &self.positional {
                out.push_str(&format!("  {p:<18} {h}\n"));
            }
        }
        if !self.options.is_empty() {
            out.push_str("\noptions:\n");
            for o in &self.options {
                let tag = if o.is_flag {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <v>", o.name)
                };
                let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                out.push_str(&format!("  {tag:<18} {}{default}\n", o.help));
            }
        }
        out
    }

    /// Parse the arguments following the subcommand name.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        for o in &self.options {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .options
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        if positional.len() > self.positional.len() {
            return Err(format!(
                "too many positional arguments: {positional:?}\n\n{}",
                self.help()
            ));
        }
        Ok(Parsed { values, flags, positional })
    }
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Parsed {
    /// Value of option `name` (default applied), if set.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Required option value.
    pub fn req(&self, name: &str) -> Result<&str, String> {
        self.get(name).ok_or_else(|| format!("missing required option --{name}"))
    }

    /// Parse an option as `T`.
    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("bad value for --{name}: {e}")),
        }
    }

    /// Whether a flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CmdSpec {
        CmdSpec::new("simulate", "run one simulation")
            .pos("name", "experiment name")
            .opt("seed", Some("42"), "rng seed")
            .opt("load", None, "load ratio")
            .flag("quick", "scaled-down run")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = spec().parse(&args(&["fig8", "--load", "0.9"])).unwrap();
        assert_eq!(p.get("seed"), Some("42"));
        assert_eq!(p.get("load"), Some("0.9"));
        assert_eq!(p.pos(0), Some("fig8"));
        assert!(!p.flag("quick"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = spec().parse(&args(&["--seed=7", "--quick"])).unwrap();
        assert_eq!(p.get("seed"), Some("7"));
        assert!(p.flag("quick"));
    }

    #[test]
    fn typed_parsing() {
        let p = spec().parse(&args(&["--load", "0.5"])).unwrap();
        assert_eq!(p.parse_as::<f64>("load").unwrap(), Some(0.5));
        assert_eq!(p.parse_as::<u64>("seed").unwrap(), Some(42));
        let bad = spec().parse(&args(&["--load", "xyz"])).unwrap();
        assert!(bad.parse_as::<f64>("load").is_err());
    }

    #[test]
    fn errors() {
        assert!(spec().parse(&args(&["--unknown", "1"])).is_err());
        assert!(spec().parse(&args(&["--load"])).is_err());
        assert!(spec().parse(&args(&["--quick=1"])).is_err());
        assert!(spec().parse(&args(&["a", "b"])).is_err());
    }

    #[test]
    fn help_mentions_everything() {
        let h = spec().help();
        assert!(h.contains("--seed"));
        assert!(h.contains("--quick"));
        assert!(h.contains("<name>"));
        assert!(h.contains("default: 42"));
    }

    #[test]
    fn req_reports_missing() {
        let p = spec().parse(&args(&[])).unwrap();
        assert!(p.req("load").is_err());
        assert!(p.req("seed").is_ok());
    }
}
