//! Minimal property-based testing harness (proptest is unavailable
//! offline).
//!
//! A property is a predicate over randomly generated inputs; `prop_check`
//! runs it many times and, on failure, retries with "smaller" inputs from
//! the same generator seed family to report a near-minimal counterexample
//! (shrink-lite: generators take a `size` hint that failure reporting
//! walks downward).

use crate::stats::Rng;

/// Generation context handed to generators/properties.
pub struct Gen<'a> {
    /// RNG for this case.
    pub rng: &'a mut Rng,
    /// Size hint in [1, 100]; generators should scale their output with it.
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in `[lo, hi]` scaled-ish by size.
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        let span = hi - lo + 1;
        lo + self.rng.gen_index(span)
    }

    /// A float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_range_f64(lo, hi)
    }

    /// A vector with size-scaled length in `[1, max_len]` of generated
    /// elements.
    pub fn vec_of<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let cap = ((max_len * self.size) / 100).max(1);
        let len = 1 + self.rng.gen_index(cap);
        (0..len)
            .map(|_| {
                let mut g = Gen { rng: self.rng, size: self.size };
                f(&mut g)
            })
            .collect()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    /// All cases passed.
    Ok { cases: usize },
    /// A counterexample was found.
    Failed { seed: u64, size: usize, message: String },
}

/// Run `prop` over `cases` random cases. The property returns
/// `Err(description)` to signal failure. On failure, smaller sizes with
/// the same case seed are tried first and the smallest failing size is
/// reported.
pub fn prop_check(
    seed: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) -> PropResult {
    let mut seeder = Rng::new(seed);
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let size = 1 + (case * 100) / cases.max(1); // ramp sizes 1..=100
        let run = |size: usize| -> Result<(), String> {
            let mut rng = Rng::new(case_seed);
            let mut g = Gen { rng: &mut rng, size };
            prop(&mut g)
        };
        if let Err(first_msg) = run(size) {
            // Shrink-lite: find the smallest failing size for this seed.
            let mut best = (size, first_msg);
            let mut s = size / 2;
            while s >= 1 {
                match run(s) {
                    Err(m) => {
                        best = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            return PropResult::Failed { seed: case_seed, size: best.0, message: best.1 };
        }
    }
    PropResult::Ok { cases }
}

/// Assert a property holds (for use inside `#[test]`).
pub fn assert_prop(name: &str, seed: u64, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    match prop_check(seed, cases, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { seed, size, message } => {
            panic!("property '{name}' failed (case_seed={seed}, size={size}): {message}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = prop_check(1, 50, |g| {
            let v = g.vec_of(64, |g| g.f64_in(0.0, 1.0));
            if v.iter().all(|x| (0.0..1.0).contains(x)) {
                Ok(())
            } else {
                Err("range".into())
            }
        });
        assert!(matches!(r, PropResult::Ok { cases: 50 }));
    }

    #[test]
    fn failing_property_reports_small_size() {
        // Fails whenever the vector is non-empty — size 1 must be found.
        let r = prop_check(2, 50, |g| {
            let v = g.vec_of(64, |g| g.int_in(0, 9));
            if v.is_empty() {
                Ok(())
            } else {
                Err(format!("len={}", v.len()))
            }
        });
        match r {
            PropResult::Failed { size, .. } => assert_eq!(size, 1),
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn failures_are_reproducible() {
        let fails_over_half = |g: &mut Gen| -> Result<(), String> {
            let x = g.f64_in(0.0, 1.0);
            if x < 0.5 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        };
        let a = prop_check(3, 100, fails_over_half);
        let b = prop_check(3, 100, fails_over_half);
        match (a, b) {
            (
                PropResult::Failed { seed: s1, .. },
                PropResult::Failed { seed: s2, .. },
            ) => assert_eq!(s1, s2),
            other => panic!("expected two identical failures, got {other:?}"),
        }
    }
}
